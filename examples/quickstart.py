"""Quickstart: the paper end to end on a local 8-node cluster.

Generates TPC-H data per node (the paper's `dbgen -S rank -C P`), compiles
the hand-written distributed plans to one SPMD executable each, runs them,
and checks every result against the float64 oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np


def main():
    import jax

    from repro.tpch.driver import TPCHDriver

    driver = TPCHDriver(sf=0.02, seed=0)
    print(f"cluster: {driver.cluster.num_nodes} shared-nothing nodes | "
          f"SF 0.02 | lineitem rows: {driver.tables['lineitem'].num_rows}")

    # Q1: the paper's pricing summary (co-partitioned, one collective reduce)
    out = np.asarray(driver.run("q1"))
    ref = driver.oracle("q1")
    assert np.allclose(out, ref, rtol=1e-3)
    print("\nQ1 pricing summary (sum_qty / sum_base / disc_price / charge "
          "/ disc / count):")
    for g in range(6):
        print("  group", g, np.round(out[g], 1))

    # Q15: the paper's §3.2.5 approximate distributed top-k
    out = driver.run("q15_approx")
    sup = int(np.asarray(out["s_suppkey"])[0])
    rev = float(np.asarray(out["total_revenue"])[0])
    stats = out["stats"]
    print(f"\nQ15 top supplier: suppkey={sup} revenue={rev:.2f}")
    print(f"  §3.2.5 exchange: {float(np.asarray(stats.approx_bits_per_node)):.0f} "
          f"bits/node vs naive {float(np.asarray(stats.naive_bits_per_node)):.0f} "
          f"({float(np.asarray(stats.naive_bits_per_node))/float(np.asarray(stats.approx_bits_per_node)):.1f}x less)")
    ov, ok = driver.oracle("q15")
    assert sup == int(ok[0]), "top supplier must match the oracle"

    # Q3 three ways (paper Fig. 2 variants)
    print("\nQ3 variants (bitset / lazy / replicated):")
    for v in ("q3", "q3_lazy", "q3_repl"):
        t0 = time.monotonic()
        out = driver.run(v)
        jax.block_until_ready(out)
        # q3_lazy returns (winners, overflow); the others a bare TopK
        topk = out[0] if isinstance(out, tuple) and not hasattr(out, "keys") else out
        keys = np.asarray(topk.keys if hasattr(topk, "keys") else topk[1])[:3]
        print(f"  {v:8s} top orders {keys.tolist()}  "
              f"({(time.monotonic()-t0)*1e3:.0f} ms incl. host)")

    # Prepared statements (paper §2/§3.1): ONE compiled plan, any literals
    from repro.tpch.queries import q6_param_ir, random_binding

    prep = driver.prepare(q6_param_ir())
    rng = np.random.default_rng(0)
    bindings = [random_binding("q6", rng) for _ in range(8)]
    t0 = time.monotonic()
    revenues = [float(np.asarray(prep.execute(b).value).reshape(()))
                for b in bindings]
    t_seq = time.monotonic() - t0
    t0 = time.monotonic()
    batched = prep.execute_batch(bindings)  # 8 queries, one vmapped dispatch
    t_batch = time.monotonic() - t0
    assert np.allclose(np.asarray(batched.value).reshape(-1), revenues,
                       rtol=1e-5)
    print(f"\nQ6 prepared: 8 random TPC-H bindings, 1 compile "
          f"({t_seq*1e3:.0f} ms sequential, {t_batch*1e3:.0f} ms batched)")
    print(f"  revenues {np.round(revenues[:4], 0).tolist()} ...")

    print("\nall results oracle-checked ✓")


if __name__ == "__main__":
    main()
