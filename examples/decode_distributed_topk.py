"""The paper's §3.2.3 top-k selection serving an LM decode head: sample
from a vocab-sharded model with the merging-reduction instead of an O(V)
allgather, and verify against the unsharded model.

    PYTHONPATH=src python examples/decode_distributed_topk.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch
    from repro.models.model import build
    from repro.models.params import values
    from repro.serve.engine import decode_loop

    cfg = get_arch("qwen2.5-3b", smoke=True)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    model = build(cfg, tp=4)
    params = values(model.init(jax.random.key(0)))
    state = model.init_decode_state(4, max_len=32, dtype=jnp.float32)
    first = jnp.zeros((4,), jnp.int32)
    with mesh:
        toks, state = decode_loop(model, params, state, first, steps=16,
                                  mesh=mesh, k=8)
    print("decoded token streams (distributed §3.2.3 top-k head):")
    for b in range(4):
        print(f"  seq {b}: {np.asarray(toks)[b].tolist()}")
    print(f"cache length: {int(state.length)}")


if __name__ == "__main__":
    main()
