"""End-to-end driver, the paper's kind: SERVE batched analytical queries
against an in-memory cluster — sustained mixed-workload throughput with
per-query latencies (the paper's power-test style run).

    PYTHONPATH=src python examples/serve_queries.py [--sf 0.05] [--rounds 5]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import numpy as np


WORKLOAD = ["q1", "q4", "q6", "q18", "q3", "q3_lazy", "q14", "q15_approx",
            "q2", "q5", "q11", "q13", "q21_late"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sf", type=float, default=0.05)
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args()

    import jax

    from repro.tpch.driver import TPCHDriver

    driver = TPCHDriver(sf=args.sf, seed=0)
    cols = {n: t.columns for n, t in driver.placed.items()}
    print(f"serving {len(WORKLOAD)} query types on "
          f"{driver.cluster.num_nodes} nodes, SF {args.sf}")

    # compile once (the paper's precompiled plans), then serve rounds
    fns = {}
    t0 = time.monotonic()
    for q in WORKLOAD:
        fns[q] = driver.compile(q)
        jax.block_until_ready(fns[q](cols))  # warm
    print(f"compiled {len(fns)} plans in {time.monotonic()-t0:.1f}s\n")

    lat = {q: [] for q in WORKLOAD}
    t_start = time.monotonic()
    for r in range(args.rounds):
        for q in WORKLOAD:
            t0 = time.monotonic()
            jax.block_until_ready(fns[q](cols))
            lat[q].append((time.monotonic() - t0) * 1e3)
    wall = time.monotonic() - t_start
    total = args.rounds * len(WORKLOAD)
    print(f"{'query':>10s} {'p50 ms':>8s} {'best ms':>8s}")
    for q in WORKLOAD:
        s = sorted(lat[q])
        print(f"{q:>10s} {s[len(s)//2]:8.2f} {s[0]:8.2f}")
    print(f"\nthroughput: {total/wall:.1f} queries/s over {total} queries "
          f"({wall:.1f}s wall)")


if __name__ == "__main__":
    main()
