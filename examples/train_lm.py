"""Train a language model end to end on the synthetic sharded pipeline:
distributed data-parallel mesh, AdamW, checkpoints, restart.

Default is a fast CPU demo (~10M params, 200 steps); pass --full for the
~100M-param variant of the same run.

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps 200]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (slower on CPU)")
    ap.add_argument("--ckpt", type=str, default="/tmp/repro_train_lm")
    args = ap.parse_args()

    import jax

    from repro.data.synthetic import SyntheticLM
    from repro.models.config import ModelConfig
    from repro.models.model import build
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    if args.full:
        cfg = ModelConfig(name="demo-100m", family="dense", n_layers=8,
                          d_model=512, n_heads=8, n_kv_heads=4, d_ff=2048,
                          vocab_size=32768, compute_dtype="float32")
    else:
        cfg = ModelConfig(name="demo-10m", family="dense", n_layers=4,
                          d_model=192, n_heads=4, n_kv_heads=2, d_ff=768,
                          vocab_size=4096, compute_dtype="float32",
                          remat=False)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    model = build(cfg, tp=2)
    n = cfg.num_params()
    print(f"{cfg.name}: {n/1e6:.1f}M params on mesh {dict(mesh.shape)}")
    data = SyntheticLM(vocab_size=cfg.vocab_size,
                       seq_len=256 if args.full else 128,
                       global_batch=16 if args.full else 8, seed=0)
    trainer = Trainer(
        model, data, mesh,
        AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(steps=args.steps, log_every=20,
                      checkpoint_dir=args.ckpt, checkpoint_every=50),
    )
    state, history = trainer.run()
    first = sum(h["loss"] for h in history[:10]) / 10
    last = sum(h["loss"] for h in history[-10:]) / 10
    print(f"\nloss {first:.3f} -> {last:.3f} over {len(history)} steps "
          f"(checkpoints in {args.ckpt}; re-run to resume)")


if __name__ == "__main__":
    main()
