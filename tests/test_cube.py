"""Two-tier rollup-cube subsystem: build correctness vs the numpy oracles,
router coverage/fallback decisions, and marginalization semantics."""
from __future__ import annotations

import numpy as np
import pytest

from repro.cube import AggQuery, CubeSpec, Dimension, Filter, Measure
from repro.cube.build import ROWS, build_cube
from repro.tpch import cubes as tpch_cubes
from repro.tpch.schema import DEFAULT_PARAMS as DP

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def cubed_driver(tpch_driver):
    """The shared SF 0.01 driver with the default TPC-H cubes built."""
    if not tpch_driver.cubes:
        tpch_driver.build_cubes()
    return tpch_driver


# ---------------------------------------------------------------------------
# Tier-1 correctness vs tpch/reference.py
# ---------------------------------------------------------------------------


def test_q1_from_cube_matches_oracle(cubed_driver):
    ans = cubed_driver.query(tpch_cubes.q1_query())
    assert ans.tier == 1
    assert ans.source == "lineitem_pricing"
    got = np.asarray(ans.value).reshape(6, 6)  # group id = returnflag*2 + linestatus
    ref = cubed_driver.oracle("q1")
    np.testing.assert_allclose(got, ref, rtol=2e-4)


def test_windowed_orders_query_matches_numpy(cubed_driver):
    ans = cubed_driver.query(tpch_cubes.orders_by_priority_query())
    assert ans.tier == 1
    o = cubed_driver.tables["orders"].columns
    sel = (o["o_orderdate"] >= DP.q4_date_min) & (o["o_orderdate"] < DP.q4_date_max)
    count = np.bincount(o["o_orderpriority"][sel], minlength=5)
    total = np.zeros(5)
    np.add.at(total, o["o_orderpriority"][sel],
              o["o_totalprice"][sel].astype(np.float64))
    np.testing.assert_allclose(ans.value[:, 0], count)
    np.testing.assert_allclose(ans.value[:, 1], total, rtol=1e-5)


def test_min_max_measures(cubed_driver):
    q = AggQuery(
        table="orders",
        group_by=("orderstatus",),
        measures=("min_totalprice", "max_totalprice"),
        filters=(Filter("ordermonth", ">=", DP.q4_date_min),
                 Filter("ordermonth", "<", DP.q4_date_max)),
    )
    ans = cubed_driver.query(q)
    assert ans.tier == 1
    o = cubed_driver.tables["orders"].columns
    window = (o["o_orderdate"] >= DP.q4_date_min) & (o["o_orderdate"] < DP.q4_date_max)
    for s in range(3):
        tp = o["o_totalprice"][window & (o["o_orderstatus"] == s)]
        np.testing.assert_allclose(ans.value[s, 0], tp.min(), rtol=1e-6)
        np.testing.assert_allclose(ans.value[s, 1], tp.max(), rtol=1e-6)


# ---------------------------------------------------------------------------
# routing decisions
# ---------------------------------------------------------------------------


def test_coarse_rollup_is_preferred(cubed_driver):
    route = cubed_driver.router.route(tpch_cubes.revenue_by_shipmonth_query())
    assert route.rollup == ("shipmonth",)  # 86 cells, not the 516-cell finest


def test_router_falls_back_for_non_edge_bound(cubed_driver):
    ans = cubed_driver.query(tpch_cubes.uncovered_query())
    assert ans.tier == 2
    assert ans.source == "q1"


def test_router_falls_back_below_first_edge(cubed_driver):
    """A bound inside the open first/last bins cuts a bin in half — never
    answerable exactly, even though the naive mask would be all-False."""
    from repro.tpch.schema import day

    for bound in (day(1992, 1, 15), day(1999, 6, 1)):
        q = AggQuery(table="lineitem", group_by=("returnflag",),
                     measures=("sum_qty",),
                     filters=(Filter("shipmonth", "<=", bound),), fallback="q1")
        assert cubed_driver.router.route(q) is None, bound


def test_router_falls_back_for_uncovered_dims(cubed_driver):
    q = AggQuery(table="lineitem", group_by=("returnflag",),
                 measures=("sum_qty",),
                 filters=(Filter("suppkey", "==", 3),), fallback="q1")
    assert cubed_driver.router.route(q) is None
    assert cubed_driver.query(q).tier == 2


def test_query_without_fallback_raises(cubed_driver):
    q = AggQuery(table="lineitem", group_by=("returnflag",),
                 measures=("no_such_measure",))
    with pytest.raises(LookupError):
        cubed_driver.query(q)


# ---------------------------------------------------------------------------
# build semantics
# ---------------------------------------------------------------------------


def test_marginalization_equals_coarser_direct_build(cubed_driver):
    """Summing a dimension out of the finest rollup must equal building the
    coarser cube directly from the base table."""
    d = cubed_driver
    coarse_spec = CubeSpec(
        name="lineitem_coarse",
        table="lineitem",
        dimensions=(
            Dimension("returnflag", "l_returnflag", 3),
            Dimension("linestatus", "l_linestatus", 2),
        ),
        measures=(
            Measure("sum_qty", "sum", "l_quantity"),
            Measure("count_order", "count"),
        ),
    )
    coarse = build_cube(d.cluster, d.ctx, d.placed, coarse_spec)
    fine = d.cubes["lineitem_pricing"]
    marg = fine.rollup(("returnflag", "linestatus"))
    direct = coarse.rollup(("returnflag", "linestatus"))
    for m in ("sum_qty", "count_order", ROWS):
        np.testing.assert_allclose(marg[m], direct[m], rtol=1e-5)


def test_kernel_method_matches_onehot(cubed_driver):
    """The fused Pallas grouped-agg path produces the same cube as the
    one-hot MXU path (interpret mode on CPU)."""
    d = cubed_driver
    dims = (
        Dimension("returnflag", "l_returnflag", 3),
        Dimension("linestatus", "l_linestatus", 2),
    )
    measures = (
        Measure("sum_qty", "sum", "l_quantity"),
        Measure("count_order", "count"),
    )
    cubes = {}
    for method in ("onehot", "kernel"):
        spec = CubeSpec(name=f"li_{method}", table="lineitem",
                        dimensions=dims, measures=measures, method=method)
        cubes[method] = build_cube(d.cluster, d.ctx, d.placed, spec)
    a = cubes["onehot"].rollup(("returnflag", "linestatus"))
    b = cubes["kernel"].rollup(("returnflag", "linestatus"))
    for m in ("sum_qty", "count_order"):
        np.testing.assert_allclose(a[m], b[m], rtol=1e-6)


def test_dense_method_matches_onehot(cubed_driver):
    d = cubed_driver
    specs = {
        method: CubeSpec(
            name=f"orders_{method}", table="orders",
            dimensions=(Dimension("orderpriority", "o_orderpriority", 5),),
            measures=(Measure("sum_totalprice", "sum", "o_totalprice"),),
            method=method,
        )
        for method in ("onehot", "dense")
    }
    built = {m: build_cube(d.cluster, d.ctx, d.placed, s) for m, s in specs.items()}
    np.testing.assert_allclose(
        built["onehot"].rollup(("orderpriority",))["sum_totalprice"],
        built["dense"].rollup(("orderpriority",))["sum_totalprice"],
        rtol=1e-5,
    )


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------


def test_spec_validation():
    dim = Dimension("a", "col_a", 4)
    with pytest.raises(ValueError):
        CubeSpec("bad", "t", (dim,), (Measure("m", "median", "col"),))
    with pytest.raises(ValueError):
        CubeSpec("bad", "t", (dim,),
                 (Measure("m", "sum", "col"),), rollups=(("nope",),))
    with pytest.raises(ValueError):
        Dimension("d", "c")  # no cardinality, no edges
    spec = CubeSpec("ok", "t", (dim,), (Measure("m", "sum", "col"),),
                    rollups=((),))
    # the finest rollup is always materialized, plus the requested scalar one
    assert spec.rollups == (("a",), ())


def test_binned_dimension_codes():
    d = Dimension("ship", "l_shipdate", edges=(10, 20))
    assert d.cardinality == 3
    assert d.binned


def test_strict_bounds_require_integral_domain():
    """'< v' -> '<= v-1' only holds on integer columns; float domains must
    route strict bounds to Tier 2."""
    from repro.cube.router import _filter_mask

    f = Filter("x", "<", 11)
    assert _filter_mask(Dimension("x", "c", edges=(10, 20)), f) is None
    got = _filter_mask(Dimension("x", "c", edges=(10, 20), integral=True), f)
    np.testing.assert_array_equal(got, [True, False, False])
