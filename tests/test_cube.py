"""Two-tier rollup-cube subsystem: build correctness vs the numpy oracles,
IR-based router coverage/fallback decisions, and marginalization
semantics."""
from __future__ import annotations

import numpy as np
import pytest

from repro.cube import CubeSpec, Dimension, Filter, Measure
from repro.cube.build import ROWS, build_cube
from repro.query import C, Q, UncoveredQueryError
from repro.tpch import cubes as tpch_cubes
from repro.tpch.schema import DEFAULT_PARAMS as DP

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def cubed_driver(tpch_driver):
    """The shared SF 0.01 driver with the default TPC-H cubes built."""
    if not tpch_driver.cubes:
        tpch_driver.build_cubes()
    return tpch_driver


# ---------------------------------------------------------------------------
# Tier-1 correctness vs tpch/reference.py
# ---------------------------------------------------------------------------


def test_q1_from_cube_matches_oracle(cubed_driver):
    ans = cubed_driver.query(tpch_cubes.q1_query())
    assert ans.tier == 1
    assert ans.source == "lineitem_pricing"
    got = np.asarray(ans.value).reshape(6, 6)  # group id = returnflag*2 + linestatus
    ref = cubed_driver.oracle("q1")
    np.testing.assert_allclose(got, ref, rtol=2e-4)


def test_windowed_orders_query_matches_numpy(cubed_driver):
    ans = cubed_driver.query(tpch_cubes.orders_by_priority_query())
    assert ans.tier == 1
    o = cubed_driver.tables["orders"].columns
    sel = (o["o_orderdate"] >= DP.q4_date_min) & (o["o_orderdate"] < DP.q4_date_max)
    count = np.bincount(o["o_orderpriority"][sel], minlength=5)
    total = np.zeros(5)
    np.add.at(total, o["o_orderpriority"][sel],
              o["o_totalprice"][sel].astype(np.float64))
    np.testing.assert_allclose(ans.value[:, 0], count)
    np.testing.assert_allclose(ans.value[:, 1], total, rtol=1e-5)


def test_min_max_measures(cubed_driver):
    q = (Q.scan("orders")
         .filter((C("o_orderdate") >= DP.q4_date_min)
                 & (C("o_orderdate") < DP.q4_date_max))
         .group_agg(keys=[("orderstatus", C("o_orderstatus"), 3)],
                    aggs=[("min_totalprice", "min", C("o_totalprice")),
                          ("max_totalprice", "max", C("o_totalprice"))]))
    ans = cubed_driver.query(q)
    assert ans.tier == 1
    o = cubed_driver.tables["orders"].columns
    window = (o["o_orderdate"] >= DP.q4_date_min) & (o["o_orderdate"] < DP.q4_date_max)
    for s in range(3):
        tp = o["o_totalprice"][window & (o["o_orderstatus"] == s)]
        np.testing.assert_allclose(ans.value[s, 0], tp.min(), rtol=1e-6)
        np.testing.assert_allclose(ans.value[s, 1], tp.max(), rtol=1e-6)


# ---------------------------------------------------------------------------
# routing decisions
# ---------------------------------------------------------------------------


def test_coarse_rollup_is_preferred(cubed_driver):
    match = cubed_driver.router.route_query(
        tpch_cubes.revenue_by_shipmonth_query())
    assert match.route.rollup == ("shipmonth",)  # 86 cells, not the finest


def test_router_falls_back_for_non_edge_bound(cubed_driver):
    """The off-edge bound routes to Tier 2 — and with the IR there is no
    hand-named fallback: the driver lowers the query itself, so the Tier-2
    answer is the ACTUAL off-edge query, not an approximation."""
    ans = cubed_driver.query(tpch_cubes.uncovered_query())
    assert ans.tier == 2
    assert ans.source == "q1_offedge"
    li = cubed_driver.tables["lineitem"].columns
    sel = li["l_shipdate"] <= DP.q1_shipdate_max - 1
    g = li["l_returnflag"][sel] * 2 + li["l_linestatus"][sel]
    ref = np.zeros((6, 2))
    np.add.at(ref[:, 0], g, li["l_quantity"][sel].astype(np.float64))
    np.add.at(ref[:, 1], g, 1.0)
    np.testing.assert_allclose(np.asarray(ans.value), ref, rtol=2e-4)


def _q1_shaped(bound):
    return (Q.scan("lineitem")
            .filter(C("l_shipdate") <= bound)
            .group_agg(keys=[("returnflag", C("l_returnflag"), 3)],
                       aggs=[("sum_qty", "sum", C("l_quantity"))]))


def test_router_falls_back_below_first_edge(cubed_driver):
    """A bound inside the open first/last bins cuts a bin in half — never
    answerable exactly, even though the naive mask would be all-False."""
    from repro.tpch.schema import day

    for bound in (day(1992, 1, 15), day(1999, 6, 1)):
        assert cubed_driver.router.route_query(_q1_shaped(bound)) is None, bound


def test_router_falls_back_for_uncovered_dims(cubed_driver):
    """A filter on a column no cube carries as a dimension routes to Tier 2
    and is answered by LOWERING the query — no registered plan involved."""
    q = (Q.scan("lineitem")
         .filter(C("l_suppkey") == 3)
         .group_agg(keys=[("returnflag", C("l_returnflag"), 3)],
                    aggs=[("sum_qty", "sum", C("l_quantity"))]))
    assert cubed_driver.router.route_query(q) is None
    ans = cubed_driver.query(q)
    assert ans.tier == 2
    li = cubed_driver.tables["lineitem"].columns
    sel = li["l_suppkey"] == 3
    ref = np.zeros(3)
    np.add.at(ref, li["l_returnflag"][sel], li["l_quantity"][sel].astype(np.float64))
    np.testing.assert_allclose(np.asarray(ans.value)[:, 0], ref, rtol=2e-4)


def test_same_name_different_params_is_lowered_not_aliased(cubed_driver):
    """A query that shares a registered NAME but not the registered IR
    (e.g. q1 with a shifted cutoff) must be answered by lowering ITSELF,
    never by silently running the stock hand plan."""
    import dataclasses

    from repro.tpch.queries import q1_ir

    shifted = dataclasses.replace(DP, q1_shipdate_max=DP.q1_shipdate_max - 10)
    q = q1_ir(shifted)  # still named "q1"
    ans = cubed_driver.query(q)
    assert ans.tier == 2
    li = cubed_driver.tables["lineitem"].columns
    sel = li["l_shipdate"] <= shifted.q1_shipdate_max
    g = li["l_returnflag"][sel] * 2 + li["l_linestatus"][sel]
    ref = np.zeros(6)
    np.add.at(ref, g, li["l_quantity"][sel].astype(np.float64))
    np.testing.assert_allclose(np.asarray(ans.value)[:, 0], ref, rtol=2e-4)


def test_stacked_shadowing_projections_derive_outer_binding(cubed_driver):
    """project(x=l_quantity) then project(x=x*2): the router must resolve
    the OUTER binding (x = l_quantity*2), which matches no cube measure —
    Tier 2 must answer with the doubled sum, agreeing with the lowering."""
    q = (Q.scan("lineitem")
         .project(x=C("l_quantity"))
         .project(x=C("x") * 2.0)
         .group_agg(keys=[("returnflag", C("l_returnflag"), 3)],
                    aggs=[("sum_x", "sum", C("x"))]))
    assert cubed_driver.router.route_query(q) is None
    ans = cubed_driver.query(q)
    assert ans.tier == 2
    li = cubed_driver.tables["lineitem"].columns
    ref = np.zeros(3)
    np.add.at(ref, li["l_returnflag"], 2.0 * li["l_quantity"].astype(np.float64))
    np.testing.assert_allclose(np.asarray(ans.value)[:, 0], ref, rtol=2e-4)


def test_compile_query_cache_is_structural(cubed_driver):
    """Reconstructing the same query object per request must reuse the
    compiled executable, not recompile."""
    from repro.tpch.queries import q1_ir

    fn1 = cubed_driver.compile_query(q1_ir())
    fn2 = cubed_driver.compile_query(q1_ir())  # fresh object, same structure
    assert fn1 is fn2


def test_shadowing_projection_derivation_terminates(cubed_driver):
    """route_query on a projection that shadows its input column must not
    recurse forever; the rewritten measure doesn't match any cube, so the
    query lowers to Tier 2."""
    q = (Q.scan("lineitem")
         .project(l_quantity=C("l_quantity") * 0.0 + 50.0)
         .group_agg(keys=[("returnflag", C("l_returnflag"), 3)],
                    aggs=[("sum_qty", "sum", C("l_quantity"))]))
    assert cubed_driver.router.route_query(q) is None
    ans = cubed_driver.query(q)
    assert ans.tier == 2


def test_uncovered_unlowerable_raises_typed_error(cubed_driver):
    """min/max measures are cube-only; with an off-edge filter no rollup
    covers the query and lowering refuses — a typed UncoveredQueryError,
    not a bare KeyError/LookupError."""
    q = (Q.scan("orders")
         .filter(C("o_orderdate") <= DP.q4_date_min + 7)  # not a bin edge
         .group_agg(keys=[("orderstatus", C("o_orderstatus"), 3)],
                    aggs=[("min_totalprice", "min", C("o_totalprice"))]))
    with pytest.raises(UncoveredQueryError):
        cubed_driver.query(q)


# ---------------------------------------------------------------------------
# build semantics
# ---------------------------------------------------------------------------


def test_marginalization_equals_coarser_direct_build(cubed_driver):
    """Summing a dimension out of the finest rollup must equal building the
    coarser cube directly from the base table."""
    d = cubed_driver
    coarse_spec = CubeSpec(
        name="lineitem_coarse",
        table="lineitem",
        dimensions=(
            Dimension("returnflag", "l_returnflag", 3),
            Dimension("linestatus", "l_linestatus", 2),
        ),
        measures=(
            Measure("sum_qty", "sum", "l_quantity"),
            Measure("count_order", "count"),
        ),
    )
    coarse = build_cube(d.cluster, d.ctx, d.placed, coarse_spec)
    fine = d.cubes["lineitem_pricing"]
    marg = fine.rollup(("returnflag", "linestatus"))
    direct = coarse.rollup(("returnflag", "linestatus"))
    for m in ("sum_qty", "count_order", ROWS):
        np.testing.assert_allclose(marg[m], direct[m], rtol=1e-5)


def test_kernel_method_matches_onehot(cubed_driver):
    """The fused Pallas grouped-agg path produces the same cube as the
    one-hot MXU path (interpret mode on CPU)."""
    d = cubed_driver
    dims = (
        Dimension("returnflag", "l_returnflag", 3),
        Dimension("linestatus", "l_linestatus", 2),
    )
    measures = (
        Measure("sum_qty", "sum", "l_quantity"),
        Measure("count_order", "count"),
    )
    cubes = {}
    for method in ("onehot", "kernel"):
        spec = CubeSpec(name=f"li_{method}", table="lineitem",
                        dimensions=dims, measures=measures, method=method)
        cubes[method] = build_cube(d.cluster, d.ctx, d.placed, spec)
    a = cubes["onehot"].rollup(("returnflag", "linestatus"))
    b = cubes["kernel"].rollup(("returnflag", "linestatus"))
    for m in ("sum_qty", "count_order"):
        np.testing.assert_allclose(a[m], b[m], rtol=1e-6)


def test_dense_method_matches_onehot(cubed_driver):
    d = cubed_driver
    specs = {
        method: CubeSpec(
            name=f"orders_{method}", table="orders",
            dimensions=(Dimension("orderpriority", "o_orderpriority", 5),),
            measures=(Measure("sum_totalprice", "sum", "o_totalprice"),),
            method=method,
        )
        for method in ("onehot", "dense")
    }
    built = {m: build_cube(d.cluster, d.ctx, d.placed, s) for m, s in specs.items()}
    np.testing.assert_allclose(
        built["onehot"].rollup(("orderpriority",))["sum_totalprice"],
        built["dense"].rollup(("orderpriority",))["sum_totalprice"],
        rtol=1e-5,
    )


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------


def test_spec_validation():
    dim = Dimension("a", "col_a", 4)
    with pytest.raises(ValueError):
        CubeSpec("bad", "t", (dim,), (Measure("m", "median", "col"),))
    with pytest.raises(ValueError):
        CubeSpec("bad", "t", (dim,),
                 (Measure("m", "sum", "col"),), rollups=(("nope",),))
    with pytest.raises(ValueError):
        Dimension("d", "c")  # no cardinality, no edges
    spec = CubeSpec("ok", "t", (dim,), (Measure("m", "sum", "col"),),
                    rollups=((),))
    # the finest rollup is always materialized, plus the requested scalar one
    assert spec.rollups == (("a",), ())


def test_binned_dimension_codes():
    d = Dimension("ship", "l_shipdate", edges=(10, 20))
    assert d.cardinality == 3
    assert d.binned


def test_strict_bounds_require_integral_domain():
    """'< v' -> '<= v-1' only holds on integer columns; float domains must
    route strict bounds to Tier 2."""
    from repro.cube.router import _filter_mask

    f = Filter("x", "<", 11)
    assert _filter_mask(Dimension("x", "c", edges=(10, 20)), f) is None
    got = _filter_mask(Dimension("x", "c", edges=(10, 20), integral=True), f)
    np.testing.assert_array_equal(got, [True, False, False])
