"""Unit tests for the distributed primitives: exchange backends, butterfly
reductions, top-k selection, semi-joins, late materialization — each checked
against a host-side oracle on the 8-device mesh."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

pytestmark = pytest.mark.tier1

from repro.core import exchange, late_materialization, semijoin, topk, topk_approx
from repro.core.partitioning import RangePartitioning

AXIS = "nodes"


def spmd(cluster, fn, *arrays, replicated_args=()):
    """Run fn inside shard_map over the cluster's nodes axis; inputs sharded
    on axis 0 unless listed in replicated_args; outputs replicated."""
    in_specs = tuple(
        P() if i in replicated_args else P(AXIS) for i in range(len(arrays))
    )
    f = jax.jit(
        jax.shard_map(fn, mesh=cluster.mesh, in_specs=in_specs, out_specs=P(),
                      check_vma=False)
    )
    return jax.tree.map(np.asarray, f(*arrays))


# ---------------------------------------------------------------------------
# all-to-all backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "one_factor"])
def test_all_to_all_semantics(cluster, backend):
    Pn = cluster.num_nodes
    m = 5
    rng = np.random.default_rng(0)
    # global input: (P*P, m); node s's rows are x[s*P:(s+1)*P] with row d
    # addressed to node d
    x = rng.normal(size=(Pn * Pn, m)).astype(np.float32)

    def fn(local):  # local: (P, m) on each node
        recv = exchange.all_to_all(local, AXIS, backend=backend)
        return jax.lax.all_gather(recv, AXIS)  # (P, P, m) for checking

    out = spmd(cluster, fn, x)
    xg = x.reshape(Pn, Pn, m)
    # node d received from node s the row xg[s, d]
    expect = np.stack([xg[:, d] for d in range(Pn)])
    np.testing.assert_allclose(out, expect, rtol=1e-6)


def test_one_factor_equals_xla(cluster):
    Pn = cluster.num_nodes
    rng = np.random.default_rng(1)
    x = rng.normal(size=(Pn * Pn, 17)).astype(np.float32)

    def fn(local):
        a = exchange.all_to_all(local, AXIS, backend="xla")
        b = exchange.all_to_all(local, AXIS, backend="one_factor")
        return jnp.max(jnp.abs(a - b))

    assert spmd(cluster, fn, x) == 0.0


# ---------------------------------------------------------------------------
# butterfly allreduce with a custom merge
# ---------------------------------------------------------------------------


def test_butterfly_matches_pmax(cluster):
    Pn = cluster.num_nodes
    rng = np.random.default_rng(2)
    x = rng.normal(size=(Pn * 4,)).astype(np.float32)

    def fn(local):
        butter = exchange.butterfly_allreduce(local, jnp.maximum, AXIS)
        direct = jax.lax.pmax(local, AXIS)
        return jnp.max(jnp.abs(butter - direct))

    assert spmd(cluster, fn, x) == 0.0


def test_broadcast_from(cluster):
    Pn = cluster.num_nodes
    x = np.arange(Pn * 3, dtype=np.float32)

    def fn(local):
        return exchange.broadcast_from(local, root=2, axis=AXIS)

    out = spmd(cluster, fn, x)
    np.testing.assert_array_equal(out, x.reshape(Pn, 3)[2])


# ---------------------------------------------------------------------------
# bucketing + request/reply
# ---------------------------------------------------------------------------


def test_bucket_by_destination_properties():
    rng = np.random.default_rng(3)
    n, num_nodes, cap = 200, 8, 64
    keys = jnp.asarray(rng.integers(0, 800, n).astype(np.int32))
    mask = jnp.asarray(rng.random(n) < 0.7)
    owner = keys // 100
    buckets, bmask, (dest, slot), ovf = exchange.bucket_by_destination(
        keys, mask, owner, num_nodes, cap
    )
    buckets, bmask = np.asarray(buckets), np.asarray(bmask)
    dest, slot = np.asarray(dest), np.asarray(slot)
    assert not bool(ovf)
    kn, mn, on = np.asarray(keys), np.asarray(mask), np.asarray(owner)
    # every masked key appears exactly once at its recorded (dest, slot)
    for i in range(n):
        if mn[i]:
            assert dest[i] == on[i]
            assert buckets[dest[i], slot[i]] == kn[i]
            assert bmask[dest[i], slot[i]]
    # bucket occupancy equals per-destination masked counts
    counts = np.bincount(on[mn], minlength=num_nodes)
    np.testing.assert_array_equal(bmask.sum(axis=1), counts)


def test_bucket_overflow_flag():
    keys = jnp.arange(64, dtype=jnp.int32)
    mask = jnp.ones(64, bool)
    owner = jnp.zeros(64, jnp.int32)  # all to node 0
    _, _, _, ovf = exchange.bucket_by_destination(keys, mask, owner, 8, 16)
    assert bool(ovf)


@pytest.mark.parametrize("backend", ["xla", "one_factor"])
def test_request_reply(cluster, backend):
    """Remote lookup: reply[i] == f(keys[i]) for masked keys, 0 otherwise."""
    Pn = cluster.num_nodes
    rows = 32
    total = Pn * rows
    part = RangePartitioning(total, Pn)
    rng = np.random.default_rng(4)
    n_per = 40
    keys = rng.integers(0, total, Pn * n_per).astype(np.int32)
    mask = rng.random(Pn * n_per) < 0.8
    # the remote attribute: owner's local value = global_key * 3 + 1
    def fn(k_local, m_local):
        def lookup(req, req_mask):
            base = part.my_base(AXIS)
            global_key = base + part.local_index(req)  # == req for owned keys
            return jnp.where(req_mask, global_key * 3 + 1, 0)

        rep, ovf = exchange.request_reply(
            k_local, m_local, part.owner(k_local), lookup,
            capacity=64, axis=AXIS, backend=backend, reply_dtype=jnp.int32,
        )
        return jax.lax.all_gather(rep, AXIS, tiled=True), ovf

    rep, ovf = spmd(cluster, fn, jnp.asarray(keys), jnp.asarray(mask))
    assert not bool(ovf)
    np.testing.assert_array_equal(rep, np.where(mask, keys * 3 + 1, 0))


def test_exchange_by_owner_aggregates(cluster):
    """Sum of routed values per key == global group-by sum."""
    Pn = cluster.num_nodes
    rows = 16
    total = Pn * rows
    part = RangePartitioning(total, Pn)
    rng = np.random.default_rng(5)
    n_per = 64
    keys = rng.integers(0, total, Pn * n_per).astype(np.int32)
    vals = rng.normal(size=Pn * n_per).astype(np.float32)
    mask = rng.random(Pn * n_per) < 0.9

    def fn(k, v, m):
        rk, rv, rm, ovf = exchange.exchange_by_owner(
            k, v, m, part.owner(k), capacity=128, axis=AXIS
        )
        local_idx = jnp.where(rm, rk - part.my_base(AXIS), rows).reshape(-1)
        agg = jnp.zeros(rows, jnp.float32).at[local_idx].add(
            jnp.where(rm, rv, 0.0).reshape(-1), mode="drop"
        )
        return jax.lax.all_gather(agg, AXIS, tiled=True), ovf

    agg, ovf = spmd(cluster, fn, jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(mask))
    assert not bool(ovf)
    expect = np.zeros(total)
    np.add.at(expect, keys[mask], vals[mask].astype(np.float64))
    np.testing.assert_allclose(agg, expect, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# top-k: local, merge, allreduce == gather == numpy
# ---------------------------------------------------------------------------


def _np_topk(values, keys, k):
    order = np.lexsort((keys, -values))[:k]
    return values[order], keys[order]


def test_local_topk_matches_numpy():
    rng = np.random.default_rng(6)
    v = rng.normal(size=100).astype(np.float32)
    keys = rng.permutation(100).astype(np.int32)
    out = topk.local_topk(jnp.asarray(v), jnp.asarray(keys), 10)
    ev, ek = _np_topk(v.astype(np.float64), keys, 10)
    np.testing.assert_allclose(np.asarray(out.values), ev, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out.keys), ek)
    assert np.asarray(out.valid).all()


def test_topk_allreduce_equals_gather_and_numpy(cluster):
    Pn = cluster.num_nodes
    rng = np.random.default_rng(7)
    n = Pn * 50
    v = rng.normal(size=n).astype(np.float32)
    keys = np.arange(n, dtype=np.int32)
    k = 12

    def fn(vl, kl):
        local = topk.local_topk(vl, kl, k)
        a = topk.topk_allreduce(local, AXIS)
        b = topk.topk_gather(local, AXIS)
        return a, b

    (a, b) = spmd(cluster, fn, jnp.asarray(v), jnp.asarray(keys))
    ev, ek = _np_topk(v.astype(np.float64), keys, k)
    for out in (a, b):
        np.testing.assert_allclose(out.values, ev, rtol=1e-6)
        np.testing.assert_array_equal(out.keys, ek)


def test_topk_fewer_than_k_valid(cluster):
    Pn = cluster.num_nodes
    n = Pn * 8
    v = np.zeros(n, np.float32)
    mask = np.zeros(n, bool)
    mask[:3] = True
    v[:3] = [5.0, 7.0, 6.0]
    keys = np.arange(n, dtype=np.int32)

    def fn(vl, kl, ml):
        return topk.topk_allreduce(topk.local_topk(vl, kl, 10, ml), AXIS)

    out = spmd(cluster, fn, jnp.asarray(v), jnp.asarray(keys), jnp.asarray(mask))
    assert out.valid[:3].all() and not out.valid[3:].any()
    np.testing.assert_allclose(out.values[:3], [7.0, 6.0, 5.0])
    np.testing.assert_array_equal(out.keys[:3], [1, 2, 0])


# ---------------------------------------------------------------------------
# approximate distributed top-k (§3.2.5) == exact, on adversarial floats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [4, 8, 16])
@pytest.mark.parametrize("dist", ["uniform", "lognormal", "sparse"])
def test_approx_topk_equals_simple(cluster, m, dist):
    Pn = cluster.num_nodes
    group = 32
    Kp = group * 4
    K = Pn * Kp
    rng = np.random.default_rng(m * 17 + len(dist))
    # per-node partials: (P, K) — i.i.d. partial sums, the adversarial case
    # for TA/TPUT that motivates the paper's algorithm
    if dist == "uniform":
        partials = rng.random((Pn, K)).astype(np.float32)
    elif dist == "lognormal":
        partials = rng.lognormal(0, 2.0, (Pn, K)).astype(np.float32)
    else:
        partials = np.where(
            rng.random((Pn, K)) < 0.05, rng.random((Pn, K)), 0.0
        ).astype(np.float32)
    k = 5

    def fn(p_local):
        p_local = p_local.reshape(K)
        exact = topk_approx.simple_topk_distributed(p_local, k, axis=AXIS)
        approx, stats, ovf = topk_approx.approx_topk_distributed(
            p_local, k, m=m, group=group, candidate_capacity=Kp, axis=AXIS
        )
        return exact, approx, stats, ovf

    exact, approx, stats, ovf = spmd(cluster, fn, jnp.asarray(partials.reshape(Pn * K)))
    assert not bool(ovf)
    np.testing.assert_array_equal(exact.keys, approx.keys)
    np.testing.assert_allclose(exact.values, approx.values, rtol=1e-5)
    # the whole point: fewer bits than the naive exchange
    assert float(stats.approx_bits_per_node) < float(stats.naive_bits_per_node)
    # and the result matches the float64 oracle
    totals = partials.astype(np.float64).sum(axis=0)
    ev, ek = _np_topk(totals, np.arange(K, dtype=np.int32), k)
    np.testing.assert_array_equal(approx.keys, ek)
    np.testing.assert_allclose(approx.values, ev, rtol=1e-4)


# ---------------------------------------------------------------------------
# semi-joins: Alt-1 == Alt-2 == oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("selectivity", [0.02, 0.5, 0.98])
def test_semijoin_alternatives_agree(cluster, selectivity):
    Pn = cluster.num_nodes
    rows = 32
    total = Pn * rows
    part = RangePartitioning(total, Pn)
    rng = np.random.default_rng(int(selectivity * 100))
    attr = (rng.random(total) < selectivity).astype(np.int32)  # remote predicate
    n_per = 48
    keys = rng.integers(0, total, Pn * n_per).astype(np.int32)
    mask = rng.random(Pn * n_per) < 0.75

    def fn(k, m, attr_local):
        def pred(local_idx, req_mask):
            return (attr_local[local_idx] == 1) & req_mask

        bits1, ovf = semijoin.alt1_request(
            k, m, part, pred, capacity=128, axis=AXIS
        )
        words = semijoin.alt2_bitset(attr_local == 1, axis=AXIS)
        bits2 = semijoin.probe(words, k, part) & m
        return (
            jax.lax.all_gather(bits1, AXIS, tiled=True),
            jax.lax.all_gather(bits2, AXIS, tiled=True),
            ovf,
        )

    b1, b2, ovf = spmd(cluster, fn, jnp.asarray(keys), jnp.asarray(mask),
                       jnp.asarray(attr))
    assert not bool(ovf)
    expect = mask & (attr[keys] == 1)
    np.testing.assert_array_equal(b1, expect)
    np.testing.assert_array_equal(b2, expect)


def test_semijoin_cost_model_crossover():
    """Few requests -> Alt-1; near-total access or tiny tables -> Alt-2
    (paper footnote 2)."""
    m, Pn = 1_000_000, 128
    assert semijoin.choose_alternative(n=1000, m=m, gamma=0.5, P=Pn) == 1
    assert semijoin.choose_alternative(n=200 * m, m=m, gamma=0.5, P=Pn) == 2
    # highly selective remote filter favors the bitset too
    assert semijoin.choose_alternative(n=50_000_000, m=m, gamma=1e-5, P=Pn) == 2


# ---------------------------------------------------------------------------
# late materialization
# ---------------------------------------------------------------------------


def test_late_materialization(cluster):
    Pn = cluster.num_nodes
    rows = 8
    total = Pn * rows
    part = RangePartitioning(total, Pn)
    rng = np.random.default_rng(9)
    col = rng.integers(0, 1000, total).astype(np.int32)
    win_keys = np.array([3, 17, 42, 63, 0, 0], np.int32) % total
    valid = np.array([True, True, True, True, False, False])

    def fn(col_local, wk, wv):
        return late_materialization.materialize(
            wk, wv, part, {"attr": col_local}, axis=AXIS
        )

    out = spmd(cluster, fn, jnp.asarray(col), jnp.asarray(win_keys),
               jnp.asarray(valid), replicated_args=(1, 2))
    np.testing.assert_array_equal(out["attr"][:4], col[win_keys[:4]])
    np.testing.assert_array_equal(out["attr"][4:], 0)
