"""Distributed top-k decode head (the paper's §3.2.3 applied to serving):
must equal a full-logits argmax/top-k at a fraction of the bytes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.serve.sampling import naive_allgather_argmax, topk_logits


def _mesh():
    return jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices()[:8])


def test_distributed_topk_equals_full_topk():
    mesh = _mesh()
    rng = np.random.default_rng(0)
    B, V = 4, 512
    logits = rng.normal(size=(B, V)).astype(np.float32)
    k = 8

    def head(local):
        return topk_logits(local, k, axis="model")

    vals, ids = jax.jit(jax.shard_map(
        head, mesh=mesh, in_specs=P("data", "model"),
        out_specs=P("data"), check_vma=False,
    ))(jnp.asarray(logits))
    vals, ids = np.asarray(vals), np.asarray(ids)
    for b in range(B):
        order = np.lexsort((np.arange(V), -logits[b].astype(np.float64)))[:k]
        np.testing.assert_array_equal(ids[b], order)
        np.testing.assert_allclose(vals[b], logits[b][order], rtol=1e-6)


def test_greedy_equals_naive_allgather():
    mesh = _mesh()
    rng = np.random.default_rng(1)
    B, V = 8, 1024
    logits = rng.normal(size=(B, V)).astype(np.float32)

    def both(local):
        vals, ids = topk_logits(local, 4, axis="model")
        return ids[:, 0], naive_allgather_argmax(local, axis="model")

    fast, naive = jax.jit(jax.shard_map(
        both, mesh=mesh, in_specs=P("data", "model"),
        out_specs=(P("data"), P("data")), check_vma=False,
    ))(jnp.asarray(logits))
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(naive))
    np.testing.assert_array_equal(np.asarray(fast), logits.argmax(-1))


def test_serve_step_end_to_end():
    """Tiny model + mesh: the jitted serve step emits tokens and advances
    the cache; greedy draw matches the full-logits argmax."""
    from repro.configs import get_arch
    from repro.models.model import build
    from repro.models.params import values
    from repro.serve.engine import make_serve_step

    mesh = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
    cfg = get_arch("qwen2.5-3b", smoke=True)
    model = build(cfg, tp=2)
    params = values(model.init(jax.random.key(0)))
    state = model.init_decode_state(4, max_len=16, dtype=jnp.float32)
    step = jax.jit(make_serve_step(model, mesh, k=4))
    tok = jnp.zeros((4,), jnp.int32)
    rng = jax.random.key(0)
    with mesh:
        nxt, state = step(params, state, tok, rng)
    assert nxt.shape == (4,)
    assert int(state.length) == 1
    # cross-check against unsharded decode + argmax
    logits, _ = model.decode_step(
        params, model.init_decode_state(4, max_len=16, dtype=jnp.float32),
        tok[:, None])
    np.testing.assert_array_equal(np.asarray(nxt),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_quant_cache_decode_matches_bf16():
    """int8 KV cache + Pallas decode kernel vs the exact bf16 path."""
    from repro.configs import get_arch
    from repro.models.model import build
    from repro.models.params import values

    cfg = get_arch("qwen3-moe-30b-a3b", smoke=True)
    model_ref = build(cfg)
    model_q = build(cfg, cache_quant=True)
    params = values(model_ref.init(jax.random.key(0)))
    s_ref = model_ref.init_decode_state(2, max_len=16, dtype=jnp.float32)
    s_q = model_q.init_decode_state(2, max_len=16)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (2, 6)).astype(np.int32)
    for t in range(6):
        tok = jnp.asarray(toks[:, t:t+1])
        logits_ref, s_ref = model_ref.decode_step(params, s_ref, tok)
        logits_q, s_q = model_q.decode_step(params, s_q, tok)
        # int8 cache: small quantization error, same ranking at the top
        np.testing.assert_allclose(np.asarray(logits_q), np.asarray(logits_ref),
                                   rtol=0.1, atol=0.15)
    # int8 noise may flip exact near-ties in a tiny random model; the
    # quantized argmax must still be among the reference top-5
    top5 = np.asarray(jax.lax.top_k(logits_ref, 5)[1])
    amax_q = np.asarray(jnp.argmax(logits_q, -1))
    for b in range(2):
        assert amax_q[b] in top5[b]


def test_decode_attention_kernel_vs_ref():
    from repro.kernels.decode_attention import decode_attention
    from repro.models import layers as L

    rng = np.random.default_rng(1)
    B, KV, G, D, S = 2, 2, 4, 16, 64
    q = jnp.asarray(rng.normal(size=(B * KV, G, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B * KV, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B * KV, S, D)).astype(np.float32))
    length = jnp.int32(37)
    out = decode_attention(q, k, v, length, bs=16, interpret=True)
    # oracle via layers.decode_attention ((B, 1, H, D) layout)
    qh = q.reshape(B, KV, G, D).reshape(B, KV * G, D)[:, None]
    kh = k.reshape(B, KV, S, D).transpose(0, 2, 1, 3)
    vh = v.reshape(B, KV, S, D).transpose(0, 2, 1, 3)
    expect = L.decode_attention(qh, kh, vh, length)
    expect_g = expect[:, 0].reshape(B, KV, G, D).reshape(B * KV, G, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect_g),
                               rtol=2e-5, atol=2e-5)
