"""Compressed-resident columns and predicate-on-packed scans.

Covers the compression width edge cases ({0, 1, 31, 32} round-trips and
random access), the PackedColumn resident format (plan/pack/decode/gather),
kernel parity across the ref / XLA / Pallas-interpret formulations, the
end-to-end property that predicate-on-packed + late decode is bit-identical
to decode-then-filter (hypothesis when installed, a fixed pre-seeded grid
otherwise), packed-vs-raw driver equivalence, the storage byte accounting,
and the resident-budget guard.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import compression
from repro.core.columnar import PackedColumn, pack_column, plan_packing
from repro.kernels import ops, ref
from repro.kernels.scan_filter import scan_filter_pallas, scan_filter_xla
from repro.query.ir import C, Lit, PackedInfo
from repro.query.stats import scan_rewrite

pytestmark = pytest.mark.tier1


# -- compression width edge cases ({0, 1, 31, 32} plus interior) -------------

@pytest.mark.parametrize("width", [0, 1, 2, 7, 17, 31, 32])
def test_pack_bits_roundtrip_width_edges(width):
    rng = np.random.default_rng(width)
    n = 97  # odd: last word partially filled, straddles exercised
    if width == 0:
        vals = np.zeros(n, np.uint32)
    else:
        vals = rng.integers(0, 1 << width, size=n,
                            dtype=np.uint64).astype(np.uint32)
    words = compression.pack_bits(jnp.asarray(vals, jnp.uint32), width)
    assert words.shape[0] == compression.packed_words(n, width)
    out = np.asarray(compression.unpack_bits(words, n, width))
    np.testing.assert_array_equal(out, vals)
    # random access must agree with the full decode
    idx = rng.permutation(n)[: max(n // 2, 1)]
    got = np.asarray(compression.gather_bits(
        words, jnp.asarray(idx, jnp.uint32), width))
    np.testing.assert_array_equal(got, vals[idx])


def test_width_zero_is_empty_and_width_32_is_identity_sized():
    assert compression.packed_words(64, 0) == 0
    assert compression.pack_bits(jnp.arange(64, dtype=jnp.uint32), 0).shape[0] == 0
    # width 32 packs 1:1 — no compression, but still correct
    assert compression.packed_words(64, 32) == 64
    assert compression.required_width(0) == 0
    assert compression.required_width(1) == 1
    assert compression.required_width((1 << 31) - 1) == 31
    assert compression.required_width((1 << 32) - 1) == 32


def test_pack_bits_extremes_survive_at_full_width():
    # all-ones values at widths 31/32: the straddle's high half carries
    # meaningful bits in every group position
    for width in (31, 32):
        n = 64
        vals = np.full(n, (1 << width) - 1, np.uint64).astype(np.uint32)
        words = compression.pack_bits(jnp.asarray(vals, jnp.uint32), width)
        out = np.asarray(compression.unpack_bits(words, n, width))
        np.testing.assert_array_equal(out, vals)


# -- PackedColumn: plan, pack, decode, gather --------------------------------

def test_plan_packing_eligibility():
    # bool -> width 1
    spec = plan_packing([np.array([True, False, True])])
    assert spec["width"] == 1 and spec["dtype"] == "bool"
    # small-span int -> FOR at required width
    spec = plan_packing([np.arange(1000, 1100, dtype=np.int64)])
    assert spec["width"] == 7 and spec["offset"] == 1000
    # wide-span int -> raw
    assert plan_packing([np.array([0, 1 << 30], np.int64)]) is None
    # all-integral float -> FOR float32
    spec = plan_packing([np.array([3.0, 10.0, 7.0])])
    assert spec["dtype"] == "float32" and spec["values"] is None
    # low-cardinality fractional float -> sorted dictionary
    spec = plan_packing([np.array([0.04, 0.02, 0.04, 0.09])])
    assert spec["values"] == (0.02, 0.04, 0.09)
    # high-cardinality fractional float -> raw
    rng = np.random.default_rng(0)
    assert plan_packing([rng.uniform(size=4096)]) is None
    # NaN/Inf disqualify
    assert plan_packing([np.array([1.0, np.nan])]) is None


@pytest.mark.parametrize("kind", ["bool", "int", "float_for", "float_dict"])
@pytest.mark.parametrize("nodes", [1, 4])
def test_pack_column_roundtrip(kind, nodes):
    rng = np.random.default_rng(7)
    rows = 173  # not a multiple of 32: padding in play
    if kind == "bool":
        chunks = [rng.integers(0, 2, rows).astype(bool) for _ in range(nodes)]
    elif kind == "int":
        chunks = [rng.integers(-50, 2000, rows) for _ in range(nodes)]
    elif kind == "float_for":
        chunks = [rng.integers(0, 300, rows).astype(np.float64)
                  for _ in range(nodes)]
    else:
        pool = np.round(np.sort(rng.uniform(0, 10, 31)), 3)
        chunks = [rng.choice(pool, rows) for _ in range(nodes)]
    spec = plan_packing(chunks)
    col = pack_column(chunks, spec)
    assert col.num_nodes == nodes and col.rows == rows
    assert col.padded_rows % 32 == 0
    expect = np.concatenate(chunks).astype(
        np.dtype(col.dtype) if kind != "bool" else bool)
    got = np.asarray(col.decode())
    np.testing.assert_array_equal(got, expect)
    # gather on a node-local view matches a slice of the decode
    wpn = col.words_per_node
    local = dataclasses.replace(
        col, words=jnp.asarray(np.asarray(col.words)[:wpn]), num_nodes=1)
    idx = rng.permutation(rows)[: rows // 3]
    np.testing.assert_array_equal(
        np.asarray(local.gather(jnp.asarray(idx, jnp.uint32))),
        expect[:rows][idx])
    # compression actually compresses (except bool, whose raw form is 1 B)
    if kind != "bool":
        assert col.nbytes < col.raw_nbytes


# -- scan_filter kernel parity (ref oracle vs XLA vs Pallas-interpret) -------

_IMPLS = {
    "ref": lambda *a, **k: ref.scan_filter(*a, **k),
    "xla": scan_filter_xla,
    "pallas": lambda w, lo, hi, **k: scan_filter_pallas(
        w, lo, hi, interpret=True, **k),
}


def _ref_call(words, lo, hi, *, rows, padded_rows, width, negate=False):
    return ref.scan_filter(words, lo, hi, rows, padded_rows, width, negate)


_IMPLS["ref"] = _ref_call


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("width", [1, 5, 13, 24, 30])
@pytest.mark.parametrize("negate", [False, True])
def test_scan_filter_matches_oracle(impl, width, negate):
    rng = np.random.default_rng(width)
    rows, padded = 173, 192
    codes = np.zeros(padded, np.uint32)
    codes[:rows] = rng.integers(0, 1 << width, rows,
                                dtype=np.uint64).astype(np.uint32)
    words = compression.pack_bits(jnp.asarray(codes), width)
    maxc = (1 << width) - 1
    for lo, hi in [(0, maxc), (0, -1), (maxc // 3, (2 * maxc) // 3),
                   (maxc, maxc)]:
        want = np.asarray(_ref_call(
            words, lo, hi, rows=rows, padded_rows=padded, width=width,
            negate=negate))
        got = np.asarray(_IMPLS[impl](
            words, lo, hi, rows=rows, padded_rows=padded, width=width,
            negate=negate))
        np.testing.assert_array_equal(got, want, err_msg=f"{impl} {lo}..{hi}")
        # rows beyond `rows` must be invalid even under negation
        mask = np.asarray(compression.unpack_bitset(got, padded))
        assert not mask[rows:].any()


def test_ops_scan_filter_dispatch_and_toggle():
    rng = np.random.default_rng(3)
    rows, padded, width = 96, 96, 8
    codes = rng.integers(0, 256, padded, dtype=np.int64).astype(np.uint32)
    words = compression.pack_bits(jnp.asarray(codes), width)
    want = np.asarray(_ref_call(words, 10, 200, rows=rows,
                                padded_rows=padded, width=width))
    got = np.asarray(ops.scan_filter(words, 10, 200, rows=rows,
                                     padded_rows=padded, width=width))
    np.testing.assert_array_equal(got, want)
    ops.use_kernels(False)
    try:
        got_ref = np.asarray(ops.scan_filter(words, 10, 200, rows=rows,
                                             padded_rows=padded, width=width))
    finally:
        ops.use_kernels(True)
    np.testing.assert_array_equal(got_ref, want)


# -- property: predicate-on-packed + late decode == decode-then-filter -------
#
# The tentpole's core claim: rewriting `col <= v` into code space, scanning
# packed words, and gathering only the surviving rows yields EXACTLY the
# rows a full decode followed by the same predicate yields — bit-identical,
# across widths, selectivities, node counts, kernel impls, and both the
# frame-of-reference and dictionary encodings.

def _check_packed_scan_equivalence(width, sel, nodes, impl, kind, seed):
    rng = np.random.default_rng(seed)
    rows = 141
    if kind == "dict":
        pool = np.round(np.sort(rng.uniform(0.0, 50.0,
                                            min(1 << width, 48))), 3)
        pool = np.unique(pool)
        chunks = [rng.choice(pool, rows) for _ in range(nodes)]
    else:
        base = -7
        chunks = [(rng.integers(0, 1 << width, rows,
                                dtype=np.int64) + base).astype(np.int64)
                  for _ in range(nodes)]
    spec = plan_packing(chunks)
    assert spec is not None
    col = pack_column(chunks, spec)
    allv = np.concatenate(chunks)
    if sel <= 0.0:
        v = float(allv.min()) - 1.0
    elif sel >= 1.0:
        v = float(allv.max()) + 1.0
    else:
        v = float(np.quantile(allv, sel))
    info = PackedInfo(width=col.width, offset=col.offset,
                      values=col.values, dtype=col.dtype)
    rw = scan_rewrite(C("x") <= Lit(v), {"x": info})
    assert rw is not None and not rw.negate
    lo, hi = rw.static_bounds()
    wpn = col.words_per_node
    all_words = np.asarray(col.words).reshape(nodes, wpn)
    for i in range(nodes):
        words = jnp.asarray(all_words[i])
        bits = _IMPLS[impl](words, lo, hi, rows=col.rows,
                            padded_rows=col.padded_rows, width=col.width)
        mask = np.asarray(compression.unpack_bitset(
            bits, col.padded_rows))[:col.rows]
        # decode-then-filter on this node
        local = dataclasses.replace(col, words=words, num_nodes=1)
        decoded = np.asarray(local.decode())
        want_mask = decoded <= np.asarray(v, decoded.dtype)
        np.testing.assert_array_equal(mask, want_mask)
        # late materialization: gather survivors only, bit-identical
        idx = np.nonzero(mask)[0]
        got = np.asarray(local.gather(jnp.asarray(idx, jnp.uint32)))
        np.testing.assert_array_equal(got, decoded[want_mask])


_GRID = [
    (w, sel, nodes, impl, kind)
    for w in (1, 6, 11)
    for sel in (0.0, 0.5, 1.0)
    for nodes in (1, 4)
    for impl in ("ref", "xla", "pallas")
    for kind in ("for", "dict")
]

try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(width=st.integers(1, 16), sel=st.sampled_from([0.0, 0.5, 1.0]),
           nodes=st.sampled_from([1, 2, 4]),
           impl=st.sampled_from(["ref", "xla", "pallas"]),
           kind=st.sampled_from(["for", "dict"]),
           seed=st.integers(0, 2 ** 16))
    def test_packed_scan_equivalence(width, sel, nodes, impl, kind, seed):
        _check_packed_scan_equivalence(width, sel, nodes, impl, kind, seed)
except ImportError:  # fixed pre-seeded grid when hypothesis is absent
    @pytest.mark.parametrize("width,sel,nodes,impl,kind", _GRID)
    def test_packed_scan_equivalence(width, sel, nodes, impl, kind):
        _check_packed_scan_equivalence(width, sel, nodes, impl, kind,
                                       seed=width * 1000 + nodes)


# -- driver: packed residency is the default and matches raw -----------------

@pytest.fixture(scope="module")
def raw_driver(cluster):
    from repro.tpch.driver import TPCHDriver

    return TPCHDriver(sf=0.01, cluster=cluster, seed=0, storage="raw")


def test_packed_driver_matches_raw_and_oracle(tpch_driver, raw_driver):
    import jax

    assert tpch_driver.storage == "packed" and raw_driver.storage == "raw"
    # hand-written plan path: packed tables decode at plan entry
    out_p = jax.tree.map(np.asarray, tpch_driver.run("q1"))
    out_r = jax.tree.map(np.asarray, raw_driver.run("q1"))
    jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-6),
                 out_p, out_r)
    np.testing.assert_allclose(out_p, tpch_driver.oracle("q1"), rtol=2e-4)
    # lowered IR path: the filter runs predicate-on-packed on the packed
    # driver and eval_expr on the raw one — results must agree
    a = jax.tree.map(np.asarray, tpch_driver.query("q6").value)
    b = jax.tree.map(np.asarray, raw_driver.query("q6").value)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-6),
                 a, b)


def test_packed_residency_shrinks_footprint(tpch_driver, raw_driver):
    assert tpch_driver.resident_bytes < raw_driver.resident_bytes
    # the decoded host views stay bit-identical to the raw generation
    for tname, rt in raw_driver.tables.items():
        pt = tpch_driver.tables[tname]
        for cname, col in rt.columns.items():
            np.testing.assert_array_equal(
                np.asarray(pt.columns[cname]), np.asarray(col),
                err_msg=f"{tname}.{cname}")


def test_storage_metrics_and_explain(tpch_driver):
    m = tpch_driver.obs.metrics
    assert m.value("storage.bytes_resident") == tpch_driver.resident_bytes
    assert m.value("storage.bytes_resident.lineitem") > 0
    before = m.value("storage.bytes_scanned")
    prep = tpch_driver.prepare("q6")
    prep.execute()
    assert m.value("storage.bytes_scanned") > before
    assert m.value("storage.bytes_scanned.lineitem") > 0
    txt = tpch_driver.explain("q6").text()
    assert "packed" in txt and "scan l_" in txt
    txt = tpch_driver.explain_analyze("q6").text()
    assert "storage: resident" in txt and "scanned (cumulative)" in txt


def test_resident_budget_guard(cluster):
    from repro.tpch.driver import ResidentBudgetError, TPCHDriver

    with pytest.raises(ResidentBudgetError, match="resident"):
        TPCHDriver(sf=0.01, cluster=cluster, seed=0, resident_budget=1024)
