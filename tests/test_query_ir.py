"""Query IR: builder/validation semantics, typed negative paths, and
lowered-plan correctness vs the numpy oracles (q1/q4/q6/q18 plus the
semi-join shape with §3.2.2-derived capacities)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.query import (
    Bin,
    C,
    IRValidationError,
    LoweringError,
    Q,
    UnknownPlanError,
    conjuncts,
    same_expr,
)
from repro.tpch import queries as tq
from repro.tpch.schema import DEFAULT_PARAMS as DP


def _np(tree):
    import jax

    return jax.tree.map(lambda x: np.asarray(x), tree)


# ---------------------------------------------------------------------------
# expression algebra (host-side, no cluster needed)
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_expr_structural_equality():
    a = C("l_extendedprice") * (1.0 - C("l_discount"))
    b = C("l_extendedprice") * (1.0 - C("l_discount"))
    assert same_expr(a, b)
    assert not same_expr(a, C("l_extendedprice") * (1.0 + C("l_discount")))
    assert same_expr(tq.REVENUE, tq.REVENUE)


@pytest.mark.tier1
def test_conjunct_flattening():
    pred = (C("a") >= 1) & (C("a") < 2) & (C("b") == 3)
    assert len(conjuncts(pred)) == 3


@pytest.mark.tier1
def test_bin_cardinality_inferred():
    q = Q.scan("lineitem").group_agg(
        keys=[("m", Bin(C("l_shipdate"), (10, 20, 30)))],
        aggs=[("n", "count")],
    )
    assert q.root.keys[0].cardinality == 4


# ---------------------------------------------------------------------------
# typed negative paths (satellite contract: never a bare KeyError/TypeError)
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_duplicate_group_key_names_rejected(tpch_driver):
    q = Q.scan("lineitem").group_agg(
        keys=[("k", C("l_returnflag"), 3), ("k", C("l_linestatus"), 2)],
        aggs=[("n", "count")],
    )
    with pytest.raises(IRValidationError, match="duplicate"):
        tpch_driver.compile_query(q)


@pytest.mark.tier1
def test_self_shadowing_projection_terminates():
    """Substituting a projection that shadows its own input (x = x*0+50)
    must not recurse forever."""
    from repro.query import Col, substitute

    e = substitute(Col("x"), {"x": Col("x") * 0 + 50})
    # inner x stays a bare column reference
    assert e.op == "+" and e.lhs.lhs.name == "x"


@pytest.mark.tier1
def test_unknown_plan_name_is_typed(tpch_driver):
    with pytest.raises(UnknownPlanError, match="q99"):
        tpch_driver.run("q99")
    with pytest.raises(UnknownPlanError):
        tpch_driver.oracle("q99")
    with pytest.raises(UnknownPlanError):
        tpch_driver.query("q99")


@pytest.mark.tier1
def test_unknown_table(tpch_driver):
    q = Q.scan("no_such_table").group_agg(aggs=[("n", "count")])
    with pytest.raises(IRValidationError, match="no_such_table"):
        tpch_driver.compile_query(q)


@pytest.mark.tier1
def test_unbound_column_in_aggregate(tpch_driver):
    q = Q.scan("lineitem").group_agg(
        keys=[("returnflag", C("l_returnflag"), 3)],
        aggs=[("s", "sum", C("l_nonexistent"))],
    )
    with pytest.raises(IRValidationError, match="l_nonexistent"):
        tpch_driver.compile_query(q)


@pytest.mark.tier1
def test_unbound_column_in_filter(tpch_driver):
    q = (Q.scan("orders").filter(C("bogus") > 0)
         .group_agg(aggs=[("n", "count")]))
    with pytest.raises(IRValidationError, match="bogus"):
        tpch_driver.compile_query(q)


@pytest.mark.tier1
def test_semijoin_on_replicated_table(tpch_driver):
    """nation is replicated, not partitioned — a semi-join against it is a
    modelling error the validator names precisely."""
    q = (Q.scan("customer")
         .semijoin("nation", key=C("c_nationkey"), pred=C("n_regionkey") == 2)
         .group_agg(aggs=[("n", "count")]))
    with pytest.raises(IRValidationError, match="replicated"):
        tpch_driver.compile_query(q)


@pytest.mark.tier1
def test_exists_needs_copartitioning(tpch_driver):
    q = (Q.scan("orders")
         .exists("customer", key="c_custkey", pred=C("c_acctbal") > 0)
         .group_agg(aggs=[("n", "count")]))
    with pytest.raises(IRValidationError, match="co-partitioned"):
        tpch_driver.compile_query(q)


@pytest.mark.tier1
def test_minmax_lowering_refused(tpch_driver):
    q = Q.scan("orders").group_agg(
        keys=[("orderstatus", C("o_orderstatus"), 3)],
        aggs=[("m", "min", C("o_totalprice"))],
    )
    with pytest.raises(LoweringError, match="min/max"):
        tpch_driver.compile_query(q)


@pytest.mark.tier1
def test_bare_filter_root_refused(tpch_driver):
    q = Q.scan("lineitem").filter(C("l_quantity") > 0)
    with pytest.raises(LoweringError, match="root"):
        tpch_driver.compile_query(q)


# ---------------------------------------------------------------------------
# lowered plans vs the oracles (single SPMD executables)
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_lowered_q1_matches_oracle(tpch_driver):
    out = _np(tpch_driver.run_ir("q1"))
    assert "overflow" not in out  # no exchange in the lowered plan
    np.testing.assert_allclose(out["value"], tpch_driver.oracle("q1"),
                               rtol=2e-4)


@pytest.mark.tier1
def test_lowered_q1_kernel_matches_oracle(tpch_driver):
    """method='kernel' lowers the filter INTO the fused Pallas grouped-agg
    kernel (interpret mode on CPU)."""
    out = _np(tpch_driver.run_ir("q1_kernel"))
    np.testing.assert_allclose(out["value"], tpch_driver.oracle("q1"),
                               rtol=2e-4)


@pytest.mark.tier1
def test_lowered_q6_matches_oracle(tpch_driver):
    out = _np(tpch_driver.run_ir("q6"))
    np.testing.assert_allclose(out["value"].reshape(()),
                               tpch_driver.oracle("q6"), rtol=2e-4)


def test_hand_q6_matches_oracle(tpch_driver):
    np.testing.assert_allclose(np.asarray(tpch_driver.run("q6")),
                               tpch_driver.oracle("q6"), rtol=2e-4)


def test_lowered_q4_matches_oracle(tpch_driver):
    out = _np(tpch_driver.run_ir("q4"))
    np.testing.assert_allclose(out["value"][:, 0], tpch_driver.oracle("q4"),
                               rtol=0)


def test_lowered_q18_matches_oracle(tpch_driver):
    out = _np(tpch_driver.run_ir("q18"))
    ov, ok = tpch_driver.oracle("q18")
    n = int(out["valid"].sum())
    assert n == int(np.isfinite(ov).sum())
    np.testing.assert_allclose(out["values"][:n], ov[:n], rtol=2e-3, atol=1e-2)
    np.testing.assert_array_equal(out["keys"][:n], ok[:n])


def test_lowered_topk_late_materialization(tpch_driver):
    """A q18-shaped query with a lower threshold so winners exist: values,
    keys and all late-materialized attributes must match numpy."""
    from repro.query import Fetch

    thresh = 220.0
    q = (Q.scan("lineitem")
         .group_by_key(C("l_orderkey"), into="orders",
                       aggs=[("sum_qty", "sum", C("l_quantity"))])
         .filter(C("sum_qty") > thresh)
         .top_k(value=C("o_totalprice"), k=20,
                fetch=(Fetch("o_custkey"), Fetch("sum_qty"),
                       Fetch("c_name_code", table="customer",
                             key="o_custkey"))))
    out = _np(tpch_driver.compile_query(q)(
        {n: t.columns for n, t in tpch_driver.placed.items()}))
    orders = tpch_driver.tables["orders"].columns
    li = tpch_driver.tables["lineitem"].columns
    cust = tpch_driver.tables["customer"].columns
    qty = np.zeros(orders["o_orderkey"].shape[0])
    np.add.at(qty, li["l_orderkey"], li["l_quantity"].astype(np.float64))
    sel = qty > thresh
    vals = orders["o_totalprice"].astype(np.float64)[sel]
    keys = orders["o_orderkey"][sel]
    order = np.lexsort((keys, -vals))[:20]
    n = int(out["valid"].sum())
    assert n == len(order) or n == 20
    np.testing.assert_allclose(out["values"][:n], vals[order][:n], rtol=2e-3)
    np.testing.assert_array_equal(out["keys"][:n], keys[order][:n])
    k = out["keys"][:n]
    np.testing.assert_array_equal(out["o_custkey"][:n], orders["o_custkey"][k])
    np.testing.assert_array_equal(
        out["c_name_code"][:n], cust["c_name_code"][orders["o_custkey"][k]])
    np.testing.assert_allclose(out["sum_qty"][:n], qty[k], rtol=1e-5)


@pytest.mark.parametrize("alt", ["auto", "request", "bitset"])
def test_lowered_semijoin_alternatives(tpch_driver, alt):
    """The Q14 semi-join shape through every physical alternative: the
    cost-model choice, the forced Alt-1 request exchange (capacity from the
    selectivity model) and the forced Alt-2 bitset all agree with the
    oracle's promo revenue."""
    q = tq.q14_promo_ir(alt=alt)
    out = _np(tpch_driver.compile_query(q)(
        {n: t.columns for n, t in tpch_driver.placed.items()}))
    # the overflow flag exists iff the plan contains a request exchange
    assert not out.get("overflow", False), f"derived capacity overflowed ({alt})"
    ref = tpch_driver.oracle("q14")[1]  # promo_rev component
    np.testing.assert_allclose(out["value"].reshape(()), ref, rtol=2e-4)


def test_semijoin_capacity_override_reaches_lowered_plan(cluster):
    """An explicit capacity override (key '<name>_sj<i>') must reach the
    lowered request exchange: an absurdly small override forces the
    overflow flag that the derived capacity avoids."""
    from repro.tpch.driver import TPCHDriver

    d = TPCHDriver(sf=0.01, cluster=cluster, seed=0,
                   capacities={"q14_promo_request_sj0": 1})
    q = tq.q14_promo_ir(alt="request")
    out = _np(d.compile_query(q)(
        {n: t.columns for n, t in d.placed.items()}))
    assert out["overflow"], "1-slot override should overflow"


def test_registry_oracle_bindings_are_explicit():
    """Multi-suffix variants bind their oracle explicitly (the old
    name.split('_')[0] munging would break on names like q14_promo)."""
    from repro.core import plans as plan_registry

    assert plan_registry.get("q15_1factor").oracle == "q15"
    assert plan_registry.get("q21_late").oracle == "q21"
    assert plan_registry.get("q14_promo").oracle is None
    assert plan_registry.get("q3_lazy").oracle == "q3"
