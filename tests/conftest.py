"""Shared test fixtures.

The distribution tests need a multi-device mesh to exercise the collective
schedules, so we ask XLA for 8 host platform devices BEFORE jax initializes.
This is deliberately 8 (a small cluster, fast compiles) and NOT the 512-way
production mesh — the 512-device placeholder config is reserved for
``launch/dryrun.py`` per the project brief.  Arch smoke tests ignore the
extra devices (their arrays live on device 0).
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def cluster():
    from repro.core import Cluster

    return Cluster()


@pytest.fixture(scope="session")
def tpch_driver(cluster):
    """Small deterministic TPC-H instance shared by correctness tests."""
    from repro.tpch.driver import TPCHDriver

    return TPCHDriver(sf=0.01, cluster=cluster, seed=0)


@pytest.fixture(scope="session")
def tpch_driver_seed1(cluster):
    from repro.tpch.driver import TPCHDriver

    return TPCHDriver(sf=0.02, cluster=cluster, seed=1)


def assert_topk_matches(values, keys, valid, oracle_values, oracle_keys,
                        rtol=2e-3, atol=1e-2):
    """Compare a plan TopK (values desc, key asc ties) against the float64
    numpy oracle.  Positionwise value check + key-set check with tolerance
    for rank flips between near-equal float32/float64 aggregates."""
    values = np.asarray(values, np.float64)
    keys = np.asarray(keys, np.int64)
    valid = np.asarray(valid, bool)
    n_valid = int(valid.sum())
    ov = np.asarray(oracle_values, np.float64)
    ok = np.asarray(oracle_keys, np.int64)
    o_valid = np.isfinite(ov)
    n_oracle = int(o_valid.sum())
    # the plan may be capped below the oracle's k on tiny data; compare the
    # overlapping prefix
    n = min(n_valid, n_oracle) if len(values) != len(ov) else max(n_valid, n_oracle)
    assert n_valid >= min(n, n_oracle), (
        f"plan found {n_valid} rows, oracle {n_oracle}"
    )
    pv, pk = values[:n], keys[:n]
    qv, qk = ov[:n], ok[:n]
    np.testing.assert_allclose(pv, qv, rtol=rtol, atol=atol)
    mismatched = pk != qk
    if mismatched.any():
        # allow key mismatches only where the values tie within tolerance
        tied = np.isclose(pv, qv, rtol=rtol, atol=atol)
        assert (mismatched <= tied).all(), (
            f"key mismatch outside value ties:\nplan {list(zip(pk, pv))}\n"
            f"oracle {list(zip(qk, qv))}"
        )
        # and the key multisets must still agree on the tied region
        assert sorted(pk.tolist()) == sorted(qk.tolist()) or np.allclose(
            np.sort(pv), np.sort(qv), rtol=rtol, atol=atol
        )
