"""Launch-layer integration: cell lowering on the scaled-down CI mesh —
train/prefill/decode kinds, the decode-optimized layout, skip rules, and
roofline-term sanity."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs.registry import SHAPES, cell_runnable, get_arch
from repro.launch.cells import (choose_decode_layout, pick_microbatches,
                                run_cell)
from repro.launch.mesh import make_test_mesh
from repro.launch.roofline import parse_collective_bytes, shape_bytes


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh()  # (4, 2) data x model on 8 host devices


def _check(res):
    assert res.error == "", res.error
    r = res.roofline
    assert r["compute_s"] > 0 or r["memory_s"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert 0 < r["useful_flops_ratio"] < 2.0
    return r


def test_train_cell(mesh):
    r = _check(run_cell("qwen2.5-3b", "train_4k", mesh, "ci"))
    # train at 8 chips: compute term must dominate collective
    assert r["compute_s"] > r["collective_s"]


def test_train_cell_flash(mesh):
    base = _check(run_cell("qwen2.5-3b", "train_4k", mesh, "ci"))
    opt = _check(run_cell("qwen2.5-3b", "train_4k", mesh, "ci",
                          fwd_kw={"attn_impl": "flash"}))
    assert opt["memory_s"] < base["memory_s"], "flash must cut HBM traffic"
    assert opt["roofline_fraction"] > base["roofline_fraction"]


def test_prefill_cell(mesh):
    _check(run_cell("whisper-medium", "prefill_32k", mesh, "ci"))


def test_decode_cell(mesh):
    _check(run_cell("mamba2-2.7b", "long_500k", mesh, "ci"))


def test_decode_opt_layout_rules():
    cfg = get_arch("qwen3-moe-30b-a3b")
    mesh_shape, kv_shard, model_b = choose_decode_layout(
        cfg, SHAPES["decode_32k"], chips=256)
    assert mesh_shape == (16, 4, 4)
    cfgp = get_arch("paligemma-3b")
    mesh_shape, kv_shard, model_b = choose_decode_layout(
        cfgp, SHAPES["decode_32k"], chips=256)
    assert kv_shard == 2  # MQA: kv=1 padded to 2, not 16
    assert cfgp.padded_heads(16, kv_shard) == (16, 2)
    # batch always divides the dp shards
    assert SHAPES["decode_32k"].global_batch % (16 * model_b) == 0


def test_skip_rule():
    cfg = get_arch("yi-34b")
    ok, why = cell_runnable(cfg, SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in why
    ok, _ = cell_runnable(get_arch("mamba2-2.7b"), SHAPES["long_500k"])
    assert ok


def test_pick_microbatches(mesh):
    mb = pick_microbatches(get_arch("yi-34b"), SHAPES["train_4k"], mesh)
    assert SHAPES["train_4k"].global_batch % mb == 0
    assert mb >= 1


def test_hlo_shape_parser():
    assert shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert shape_bytes("(bf16[2,4]{1,0}, s8[16]{0})") == 16 + 16
    text = """
  %p0 = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p0), replica_groups={}
  %ag.1 = f32[128]{0} all-gather(%p0), dimensions={0}
"""
    stats = parse_collective_bytes(text)
    assert stats.bytes_by_op["all-reduce"] == 256
    assert stats.bytes_by_op["all-gather"] == 256  # operand bytes
