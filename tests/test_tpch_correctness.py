"""Every distributed plan vs the float64 numpy oracle (paper §4.1: "we check
the query results for correctness").  Runs on the 8-device CPU cluster."""
from __future__ import annotations

import numpy as np
import pytest

from conftest import assert_topk_matches


def _np(tree):
    import jax

    return jax.tree.map(lambda x: np.asarray(x), tree)


# ---------------------------------------------------------------------------
# local-only queries (Q1, Q4) — exact aggregates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plan", ["q1", "q1_kernel"])
def test_q1(tpch_driver, plan):
    out = _np(tpch_driver.run(plan))
    ref = tpch_driver.oracle("q1")
    np.testing.assert_allclose(out, ref, rtol=2e-4)


def test_q4(tpch_driver):
    out = _np(tpch_driver.run("q4"))
    ref = tpch_driver.oracle("q4")
    np.testing.assert_allclose(out, ref, rtol=0)


# ---------------------------------------------------------------------------
# semi-join queries
# ---------------------------------------------------------------------------


def test_q2(tpch_driver):
    out = _np(tpch_driver.run("q2"))
    assert not out["overflow"]
    ov, ok = tpch_driver.oracle("q2")
    assert_topk_matches(out["s_acctbal"], out["part_supp_key"], out["valid"], ov, ok)


@pytest.mark.parametrize("plan", ["q3", "q3_lazy", "q3_repl"])
def test_q3_variants(tpch_driver, plan):
    out = _np(tpch_driver.run(plan))
    topk = out[0] if isinstance(out, (tuple, list)) and not hasattr(out, "values") else out
    if hasattr(topk, "values"):
        v, k, m = topk.values, topk.keys, topk.valid
    else:
        v, k, m = topk[0], topk[1], topk[2]
    ov, ok = tpch_driver.oracle("q3")
    assert_topk_matches(v, k, m, ov, ok)


def test_q5(tpch_driver):
    rev, ovf = _np(tpch_driver.run("q5"))
    assert not ovf
    ref = tpch_driver.oracle("q5")
    np.testing.assert_allclose(rev, ref, rtol=2e-4, atol=1e-2)


def test_q11(tpch_driver):
    out = _np(tpch_driver.run("q11"))
    v, k, m = out[0], out[1], out[2]
    ov, ok = tpch_driver.oracle("q11")
    assert_topk_matches(v, k, m, ov, ok)


def test_q13(tpch_driver):
    hist, ovf = _np(tpch_driver.run("q13"))
    assert not ovf
    ref = tpch_driver.oracle("q13")
    np.testing.assert_allclose(hist, ref, rtol=0)


def test_q14(tpch_driver):
    out, ovf = _np(tpch_driver.run("q14"))
    assert not ovf
    ref = tpch_driver.oracle("q14")
    np.testing.assert_allclose(out, ref, rtol=2e-4)


# ---------------------------------------------------------------------------
# distributed top-k queries (Q15 variants, Q18, Q21 variants)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("plan", ["q15", "q15_1factor", "q15_approx"])
def test_q15_variants(tpch_driver, plan):
    out = _np(tpch_driver.run(plan))
    if "overflow" in out:
        assert not out["overflow"]
    ov, ok = tpch_driver.oracle("q15")
    assert_topk_matches(out["total_revenue"], out["s_suppkey"], out["valid"], ov, ok)
    # late materialization correctness: s_name_code == s_suppkey by generator
    # construction, so the fetched attribute must equal the winning key
    n = int(np.asarray(out["valid"]).sum())
    np.testing.assert_array_equal(
        np.asarray(out["s_name_code"])[:n], np.asarray(out["s_suppkey"])[:n]
    )


def test_q15_approx_saves_traffic(tpch_driver):
    out = _np(tpch_driver.run("q15_approx"))
    stats = out["stats"]
    assert float(stats.approx_bits_per_node) < float(stats.naive_bits_per_node)


def test_q18(tpch_driver):
    out = _np(tpch_driver.run("q18"))
    ov, ok = tpch_driver.oracle("q18")
    assert_topk_matches(out["o_totalprice"], out["o_orderkey"], out["valid"], ov, ok)
    # late-materialized attributes must match the global table row for the key
    orders = tpch_driver.tables["orders"].columns
    cust = tpch_driver.tables["customer"].columns
    n = int(np.asarray(out["valid"]).sum())
    keys = np.asarray(out["o_orderkey"])[:n]
    np.testing.assert_array_equal(
        np.asarray(out["o_custkey"])[:n], orders["o_custkey"][keys]
    )
    np.testing.assert_array_equal(
        np.asarray(out["o_orderdate"])[:n], orders["o_orderdate"][keys]
    )
    np.testing.assert_array_equal(
        np.asarray(out["c_name_code"])[:n],
        cust["c_name_code"][orders["o_custkey"][keys]],
    )


@pytest.mark.parametrize("plan", ["q21", "q21_late"])
def test_q21_variants(tpch_driver, plan):
    out = _np(tpch_driver.run(plan))
    if plan == "q21_late":
        topk, ovf = out
        assert not ovf
    else:
        topk = out
    v, k, m = (topk.values, topk.keys, topk.valid) if hasattr(topk, "values") else (
        topk[0], topk[1], topk[2])
    ov, ok = tpch_driver.oracle("q21")
    assert_topk_matches(v, k, m, ov, ok, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# robustness: different seed/SF + the 1-factor backend end to end
# ---------------------------------------------------------------------------

CHECKED = ["q1", "q2", "q3", "q4", "q5", "q13", "q14", "q18"]


@pytest.mark.parametrize("plan", CHECKED)
def test_second_instance(tpch_driver_seed1, plan):
    d = tpch_driver_seed1
    out = _np(d.run(plan))
    if plan == "q1":
        np.testing.assert_allclose(out, d.oracle("q1"), rtol=2e-4)
    elif plan == "q4":
        np.testing.assert_allclose(out, d.oracle("q4"), rtol=0)
    elif plan in ("q5",):
        np.testing.assert_allclose(out[0], d.oracle("q5"), rtol=2e-4, atol=1e-2)
    elif plan == "q13":
        np.testing.assert_allclose(out[0], d.oracle("q13"), rtol=0)
    elif plan == "q14":
        np.testing.assert_allclose(out[0], d.oracle("q14"), rtol=2e-4)
    elif plan == "q2":
        ov, ok = d.oracle("q2")
        assert_topk_matches(out["s_acctbal"], out["part_supp_key"], out["valid"], ov, ok)
    elif plan == "q3":
        ov, ok = d.oracle("q3")
        assert_topk_matches(out.values, out.keys, out.valid, ov, ok)
    elif plan == "q18":
        ov, ok = d.oracle("q18")
        assert_topk_matches(out["o_totalprice"], out["o_orderkey"], out["valid"], ov, ok)


def test_one_factor_backend_end_to_end(cluster):
    """Full driver with backend='one_factor': the §3.2.6 schedule must be a
    drop-in replacement for the library all-to-all."""
    from repro.tpch.driver import TPCHDriver

    d = TPCHDriver(sf=0.01, cluster=cluster, seed=0, backend="one_factor")
    for plan, check in [("q14", "q14"), ("q15", "q15")]:
        out = _np(d.run(plan))
        if plan == "q14":
            np.testing.assert_allclose(out[0], d.oracle("q14"), rtol=2e-4)
        else:
            ov, ok = d.oracle("q15")
            assert_topk_matches(out["total_revenue"], out["s_suppkey"], out["valid"], ov, ok)
