"""Observability layer: metrics registry math, structured traces, and
EXPLAIN ANALYZE.

- histogram percentiles on fixed distributions with known quantiles (the
  log-bucket scheme guarantees ~2.2% relative error),
- span nesting + Chrome-trace export round-trip (valid trace-event JSON
  with complete/instant phases — the shape Perfetto loads), and a sample
  trace artifact written for CI,
- ``explain_analyze`` golden checks on q6 (predicted plan fields next to
  observed timings/counters) and on a Tier-1 cube-served query,
- per-semijoin all-to-all attribution against synthetic instruction
  streams,
- routing/caching/overflow counters emitted by the driver paths, and the
  serving-layer trimmed-median/p99 statistics.
"""
from __future__ import annotations

import dataclasses
import json

import pytest

from repro.launch.roofline import CollectiveInstr
from repro.obs import (
    Histogram,
    MetricsRegistry,
    Observer,
    SemiJoinInfo,
    attribute_semijoin_bytes,
)
from repro.query import Q, C

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_histogram_percentiles_uniform():
    h = Histogram("t")
    for v in range(1, 1001):  # uniform 1..1000
        h.record(float(v))
    assert h.count == 1000
    # log-bucketing guarantees ~2.2% relative error; allow 5% headroom
    assert h.quantile(0.50) == pytest.approx(500, rel=0.05)
    assert h.quantile(0.95) == pytest.approx(950, rel=0.05)
    assert h.quantile(0.99) == pytest.approx(990, rel=0.05)
    assert h.quantile(0.0) == pytest.approx(1, rel=0.05)
    assert h.quantile(1.0) == 1000  # clamped to observed max


def test_histogram_bimodal_and_zeros():
    h = Histogram("t")
    for _ in range(50):
        h.record(1.0)
    for _ in range(50):
        h.record(1000.0)
    assert h.quantile(0.25) == pytest.approx(1.0, rel=0.05)
    assert h.quantile(0.75) == pytest.approx(1000.0, rel=0.05)
    z = Histogram("z")
    for _ in range(90):
        z.record(0.0)
    for _ in range(10):
        z.record(100.0)
    assert z.quantile(0.5) == 0.0
    assert z.quantile(0.95) == pytest.approx(100.0, rel=0.05)
    s = z.snapshot()
    assert s["count"] == 100 and s["max"] == 100.0


def test_registry_counters_gauges_and_report():
    reg = MetricsRegistry()
    reg.counter("a.hits").inc()
    reg.counter("a.hits").inc(4)
    reg.gauge("a.size").set(7)
    reg.histogram("a.lat").record(3.0)
    assert reg.value("a.hits") == 5
    assert reg.value("never.touched") == 0
    snap = reg.snapshot()
    assert snap["a.hits"] == 5 and snap["a.size"] == 7.0
    assert snap["a.lat"]["count"] == 1
    report = reg.report()
    assert "a.hits" in report and "p99" in report
    with pytest.raises(TypeError):
        reg.gauge("a.hits")  # type collision is a bug, not a silent rebind


# ---------------------------------------------------------------------------
# trace layer
# ---------------------------------------------------------------------------


def test_span_nesting_and_chrome_export_roundtrip(tmp_path):
    obs = Observer()
    with obs.span("query", source="qX") as sp:
        sp.set(tier=2)
        with obs.span("route", cat="route"):
            pass
        obs.event("xla.trace", cat="plan", label="qX")
    roots = list(obs.spans)
    assert len(roots) == 1
    root = roots[0]
    assert [c.name for c in root.children] == ["route", "xla.trace"]
    assert root.attrs["tier"] == 2
    assert root.dur >= root.children[0].dur >= 0

    path = obs.save_chrome_trace(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())  # round-trip through disk
    events = doc["traceEvents"]
    assert {e["name"] for e in events} == {"query", "route", "xla.trace"}
    for e in events:
        assert e["ph"] in ("X", "i")
        assert isinstance(e["ts"], float) and "pid" in e and "tid" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
    q = next(e for e in events if e["name"] == "query")
    r = next(e for e in events if e["name"] == "route")
    assert q["ts"] <= r["ts"] <= q["ts"] + q["dur"]  # child inside parent
    assert q["args"]["tier"] == 2


def test_disabled_observer_swallows_spans_keeps_metrics():
    obs = Observer(enabled=False)
    with obs.span("query") as sp:
        sp.set(tier=1)
        obs.event("nested")
    assert len(obs.spans) == 0
    obs.metrics.counter("still.live").inc()
    assert obs.metrics.value("still.live") == 1


def test_span_records_exception():
    obs = Observer()
    with pytest.raises(ValueError):
        with obs.span("boom"):
            raise ValueError("no")
    assert "ValueError" in obs.last("boom").attrs["error"]


# ---------------------------------------------------------------------------
# per-semijoin byte attribution
# ---------------------------------------------------------------------------


def _sj(alt, wire_kind="packed", index=0):
    return SemiJoinInfo(index=index, table="part", alt=alt, capacity=256,
                        capacity_key="sj", wire_kind=wire_kind, key_bits=11,
                        gamma=0.1)


def _a2a(n, nbytes=100):
    return [CollectiveInstr(name=f"a2a.{i}", kind="all-to-all", bytes=nbytes)
            for i in range(n)]


def test_attribution_packed_and_raw_chunks():
    sjs = [_sj("request", "packed", 0), _sj("bitset", index=1),
           _sj("request", "raw", 2)]
    instrs = ([CollectiveInstr("ar", "all-reduce", 999)]  # non-a2a: ignored
              + _a2a(5))
    assert attribute_semijoin_bytes(instrs, sjs)
    assert sjs[0].a2a_bytes == 200 and sjs[0].a2a_count == 2
    assert sjs[1].a2a_bytes is None  # bitset semi-join owns no all-to-all
    assert sjs[2].a2a_bytes == 300 and sjs[2].a2a_count == 3


def test_attribution_refuses_count_mismatch():
    sjs = [_sj("request", "packed")]
    assert not attribute_semijoin_bytes(_a2a(3), sjs)  # packed expects 2
    assert sjs[0].a2a_bytes is None  # untouched — totals-only fallback


# ---------------------------------------------------------------------------
# serving statistics
# ---------------------------------------------------------------------------


def test_trimmed_median_and_p99():
    from repro.cube.serving import _p99, _trimmed_median

    # an outlier that min-of-N would hide and a mean would absorb
    xs = [1.0] * 9 + [100.0]
    assert _trimmed_median(xs) == 1.0
    assert _p99(xs) == 100.0
    assert _trimmed_median([3.0, 1.0, 2.0]) == 2.0  # n<5: no trim


# ---------------------------------------------------------------------------
# EXPLAIN / EXPLAIN ANALYZE against the real driver
# ---------------------------------------------------------------------------


def test_explain_is_static(tpch_driver):
    ev0 = len(tpch_driver.compile_events)
    rep = tpch_driver.explain("q6")
    assert not rep.analyzed
    assert len(tpch_driver.compile_events) == ev0  # nothing compiled
    text = rep.text()
    assert text.startswith("EXPLAIN q6")
    assert "Scan[lineitem" in text and "Filter[" in text
    assert "parameters:" in text


def test_explain_analyze_q6_golden(tpch_driver):
    rep = tpch_driver.explain_analyze("q6")
    assert rep.analyzed
    obs = rep.observed
    # predicted side: plan rows with selectivities, auto-extracted params
    assert [r["op"] for r in rep.plan_rows] == ["Scan", "Filter", "GroupAgg"]
    assert 0.0 < rep.plan_rows[1]["sel"] <= 1.0
    assert rep.params and all(k.startswith("_p") for k in rep.params)
    assert rep.cache in ("hit", "miss")
    # observed side: tier, timings, counters
    assert obs["tier"] == 2 and obs["source"] == "q6"
    assert obs["execute_ms"] > 0.0
    assert (obs["compile_ms"] is not None) == (obs["xla_traces"] > 0)
    assert obs["overflow"] is False
    assert "overflow_count" in obs and "compile_events" in obs
    # tier-2 plans carry the HLO collective profile
    assert obs["collective_bytes_by_op"]
    text = rep.text()
    assert "EXPLAIN ANALYZE q6" in text
    assert "route: tier 2" in text
    assert "timings:" in text and "collectives/device:" in text
    assert "exchange.overflow=" in text and "plan.compile_events=" in text


def test_explain_analyze_fresh_shape_reports_compile_time(tpch_driver):
    # a shape no other test prepares: the first execution must trace, so
    # compile vs execute time separate
    q = (Q.scan("lineitem")
         .filter((C("l_quantity") < 7.0) & (C("l_tax") >= 0.0)
                 & (C("l_discount") > 0.001))
         .group_agg(keys=(), aggs=[("obs_rev", "sum",
                                    C("l_extendedprice") * C("l_discount"))])
         .named("obs_fresh"))
    rep = tpch_driver.explain_analyze(q)
    obs = rep.observed
    assert obs["xla_traces"] >= 1
    assert obs["compile_ms"] is not None and obs["compile_ms"] >= 0.0
    assert obs["execute_ms"] > 0.0
    assert "XLA trace" in rep.text()


def test_explain_analyze_all_ir_queries(tpch_driver):
    """Acceptance sweep: every registered IR query explains with route
    tier, cache state, timings, and (tier 2) per-op collective bytes."""
    for name in ("q1", "q4", "q6", "q14_promo", "q18"):
        rep = tpch_driver.explain_analyze(name)
        assert rep.analyzed, name
        obs = rep.observed
        assert obs["tier"] in (1, 2), name
        assert obs["execute_ms"] > 0.0, name
        assert rep.plan_rows, name
        if obs["tier"] == 2:
            assert obs["collective_bytes_by_op"], name
        text = rep.text()
        assert f"EXPLAIN ANALYZE {name}" in text
        assert "plan cache" in text


def test_explain_analyze_tier1_route(tpch_driver):
    if tpch_driver.router is None:
        tpch_driver.build_cubes()
    rep = tpch_driver.explain_analyze("q1")
    assert rep.observed["tier"] == 1
    assert rep.observed["compile_ms"] is None  # cube slice, nothing compiled
    assert "rollup cube" in rep.text()


def test_driver_counters_and_spans(tpch_driver):
    d = tpch_driver
    if d.router is None:
        d.build_cubes()
    m = d.obs.metrics
    t1, t2 = m.value("driver.tier1"), m.value("driver.tier2")
    hits = m.value("plan_cache.hit")
    d.query("q1")   # cube-served
    d.query("q6")   # compiled plan
    d.query("q6")   # same shape again -> structural cache hit
    assert m.value("driver.tier1") == t1 + 1
    assert m.value("driver.tier2") == t2 + 2
    assert m.value("plan_cache.hit") >= hits + 1
    assert m.value("router.match") >= 1
    # spans: the last tier-2 query recorded a query->route(+execute) tree
    span = d.obs.last("query")
    assert span is not None and span.attrs["tier"] == 2
    assert span.find("route")
    # latency histograms feed the p99 gates
    assert m.histogram("query.tier2_us").count >= 2


def test_sample_trace_artifact(tpch_driver):
    """Write the CI trace artifact (uploaded by the workflow) and check it
    is a loadable Chrome trace with driver spans in it."""
    tpch_driver.query("q6")
    path = tpch_driver.obs.save_chrome_trace(
        "experiments/trace/sample_trace.json")
    doc = json.loads(open(path).read())
    names = {e["name"] for e in doc["traceEvents"]}
    assert "query" in names and "route" in names
    assert all(set(e) >= {"name", "ph", "ts", "pid", "tid"}
               for e in doc["traceEvents"])


def test_semijoin_info_describes_roofline_prediction():
    info = SemiJoinInfo(index=0, table="orders", alt="request", capacity=4096,
                        capacity_key="sj", wire_kind="packed", key_bits=12,
                        gamma=0.2, codec_ms=0.143, wire_ms=0.674)
    s = info.describe()
    assert "predict codec 0.143ms+wire 0.674ms" in s
    # without a prediction (or on a local semi-join) the line is unchanged
    assert "predict" not in dataclasses.replace(info, codec_ms=None).describe()
    assert "predict" not in dataclasses.replace(info, alt="local").describe()


def test_explain_text_renders_codec_histograms():
    from repro.obs.explain import ExplainReport

    base = dict(query="x", route_tier=2, route_source="x", cache="miss",
                params={})
    obs = {"tier": 2, "source": "x", "execute_ms": 1.0, "compile_ms": None,
           "xla_traces": 0, "overflow": False, "overflow_count": 0,
           "compile_events": 0,
           "exchange.encode_ms": {"count": 3, "mean": 0.07},
           "exchange.decode_ms": {"count": 3, "mean": 0.12}}
    txt = ExplainReport(**base, observed=obs).text()
    assert "codec predicted/exchange: encode mean 0.07 ms (n=3), " \
           "decode mean 0.12 ms (n=3)" in txt
    # absent histograms (raw wire, cached plan): no codec line at all
    obs2 = {k: v for k, v in obs.items() if not k.startswith("exchange.")}
    assert "codec predicted" not in ExplainReport(**base, observed=obs2).text()
