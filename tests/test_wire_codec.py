"""Wire codec (§3.2.1): fixed-width packing, the EF-coded key buckets with
folded masks, packed request/reply + fused owner exchanges vs their raw
twins, the byte-accurate §3.2.2 model, and overflow surfacing."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import compression, exchange, semijoin
from repro.core.exchange import WireFormat
from repro.core.partitioning import RangePartitioning

AXIS = "nodes"


def spmd(cluster, fn, *arrays, replicated_args=()):
    in_specs = tuple(
        P() if i in replicated_args else P(AXIS) for i in range(len(arrays))
    )
    f = jax.jit(
        jax.shard_map(fn, mesh=cluster.mesh, in_specs=in_specs, out_specs=P(),
                      check_vma=False)
    )
    return jax.tree.map(np.asarray, f(*arrays))


# ---------------------------------------------------------------------------
# fixed-width packing: every width, word-straddling lengths, delta fusion
# ---------------------------------------------------------------------------


@pytest.mark.tier1
@pytest.mark.parametrize("width", range(1, 33))
def test_pack_unpack_roundtrip_every_width(width):
    """n=97 values straddle word boundaries for every non-divisor width."""
    rng = np.random.default_rng(width)
    n = 97
    hi = (1 << width) - 1 if width < 32 else 0xFFFFFFFF
    vals = rng.integers(0, hi, n, dtype=np.uint64).astype(np.uint32)
    words = compression.pack_bits(jnp.asarray(vals), width)
    assert words.shape[0] == compression.packed_words(n, width)
    out = compression.unpack_bits(words, n, width)
    np.testing.assert_array_equal(np.asarray(out), vals)


@pytest.mark.tier1
@pytest.mark.parametrize("width", [1, 5, 17, 31])
def test_pack_boundary_values(width):
    """All-zero and all-max inputs at word-straddling widths."""
    n = 65
    hi = (1 << width) - 1
    for vals in (np.zeros(n, np.uint32), np.full(n, hi, np.uint32)):
        words = compression.pack_bits(jnp.asarray(vals), width)
        np.testing.assert_array_equal(
            np.asarray(compression.unpack_bits(words, n, width)), vals)


@pytest.mark.tier1
def test_delta_then_pack_composition():
    """The §3.2.1 pipeline: sorted keys -> deltas -> fixed-width words ->
    unpack -> prefix sum recovers the keys."""
    rng = np.random.default_rng(3)
    keys = np.sort(rng.integers(0, 1 << 20, 500)).astype(np.int32)
    deltas = compression.delta_encode(jnp.asarray(keys))
    width = compression.required_width(int(np.asarray(deltas).max()))
    words = compression.pack_bits(jnp.asarray(deltas).astype(jnp.uint32), width)
    back = compression.delta_decode(
        compression.unpack_bits(words, keys.shape[0], width).astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(back), keys)


# ---------------------------------------------------------------------------
# §3.2.2 cost model: degenerate gammas + byte-accurate wire model
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_alt2_bits_degenerate_gammas():
    m = 10_000
    # gamma <= 0: nothing qualifies, an all-zero bitset carries no info
    assert compression.alt2_bits(m, 0.0) == 0.0
    assert compression.alt2_bits(m, -0.5) == 0.0
    # gamma >= 1: everything qualifies, the m raw bits are still shipped
    assert compression.alt2_bits(m, 1.0) == float(m)
    assert compression.alt2_bits(m, 2.0) == float(m)
    # interior: the information bound, strictly below m around the peak
    mid = compression.alt2_bits(m, 0.5)
    assert 0.0 < mid < m
    # continuity toward the degenerate edges
    assert compression.alt2_bits(m, 1e-9) < 1.0e-3 * m


@pytest.mark.tier1
def test_byte_accurate_wire_model():
    cap, Pn, domain = 1024, 8, 4096
    raw = compression.alt1_wire_bytes(cap, Pn, domain, packed=False)
    packed = compression.alt1_wire_bytes(cap, Pn, domain, packed=True)
    assert raw == (Pn - 1) * cap * 6
    assert packed < raw / 4  # the benchmark's gate, analytically
    # selection crossover: big remote table + tiny request buffer -> Alt-1;
    # tiny remote table -> the bitset allgather is nearly free -> Alt-2
    assert compression.choose_semijoin_wire(
        64, 10_000_000, Pn, domain=10_000_000 // Pn) == 1
    assert compression.choose_semijoin_wire(
        4096, 1_000, Pn, domain=1_000 // Pn) == 2


@pytest.mark.tier1
def test_packed_words_match_codec_output():
    """The cost model and the codec share ef_params — verify the predicted
    word count is EXACTLY the encoded message width."""
    for cap, domain in [(64, 250), (128, 32), (256, 375), (1024, 9375)]:
        wf = WireFormat(kind="packed", domain=domain)
        buckets = jnp.zeros((4, cap), jnp.int32)
        mask = jnp.zeros((4, cap), bool)
        words = exchange.encode_key_buckets(buckets, mask, wf)
        assert words.shape == (4, compression.packed_request_words(cap, domain))


# ---------------------------------------------------------------------------
# EF key-bucket codec roundtrip (host-side, one simulated receiver per row)
# ---------------------------------------------------------------------------


@pytest.mark.tier1
@pytest.mark.parametrize("cap,domain", [(8, 40), (64, 250), (64, 64),
                                        (128, 17), (256, 4096), (100, 1000)])
def test_key_bucket_codec_roundtrip(cap, domain):
    rng = np.random.default_rng(cap + domain)
    Pn = 4
    buckets = np.zeros((Pn, cap), np.int32)
    mask = np.zeros((Pn, cap), bool)
    for d in range(Pn):
        count = int(rng.integers(0, cap + 1))
        # sorted, WITH duplicates (foreign keys repeat), in the dest range
        keys = np.sort(rng.integers(0, domain, count)) + d * domain
        buckets[d, :count] = keys
        mask[d, :count] = True
    wf = WireFormat.packed_for(domain * Pn, Pn)
    words = exchange.encode_key_buckets(
        jnp.asarray(buckets), jnp.asarray(mask), wf)
    for d in range(Pn):
        keys, got_mask = exchange.decode_key_buckets(
            words[d:d + 1], cap, wf, my_base=d * domain)
        np.testing.assert_array_equal(np.asarray(got_mask)[0], mask[d])
        np.testing.assert_array_equal(
            np.asarray(keys)[0][mask[d]], buckets[d][mask[d]])


@pytest.mark.tier1
def test_key_bucket_codec_full_and_empty_rows():
    cap, domain, Pn = 32, 64, 2
    buckets = np.zeros((Pn, cap), np.int32)
    mask = np.zeros((Pn, cap), bool)
    buckets[0] = np.sort(np.arange(cap) * 2)  # full row, strided keys
    mask[0] = True                            # row 1 stays empty
    wf = WireFormat.packed_for(domain * Pn, Pn)
    words = exchange.encode_key_buckets(jnp.asarray(buckets), jnp.asarray(mask), wf)
    k0, m0 = exchange.decode_key_buckets(words[0:1], cap, wf, my_base=0)
    np.testing.assert_array_equal(np.asarray(k0)[0], buckets[0])
    assert np.asarray(m0).all()
    k1, m1 = exchange.decode_key_buckets(words[1:2], cap, wf, my_base=domain)
    assert not np.asarray(m1).any()


# ---------------------------------------------------------------------------
# packed exchanges == raw exchanges, on both collective backends
# ---------------------------------------------------------------------------


def _request_reply_case(cluster, seed=11):
    Pn = cluster.num_nodes
    rows = 32
    total = Pn * rows
    part = RangePartitioning(total, Pn)
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, total, Pn * 48).astype(np.int32)
    mask = rng.random(Pn * 48) < 0.8
    attr = (rng.random(total) < 0.3).astype(np.int32)
    return Pn, part, jnp.asarray(keys), jnp.asarray(mask), jnp.asarray(attr), keys, mask, attr


@pytest.mark.tier1
@pytest.mark.parametrize("backend", ["xla", "one_factor"])
@pytest.mark.parametrize("reply", ["bool", "int32"])
def test_request_reply_packed_equals_raw(cluster, backend, reply):
    Pn, part, k, m, a, keys, mask, attr = _request_reply_case(cluster)
    wf = WireFormat.packed_for(part.total_rows, Pn)
    rdt = jnp.bool_ if reply == "bool" else jnp.int32

    def fn(k_local, m_local, attr_local):
        def lookup(req, req_mask):
            bits = attr_local[part.local_index(req)] == 1
            if reply == "bool":
                return bits & req_mask
            return jnp.where(req_mask & bits, req * 3 + 1, 0)

        outs = []
        for wire in (None, wf):
            rep, ovf = exchange.request_reply(
                k_local, m_local, part.owner(k_local), lookup,
                capacity=128, axis=AXIS, backend=backend,
                reply_dtype=rdt, wire=wire,
            )
            outs.append((jax.lax.all_gather(rep, AXIS, tiled=True), ovf))
        return outs

    (raw, ovf_r), (packed, ovf_p) = spmd(cluster, fn, k, m, a)
    assert not bool(ovf_r) and not bool(ovf_p)
    np.testing.assert_array_equal(packed, raw)
    if reply == "bool":
        np.testing.assert_array_equal(packed, mask & (attr[keys] == 1))
    else:
        np.testing.assert_array_equal(
            packed, np.where(mask & (attr[keys] == 1), keys * 3 + 1, 0))


@pytest.mark.tier1
def test_request_reply_packed_one_factor_equals_xla(cluster):
    """The 1-factor schedule must be payload-agnostic: identical replies on
    the PACKED uint32 wire buffers."""
    Pn, part, k, m, a, *_ = _request_reply_case(cluster, seed=12)
    wf = WireFormat.packed_for(part.total_rows, Pn)

    def fn(k_local, m_local, attr_local):
        def lookup(req, req_mask):
            return (attr_local[part.local_index(req)] == 1) & req_mask

        outs = []
        for backend in ("xla", "one_factor"):
            rep, _ = exchange.request_reply(
                k_local, m_local, part.owner(k_local), lookup,
                capacity=128, axis=AXIS, backend=backend,
                reply_dtype=jnp.bool_, wire=wf,
            )
            outs.append(jax.lax.all_gather(rep, AXIS, tiled=True))
        return outs

    a_out, b_out = spmd(cluster, fn, k, m, a)
    np.testing.assert_array_equal(a_out, b_out)


@pytest.mark.tier1
@pytest.mark.parametrize("backend", ["xla", "one_factor"])
def test_exchange_by_owner_fused_packed(cluster, backend):
    """The fused single-collective owner exchange aggregates identically to
    the raw three-collective version."""
    Pn = cluster.num_nodes
    rows = 16
    total = Pn * rows
    part = RangePartitioning(total, Pn)
    rng = np.random.default_rng(13)
    keys = rng.integers(0, total, Pn * 64).astype(np.int32)
    vals = rng.normal(size=Pn * 64).astype(np.float32)
    mask = rng.random(Pn * 64) < 0.9
    wf = WireFormat.packed_for(total, Pn)

    def fn(k, v, m):
        aggs = []
        for wire in (None, wf):
            rk, rv, rm, ovf = exchange.exchange_by_owner(
                k, v, m, part.owner(k), capacity=128, axis=AXIS,
                backend=backend, wire=wire,
            )
            local_idx = jnp.where(rm, rk - part.my_base(AXIS), rows).reshape(-1)
            agg = jnp.zeros(rows, jnp.float32).at[local_idx].add(
                jnp.where(rm, rv, 0.0).reshape(-1), mode="drop"
            )
            aggs.append((jax.lax.all_gather(agg, AXIS, tiled=True), ovf))
        return aggs

    (raw, ovf_r), (packed, ovf_p) = spmd(
        cluster, fn, jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(mask))
    assert not bool(ovf_r) and not bool(ovf_p)
    np.testing.assert_allclose(packed, raw, rtol=1e-6, atol=1e-6)
    expect = np.zeros(total)
    np.add.at(expect, keys[mask], vals[mask].astype(np.float64))
    np.testing.assert_allclose(packed, expect, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# overflow surfacing: the driver reports it, never silently clamps
# ---------------------------------------------------------------------------


def test_driver_surfaces_hand_plan_overflow(cluster):
    """An undersized exchange capacity must surface as
    ``QueryAnswer.overflow`` (bucket_by_destination's flag), not vanish
    into a silently-clamped result."""
    from repro.tpch.driver import TPCHDriver

    d = TPCHDriver(sf=0.01, cluster=cluster, seed=0,
                   capacities={"q14_request": 1})
    ans = d.query("q14")
    assert ans.tier == 2 and ans.overflow, \
        "1-slot q14 request buffer must report overflow"
    # the flag is stripped from the value, not duplicated inside it
    assert not isinstance(ans.value, tuple)


def test_driver_no_overflow_with_derived_capacity(tpch_driver):
    ans = tpch_driver.query("q14")
    assert ans.tier == 2 and not ans.overflow


# ---------------------------------------------------------------------------
# kernel codec parity: Pallas lanes (interpret) == gather-light XLA == ref
# ---------------------------------------------------------------------------


def _synth_buckets(cap, domain, Pn=4, seed=0):
    """Random sorted per-destination buckets WITH duplicates, plus one
    empty row and one full row."""
    rng = np.random.default_rng(seed)
    buckets = np.zeros((Pn, cap), np.int32)
    mask = np.zeros((Pn, cap), bool)
    for d in range(Pn):
        if d == 0:
            count = 0                      # empty bucket
        elif d == 1:
            count = cap                    # full bucket
        else:
            count = int(rng.integers(1, cap + 1))
        keys = np.sort(rng.integers(0, domain, count)) + d * domain
        buckets[d, :count] = keys
        mask[d, :count] = True
    return jnp.asarray(buckets), jnp.asarray(mask), buckets, mask


# widths l = 0, 1, 4, 8 low bits; word-straddling capacities
_PARITY_SHAPES = [(8, 16), (33, 32), (64, 250), (100, 1000), (96, 4096)]


@pytest.mark.tier1
@pytest.mark.parametrize("cap,domain", _PARITY_SHAPES)
def test_ef_codec_impls_word_identical(cap, domain):
    """All three encoder implementations emit bit-for-bit identical wire
    words, and every decoder recovers the keys from any of them."""
    from repro.kernels import ops, wire_codec

    b, m, buckets, mask = _synth_buckets(cap, domain, seed=cap + domain)
    words = {
        "ref": ops._ef_encode(b, m, domain=domain, impl="ref"),
        "xla": ops._ef_encode(b, m, domain=domain, impl="xla"),
        "pallas": wire_codec.ef_encode(b, m, domain,
                                       use_pallas=True, interpret=True),
    }
    for name in ("xla", "pallas"):
        np.testing.assert_array_equal(
            np.asarray(words[name]), np.asarray(words["ref"]), err_msg=name)
    Pn = b.shape[0]
    decoders = {
        "ref": lambda w: ops._ef_decode(w, jnp.int32(0), capacity=cap,
                                        domain=domain, impl="ref"),
        "xla": lambda w: ops._ef_decode(w, jnp.int32(0), capacity=cap,
                                        domain=domain, impl="xla"),
        "pallas": lambda w: wire_codec.ef_decode(w, cap, domain,
                                                 jnp.int32(0),
                                                 use_pallas=True,
                                                 interpret=True),
    }
    offs = buckets - np.arange(Pn)[:, None] * domain
    for name, dec in decoders.items():
        keys, got = dec(words["ref"])
        np.testing.assert_array_equal(np.asarray(got), mask, err_msg=name)
        np.testing.assert_array_equal(
            np.where(mask, np.asarray(keys), 0),
            np.where(mask, offs, 0), err_msg=name)


@pytest.mark.tier1
@pytest.mark.parametrize("cols", [8, 32, 33, 97, 256])
def test_mask_fold_impls_word_identical(cols):
    from repro.kernels import ops, ref, wire_codec

    rng = np.random.default_rng(cols)
    mask = jnp.asarray(rng.random((4, cols)) < 0.5)
    want = np.asarray(ref.mask_fold(mask))
    np.testing.assert_array_equal(
        np.asarray(wire_codec.mask_fold(mask)), want)
    np.testing.assert_array_equal(
        np.asarray(wire_codec.mask_fold(mask, use_pallas=True,
                                        interpret=True)), want)
    for unfold in (
        lambda w: ref.mask_unfold(w, cols),
        lambda w: wire_codec.mask_unfold(w, cols),
        lambda w: wire_codec.mask_unfold(w, cols, use_pallas=True,
                                         interpret=True),
        lambda w: ops.mask_unfold(w, n=cols),
    ):
        np.testing.assert_array_equal(np.asarray(unfold(jnp.asarray(want))),
                                      np.asarray(mask))


@pytest.mark.tier1
def test_use_kernels_toggle_selects_codec_at_call_time():
    """use_kernels(False) must reroute ef_encode to the ref codec even at
    shapes the kernel path already traced (impl is a static jit arg, not a
    baked-in global)."""
    from repro.kernels import ops

    b, m, *_ = _synth_buckets(64, 250)
    want = np.asarray(ops._ef_encode(b, m, domain=250, impl="ref"))
    np.testing.assert_array_equal(np.asarray(ops.ef_encode(b, m, domain=250)),
                                  want)
    ops.use_kernels(False)
    try:
        np.testing.assert_array_equal(
            np.asarray(ops.ef_encode(b, m, domain=250)), want)
    finally:
        ops.use_kernels(True)


# ---------------------------------------------------------------------------
# latency-aware wire chooser: both directions, from both entry points
# ---------------------------------------------------------------------------


def _slow_codec_cal():
    from repro.core.wirecal import WireCalibration
    return WireCalibration(encode_gbps=0.001, decode_gbps=0.001,
                           link_gbps=100.0, msg_ms=0.0, source="test")


def _fast_codec_cal():
    from repro.core.wirecal import WireCalibration
    return WireCalibration(encode_gbps=100.0, decode_gbps=100.0,
                           link_gbps=0.01, msg_ms=0.05, source="test")


@pytest.mark.tier1
def test_latency_chooser_both_directions():
    """Slow codec + fast network -> raw; fast codec + slow network ->
    packed.  Byte counts alone would pick packed in BOTH cases."""
    from repro.core import compression, wirecal

    cap, Pn, domain = 4096, 8, 3750
    assert compression.alt1_wire_bytes(cap, Pn, domain, packed=True) < \
        compression.alt1_wire_bytes(cap, Pn, domain, packed=False)
    assert wirecal.choose_wire_kind(cap, Pn, domain,
                                    cal=_slow_codec_cal()) == "raw"
    assert wirecal.choose_wire_kind(cap, Pn, domain,
                                    cal=_fast_codec_cal()) == "packed"


@pytest.mark.tier1
def test_wire_format_for_auto_follows_calibration():
    from repro.query.stats import wire_format_for

    wf = wire_format_for(30_000, 8, kind="auto", capacity=4096,
                         cal=_fast_codec_cal())
    assert wf.packed
    wf = wire_format_for(30_000, 8, kind="auto", capacity=4096,
                         cal=_slow_codec_cal())
    assert not wf.packed


@pytest.mark.tier1
def test_choose_semijoin_wire_latency_mode():
    """Latency-accurate alternative selection: the byte-model crossovers
    survive under the builtin calibration, and a per-message-dominated
    network tips toward Alt-2's single collective."""
    import dataclasses

    from repro.core import compression, wirecal

    Pn = 8
    assert compression.choose_semijoin_wire(
        64, 10_000_000, Pn, domain=10_000_000 // Pn,
        cal=wirecal.BUILTIN) == 1
    assert compression.choose_semijoin_wire(
        4096, 1_000, Pn, domain=1_000 // Pn, cal=wirecal.BUILTIN) == 2
    lossy_net = dataclasses.replace(wirecal.BUILTIN, msg_ms=1e9)
    assert compression.choose_semijoin_wire(
        64, 10_000_000, Pn, domain=10_000_000 // Pn, cal=lossy_net) == 2


# ---------------------------------------------------------------------------
# calibration-file loading: explicit overrides fail loudly
# ---------------------------------------------------------------------------


@pytest.mark.tier1
def test_wirecal_explicit_env_missing_file_raises(monkeypatch, tmp_path):
    """$REPRO_WIRE_CAL pointing at a missing file is a misconfiguration,
    not an excuse to silently plan on builtin GbE rates."""
    from repro.core import wirecal

    monkeypatch.setenv(wirecal.ENV_VAR, str(tmp_path / "nope.json"))
    with pytest.raises(wirecal.WireCalError, match=wirecal.ENV_VAR):
        wirecal.load()


@pytest.mark.tier1
def test_wirecal_explicit_path_corrupt_file_raises(monkeypatch, tmp_path):
    from repro.core import wirecal

    monkeypatch.delenv(wirecal.ENV_VAR, raising=False)
    bad = tmp_path / "cal.json"
    bad.write_text("{broken")
    with pytest.raises(wirecal.WireCalError, match="cal.json"):
        wirecal.load(str(bad))
    bad.write_text("[1, 2, 3]")     # valid JSON, not a calibration object
    with pytest.raises(wirecal.WireCalError,
                       match="not a calibration JSON object"):
        wirecal.load(str(bad))


@pytest.mark.tier1
def test_wirecal_default_path_still_falls_back(monkeypatch, tmp_path):
    """Only EXPLICIT sources are strict: an absent default-location file
    means 'never calibrated' and keeps the deterministic builtin."""
    from repro.core import wirecal

    monkeypatch.delenv(wirecal.ENV_VAR, raising=False)
    monkeypatch.chdir(tmp_path)     # default path is repo-relative
    assert wirecal.load() is wirecal.BUILTIN


@pytest.mark.tier1
def test_wirecal_strict_override_for_calibrate_inherit(monkeypatch, tmp_path):
    """calibrate --out into a not-yet-existing file is the normal fresh
    flow: strict=False restores the tolerant fallback for that one path."""
    from repro.core import wirecal

    monkeypatch.delenv(wirecal.ENV_VAR, raising=False)
    missing = tmp_path / "fresh.json"
    assert wirecal.load(str(missing), strict=False) is wirecal.BUILTIN
    with pytest.raises(wirecal.WireCalError):
        wirecal.load(str(missing))
