"""The roofline instrument itself is load-bearing — test it: exact dot
FLOPs, scan trip-count multiplication, pallas cost_estimate pickup, and
the fusion-aware traffic conventions."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import flops as FL

pytestmark = pytest.mark.tier1


def test_dot_flops_exact():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = FL.count(f, a, b)
    assert c.flops == 2 * 64 * 128 * 32
    # traffic: both operands + result
    assert c.traffic == (64 * 128 + 128 * 32 + 64 * 32) * 4


def test_scan_multiplies_body():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    c = FL.count(f, x, w)
    assert c.flops == 7 * 2 * 16 * 16 * 16


def test_grad_counts_backward():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    w = jax.ShapeDtypeStruct((32, 8), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)
    fwd = FL.count(lambda w, x: loss(w, x), w, x).flops
    # grad wrt w only: fwd dot + dw dot = 2x
    gw = FL.count(lambda w, x: jax.grad(loss)(w, x), w, x).flops
    assert gw == 2 * fwd
    # grad wrt both args: fwd + dw + dx = 3x
    gboth = FL.count(lambda w, x: jax.grad(loss, argnums=(0, 1))(w, x),
                     w, x).flops
    assert gboth == 3 * fwd


def test_pallas_cost_estimate_used():
    from repro.kernels import ops

    q = jax.ShapeDtypeStruct((2, 64, 4, 16), jnp.float32)
    k = jax.ShapeDtypeStruct((2, 64, 2, 16), jnp.float32)
    c = FL.count(lambda q, k, v: ops.flash_attention(q, k, v, bq=32, bk=32),
                 q, k, k)
    from repro.kernels.flash_attention import block_pairs

    pairs = 2 * 2 * 2 * block_pairs(64, 64, 32, 32, True, 0)
    assert c.flops == 4 * pairs * 16
    # flash property: traffic is q+k+v+out+lse, NOT the score tiles
    assert c.traffic < (2 * 64 * 4 * 16 * 2 + 2 * 64 * 2 * 16 * 2) * 4 + 4096


def test_gather_counts_touched_rows_only():
    def f(table, idx):
        return table[idx]

    table = jax.ShapeDtypeStruct((100000, 64), jnp.float32)
    idx = jax.ShapeDtypeStruct((8,), jnp.int32)
    c = FL.count(f, table, idx)
    assert c.traffic <= 8 * 64 * 4 + 8 * 4 + 64  # rows + indices, NOT the table
