"""Per-architecture smoke tests: a REDUCED config of each family runs one
forward + one train step on CPU; outputs must have the right shapes and no
NaNs.  Full configs are exercised only via the dry-run (ShapeDtypeStruct)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.data.synthetic import batch_specs
from repro.models.model import build
from repro.models.params import values
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainState, init_train_state, make_train_step

B, S = 2, 32


def _smoke_batch(cfg, rng):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size, jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encdec.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.vlm.num_patches, cfg.vlm.patch_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_arch(arch, smoke=True)
    model = build(cfg)
    params = values(model.init(jax.random.key(0)))
    batch = _smoke_batch(cfg, jax.random.key(1))
    h = jax.jit(lambda p, b: model.hidden(p, b, chunk_q=16, chunk_k=16))(
        params, batch)
    S_out = S + (cfg.vlm.num_patches if cfg.family == "vlm" else 0)
    assert h.shape == (B, S_out, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all(), f"{arch}: NaN/Inf hidden"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = get_arch(arch, smoke=True)
    model = build(cfg)
    state = init_train_state(model, jax.random.key(0))
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10),
        fwd_kw=dict(chunk_q=16, chunk_k=16)))
    batch = _smoke_batch(cfg, jax.random.key(1))
    new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert float(metrics["grad_norm"]) > 0, f"{arch}: zero grads"
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda old, new: float(jnp.sum(jnp.abs(old - new))),
                     state.params, new_state.params))
    assert moved > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_arch(arch, smoke=True)
    model = build(cfg)
    params = values(model.init(jax.random.key(0)))
    state = model.init_decode_state(B, max_len=S, dtype=jnp.float32)
    if cfg.family == "encdec":
        # cross cache must be filled before decode
        from repro.models import encdec
        frames = jax.random.normal(jax.random.key(2),
                                   (B, cfg.encdec.enc_seq, cfg.d_model))
        enc = encdec.encode(params, frames, cfg)
        state = encdec.fill_cross_cache(params, enc, cfg, state)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, state = step(params, state, tok)
    assert logits.shape == (B, cfg.padded_vocab())
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, state = step(params, state, tok + 1)
    assert int(state.length) == 2
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-2.7b", "whisper-medium",
                                  "paligemma-3b", "recurrentgemma-2b"])
def test_prefill_matches_stepwise_decode(arch):
    """Prefill(prompt) must agree with token-by-token decode — the cache
    semantics check (positions, rope, ring buffers, ssd state)."""
    cfg = get_arch(arch, smoke=True)
    model = build(cfg)
    params = values(model.init(jax.random.key(0)))
    rng = jax.random.key(3)
    T = 8
    toks = jax.random.randint(rng, (B, T), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, cfg.encdec.enc_seq,
                                                  cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (B, cfg.vlm.num_patches,
                                                   cfg.vlm.patch_dim))
    # prefill path
    st = model.init_decode_state(B, max_len=S, dtype=jnp.float32)
    logits_p, _ = model.prefill(params, batch, st, chunk_q=8, chunk_k=8)
    # stepwise path
    st2 = model.init_decode_state(B, max_len=S, dtype=jnp.float32)
    if cfg.family == "encdec":
        from repro.models import encdec
        enc = encdec.encode(params, batch["frames"], cfg)
        st2 = encdec.fill_cross_cache(params, enc, cfg, st2)
    if cfg.family == "vlm":
        # stepwise VLM decode starts after the image prefix — compare the
        # prefill against itself at reduced chunk as the consistency check
        logits_p2, _ = model.prefill(params, batch, st, chunk_q=4, chunk_k=4)
        np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_p2),
                                   rtol=2e-4, atol=2e-4)
        return
    logits_s = None
    for t in range(T):
        logits_s, st2 = model.decode_step(params, st2, toks[:, t:t+1])
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(logits_s, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_moe_capacity_and_balance():
    from repro.models import moe as moe_mod

    cfg = get_arch("qwen3-moe-30b-a3b", smoke=True)
    model = build(cfg)
    params = values(model.init(jax.random.key(0)))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    lp = jax.tree.map(lambda v: v[0], params["layers"])
    stats = moe_mod.load_balance_stats(lp["moe"], x, cfg)
    assert float(stats["drop_frac"]) <= 1.0
    load = np.asarray(stats["expert_load"])
    np.testing.assert_allclose(load.sum(), 1.0, rtol=1e-5)
