"""Serving tier under concurrency: the thread-safe driver caches, the
continuous-batching engine, admission bounds, and the serving CLI.

- concurrent ``prepare()``/``query()`` from threads: ONE compile per
  shape/specialization, hit/miss counters that add up, identical answers
  on every thread (the racing-first-trace and double-compile regressions),
- engine coalescing: concurrent same-shape submissions stack into vmapped
  batches whose per-lane answers are BIT-IDENTICAL to sequential
  ``execute`` of the same bindings (q6: no float reassociation),
- Tier-1 inline: cube-covered submissions answer synchronously and never
  touch the batch path,
- bounded admission: past ``max_queue`` the engine rejects with
  :class:`AdmissionError` instead of queueing without limit,
- power-of-two padding: odd batch sizes reuse the padded bucket's
  executable instead of minting a new specialization per observed size,
- the serving CLI validates ``--queries`` names up front (exit 2, names
  listed) and the --cubes table survives a 0.0 trimmed-median Tier-1 time.
"""
from __future__ import annotations

import asyncio
import threading

import numpy as np
import pytest

from repro.serve.olap_engine import AdmissionError, OLAPEngine
from repro.tpch import queries as tq
from repro.tpch.driver import TPCHDriver

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def serve_driver(cluster):
    """Small cubed instance shared by the engine tests."""
    d = TPCHDriver(sf=0.005, cluster=cluster, seed=0)
    d.build_cubes()
    return d


def _off_edge_bindings(prep, n, seed=7):
    """q6 bindings that MISS the cube router (so they queue and batch)."""
    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        b = tq.random_binding("q6", rng)
        if prep.answer_tier1(prep.binding(b)) is None:
            out.append(b)
    return out


# ---------------------------------------------------------------------------
# driver thread safety
# ---------------------------------------------------------------------------


def test_concurrent_prepare_execute_single_compile(cluster):
    """8 threads racing prepare()+execute() of one shape: one cache miss,
    7 hits, exactly ONE XLA trace, and every thread gets the same bits."""
    d = TPCHDriver(sf=0.002, cluster=cluster, seed=0)
    n = 8
    binding = tq.default_binding("q6")
    barrier = threading.Barrier(n)
    outs, errs = [None] * n, []

    def worker(i):
        try:
            barrier.wait()
            prep = d.prepare(tq.q6_param_ir())
            outs[i] = np.asarray(prep.execute(binding).value)
        except Exception as e:  # pragma: no cover - the failure we test for
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert d.compile_events == ["q6_param"], (
        f"racing threads must share one trace, got {d.compile_events}")
    assert d.obs.metrics.value("plan_cache.miss") == 1
    assert d.obs.metrics.value("plan_cache.hit") == n - 1
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


def test_concurrent_query_threads_consistent_counters(cluster):
    """query() end-to-end from 12 threads (same literal tree): counters
    add up to the call count and the plan compiles once."""
    d = TPCHDriver(sf=0.002, cluster=cluster, seed=0)
    n = 12
    barrier = threading.Barrier(n)
    outs, errs = [None] * n, []

    def worker(i):
        try:
            barrier.wait()
            outs[i] = np.asarray(d.query(tq.q6_ir()).value)
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    mreg = d.obs.metrics
    assert (mreg.value("plan_cache.hit")
            + mreg.value("plan_cache.miss")) == n
    assert mreg.value("plan_cache.miss") == 1
    assert len(d.compile_events) == 1
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


# ---------------------------------------------------------------------------
# the continuous-batching engine
# ---------------------------------------------------------------------------


def test_engine_coalesced_batches_bit_identical_to_sequential(serve_driver):
    d = serve_driver
    prep = d.prepare(tq.q6_param_ir())
    bindings = _off_edge_bindings(prep, 12)
    expected = [np.asarray(prep.execute(b).value) for b in bindings]
    mreg = d.obs.metrics
    batches0 = mreg.value("serve.batches")
    lanes0 = mreg.value("serve.coalesced_lanes")

    async def go():
        async with OLAPEngine(d, max_batch=8, max_wait_us=50000) as eng:
            return await asyncio.gather(
                *[eng.submit(prep, b) for b in bindings])

    answers = asyncio.run(go())
    for got, want in zip(answers, expected):
        assert got.tier == 2
        np.testing.assert_array_equal(np.asarray(got.value), want)
    # all 12 queued before the window closed: sealed as 8 + 4, not 12 solos
    assert mreg.value("serve.batches") - batches0 == 2
    assert mreg.value("serve.coalesced_lanes") - lanes0 == 12


def test_engine_tier1_inline_never_queued(serve_driver):
    d = serve_driver
    prep = next(p for p in (d.prepare(make())
                            for make in tq.SERVING_QUERIES.values())
                if p.answer_tier1(p.binding()) is not None)
    mreg = d.obs.metrics
    before = (mreg.value("serve.batches"), mreg.value("serve.solo"))

    async def go():
        async with OLAPEngine(d) as eng:
            return await eng.submit(prep)

    ans = asyncio.run(go())
    assert ans.tier == 1
    assert (mreg.value("serve.batches"), mreg.value("serve.solo")) == before


def test_engine_admission_bound_rejects_past_max_queue(serve_driver):
    d = serve_driver
    prep = d.prepare(tq.q6_param_ir())
    bindings = _off_edge_bindings(prep, 6, seed=11)

    async def go():
        async with OLAPEngine(d, max_batch=16, max_wait_us=50000,
                              max_queue=3) as eng:
            tasks = [asyncio.ensure_future(eng.submit(prep, b))
                     for b in bindings]
            return await asyncio.gather(*tasks, return_exceptions=True)

    res = asyncio.run(go())
    rejected = [r for r in res if isinstance(r, AdmissionError)]
    served = [r for r in res if not isinstance(r, BaseException)]
    assert len(rejected) == 3 and len(served) == 3
    assert d.obs.metrics.value("serve.rejected") >= 3


def test_engine_submit_when_stopped_rejected(serve_driver):
    eng = OLAPEngine(serve_driver)
    prep = serve_driver.prepare(tq.q6_param_ir())
    with pytest.raises(AdmissionError, match="not running"):
        asyncio.run(eng.submit(prep))


def test_batch_padding_reuses_bucket_executable(serve_driver):
    """Odd batch sizes pad to the power-of-two bucket: no per-size
    specialization, padding lanes counted, outputs sliced to the real B."""
    d = serve_driver
    prep = d.prepare(tq.q6_param_ir())
    bindings = _off_edge_bindings(prep, 3, seed=13)
    expected = [np.asarray(prep.execute(b).value) for b in bindings]

    first = prep.execute_batch(bindings, pad_to=4)      # may trace B=4 once
    n_compiles = len(d.compile_events)
    pads0 = d.obs.metrics.value("driver.batch_pad_lanes")
    again = prep.execute_batch(bindings[:2], pad_to=4)  # MUST reuse it
    assert len(d.compile_events) == n_compiles
    assert d.obs.metrics.value("driver.batch_pad_lanes") - pads0 == 2
    assert ("batch", 4) in prep.entry.warm
    assert ("batch", 3) not in prep.entry.warm
    assert ("batch", 2) not in prep.entry.warm
    assert np.asarray(first.value).shape[0] == 3
    assert np.asarray(again.value).shape[0] == 2
    for lane, want in enumerate(expected):
        np.testing.assert_array_equal(np.asarray(first.value)[lane], want)


# ---------------------------------------------------------------------------
# serving CLI
# ---------------------------------------------------------------------------


def test_cli_unknown_query_names_exit_2(capsys):
    from repro.launch import serve_olap

    rc = serve_olap.main(["--queries", "q6", "nope", "q999", "--sf", "0.005"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "nope" in err and "q999" in err
    assert "valid --queries names" in err and "q6" in err


def test_cli_speedup_str_handles_zero_tier1_time():
    from repro.launch.serve_olap import _speedup_str

    assert _speedup_str(0.0, 0.0).strip() == "--"
    assert _speedup_str(1.0, 0.0).strip() == "infx"   # underflowed median
    assert _speedup_str(2.0, 1.0).strip() == "2x"
