"""The MoE expert dispatch as the paper's personalized all-to-all: the
sequence-sharded shard_map variant must reproduce the GSPMD gather-based
block, with both §3.2.6 schedules."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.models import moe as moe_mod
from repro.models.moe_dispatch import moe_block_sharded
from repro.models.model import build
from repro.models.params import values


@pytest.mark.parametrize("backend", ["xla", "one_factor"])
def test_sharded_dispatch_matches_dense(backend):
    cfg = get_arch("qwen3-moe-30b-a3b", smoke=True)
    model = build(cfg)
    params = values(model.init(jax.random.key(0)))
    lp = jax.tree.map(lambda v: v[0], params["layers"])["moe"]
    E = cfg.moe.num_experts
    mesh = jax.make_mesh((4,), ("model",), devices=jax.devices()[:4])
    N, d = 64, cfg.d_model
    x = jax.random.normal(jax.random.key(1), (N, d), jnp.float32)

    # reference: the GSPMD gather-based block with capacity genuinely ample
    # (the default cf=1.25 drops the tail of a popular expert's tokens, which
    # the all-to-all variant under test correctly keeps)
    ref_cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    ref = moe_mod.apply_moe(lp, x[None], ref_cfg)[0]

    def fn(x_local, router, wg, wu, wd):
        p = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        y, ovf = moe_block_sharded(p, x_local, cfg, axis="model",
                                   backend=backend, capacity_factor=4.0)
        return y, ovf

    y, ovf = jax.jit(jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P("model"), P(), P("model"), P("model"), P("model")),
        out_specs=(P("model"), P()),
        check_vma=False,
    ))(x, lp["router"], lp["w_gate"], lp["w_up"], lp["w_down"])
    assert not bool(ovf)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
