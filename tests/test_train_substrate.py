"""Training-substrate tests: optimizer, trainer loop, checkpoint atomicity,
elastic re-mesh restore, gradient compression, data determinism."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.synthetic import SyntheticLM
from repro.models.model import build
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, schedule
from repro.optim.compression import (compress_gradients, compression_init,
                                     decompress_gradients)
from repro.train import checkpoint as ckpt
from repro.train.elastic import Heartbeat, StragglerMonitor, plan_restart
from repro.train.trainer import Trainer, TrainerConfig
from repro.train.train_step import init_train_state


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_frac=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 1.0])
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = adamw_update(grads, state, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    s = [float(schedule(cfg, jnp.int32(t))) for t in [0, 5, 10, 55, 100]]
    assert s[0] == 0.0
    assert s[1] == pytest.approx(0.5)
    assert s[2] == pytest.approx(1.0)
    assert 0.1 < s[3] < 1.0
    assert s[4] == pytest.approx(0.1, abs=1e-6)


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    _, _, m = adamw_update({"w": jnp.full(4, 100.0)}, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# trainer end-to-end: loss must drop on learnable synthetic data
# ---------------------------------------------------------------------------


def test_trainer_loss_decreases(tmp_path, cluster):
    cfg = get_arch("qwen2.5-3b", smoke=True)
    model = build(cfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                       seed=0)
    mesh = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
    trainer = Trainer(model, data, mesh,
                      AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=30),
                      TrainerConfig(steps=30, log_every=1000,
                                    checkpoint_dir=str(tmp_path / "ck"),
                                    checkpoint_every=10))
    state, history = trainer.run()
    first = np.mean([h["loss"] for h in history[:5]])
    last = np.mean([h["loss"] for h in history[-5:]])
    assert last < first - 0.1, f"no learning: {first:.3f} -> {last:.3f}"
    # checkpoints were written atomically
    assert ckpt.latest_step(str(tmp_path / "ck")) == 30


def test_trainer_restart_resumes(tmp_path, cluster):
    cfg = get_arch("qwen2.5-3b", smoke=True)
    model = build(cfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
                       seed=0)
    mesh = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    mk = lambda steps: Trainer(
        model, data, mesh, AdamWConfig(lr=1e-3, total_steps=20),
        TrainerConfig(steps=steps, log_every=1000,
                      checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=5))
    mk(10).run()
    assert ckpt.latest_step(str(tmp_path / "ck")) == 10
    # a "restarted job" resumes from step 10, not 0
    t2 = mk(12)
    state, start = t2.init_or_restore()
    assert start == 10
    _, hist = t2.run(state, start)
    assert [h["step"] for h in hist] == [11, 12]


# ---------------------------------------------------------------------------
# checkpoint: atomicity + elastic re-mesh
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    ckpt.save(str(tmp_path), tree, 7, data_state={"seed": 3})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out, step, ds = ckpt.restore(str(tmp_path), like)
    assert step == 7 and ds == {"seed": 3}
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(np.asarray(x), y),
                 tree, out)


def test_checkpoint_partial_write_is_invisible(tmp_path):
    """A crashed save (tmp dir left behind) must not be picked up."""
    tree = {"a": jnp.zeros(2)}
    ckpt.save(str(tmp_path), tree, 1)
    os.makedirs(tmp_path / "step_00000002.tmp")  # simulated crash mid-save
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"a": jnp.zeros(2)}
    for s in range(1, 6):
        ckpt.save(str(tmp_path), tree, s)
    remaining = sorted(os.listdir(tmp_path))
    assert remaining == ["step_00000003", "step_00000004", "step_00000005"]


def test_elastic_restore_onto_different_mesh(tmp_path, cluster):
    """Save on a (4,2) mesh, restore on (2,2) — the mesh-agnostic property
    that makes pod-loss restarts possible."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh_a = jax.make_mesh((4, 2), ("data", "model"), devices=jax.devices()[:8])
    mesh_b = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
    x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
    xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
    ckpt.save(str(tmp_path), {"x": xa}, 1)
    like = {"x": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    sh = {"x": NamedSharding(mesh_b, P("data", "model"))}
    out, _, _ = ckpt.restore(str(tmp_path), like, shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(x))
    assert out["x"].sharding.mesh.shape["data"] == 2


def test_plan_restart_shrinks_gracefully():
    plan = plan_restart(512, model_parallel=16, want_pods=2)
    assert plan.shape == (2, 16, 16)
    plan = plan_restart(496, model_parallel=16)   # lost one host of 16 chips
    assert plan.shape == (31, 16)
    assert plan.devices_used == 496
    with pytest.raises(AssertionError):
        plan_restart(8, model_parallel=16)


def test_straggler_and_heartbeat():
    mon = StragglerMonitor(window=4, threshold=2.0)
    for r in range(4):
        for _ in range(4):
            mon.record(r, 1.0 if r != 3 else 5.0)
    assert mon.stragglers() == [3]
    t = [0.0]
    hb = Heartbeat(deadline_seconds=10.0, clock=lambda: t[0])
    assert hb.is_alive()
    t[0] = 11.0
    assert not hb.is_alive()
    hb.beat()
    assert hb.is_alive()


# ---------------------------------------------------------------------------
# gradient compression (int8 + error feedback)
# ---------------------------------------------------------------------------


def test_compression_bounded_error_and_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=256).astype(np.float32))}
    st = compression_init(g)
    q, st = compress_gradients(g, st)
    deq = decompress_gradients(q)
    amax = float(jnp.max(jnp.abs(g["w"])))
    err = np.abs(np.asarray(deq["w"] - g["w"]))
    assert err.max() <= amax / 127.0 * 0.5 + 1e-6
    # error feedback: residual carried, so the SUM over steps converges
    total_sent = np.zeros(256)
    st = compression_init(g)
    for _ in range(50):
        q, st = compress_gradients(g, st)
        total_sent += np.asarray(decompress_gradients(q)["w"])
    np.testing.assert_allclose(total_sent / 50, np.asarray(g["w"]), atol=1e-3)


# ---------------------------------------------------------------------------
# data pipeline determinism (the straggler/elastic substrate property)
# ---------------------------------------------------------------------------


def test_data_shards_deterministic():
    d = SyntheticLM(vocab_size=97, seq_len=16, global_batch=8, seed=5)
    a = d.host_batch(step=3, shard=2, num_shards=4)
    b = d.host_batch(step=3, shard=2, num_shards=4)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.host_batch(step=4, shard=2, num_shards=4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # device path deterministic too
    x = np.asarray(d.device_batch(0)["tokens"])
    y = np.asarray(d.device_batch(0)["tokens"])
    np.testing.assert_array_equal(x, y)
    assert (np.asarray(d.device_batch(0)["labels"])
            == np.asarray(d.device_batch(0)["tokens"]))[:, 1:].all() is not False
