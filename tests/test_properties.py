"""Property-based tests (hypothesis) for the system's invariants:

- bit-packing roundtrips for every width,
- delta coding roundtrips on sorted keys,
- §3.2.5 codec bound safety for arbitrary uint32 inputs,
- top-k ranking == numpy lexsort oracle for arbitrary floats/ties,
- §3.2.2 cost model: chooses the argmin of the two analytic costs,
- three-way agreement (lowered IR == hand plan == numpy oracle) for q1/q6
  across seeds and cluster sizes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

pytestmark = pytest.mark.tier1

from repro.core import compression
from repro.core.topk_approx import decode_bounds, encode_partials
from repro.core import topk

SETTINGS = dict(max_examples=40, deadline=None)


# ---------------------------------------------------------------------------
# fixed-width bit packing
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    width=st.integers(1, 32),
    data=st.data(),
)
def test_pack_unpack_roundtrip(width, data):
    n = data.draw(st.integers(1, 200))
    max_val = (1 << width) - 1 if width < 32 else 0xFFFFFFFF
    vals = data.draw(
        st.lists(st.integers(0, max_val), min_size=n, max_size=n)
    )
    v = jnp.asarray(np.array(vals, np.uint32))
    words = compression.pack_bits(v, width)
    assert words.shape[0] == compression.packed_words(n, width)
    out = compression.unpack_bits(words, n, width)
    np.testing.assert_array_equal(np.asarray(out), np.array(vals, np.uint32))


@settings(**SETTINGS)
@given(data=st.data())
def test_delta_roundtrip(data):
    n = data.draw(st.integers(1, 300))
    vals = sorted(data.draw(st.lists(st.integers(0, 1 << 30), min_size=n, max_size=n)))
    v = jnp.asarray(np.array(vals, np.int64))
    deltas = compression.delta_encode(v)
    out = compression.delta_decode(deltas)
    np.testing.assert_array_equal(np.asarray(out), np.array(vals, np.int64))


@settings(**SETTINGS)
@given(data=st.data())
def test_bitset_roundtrip_and_probe(data):
    nwords = data.draw(st.integers(1, 8))
    n = nwords * 32
    bits = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    b = jnp.asarray(np.array(bits, bool))
    words = compression.pack_bitset(b)
    out = compression.unpack_bitset(words, n)
    np.testing.assert_array_equal(np.asarray(out), np.array(bits, bool))
    idx = jnp.asarray(np.arange(n, dtype=np.int32))
    probed = compression.probe_bitset(words, idx)
    np.testing.assert_array_equal(np.asarray(probed), np.array(bits, bool))


# ---------------------------------------------------------------------------
# §3.2.5 codec bounds are SAFE for arbitrary inputs
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.sampled_from([2, 4, 8, 12, 16]),
    group=st.sampled_from([4, 16, 64]),
    data=st.data(),
)
def test_encode_bounds_safety(m, group, data):
    ngroups = data.draw(st.integers(1, 6))
    K = group * ngroups
    vals = data.draw(
        st.lists(st.integers(0, (1 << 31) - 1), min_size=K, max_size=K)
    )
    q = jnp.asarray(np.array(vals, np.uint32))
    codes, shifts = encode_partials(q, m, group)
    assert (np.asarray(codes) < (1 << m)).all() or m >= 31
    lower, upper = decode_bounds(codes, shifts, group)
    lo, hi = np.asarray(lower), np.asarray(upper)
    qn = np.array(vals, np.uint32)
    assert (lo <= qn).all(), "lower bound must never exceed the value"
    assert (qn <= hi).all(), "upper bound must never undercut the value"


# ---------------------------------------------------------------------------
# ranking invariants
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(data=st.data())
def test_local_topk_matches_lexsort(data):
    n = data.draw(st.integers(1, 100))
    k = data.draw(st.integers(1, 20))
    # many ties on purpose: values drawn from a tiny set
    vals = data.draw(
        st.lists(st.sampled_from([0.0, 1.0, 2.0, -1.0, 1e30, -1e30]),
                 min_size=n, max_size=n)
    )
    v = np.array(vals, np.float32)
    keys = np.arange(n, dtype=np.int32)
    out = topk.local_topk(jnp.asarray(v), jnp.asarray(keys), k)
    order = np.lexsort((keys, -v.astype(np.float64)))[:k]
    kk = min(k, n)
    np.testing.assert_array_equal(np.asarray(out.keys)[:kk], keys[order][:kk])
    np.testing.assert_allclose(np.asarray(out.values)[:kk], v[order][:kk])


@settings(**SETTINGS)
@given(data=st.data())
def test_merge_topk_is_commutative_and_correct(data):
    k = data.draw(st.integers(1, 10))
    def draw_topk(tag):
        vals = sorted(
            data.draw(st.lists(st.floats(-100, 100, width=32), min_size=k, max_size=k)),
            reverse=True,
        )
        keys = data.draw(
            st.lists(st.integers(0, 1000), min_size=k, max_size=k, unique=True)
        )
        nvalid = data.draw(st.integers(0, k))
        valid = np.zeros(k, bool)
        valid[:nvalid] = True
        v = np.where(valid, np.array(vals, np.float32), -np.inf)
        return topk.TopK(jnp.asarray(v.astype(np.float32)),
                         jnp.asarray(np.array(keys, np.int32)),
                         jnp.asarray(valid))

    a, b = draw_topk("a"), draw_topk("b")
    ab = topk.merge_topk(a, b)
    ba = topk.merge_topk(b, a)
    np.testing.assert_array_equal(np.asarray(ab.valid), np.asarray(ba.valid))
    nv = int(np.asarray(ab.valid).sum())
    np.testing.assert_allclose(
        np.asarray(ab.values)[:nv], np.asarray(ba.values)[:nv]
    )
    np.testing.assert_array_equal(np.asarray(ab.keys)[:nv], np.asarray(ba.keys)[:nv])
    # correctness vs numpy on the union of valid entries
    av, ak, am = (np.asarray(x) for x in a)
    bv, bk, bm = (np.asarray(x) for x in b)
    uv = np.concatenate([av[am], bv[bm]]).astype(np.float64)
    uk = np.concatenate([ak[am], bk[bm]])
    order = np.lexsort((uk, -uv))[:k]
    np.testing.assert_array_equal(np.asarray(ab.keys)[:len(order)], uk[order])


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 10**9),
    m=st.integers(1, 10**8),
    gamma=st.floats(1e-6, 1.0 - 1e-6),
    P=st.sampled_from([2, 16, 128, 512]),
)
def test_choose_semijoin_is_argmin(n, m, gamma, P):
    choice = compression.choose_semijoin(n, m, gamma, P)
    assert choice in (1, 2)
    if n / P > m:  # footnote 2: request set exceeds the table — Alt-2 always
        assert choice == 2
    else:
        c1 = compression.alt1_bits(n, m, P)
        c2 = compression.alt2_bits(m, gamma)
        assert choice == (1 if c1 <= c2 else 2)


# ---------------------------------------------------------------------------
# lowered IR == hand plan == numpy oracle, across seeds and cluster sizes
# ---------------------------------------------------------------------------

_DRIVERS = {}  # (seed, nodes) -> TPCHDriver, cached across examples


def _driver(seed: int, nodes: int):
    key = (seed, nodes)
    if key not in _DRIVERS:
        import jax

        from repro.core import Cluster
        from repro.tpch.driver import TPCHDriver

        cluster = Cluster(devices=jax.devices()[:nodes])
        _DRIVERS[key] = TPCHDriver(sf=0.002, cluster=cluster, seed=seed)
    return _DRIVERS[key]


@settings(max_examples=4, deadline=None)
@given(
    seed=st.sampled_from([0, 1, 2]),
    nodes=st.sampled_from([1, 2, 8]),
)
def test_lowered_ir_hand_plan_and_oracle_agree(seed, nodes):
    """For q1 and q6 the lowered-IR plan, the hand-written plan, and the
    float64 numpy oracle agree (bitwise-tolerantly) on any instance and any
    power-of-two cluster size."""
    d = _driver(seed, nodes)

    hand1 = np.asarray(d.run("q1"))
    ir1 = np.asarray(d.run_ir("q1")["value"])
    ref1 = d.oracle("q1")
    np.testing.assert_allclose(hand1, ref1, rtol=2e-4)
    np.testing.assert_allclose(ir1, ref1, rtol=2e-4)
    np.testing.assert_allclose(ir1, hand1, rtol=1e-5)

    hand6 = float(np.asarray(d.run("q6")))
    ir6 = float(np.asarray(d.run_ir("q6")["value"]).reshape(()))
    ref6 = d.oracle("q6")
    np.testing.assert_allclose(hand6, ref6, rtol=2e-4)
    np.testing.assert_allclose(ir6, ref6, rtol=2e-4)
    # f32 reduction order differs (tree-sum vs MXU contraction) — the two
    # plans agree far tighter than either agrees with the f64 oracle
    np.testing.assert_allclose(ir6, hand6, rtol=1e-4)
