"""Flash-attention Pallas kernel (fwd + custom-vjp bwd) vs the pure-jnp
oracle, swept over GQA ratios / masks / block sizes (interpret mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(B, S, H, KV, D, Sk=None, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    Sk = Sk or S
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sk, KV, D)).astype(np.float32), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sk, KV, D)).astype(np.float32), dtype)
    return q, k, v


@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("S", [64, 96, 128])
def test_flash_forward_matches_ref(H, KV, S):
    q, k, v = _mk(2, S, H, KV, 16, seed=S + H)
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    expect = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("mask_kw", [
    dict(causal=False),
    dict(causal=True, window=24),
    dict(causal=True, prefix=16),
])
def test_flash_masks(mask_kw):
    q, k, v = _mk(1, 64, 4, 2, 16, seed=7)
    out = ops.flash_attention(q, k, v, bq=16, bk=16, **mask_kw)
    expect = ref.flash_attention(q, k, v, **mask_kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("H,KV", [(4, 2), (4, 1)])
def test_flash_backward_matches_ref(H, KV):
    q, k, v = _mk(2, 64, H, KV, 16, seed=3)

    def loss_flash(q, k, v):
        o = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_ref(q, k, v):
        o = ref.flash_attention(q, k, v, causal=True)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"d{name} mismatch")


def test_flash_backward_window_and_prefix():
    q, k, v = _mk(1, 48, 4, 2, 8, seed=11)
    for kw in (dict(causal=True, window=16), dict(causal=True, prefix=8)):
        gf = jax.grad(lambda q, k, v: jnp.sum(
            ops.flash_attention(q, k, v, bq=16, bk=16, **kw)
            .astype(jnp.float32) ** 2), argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda q, k, v: jnp.sum(
            ref.flash_attention(q, k, v, **kw)
            .astype(jnp.float32) ** 2), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gf, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-4)


def test_flash_fully_masked_rows_are_zero():
    """window smaller than block + row far from any key: l==0 rows must not
    produce NaNs."""
    q, k, v = _mk(1, 32, 2, 2, 8, seed=5)
    out = ops.flash_attention(q, k, v, causal=True, window=4, bq=8, bk=8)
    assert np.isfinite(np.asarray(out, np.float32)).all()


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "paligemma-3b",
                                  "recurrentgemma-2b", "whisper-medium"])
def test_model_flash_equals_xla(arch):
    """Whole-model consistency: hidden states with attn_impl='flash' must
    match the XLA chunked baseline."""
    from repro.configs import get_arch
    from repro.models.model import build
    from repro.models.params import values

    cfg = get_arch(arch, smoke=True)
    model = build(cfg)
    params = values(model.init(jax.random.key(0)))
    rng = jax.random.key(1)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, cfg.encdec.enc_seq,
                                                  cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (B, cfg.vlm.num_patches,
                                                   cfg.vlm.patch_dim))
    hx = model.hidden(params, batch, chunk_q=16, chunk_k=16, attn_impl="xla")
    hf = model.hidden(params, batch, chunk_q=16, chunk_k=16, attn_impl="flash")
    np.testing.assert_allclose(np.asarray(hx, np.float32),
                               np.asarray(hf, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_model_flash_grads_match():
    from repro.configs import get_arch
    from repro.models.model import build
    from repro.models.params import values

    cfg = get_arch("qwen2.5-3b", smoke=True)
    model = build(cfg)
    params = values(model.init(jax.random.key(0)))
    rng = jax.random.key(1)
    batch = {"tokens": jax.random.randint(rng, (2, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (2, 32), 0, cfg.vocab_size)}
    gx = jax.grad(lambda p: model.loss(p, batch, chunk_q=16, chunk_k=16,
                                       attn_impl="xla"))(params)
    gf = jax.grad(lambda p: model.loss(p, batch, chunk_q=16, chunk_k=16,
                                       attn_impl="flash"))(params)
    leaves_x, leaves_f = jax.tree.leaves(gx), jax.tree.leaves(gf)
    for a, b in zip(leaves_x, leaves_f):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-3, atol=3e-4)
