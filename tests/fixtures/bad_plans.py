"""Deliberately broken plans, one per verifier rule.

Each :class:`BadPlan` is a (query, catalog, verify-kwargs) triple built so
that running ``repro.query.verify.verify`` on it fires EXACTLY its
``expected_rule`` (plus, for non-info rules, nothing else at error/warn
severity) — the seeded negative corpus ``tests/test_verify.py`` pins the
stable rule IDs with.

The catalogs are synthetic (a 64k-row ``fact`` table with an 8k-row
``dim`` dimension), built directly from ``TableInfo``/``ColumnStats`` so
each hazard is isolated: real TPC-H plans exercise the clean path.
"""
from __future__ import annotations

import dataclasses

from repro.core.wirecal import WireCalibration
from repro.launch.roofline import CollectiveInstr
from repro.query.ir import (
    Catalog, ColumnStats, Lit, PackedInfo, Param, Q, TableInfo, C,
)
from repro.query.verify import CollectiveOp, PlanArtifacts


@dataclasses.dataclass(frozen=True)
class BadPlan:
    name: str
    expected_rule: str
    query: object            # repro.query.ir.Query
    catalog: Catalog
    kwargs: dict = dataclasses.field(default_factory=dict)


def make_catalog(num_nodes: int = 8, fact_rows: int = 64000,
                 dim_rows: int = 8000, fact_key_hi: float = None,
                 packed: bool = False) -> Catalog:
    """Synthetic star-schema catalog.  ``fact_key_hi`` widens the foreign
    key's stats beyond the dimension's key space (the NUM003 hazard);
    ``packed`` declares ``f_x`` compressed-resident (7-bit FOR codes),
    arming the SCAN001 packed-scan analyzer."""
    if fact_key_hi is None:
        fact_key_hi = dim_rows - 1
    fact_stats = {
        "f_key": ColumnStats(0, float(fact_key_hi), dim_rows),
        "f_fkey": ColumnStats(0.0, float(dim_rows - 1), 0),  # float key
        "f_a": ColumnStats(0, 9999, 10000),
        "f_x": ColumnStats(1, 100, 100),
        "f_y": ColumnStats(-5, 5, 11),          # interval crosses zero
        "f_g": ColumnStats(0, 3, 4),
        # f_tag: string column, no stats (build_catalog skips non-numerics)
    }
    dim_stats = {
        "d_key": ColumnStats(0, dim_rows - 1, dim_rows),
        "d_flag": ColumnStats(0, 2, 3),
    }
    fact_packed = {}
    if packed:
        # f_x resides bit-packed: FOR codes 0..99 at width 7, offset 1
        fact_packed = {"f_x": PackedInfo(width=7, offset=1)}
    return Catalog(
        tables={
            "fact": TableInfo(name="fact", columns=tuple(fact_stats) + ("f_tag",),
                              replicated=False, num_rows=fact_rows,
                              stats=fact_stats, packed=fact_packed),
            "dim": TableInfo(name="dim", columns=tuple(dim_stats),
                             replicated=False, num_rows=dim_rows,
                             stats=dim_stats),
        },
        copartitioned={},
        num_nodes=num_nodes,
    )


_CAT = make_catalog()

_SUM_X = [("total", "sum", C("f_x"))]


def _groupagg(q):
    return q.group_agg(aggs=_SUM_X)


def _request_semijoin(name: str):
    """fact -> dim request semi-join (alt pinned; packed wire)."""
    return (Q.scan("fact")
            .semijoin("dim", key=C("f_key"), pred=C("d_flag") == 1,
                      alt="request")
            .group_agg(aggs=_SUM_X)
            .named(name))


def _a2a(count: int, **kw) -> CollectiveOp:
    return CollectiveOp("all-to-all", count, "fact_sj0", **kw)


BAD_PLANS = (
    # -- SPMD: collective-consistency ----------------------------------------
    BadPlan(
        name="divergent_collectives",
        expected_rule="SPMD001",
        query=_groupagg(Q.scan("fact")).named("bad_divergent"),
        catalog=_CAT,
        kwargs=dict(artifacts=PlanArtifacts(shard_scripts={
            0: (_a2a(2), CollectiveOp("all-reduce", 1, "group_agg")),
            # shard 1 thinks the exchange is raw: 3 all-to-alls
            1: (_a2a(3), CollectiveOp("all-reduce", 1, "group_agg")),
        })),
    ),
    BadPlan(
        name="guarded_collective",
        expected_rule="SPMD002",
        query=_groupagg(Q.scan("fact")).named("bad_guarded"),
        catalog=_CAT,
        kwargs=dict(artifacts=PlanArtifacts(shard_scripts={
            0: (_a2a(2, guard="any(local_hits) (data-dependent)"),),
            1: (_a2a(2, guard="any(local_hits) (data-dependent)"),),
        })),
    ),
    BadPlan(
        name="collective_in_loop",
        expected_rule="SPMD003",
        query=_groupagg(Q.scan("fact")).named("bad_loop"),
        catalog=_CAT,
        kwargs=dict(artifacts=PlanArtifacts(shard_scripts={
            0: (_a2a(2, in_loop=True),),
            1: (_a2a(2, in_loop=True),),
        })),
    ),
    BadPlan(
        name="hlo_count_mismatch",
        expected_rule="SPMD004",
        query=_request_semijoin("bad_count"),
        catalog=_CAT,
        # static model expects 2 packed all-to-alls; the "lowered" HLO
        # shows only one
        kwargs=dict(artifacts=PlanArtifacts(instructions=(
            CollectiveInstr("all-to-all.1", "all-to-all", 4096),
        ))),
    ),
    # -- CAP: capacity soundness ---------------------------------------------
    BadPlan(
        name="undersized_capacity",
        expected_rule="CAP001",
        query=_request_semijoin("bad_cap"),
        catalog=_CAT,
        # a context override pins the exchange buffer far below the
        # model's worst-case requirement
        kwargs=dict(capacities={"bad_cap_sj0": 64}),
    ),
    # -- PRM: binding vs declared range --------------------------------------
    BadPlan(
        name="off_range_param",
        expected_rule="PRM001",
        query=(Q.scan("fact")
               .filter(C("f_a") <= Param("p_cut", "int32", lo=0, hi=1000))
               .group_agg(aggs=_SUM_X)
               .named("bad_range")),
        catalog=_CAT,
        kwargs=dict(binding={"p_cut": 5000}),
    ),
    # -- RCP: recompilation hazards ------------------------------------------
    BadPlan(
        name="string_literal_predicate",
        expected_rule="RCP001",
        query=(Q.scan("fact")
               .filter(C("f_tag") == Lit("BRAND#12"))
               .group_agg(aggs=_SUM_X)
               .named("bad_string_lit")),
        catalog=_CAT,
    ),
    BadPlan(
        name="kernel_skips_parameterization",
        expected_rule="RCP002",
        query=(Q.scan("fact")
               .filter(C("f_a") <= 905)
               .group_agg(keys=[("g", C("f_g"), 4)], aggs=_SUM_X,
                          method="kernel")
               .named("bad_kernel")),
        catalog=_CAT,
    ),
    BadPlan(
        name="constant_comparison",
        expected_rule="RCP003",
        query=(Q.scan("fact")
               .filter((Lit(1) < Lit(2)) & (C("f_a") <= 905))
               .group_agg(aggs=_SUM_X)
               .named("bad_const_cmp")),
        catalog=_CAT,
    ),
    # -- NUM: numeric hazards ------------------------------------------------
    BadPlan(
        name="zero_crossing_division",
        expected_rule="NUM001",
        query=(Q.scan("fact")
               .group_agg(aggs=[("ratio", "sum", C("f_x") / C("f_y"))])
               .named("bad_div")),
        catalog=_CAT,
    ),
    BadPlan(
        name="division_disables_maskgemm",
        expected_rule="NUM002",
        query=(Q.scan("fact")
               .group_agg(keys=[("g", C("f_g"), 4)],
                          # denominator stats [1, 100]: NaN-safe, but the
                          # division still forces the per-lane fallback
                          aggs=[("ratio", "sum", C("f_x") / C("f_x"))])
               .named("bad_gemm")),
        catalog=_CAT,
    ),
    BadPlan(
        name="key_exceeds_wire_domain",
        expected_rule="NUM003",
        query=_request_semijoin("bad_domain"),
        catalog=make_catalog(fact_key_hi=8500),  # keys beyond dim's 8000
    ),
    # -- WIRE: wire-choice audit ---------------------------------------------
    BadPlan(
        name="packed_forced_despite_latency",
        expected_rule="WIRE001",
        query=_request_semijoin("bad_wire"),
        catalog=_CAT,
        # a machine where the codec crawls (1 MB/s) but the link flies
        # (100 GB/s, zero per-message latency): packing costs far more
        # time than the byte savings recover, yet wire="packed" (the
        # default override) forces the packed codec anyway
        kwargs=dict(calibration=WireCalibration(
            encode_gbps=0.001, decode_gbps=0.001,
            link_gbps=100.0, msg_ms=0.0, source="fixture")),
    ),
    # -- SCAN: predicate-on-packed audit --------------------------------------
    BadPlan(
        name="packed_predicate_outside_code_space",
        expected_rule="SCAN001",
        query=(Q.scan("fact")
               # col-vs-col comparison: no code-space rewrite exists, so
               # the packed f_x column decodes in full before the filter
               .filter(C("f_x") < C("f_g"))
               .group_agg(aggs=[("total", "sum", C("f_a"))])
               .named("bad_packed_scan")),
        catalog=make_catalog(packed=True),
    ),
    BadPlan(
        name="float_semijoin_key",
        expected_rule="NUM004",
        query=(Q.scan("fact")
               .semijoin("dim", key=C("f_fkey"), pred=C("d_flag") == 1,
                         alt="request")
               .group_agg(aggs=_SUM_X)
               .named("bad_float_key")),
        catalog=_CAT,
    ),
)


def by_name(name: str) -> BadPlan:
    for case in BAD_PLANS:
        if case.name == name:
            return case
    raise KeyError(name)
