"""Seeded test fixtures (deliberately broken plans for the verifier)."""
