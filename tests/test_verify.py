"""Static plan verifier: rule catalog, clean registry plans, the seeded
bad-plan corpus, capacity diagnostics that reproduce at runtime, degenerate
statistics, and eager parameter-binding validation."""
from __future__ import annotations

import pytest

from fixtures.bad_plans import BAD_PLANS, make_catalog
from repro.query import UnboundParamError
from repro.query.ir import C, Param, Q
from repro.query.verify import (
    RULES,
    collective_script,
    collectives_in_control_flow,
    verify,
)
from repro.tpch import queries as tq
from repro.tpch.schema import day

pytestmark = pytest.mark.tier1

_SUM = [("total", "sum", C("f_x"))]


# -- rule catalog ------------------------------------------------------------

def test_rule_registry_is_sane():
    assert len(RULES) >= 13
    for rid, rule in RULES.items():
        assert rule.id == rid
        assert rule.severity in ("error", "warn", "info")
        assert rule.title and rule.summary
    # the stable public IDs the docs and fixtures pin
    expected = {"SPMD001", "SPMD002", "SPMD003", "SPMD004", "CAP001",
                "PRM001", "RCP001", "RCP002", "RCP003", "NUM001", "NUM002",
                "NUM003", "NUM004"}
    assert expected <= set(RULES)


# -- every registry plan verifies clean --------------------------------------

def test_registry_ir_queries_verify_clean(tpch_driver):
    from repro.core.plans import REGISTRY

    checked = 0
    for name, qd in REGISTRY.items():
        if qd.ir is None:
            continue
        rep = tpch_driver.check(qd.ir)
        assert rep.clean, f"{name}: {rep.text()}"
        checked += 1
    assert checked >= 5  # q1, q1_kernel, q4, q6, q14_promo, q18, ...


def test_param_and_serving_queries_verify_clean(tpch_driver):
    targets = [make() for make in tq.PARAM_QUERIES.values()]
    targets += [make() for make in tq.SERVING_QUERIES.values()]
    assert targets
    for q in targets:
        rep = tpch_driver.check(q)
        assert rep.clean, rep.text()


# -- seeded bad-plan corpus: each fixture fires exactly its rule -------------

@pytest.mark.parametrize("case", BAD_PLANS, ids=[c.name for c in BAD_PLANS])
def test_bad_plan_fires_expected_rule(case):
    rep = verify(case.query, case.catalog, **case.kwargs)
    ids = rep.rule_ids()
    assert case.expected_rule in ids, rep.text()
    hard = {d.rule_id for d in rep.errors + rep.warnings}
    if RULES[case.expected_rule].severity == "info":
        # advisory-only fixtures stay clean and fire nothing else
        assert rep.clean and ids == {case.expected_rule}, rep.text()
    else:
        assert hard == {case.expected_rule}, rep.text()


def test_diagnostic_format_names_rule_and_site():
    case = BAD_PLANS[0]
    rep = verify(case.query, case.catalog, **case.kwargs)
    d = rep.diagnostics[0]
    line = d.format()
    assert d.rule_id in line and d.severity in line
    assert rep.query in rep.text()


# -- CAP001 is sound: the reported witness binding overflows at runtime ------

def test_capacity_diagnostic_reproduces_runtime_overflow(tpch_driver):
    q = tq.q14_promo_ir(alt="request")
    # defaults (one shipdate month) are clean ...
    assert tpch_driver.check(q).clean
    # ... the full 1992-1998 range is not: the derived capacity was sized
    # for the prepared defaults
    wide = {"_p0": day(1992, 1, 1), "_p1": day(1998, 12, 1)}
    rep = tpch_driver.check(q, params=wide)
    cap = [d for d in rep.errors if d.rule_id == "CAP001"]
    assert cap, rep.text()
    assert cap[0].data["required"] > cap[0].data["capacity"]
    # executing with the diagnostic's own witness binding must overflow
    prep = tpch_driver.prepare(q)
    ans = prep.execute(cap[0].data["binding"])
    assert ans.overflow, "CAP001 witness binding did not overflow at runtime"


# -- degenerate statistics ---------------------------------------------------

def test_zero_row_table_verifies_without_crashing():
    cat = make_catalog(fact_rows=0, dim_rows=8)
    q = (Q.scan("fact")
         .semijoin("dim", key=C("f_key"), pred=C("d_flag") == 1,
                   alt="request")
         .group_agg(aggs=_SUM)
         .named("zero_rows"))
    rep = verify(q, cat)
    assert rep.ok
    script = collective_script(q, cat)
    assert any(op.kind == "all-to-all" for op in script)


@pytest.mark.parametrize("pred,label", [
    (C("f_a") <= -1, "sel_zero"),       # below lo=0 -> selectivity 0.0
    (C("f_a") <= 99999, "sel_one"),     # above hi=9999 -> selectivity 1.0
])
def test_selectivity_endpoints_verify_clean(pred, label):
    cat = make_catalog()
    q = (Q.scan("fact")
         .filter(pred)
         .semijoin("dim", key=C("f_key"), pred=C("d_flag") == 1,
                   alt="request")
         .group_agg(aggs=_SUM)
         .named(label))
    rep = verify(q, cat)
    assert rep.ok, rep.text()


def test_param_with_lo_equal_hi():
    cat = make_catalog()
    point = Param("p_point", "int32", lo=5, hi=5)
    q = (Q.scan("fact")
         .filter(C("f_a") <= point)
         .group_agg(aggs=_SUM)
         .named("point_param"))
    assert verify(q, cat, binding={"p_point": 5}).clean
    rep = verify(q, cat, binding={"p_point": 6})
    assert {d.rule_id for d in rep.errors} == {"PRM001"}, rep.text()


# -- eager binding validation (driver layer) ---------------------------------

def test_unknown_binding_key_rejected_before_tracing(tpch_driver):
    prep = tpch_driver.prepare("q6")
    with pytest.raises(UnboundParamError, match="bogus"):
        prep.binding({"bogus": 1})


def test_missing_binding_key_rejected(tpch_driver):
    prep = tpch_driver.prepare("q6")
    name = prep.params[0].name
    defaults = dict(prep.defaults)
    prep.defaults.pop(name)
    try:
        with pytest.raises(UnboundParamError, match=name):
            prep.binding()
    finally:
        prep.defaults = defaults


def test_uncastable_binding_value_named(tpch_driver):
    prep = tpch_driver.prepare("q6")
    name = prep.params[0].name
    with pytest.raises(UnboundParamError, match=name):
        prep.binding({name: "not-a-number"})


def test_params_on_hand_written_plan_rejected(tpch_driver):
    with pytest.raises(UnboundParamError, match="q3"):
        tpch_driver.query("q3", params={"cutoff": 1})


def test_check_rejects_unknown_param_names(tpch_driver):
    with pytest.raises(UnboundParamError, match="nope"):
        tpch_driver.check(tq.q14_promo_ir(), params={"nope": 1})


# -- explain renders diagnostics ---------------------------------------------

def test_explain_renders_verifier_diagnostics(tpch_driver):
    wide = {"_p0": day(1992, 1, 1), "_p1": day(1998, 12, 1)}
    txt = tpch_driver.explain(tq.q14_promo_ir(alt="request"),
                              params=wide).text()
    assert "diagnostics:" in txt and "CAP001" in txt


def test_explain_clean_plan_has_no_diagnostics_section(tpch_driver):
    txt = tpch_driver.explain("q6").text()
    assert "diagnostics:" not in txt


# -- HLO control-flow scanner ------------------------------------------------

_HLO_WHILE = """
HloModule m

%body (p: s32[8]) -> s32[8] {
  %p = s32[8] parameter(0)
  ROOT %ar = s32[8] all-reduce(%p), to_apply=%add
}

%cond (p: s32[8]) -> pred[] {
  %p = s32[8] parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (x: s32[8]) -> s32[8] {
  %x = s32[8] parameter(0)
  ROOT %w = s32[8] while(%x), condition=%cond, body=%body
}
"""

_HLO_STRAIGHT = """
HloModule m

ENTRY %main (x: s32[8]) -> s32[8] {
  %x = s32[8] parameter(0)
  ROOT %ar = s32[8] all-reduce(%x), to_apply=%add
}
"""


def test_hlo_scanner_flags_collective_in_while_body():
    hits = collectives_in_control_flow(_HLO_WHILE)
    assert hits, "all-reduce inside while body not detected"
    assert any(k == "all-reduce" for h in hits for k, _ in h.kinds)
    assert all(h.region in ("while", "conditional") for h in hits)


def test_hlo_scanner_ignores_straight_line_collectives():
    assert not collectives_in_control_flow(_HLO_STRAIGHT)
