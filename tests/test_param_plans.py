"""Runtime query parameters: prepared plans compiled ONCE and executed for
any literal binding (the paper's §2/§3.1 compile-once model).

- hypothesis sweep: for q1/q6/q14 random TPC-H §2.4 substitution draws
  across seeds x cluster sizes must match the float64 numpy oracle via the
  SAME prepared plan object, with exactly one XLA compile per shape
  (``TPCHDriver.compile_events`` counts traces),
- the prepared plan is BIT-FOR-BIT identical to a freshly compiled
  literal-bound plan (parameterization changes no arithmetic),
- plan-cache regression: IR trees differing only in literals share one
  executable; trees differing in structure still miss,
- parameterized Tier-1 routing: bin-edge exactness decided per binding at
  execute time (in-range edge -> cube, off-edge/out-of-range -> the
  prepared Tier-2 plan),
- batched execution: ``execute_batch`` lanes are bitwise equal to scalar
  executes and one overflowing lane never poisons its siblings.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.query import (
    C,
    IRValidationError,
    Param,
    Q,
    UnboundParamError,
    bind_params,
    lower,
    parameterize,
    query_params,
    same_query,
)
from repro.tpch import queries as tq
from repro.tpch.driver import TPCHDriver
from repro.tpch.reference import ALL as ORACLES
from repro.tpch.schema import DEFAULT_PARAMS as DP, day

pytestmark = pytest.mark.tier1

PARAM_LABELS = {"q1": "q1_param", "q6": "q6_param",
                "q14_promo": "q14_promo_param"}


def _oracle(name: str, driver, binding: dict):
    p = tq.oracle_params(name, binding)
    if name == "q14_promo":
        return ORACLES["q14"](driver.tables, p=p)[1]  # promo revenue term
    return ORACLES[name](driver.tables, p=p)


def _check(name: str, value, ref):
    got = np.asarray(value)
    if name == "q1":
        np.testing.assert_allclose(got.reshape(6, 6), ref, rtol=2e-4)
    else:
        np.testing.assert_allclose(got.reshape(()), ref, rtol=2e-4, atol=1e-2)


# ---------------------------------------------------------------------------
# acceptance: one prepare, many executes, ONE compile, oracle on every binding
# ---------------------------------------------------------------------------


def test_one_compile_serves_eight_distinct_bindings(cluster):
    driver = TPCHDriver(sf=0.005, cluster=cluster, seed=0)
    prep = driver.prepare(tq.q6_param_ir())
    rng = np.random.default_rng(11)
    bindings = [tq.random_binding("q6", rng) for _ in range(8)]
    assert len({tuple(sorted(b.items())) for b in bindings}) == 8
    for b in bindings:
        ans = prep.execute(b)
        assert ans.tier == 2 and not ans.overflow
        _check("q6", ans.value, _oracle("q6", driver, b))
    assert driver.compile_events == ["q6_param"], (
        "8 distinct executes of one prepared q6 must trigger exactly 1 "
        f"XLA compile, saw {driver.compile_events}"
    )


# ---------------------------------------------------------------------------
# property sweep across seeds x node counts (same prepared plan object):
# hypothesis drives the draws when available; a fixed grid of pre-seeded
# draws keeps the property exercised when it is not (requirements-dev.txt)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # the suite degrades gracefully without hypothesis
    HAVE_HYPOTHESIS = False

_DRIVERS = {}   # (seed, nodes) -> TPCHDriver, cached across examples
_PREPARED = {}  # (seed, nodes, qname) -> PreparedQuery


def _driver(seed: int, nodes: int) -> TPCHDriver:
    key = (seed, nodes)
    if key not in _DRIVERS:
        from repro.core import Cluster

        cluster = Cluster(devices=jax.devices()[:nodes])
        _DRIVERS[key] = TPCHDriver(sf=0.002, cluster=cluster, seed=seed)
    return _DRIVERS[key]


def _prepared(seed: int, nodes: int, qname: str):
    key = (seed, nodes, qname)
    if key not in _PREPARED:
        _PREPARED[key] = _driver(seed, nodes).prepare(
            tq.PARAM_QUERIES[qname]())
    return _PREPARED[key]


def _sweep_example(seed, nodes, qname, draw):
    d = _driver(seed, nodes)
    prep = _prepared(seed, nodes, qname)
    binding = tq.random_binding(qname, np.random.default_rng(draw))
    ans = prep.execute(binding)
    assert not np.any(ans.overflow), (qname, binding)
    _check(qname, ans.value, _oracle(qname, d, binding))
    # the compile-once contract: however many examples ran on this driver,
    # the prepared shape traced exactly once
    assert d.compile_events.count(PARAM_LABELS[qname]) == 1


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.sampled_from([0, 1]),
        nodes=st.sampled_from([1, 2, 8]),
        qname=st.sampled_from(["q1", "q6", "q14_promo"]),
        draw=st.integers(0, 2**31 - 1),
    )
    def test_prepared_plan_matches_oracle_for_any_binding(seed, nodes, qname,
                                                          draw):
        _sweep_example(seed, nodes, qname, draw)


_FIXED_GRID = [
    (0, 8, "q1", 101), (0, 8, "q6", 202), (0, 8, "q14_promo", 303),
    (1, 2, "q1", 404), (1, 2, "q6", 505), (1, 2, "q14_promo", 606),
    (0, 1, "q6", 707), (1, 8, "q6", 808),
]


@pytest.mark.parametrize("seed,nodes,qname,draw", _FIXED_GRID)
def test_prepared_plan_matches_oracle_fixed_grid(seed, nodes, qname, draw):
    _sweep_example(seed, nodes, qname, draw)


# ---------------------------------------------------------------------------
# bit-for-bit: the prepared plan IS the literal plan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qname", ["q1", "q6", "q14_promo"])
def test_prepared_bitwise_equals_fresh_literal_compile(tpch_driver, qname):
    """Executing a prepared plan with a binding must produce byte-identical
    results to compiling the literal-bound query from scratch —
    parameterization moves literals out of the executable without touching
    a single arithmetic op."""
    d = tpch_driver
    prep = d.prepare(tq.PARAM_QUERIES[qname]())
    binding = tq.random_binding(qname, np.random.default_rng(23))
    cols = {n: t.columns for n, t in d.placed.items()}
    fn = d._ensure_compiled(prep.entry)
    merged = prep.binding(binding)  # incl. auto-extracted defaults
    out_p = jax.device_get(fn(cols, prep._cast(merged)))
    literal = bind_params(prep.query, merged)
    assert not query_params(literal.root)
    fn_l = d.cluster.compile(
        lower(literal, d.catalog, wire=d.wire, binding=merged),
        d.ctx, d.placed)
    out_l = jax.device_get(fn_l(cols))
    assert set(out_p) == set(out_l)
    for k in out_p:
        assert np.asarray(out_p[k]).tobytes() == np.asarray(out_l[k]).tobytes(), (
            f"{qname}[{k}] differs between prepared and literal plan"
        )


def test_batched_q1_lanes_match_oracle(tpch_driver):
    """The batched lowering swaps q1's grouped aggregation for the
    ``mask @ (onehot (x) measures)`` GEMM — every lane must still agree
    with the float64 oracle for its own binding."""
    prep = tpch_driver.prepare(tq.q1_param_ir())
    rng = np.random.default_rng(41)
    bindings = [tq.random_binding("q1", rng) for _ in range(8)]
    ansb = prep.execute_batch(bindings)
    for i, b in enumerate(bindings):
        _check("q1", np.asarray(ansb.value)[i],
               _oracle("q1", tpch_driver, b))


def test_batch_lanes_bitwise_equal_scalar_executes(tpch_driver):
    d = tpch_driver
    prep = d.prepare(tq.q6_param_ir())
    rng = np.random.default_rng(31)
    bindings = [tq.random_binding("q6", rng) for _ in range(8)]
    ansb = prep.execute_batch(bindings)
    batched = np.asarray(ansb.value)
    assert batched.shape[0] == 8
    assert np.asarray(ansb.overflow).shape == (8,)
    cols = {n: t.columns for n, t in d.placed.items()}
    fn = d._ensure_compiled(prep.entry)
    for i, b in enumerate(bindings):
        scalar = jax.device_get(fn(cols, prep._cast(prep.binding(b))))
        assert batched[i].tobytes() == np.asarray(scalar["value"]).tobytes()


# ---------------------------------------------------------------------------
# plan-cache regression: key modulo parameter values, not modulo structure
# ---------------------------------------------------------------------------


def test_plan_cache_hits_for_literal_differing_trees(tpch_driver):
    """Two IR trees differing ONLY in predicate literals canonicalize to
    one shape and share one compiled executable (they used to be two
    separate XLA compiles)."""
    shifted = dataclasses.replace(DP, q6_quantity=30.0,
                                  q6_date_min=day(1995, 1, 1))
    p1 = tpch_driver.prepare(tq.q6_ir())
    p2 = tpch_driver.prepare(tq.q6_ir(shifted))
    assert p1.entry is p2.entry, "literal-differing trees must share a plan"
    assert p1.defaults != p2.defaults  # ... but keep their own bindings
    # identical literals memoize down to the same bound closure
    assert (tpch_driver.compile_query(tq.q6_ir())
            is tpch_driver.compile_query(tq.q6_ir()))


def test_plan_cache_misses_for_structure_differing_trees(tpch_driver):
    """Guards against over-normalizing the cache key: a structural change
    (extra conjunct / different aggregate expression) must MISS."""
    base = tpch_driver.prepare(tq.q6_ir())
    extra_filter = (
        Q.scan("lineitem")
        .filter((C("l_shipdate") >= DP.q6_date_min)
                & (C("l_shipdate") < DP.q6_date_max)
                & (C("l_discount") >= DP.q6_disc_min)
                & (C("l_discount") <= DP.q6_disc_max)
                & (C("l_quantity") < DP.q6_quantity)
                & (C("l_tax") >= 0.0))
        .group_agg(aggs=[("revenue", "sum",
                          C("l_extendedprice") * C("l_discount"))])
    )
    other_measure = (
        Q.scan("lineitem")
        .filter((C("l_shipdate") >= DP.q6_date_min)
                & (C("l_shipdate") < DP.q6_date_max)
                & (C("l_discount") >= DP.q6_disc_min)
                & (C("l_discount") <= DP.q6_disc_max)
                & (C("l_quantity") < DP.q6_quantity))
        .group_agg(aggs=[("revenue", "sum", C("l_extendedprice"))])
    )
    assert tpch_driver.prepare(extra_filter).entry is not base.entry
    assert tpch_driver.prepare(other_measure).entry is not base.entry


def test_parameterize_reaches_literals_under_nested_not():
    """A comparison literal inside ~(...) nested in a conjunction must be
    parameterized too — otherwise literal variants silently miss the
    cache."""

    def q(qty):
        return (Q.scan("lineitem")
                .filter(~(C("l_quantity") < qty) & (C("l_discount") >= 0.05))
                .group_agg(aggs=[("n", "count")]))

    s1, b1 = parameterize(q(24.0))
    s2, b2 = parameterize(q(30.0))
    assert same_query(s1, s2)
    assert sorted(b1.values()) != sorted(b2.values())


def test_bound_closure_cache_is_lru_bounded(cluster):
    """compile_query memoizes one closure per literal binding; a stream of
    ever-changing literals must not grow that memo without bound."""
    driver = TPCHDriver(sf=0.002, cluster=cluster, seed=0)
    fns = [driver.compile_query(
        tq.q6_ir(dataclasses.replace(DP, q6_quantity=float(q))))
        for q in range(20, 34)]
    prep = driver.prepare(tq.q6_ir())
    assert len(prep.entry.bound) <= driver.BOUND_CACHE_MAX
    assert len(set(map(id, fns))) == len(fns)  # distinct bindings, own closures
    cols = {n: t.columns for n, t in driver.placed.items()}
    fns[0](cols)
    fns[-1](cols)
    assert driver.compile_events == ["q6"]     # ... but ONE executable


def _q6_variant(extra_cols):
    """A q6-shaped tree with one extra conjunct per column in
    ``extra_cols`` — each distinct column SET is a distinct structure
    (literal values alone would canonicalize to the same shape)."""
    cond = ((C("l_shipdate") >= DP.q6_date_min)
            & (C("l_shipdate") < DP.q6_date_max)
            & (C("l_discount") >= DP.q6_disc_min)
            & (C("l_discount") <= DP.q6_disc_max)
            & (C("l_quantity") < DP.q6_quantity))
    for col in extra_cols:
        cond = cond & (C(col) >= 0.0)
    return (Q.scan("lineitem").filter(cond)
            .group_agg(aggs=[("revenue", "sum",
                              C("l_extendedprice") * C("l_discount"))]))


def test_prepared_plan_cache_evicts_oldest_shape(cluster):
    """Overfill the structural plan-cache LRU: the OLDEST (least recently
    used) shape is the one evicted, a hit refreshes recency, and an
    evicted shape re-prepares as a fresh miss."""
    cols = ["l_tax", "l_quantity", "l_discount", "l_extendedprice",
            "l_shipdate", "l_orderkey"]
    shapes = [_q6_variant(cols[:k]) for k in range(6)]
    driver = TPCHDriver(sf=0.002, cluster=cluster, seed=0)
    driver.IR_CACHE_MAX = 4
    mreg = driver.obs.metrics

    preps = [driver.prepare(s) for s in shapes[:5]]   # 5th insert evicts #0
    assert len(driver._prepared) == 4
    miss0 = mreg.value("plan_cache.miss")
    again0 = driver.prepare(shapes[0])                # oldest: gone -> miss
    assert mreg.value("plan_cache.miss") == miss0 + 1
    assert again0.entry is not preps[0].entry
    hit0 = mreg.value("plan_cache.hit")
    assert driver.prepare(shapes[4]).entry is preps[4].entry  # newest: hit
    assert mreg.value("plan_cache.hit") == hit0 + 1
    # recency, not insertion order: after again0's insert evicted #1 and
    # the hit refreshed #4, the oldest entry is #2 — the next overfill
    # must drop IT while the refreshed #3/#4 survive
    driver.prepare(shapes[5])
    assert driver.prepare(shapes[3]).entry is preps[3].entry
    m = mreg.value("plan_cache.miss")
    assert driver.prepare(shapes[2]).entry is not preps[2].entry
    assert mreg.value("plan_cache.miss") == m + 1


def test_bound_closure_cache_evicts_oldest_binding(cluster):
    """Overfill the per-shape bound-closure LRU: the oldest binding's
    closure is dropped (rebuilt on re-request), the newest survives."""
    driver = TPCHDriver(sf=0.002, cluster=cluster, seed=0)
    driver.BOUND_CACHE_MAX = 3

    def fn_for(q):
        return driver.compile_query(
            tq.q6_ir(dataclasses.replace(DP, q6_quantity=float(q))))

    fns = [fn_for(q) for q in (20, 21, 22, 23)]       # 4th insert evicts 20
    prep = driver.prepare(tq.q6_ir())
    assert len(prep.entry.bound) == 3
    assert fn_for(23) is fns[3], "newest binding must still be memoized"
    assert fn_for(20) is not fns[0], "evicted binding must rebuild"
    assert fn_for(21) is not fns[1], "20's rebuild evicted 21, next-oldest"
    assert driver.compile_events == [], (
        "closure churn must not touch the compiled executable")


def test_batched_division_measure_stays_finite_and_correct(cluster):
    """A measure that divides can be non-finite on filtered-out rows; the
    batched lowering must not take the mask-GEMM shortcut there (0 * inf
    poisons group sums) — lanes must match a numpy oracle computed over
    unmasked rows only."""
    driver = TPCHDriver(sf=0.005, cluster=cluster, seed=0)
    q = (Q.scan("lineitem")
         .filter(C("l_shipdate") > Param("cut", "int32"))
         .group_agg(keys=[("returnflag", C("l_returnflag"), 3)],
                    aggs=[("ratio_sum", "sum",
                           C("l_quantity") / (C("l_shipdate") - 100.0))]))
    prep = driver.prepare(q)
    cuts = [150, 400, 800, 1200, 1600, 2000, 2200, 2400]
    ans = prep.execute_batch([{"cut": c} for c in cuts])
    got = np.asarray(ans.value)
    assert np.isfinite(got).all(), "masked non-finite rows leaked into sums"
    li = driver.tables["lineitem"].columns
    ship = li["l_shipdate"].astype(np.float64)
    assert (ship == 100).any(), "test needs a zero-denominator masked row"
    for i, c in enumerate(cuts):
        sel = ship > c
        ref = np.zeros(3)
        np.add.at(ref, li["l_returnflag"][sel],
                  li["l_quantity"][sel].astype(np.float64)
                  / (ship[sel] - 100.0))
        np.testing.assert_allclose(got[i].reshape(3), ref, rtol=2e-4)


def test_maskgemm_eligibility_guards():
    from repro.query.ir import GroupAgg
    from repro.query.lower import ONEHOT_MAX_GROUPS, _maskgemm_eligible

    def root_of(q):
        assert isinstance(q.root, GroupAgg)
        return q.root

    assert _maskgemm_eligible(root_of(tq.q1_param_ir()), 6)
    big = Q.scan("lineitem").group_agg(
        keys=[("k", C("l_orderkey"), ONEHOT_MAX_GROUPS + 1)],
        aggs=[("n", "count")])
    assert not _maskgemm_eligible(root_of(big), ONEHOT_MAX_GROUPS + 1)
    div = Q.scan("lineitem").group_agg(
        keys=[("returnflag", C("l_returnflag"), 3)],
        aggs=[("r", "sum", C("l_quantity") / C("l_extendedprice"))])
    assert not _maskgemm_eligible(root_of(div), 3)
    param_measure = Q.scan("lineitem").group_agg(
        keys=[("returnflag", C("l_returnflag"), 3)],
        aggs=[("s", "sum", C("l_quantity") * Param("w", "float32"))])
    assert not _maskgemm_eligible(root_of(param_measure), 3)


def test_parameterize_is_deterministic_and_invertible():
    shape1, b1 = parameterize(tq.q6_ir())
    shape2, b2 = parameterize(
        tq.q6_ir(dataclasses.replace(DP, q6_quantity=30.0)))
    assert same_query(shape1, shape2)
    assert b1 != b2 and set(b1) == set(b2)
    round_trip = bind_params(shape1, b1)
    assert same_query(round_trip, tq.q6_ir())
    # structural literals survive: the Bin edges of a grouped key are not
    # parameterized
    shape3, b3 = parameterize(tq.revenue_by_shipmonth_query())
    assert b3 == {} and same_query(shape3, tq.revenue_by_shipmonth_query())


# ---------------------------------------------------------------------------
# typed negative paths
# ---------------------------------------------------------------------------


def test_missing_and_unknown_bindings_are_typed(tpch_driver):
    prep = tpch_driver.prepare(tq.q6_param_ir())
    with pytest.raises(UnboundParamError, match="q6_date_min"):
        prep.execute({"q6_date_max": DP.q6_date_max})
    with pytest.raises(UnboundParamError, match="q6_typo"):
        prep.execute({**tq.default_binding("q6"), "q6_typo": 1})


def test_conflicting_param_declarations_rejected():
    q = (Q.scan("lineitem")
         .filter((C("l_shipdate") >= Param("p", "int32"))
                 & (C("l_quantity") < Param("p", "float32")))
         .group_agg(aggs=[("n", "count")]))
    with pytest.raises(IRValidationError, match="declared twice"):
        query_params(q.root)


# ---------------------------------------------------------------------------
# parameterized Tier-1 routing (execute-time bin-edge exactness)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def cubed_driver(tpch_driver):
    if not tpch_driver.cubes:
        tpch_driver.build_cubes()
    return tpch_driver


def test_param_binding_on_bin_edge_serves_tier1(cubed_driver):
    prep = cubed_driver.prepare(tq.q1_param_ir())
    ans = prep.execute(tq.default_binding("q1"))  # validation cutoff = edge
    assert ans.tier == 1 and ans.source == "lineitem_pricing"
    _check("q1", np.asarray(ans.value).reshape(6, 6),
           ORACLES["q1"](cubed_driver.tables))


def test_param_binding_off_edge_falls_back_to_prepared_tier2(cubed_driver):
    prep = cubed_driver.prepare(tq.q1_param_ir())
    binding = {"q1_shipdate_max": DP.q1_shipdate_max - 1}  # inside a bin
    ans = prep.execute(binding)
    assert ans.tier == 2
    _check("q1", ans.value, _oracle("q1", cubed_driver, binding))


def test_param_binding_out_of_range_falls_back_to_prepared_tier2(cubed_driver):
    prep = cubed_driver.prepare(tq.q1_param_ir())
    beyond = day(1999, 6, 1)  # past the last bin edge (open last bin)
    ans = prep.execute({"q1_shipdate_max": beyond})
    assert ans.tier == 2
    _check("q1", ans.value, _oracle("q1", cubed_driver,
                                    {"q1_shipdate_max": beyond}))


def test_tier1_and_tier2_share_one_prepared_object(cubed_driver):
    """The SAME PreparedQuery serves edge bindings from the cube and
    off-edge bindings from the compiled plan — one compile covers every
    fallback."""
    d = cubed_driver
    prep = d.prepare(tq.q1_param_ir())
    before = d.compile_events.count("q1_param")
    tiers = {prep.execute(tq.default_binding("q1")).tier,
             prep.execute({"q1_shipdate_max": DP.q1_shipdate_max - 3}).tier,
             prep.execute({"q1_shipdate_max": DP.q1_shipdate_max - 9}).tier}
    assert tiers == {1, 2}
    assert d.compile_events.count("q1_param") <= max(before, 1)


# ---------------------------------------------------------------------------
# batched execution: overflow lanes stay isolated
# ---------------------------------------------------------------------------


def test_batch_overflow_lane_does_not_poison_siblings(cluster):
    """Force the q14 request exchange down to a tiny capacity: a narrow
    month window fits, the five-year window overflows — the overflow flag
    must come back PER LANE and the narrow lane's revenue must still match
    the oracle."""
    driver = TPCHDriver(sf=0.01, cluster=cluster, seed=0,
                        capacities={"q14_promo_param_request_sj0": 64})
    prep = driver.prepare(tq.q14_promo_param_ir(alt="request"))
    narrow = tq.default_binding("q14_promo")
    wide = {"q14_date_min": day(1993, 1, 1), "q14_date_max": day(1998, 1, 1)}
    ans = prep.execute_batch([narrow, wide])
    overflow = np.asarray(ans.overflow)
    assert overflow.tolist() == [False, True], overflow
    _check("q14_promo", np.asarray(ans.value)[0],
           _oracle("q14_promo", driver, narrow))
    # scalar executions agree with the per-lane flags
    assert prep.execute(narrow).overflow is False
    assert prep.execute(wide).overflow is True
