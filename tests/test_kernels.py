"""Per-kernel validation: Pallas (interpret mode on CPU) vs the pure-jnp
oracle in kernels/ref.py, swept over shapes/blocks/dtypes."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import bitset_pack, grouped_agg, mbit_codec, ref, topk_select


# ---------------------------------------------------------------------------
# grouped_agg: fused filter + one-hot aggregation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 7, 100, 256, 1000])
@pytest.mark.parametrize("c", [1, 6])
@pytest.mark.parametrize("g", [1, 6, 32])
def test_grouped_agg_shapes(n, c, g):
    rng = np.random.default_rng(n * 100 + c * 10 + g)
    measures = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    groups = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
    pred = jnp.asarray(rng.integers(0, 100, n).astype(np.int32))
    out = grouped_agg.filtered_group_sum(
        measures, groups, pred, cutoff=50, num_groups=g, block=128, interpret=True
    )
    expect = ref.filtered_group_sum(measures, groups, pred, 50, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block", [64, 256, 2048])
def test_grouped_agg_blocks(block):
    rng = np.random.default_rng(0)
    n, c, g = 777, 6, 6
    measures = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    groups = jnp.asarray(rng.integers(0, g, n).astype(np.int32))
    pred = jnp.asarray(rng.integers(0, 100, n).astype(np.int32))
    out = grouped_agg.filtered_group_sum(
        measures, groups, pred, cutoff=30, num_groups=g, block=block, interpret=True
    )
    expect = ref.filtered_group_sum(measures, groups, pred, 30, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)


def test_grouped_agg_all_filtered():
    n, c, g = 100, 3, 4
    measures = jnp.ones((n, c), jnp.float32)
    groups = jnp.zeros(n, jnp.int32)
    pred = jnp.full(n, 99, jnp.int32)
    out = grouped_agg.filtered_group_sum(
        measures, groups, pred, cutoff=0, num_groups=g, block=64, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(out), np.zeros((g, c), np.float32))


# ---------------------------------------------------------------------------
# topk_select: block top-k via masked argmax sweeps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [5, 64, 500, 4096])
@pytest.mark.parametrize("k", [1, 4, 16])
def test_block_topk(n, k):
    rng = np.random.default_rng(n + k)
    values = jnp.asarray(rng.normal(size=n).astype(np.float32))
    keys = jnp.arange(n, dtype=jnp.int32)
    out_v, out_k = topk_select.block_topk(values, keys, k, block=256, interpret=True)
    ref_v, ref_k = ref.block_topk(values, keys, k, block=256)
    np.testing.assert_allclose(np.asarray(out_v), np.asarray(ref_v), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(ref_k))


def test_block_topk_mask_and_ties():
    values = jnp.asarray([3.0, 3.0, 1.0, 3.0, 2.0, 2.0], jnp.float32)
    keys = jnp.arange(6, dtype=jnp.int32)
    mask = jnp.asarray([True, True, True, False, True, True])
    out_v, out_k = topk_select.block_topk(values, keys, 3, mask, block=8, interpret=True)
    # ties break toward the smaller key; masked row 3 never wins
    np.testing.assert_allclose(np.asarray(out_v)[0], [3.0, 3.0, 2.0])
    np.testing.assert_array_equal(np.asarray(out_k)[0], [0, 1, 4])


# ---------------------------------------------------------------------------
# bitset_pack: predicate -> packed words
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [32, 33, 100, 8192, 10000])
def test_predicate_bitset(n):
    rng = np.random.default_rng(n)
    col = jnp.asarray(rng.integers(0, 5, n).astype(np.int32))
    words = bitset_pack.predicate_bitset(col, 3, block=256, interpret=True)
    expect = ref.predicate_bitset(col, 3)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(expect))
    # probe every bit
    from repro.core import compression

    bits = compression.unpack_bitset(words, n)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(col) == 3)


# ---------------------------------------------------------------------------
# mbit_codec: m-bit group-offset encode + bound decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [4, 8, 16])
@pytest.mark.parametrize("group", [32, 64, 256])
def test_mbit_codec_vs_ref(m, group):
    rng = np.random.default_rng(m * group)
    K = group * 8
    q = jnp.asarray(rng.integers(0, 1 << 30, K).astype(np.uint32))
    words, shifts = mbit_codec.encode(q, m, group, interpret=True)
    ref_words, ref_shifts = ref.mbit_encode(q, m, group)
    np.testing.assert_array_equal(np.asarray(words), np.asarray(ref_words))
    np.testing.assert_array_equal(np.asarray(shifts), np.asarray(ref_shifts))


@pytest.mark.parametrize("m", [4, 8, 16])
def test_mbit_bounds_contain_value(m):
    """The §3.2.5 safety invariant: lower <= q <= upper for every key."""
    rng = np.random.default_rng(m)
    group = 64
    K = group * 16
    # mixed magnitudes stress the per-group shift
    q = np.concatenate([
        rng.integers(0, 1 << 8, K // 4),
        rng.integers(0, 1 << 16, K // 4),
        rng.integers(0, 1 << 24, K // 4),
        rng.integers(0, 1 << 30, K // 4),
    ]).astype(np.uint32)
    rng.shuffle(q)
    qj = jnp.asarray(q)
    words, shifts = mbit_codec.encode(qj, m, group, interpret=True)
    lower, upper = mbit_codec.decode_bounds(words, shifts, m, group)
    lower, upper = np.asarray(lower), np.asarray(upper)
    assert (lower <= q).all()
    assert (q <= upper).all()
    # and the window is exactly 2^shift - 1 wide
    s = np.repeat(np.asarray(shifts), group)
    np.testing.assert_array_equal(upper - lower, (1 << s.astype(np.uint64)) - 1)


def test_mbit_small_values_exact():
    """Values below 2^m need no shift: bounds must be exact."""
    group = 32
    q = jnp.asarray(np.arange(group * 4, dtype=np.uint32) % 200)
    words, shifts = mbit_codec.encode(q, 8, group, interpret=True)
    lower, upper = mbit_codec.decode_bounds(words, shifts, 8, group)
    np.testing.assert_array_equal(np.asarray(lower), np.asarray(q))
    np.testing.assert_array_equal(np.asarray(upper), np.asarray(q))
