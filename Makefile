# Developer entrypoints.  The full suite takes ~7 minutes on the 8-device
# CPU mesh; `test-fast` runs the sub-minute tier1 subset (cube subsystem,
# query IR + lowering, core distributed primitives, flops counter,
# property tests).  CI (.github/workflows/ci.yml) runs `make test-fast`.

PYTEST ?= python -m pytest

.PHONY: test test-fast bench-cubes

test:
	$(PYTEST) -q

test-fast:
	$(PYTEST) -q -m tier1

bench-cubes:
	PYTHONPATH=src python -m benchmarks.cube_speedup --sf 0.05
