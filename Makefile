# Developer entrypoints.  The full suite takes ~7 minutes on the 8-device
# CPU mesh; `test-fast` runs the sub-minute tier1 subset (cube subsystem,
# query IR + lowering, core distributed primitives, flops counter,
# property tests).  CI (.github/workflows/ci.yml) runs `make test-fast`.

PYTEST ?= python -m pytest

.PHONY: test test-fast lint lint-plans bench-cubes bench-smoke

test:
	$(PYTEST) -q

test-fast:
	$(PYTEST) -q -m tier1 --durations=15

# static plan verification: every registry IR query, parameterized TPC-H
# form, and cube serving preset must verify clean (rule catalog:
# docs/RULES.md).  CI gates on this; errors AND warnings fail, infos pass.
lint-plans:
	PYTHONPATH=src python -m repro.launch.serve_olap --lint --sf 0.01

# ruff is a dev-only extra (requirements-dev.txt); skip gracefully where
# it isn't installed so `make lint` works in the minimal container too
lint: lint-plans
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src/repro/query src/repro/core; \
	else \
		echo "ruff not installed; skipping style lint (pip install -r requirements-dev.txt)"; \
	fi

bench-cubes:
	PYTHONPATH=src python -m benchmarks.cube_speedup --sf 0.05

# tiny-scale smoke of the perf benchmarks (CI runs this and uploads the
# JSON from experiments/bench/ as an artifact).  exchange_compression,
# param_throughput, serving_load, and compressed_scan are GATES (non-zero
# exit below 4x wire bytes / 3x batched sweep throughput / 2x coalesced
# serving throughput + 1.2x tier-1 tail bound / 4x scan-column residency
# + 1.1x DRAM-bound packed-scan latency, or on oracle/parity mismatch);
# ir_overhead is a REPORT — its <5% latency target is too noisy to fail
# CI on shared runners
bench-smoke:
	PYTHONPATH=src python -m benchmarks.exchange_compression --sf 0.02 --repeat 5
	PYTHONPATH=src python -m benchmarks.param_throughput --sf 0.02 --repeat 5
	PYTHONPATH=src python -m benchmarks.ir_overhead --sf 0.02 --repeat 5
	PYTHONPATH=src python -m benchmarks.serving_load --sf 0.02 --requests 256 --repeat 3
	PYTHONPATH=src python -m benchmarks.compressed_scan --sf 0.02 --repeat 15
