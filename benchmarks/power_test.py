"""Paper Table 2: the power test — per-query wall times at the largest SF
this container sustains, all 11 queries + variants, plus correctness vs
oracle (the paper checks results against the TPC-H reference)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.tpch.driver import TPCHDriver

QUERIES = ["q1", "q1_kernel", "q2", "q3", "q3_lazy", "q3_repl", "q4", "q5",
           "q11", "q13", "q14", "q15", "q15_1factor", "q15_approx", "q18",
           "q21", "q21_late"]


def run(sf: float = 0.05, repeat: int = 3):
    driver = TPCHDriver(sf=sf, seed=0)
    cols = {n: t.columns for n, t in driver.placed.items()}
    li_rows = driver.tables["lineitem"].num_rows
    rows = []
    for q in QUERIES:
        fn = driver.compile(q)
        dt, _ = timeit(fn, cols, repeat=repeat)
        rows.append({
            "query": q,
            "runtime_ms": dt * 1e3,
            "rows_per_sec": li_rows / dt,
        })
    emit("table2_power_test", rows, ["query", "runtime_ms", "rows_per_sec"])
    print(f"(SF={sf}: lineitem={li_rows} rows, "
          f"{driver.cluster.num_nodes} nodes)")
    return rows


if __name__ == "__main__":
    run()
