"""Paper Fig. 2 + Fig. 3: weak-scaling runtimes and communication share.

The paper runs {2^i nodes, SF 100*2^i}; this CPU container weak-scales the
same way at reduced absolute size: {P nodes, SF base*P} for P in {1, 2, 4, 8}
host devices, per query.  Communication share is derived from the lowered
HLO's collective bytes (launch/roofline.py) — the walltime of a CPU
collective is not meaningful for the paper's InfiniBand story, but the
BYTES exchanged per node scale exactly like the paper's Fig. 3.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, timeit
from repro.core import Cluster
from repro.core.plans import PLANS
from repro.launch.roofline import parse_collective_bytes
from repro.tpch.driver import TPCHDriver

QUERIES = ["q1", "q2", "q3", "q3_lazy", "q3_repl", "q4", "q5", "q11", "q13",
           "q14", "q15", "q18", "q21", "q21_late"]
BASE_SF = 0.004


def run(repeat: int = 3):
    devices = jax.devices()
    rows = []
    sizes = [p for p in (1, 2, 4, 8) if p <= len(devices)]
    for P in sizes:
        cluster = Cluster(devices=devices[:P])
        driver = TPCHDriver(sf=BASE_SF * P, cluster=cluster, seed=0)
        cols = {n: t.columns for n, t in driver.placed.items()}
        for q in QUERIES:
            fn = driver.compile(q)
            dt, _ = timeit(fn, cols, repeat=repeat)
            lowered = jax.jit(
                jax.shard_map(
                    lambda c, _plan=PLANS[q], _ctx=driver.ctx: _plan(_ctx, c),
                    mesh=cluster.mesh,
                    in_specs=(_in_specs(driver),),
                    out_specs=jax.sharding.PartitionSpec(),
                    check_vma=False,
                )
            ).lower(cols)
            coll = parse_collective_bytes(lowered.compile().as_text())
            rows.append({
                "nodes": P, "sf": BASE_SF * P, "query": q,
                "runtime_ms": dt * 1e3,
                "collective_bytes_per_node": coll.total_bytes,
                "collective_ops": sum(coll.count_by_op.values()),
            })
    emit("fig2_weak_scaling", rows,
         ["nodes", "sf", "query", "runtime_ms",
          "collective_bytes_per_node", "collective_ops"])
    return rows


def _in_specs(driver):
    from jax.sharding import PartitionSpec as P

    return {
        name: {c: (P() if t.replicated else P("nodes")) for c in t.columns}
        for name, t in driver.placed.items()
    }


if __name__ == "__main__":
    run()
