"""Paper Fig. 2 + Fig. 3: weak-scaling runtimes and communication share.

The paper runs {2^i nodes, SF 100*2^i}; this CPU container weak-scales the
same way at reduced absolute size: {P nodes, SF base*P} for P in {1, 2, 4, 8}
host devices, per query.  Communication share is derived from the lowered
HLO's collective bytes (launch/roofline.py) — the walltime of a CPU
collective is not meaningful for the paper's InfiniBand story, but the
BYTES exchanged per node scale exactly like the paper's Fig. 3.

A final "extended SF" point demonstrates the compressed-resident lever:
at SF_EXT the RAW residency exceeds a per-run budget (TPCHDriver raises
ResidentBudgetError) while the packed residency fits in the same budget
and still answers queries — the scale factors a node can hold grow by
the residency-reduction factor without new hardware.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from benchmarks.common import emit, timeit
from repro.core import Cluster
from repro.core.columnar import decode_columns
from repro.core.plans import PLANS
from repro.launch.roofline import parse_collective_bytes
from repro.tpch.driver import ResidentBudgetError, TPCHDriver

QUERIES = ["q1", "q2", "q3", "q3_lazy", "q3_repl", "q4", "q5", "q11", "q13",
           "q14", "q15", "q18", "q21", "q21_late"]
BASE_SF = 0.004
SF_EXT_FACTOR = 4      # extended point: SF beyond what raw residency holds
EXT_QUERIES = ["q1", "q6"]


def run(repeat: int = 3):
    devices = jax.devices()
    rows = []
    sizes = [p for p in (1, 2, 4, 8) if p <= len(devices)]
    for P in sizes:
        cluster = Cluster(devices=devices[:P])
        driver = TPCHDriver(sf=BASE_SF * P, cluster=cluster, seed=0)
        cols = {n: t.columns for n, t in driver.placed.items()}
        for q in QUERIES:
            fn = driver.compile(q)
            dt, _ = timeit(fn, cols, repeat=repeat)
            lowered = jax.jit(
                jax.shard_map(
                    lambda c, _plan=PLANS[q], _ctx=driver.ctx: _plan(
                        _ctx, {t: decode_columns(cs) for t, cs in c.items()}),
                    mesh=cluster.mesh,
                    in_specs=(_in_specs(driver),),
                    out_specs=jax.sharding.PartitionSpec(),
                    check_vma=False,
                )
            ).lower(cols)
            coll = parse_collective_bytes(lowered.compile().as_text())
            rows.append({
                "nodes": P, "sf": BASE_SF * P, "query": q,
                "storage": "packed", "runtime_ms": dt * 1e3,
                "collective_bytes_per_node": coll.total_bytes,
                "collective_ops": sum(coll.count_by_op.values()),
            })
    rows.extend(extended_sf_point(devices, repeat=repeat))
    emit("fig2_weak_scaling", rows,
         ["nodes", "sf", "query", "storage", "runtime_ms",
          "collective_bytes_per_node", "collective_ops"])
    return rows


def extended_sf_point(devices, repeat: int = 3):
    """One SF beyond raw residency: packed fits the budget, raw raises."""
    P = min(8, len(devices))
    sf_ext = BASE_SF * P * SF_EXT_FACTOR
    cluster = Cluster(devices=devices[:P])
    driver = TPCHDriver(sf=sf_ext, cluster=cluster, seed=0)
    # a budget between the packed footprint and the raw one: the packed
    # driver just fit in it; the raw driver must refuse to build.
    budget = driver.resident_bytes * 2
    try:
        TPCHDriver(sf=sf_ext, cluster=cluster, seed=0, storage="raw",
                   resident_budget=budget)
        raise AssertionError(
            f"raw residency unexpectedly fit the {budget}-byte budget at "
            f"SF {sf_ext} — the extended weak-scaling point is meaningless")
    except ResidentBudgetError:
        pass
    # the packed driver re-checked against the same budget is a no-op
    # (already resident), so assert the invariant directly:
    assert driver.resident_bytes <= budget
    cols = {n: t.columns for n, t in driver.placed.items()}
    rows = []
    for q in EXT_QUERIES:
        fn = driver.compile(q)
        dt, _ = timeit(fn, cols, repeat=repeat)
        rows.append({
            "nodes": P, "sf": sf_ext, "query": q, "storage": "packed",
            "runtime_ms": dt * 1e3,
            "collective_bytes_per_node": 0, "collective_ops": 0,
        })
    print(f"extended SF point: sf={sf_ext} packed resident "
          f"{driver.resident_bytes}B fits budget {budget}B; "
          f"raw residency raises ResidentBudgetError")
    return rows


def _in_specs(driver):
    from jax.sharding import PartitionSpec as P

    return {
        name: {c: (P() if t.replicated else P("nodes")) for c in t.columns}
        for name, t in driver.placed.items()
    }


if __name__ == "__main__":
    run()
