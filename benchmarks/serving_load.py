"""Serving-tier load benchmark: continuous batching vs one-client serving.

Every other benchmark in this directory measures a single synchronous
client; this one measures the thing the serving tier exists for —
throughput and TAIL latency under concurrent mixed load (the paper's
interactive-analytics setting).  Three measurements on one mixed
Tier-1/Tier-2/parameterized workload (``repro.serve.workload``):

  sequential  ONE synchronous client replaying the stream through
              prepared ``execute`` — the pre-engine status quo,
  engine      the continuous-batching engine under a closed-loop client
              swarm — same items, coalesced dispatches.

Gates (CI fails when any is violated):

  * coalesced throughput >= 2x the sequential-prepared q/s,
  * Tier-1 p99 under full concurrent load <= 1.2x the solo-client
    Tier-1 p99, OR within 1 ms of it — the router path must not queue
    behind Tier-2 batches.  The solo baseline is the tier1 class of the
    SEQUENTIAL replay: same mixed stream, one client, so both
    measurements see a Tier-1 request in the cache/scheduler shadow of
    adjacent Tier-2 work and the ratio isolates added QUEUEING (the
    thing the engine controls) from core-sharing (which hits any
    co-located workload, engine or not).  The absolute slack exists
    because both p99s are sub-millisecond: a Tier-1 request actually
    queued behind a batch would wait one batch execution (~15 ms),
    while one scheduler hiccup on a shared single core moves a
    sub-ms p99 by a few hundred us — only the former is a regression,
  * answer parity: every engine answer matches the sequential answer for
    the same item (allclose; the batched GEMM lowering of the q1 family
    reassociates float sums, so bitwise equality is only a q6 property).

The GC is disabled inside the measured region (all modes equally):
collection pauses land on whichever request triggers them and a
load-correlated pause is exactly the artifact the tail gate must not
measure.  Results land in ``experiments/bench/serving_load.json``.

  PYTHONPATH=src python -m benchmarks.serving_load --sf 0.02
"""
from __future__ import annotations

import argparse
import asyncio
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np

from benchmarks.common import emit

GATE_COALESCE_X = 2.0     # engine q/s vs sequential-prepared q/s
GATE_TAIL_X = 1.2         # loaded tier1 p99 vs solo tier1 p99, or ...
GATE_TAIL_SLACK_MS = 1.0  # ... within this absolute delta (queueing
                          # behind a batch would add ~15 ms, not sub-ms)


def _flat(value) -> np.ndarray:
    if isinstance(value, dict):
        return np.concatenate([np.ravel(np.asarray(v, np.float64))
                               for _, v in sorted(value.items())])
    return np.ravel(np.asarray(value, np.float64))


def _parity(a, b) -> bool:
    """Engine answer vs sequential answer for the same work item."""
    return bool(np.allclose(_flat(a.value), _flat(b.value),
                            rtol=5e-4, atol=1e-6))


_COUNTERS = ("requests", "tier1", "solo", "batches", "coalesced_lanes")


async def _engine_run(driver, items, *, clients, max_batch, max_wait_us):
    from repro.serve.olap_engine import OLAPEngine
    from repro.serve import workload as wl

    engine = OLAPEngine(driver, max_batch=max_batch,
                        max_wait_us=max_wait_us)
    async with engine:
        before = engine.stats()   # serve.* counters are process-cumulative
        t0 = time.perf_counter()
        res = await wl.run_closed_loop(engine, items, clients=clients)
        wall = time.perf_counter() - t0
        stats = engine.stats()
    for k in _COUNTERS:           # report THIS run, not the whole process
        stats[k] -= before[k]
    return res, wall, stats


def _tier1_p99(completions) -> float:
    from repro.serve import workload as wl

    return wl.percentile([c.latency_s for c in completions
                          if c.item.kind == "tier1"], 0.99)


def run(sf: float = 0.02, requests: int = 384, clients: int = 16,
        max_batch: int = 16, max_wait_us: float = 2000.0,
        repeat: int = 3, seed: int = 0):
    import gc

    from repro.serve import workload as wl
    from repro.tpch.driver import TPCHDriver

    driver = TPCHDriver(sf=sf, seed=seed)
    driver.build_cubes()
    items = wl.mixed_workload(driver, requests, seed=seed)
    sizes = sorted({2 ** i for i in range(max_batch.bit_length())
                    if 2 ** i <= max_batch} | {max_batch})
    wl.warm_workload(driver, items, batch_sizes=sizes)

    # PAIRED passes: the host this runs on is small and shared, so
    # absolute q/s drifts minute to minute — alternating the two modes
    # and gating on the best sequential/engine PAIR cancels the drift
    # (both halves of a pair see the same machine weather)
    gc.collect()
    gc.disable()
    try:
        speedup, tail_x, tail_dms = 0.0, float("inf"), float("inf")
        seq_wall, seq_qps, seq_res, solo_p99 = float("inf"), 0.0, None, None
        eng_qps, eng_wall, loaded_p99 = 0.0, 0.0, None
        res, stats = None, None
        for _ in range(repeat):
            t0 = time.perf_counter()
            sr = wl.sequential_baseline(driver, items)
            s_wall = time.perf_counter() - t0
            s_qps, s_p99 = len(items) / s_wall, _tier1_p99(sr)

            r, wall, st = asyncio.run(_engine_run(
                driver, items, clients=clients, max_batch=max_batch,
                max_wait_us=max_wait_us))
            e_qps = sum(1 for c in r if c.ok) / wall
            e_p99 = _tier1_p99(r)

            if s_wall < seq_wall:
                seq_wall, seq_qps, seq_res, solo_p99 = (
                    s_wall, s_qps, sr, s_p99)
            if e_qps > eng_qps:
                eng_qps, eng_wall, loaded_p99, res, stats = (
                    e_qps, wall, e_p99, r, st)
            speedup = max(speedup, e_qps / s_qps)
            tail_x = min(tail_x, e_p99 / s_p99 if s_p99 > 0
                         else float("inf"))
            tail_dms = min(tail_dms, (e_p99 - s_p99) * 1e3)
    finally:
        gc.enable()
    rep = wl.summarize(res, eng_wall)

    # -- gates --------------------------------------------------------------
    mismatch = sum(1 for e, s in zip(res, seq_res)
                   if not (e.ok and _parity(e.answer, s.answer)))
    tail_ok = tail_x <= GATE_TAIL_X or tail_dms <= GATE_TAIL_SLACK_MS
    ok = speedup >= GATE_COALESCE_X and tail_ok and mismatch == 0

    lanes = stats["requests"] - stats["tier1"] - stats["solo"]
    rows = [
        {"mode": "sequential", "n": len(items), "qps": seq_qps,
         "wall_s": seq_wall},
        {"mode": "engine", "n": len(items), "qps": eng_qps,
         "wall_s": eng_wall, "batches": stats["batches"],
         "coalesced_lanes": stats["coalesced_lanes"],
         "mean_batch": lanes / stats["batches"] if stats["batches"] else 0.0,
         "tier1_inline": stats["tier1"]},
    ]
    for kind, s in rep["kinds"].items():
        rows.append({"mode": f"engine:{kind}", "n": s["n"],
                     "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"]})
    rows.append({"mode": "tier1_solo",
                 "n": sum(1 for it in items if it.kind == "tier1"),
                 "p99_ms": solo_p99 * 1e3})
    rows.append({"mode": "GATES", "qps": eng_qps,
                 "speedup_x": speedup, "tier1_tail_x": tail_x,
                 "tier1_tail_dms": tail_dms,
                 "parity_mismatches": mismatch, "ok": ok})
    emit("serving_load", rows,
         ["mode", "n", "qps", "wall_s", "p50_ms", "p99_ms", "batches",
          "coalesced_lanes", "mean_batch", "tier1_inline", "speedup_x",
          "tier1_tail_x", "tier1_tail_dms", "parity_mismatches", "ok"])
    status = "OK" if ok else "FAILED"
    print(f"\ncoalesced {speedup:.1f}x sequential q/s "
          f"(>= {GATE_COALESCE_X:.0f}x), tier1 p99 {tail_x:.2f}x solo "
          f"/ {tail_dms:+.2f} ms (<= {GATE_TAIL_X:.1f}x or "
          f"<= +{GATE_TAIL_SLACK_MS:.0f} ms), "
          f"{mismatch} parity mismatches: {status}")
    return rows, ok


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.02)
    p.add_argument("--requests", type=int, default=384)
    p.add_argument("--clients", type=int, default=16)
    p.add_argument("--max-batch", type=int, default=16)
    p.add_argument("--max-wait-us", type=float, default=2000.0)
    p.add_argument("--repeat", type=int, default=3)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    _, ok = run(sf=args.sf, requests=args.requests, clients=args.clients,
                max_batch=args.max_batch, max_wait_us=args.max_wait_us,
                repeat=args.repeat, seed=args.seed)
    sys.exit(0 if ok else 1)
