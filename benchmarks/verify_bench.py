"""Static-verifier latency: how long does ``TPCHDriver.check`` take per
registry query?  The verifier sits on the prepare path (EXPLAIN renders
its diagnostics, ``--lint`` gates CI on it), so it must stay cheap
relative to an XLA compile — this reports per-query wall time plus the
diagnostic counts so a rule that suddenly explodes in cost shows up.

  PYTHONPATH=src python -m benchmarks.verify_bench --sf 0.02
"""
from __future__ import annotations

import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def run(sf: float = 0.02, repeat: int = 5):
    from benchmarks.common import emit
    from repro.core.plans import REGISTRY
    from repro.tpch import queries as tq
    from repro.tpch.driver import TPCHDriver

    d = TPCHDriver(sf=sf, seed=0)
    targets = [(name, qd.ir) for name, qd in REGISTRY.items()
               if qd.ir is not None]
    targets += [(f"{name}_param", make()) for name, make
                in tq.PARAM_QUERIES.items()]

    rows = []
    for name, q in targets:
        rep = d.check(q)  # warm the prepare cache
        times = []
        for _ in range(repeat):
            t0 = time.perf_counter()
            rep = d.check(q)
            times.append(time.perf_counter() - t0)
        rows.append({
            "query": name,
            "verify_ms": min(times) * 1e3,
            "errors": len(rep.errors),
            "warnings": len(rep.warnings),
            "infos": len(rep.infos),
        })
    emit("verify_bench", rows,
         ["query", "verify_ms", "errors", "warnings", "infos"])
    return rows


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.02)
    p.add_argument("--repeat", type=int, default=5)
    args = p.parse_args(argv)
    run(sf=args.sf, repeat=args.repeat)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
