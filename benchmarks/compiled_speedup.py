"""Paper Table 1: intra-node parallel speedup.

The paper compares multi-threaded vs single-threaded runtimes per query
(speedups 1.8–24x).  The TPU-era analogue of "use all cores of the node" is
"run the compiled XLA data-parallel program instead of a scalar
interpreter": we report jitted-plan runtime vs the numpy oracle (scalar
reference semantics) on identical data — the same quantity the paper's
Table 1 isolates (single-node parallel efficiency of the local operators),
reported as oracle_ms / plan_ms."""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.tpch.driver import TPCHDriver

QUERIES = ["q1", "q2", "q3", "q3_lazy", "q4", "q5", "q6", "q11", "q13",
           "q14", "q15", "q18", "q21", "q21_late"]


def run(sf: float = 0.02, repeat: int = 3):
    driver = TPCHDriver(sf=sf, seed=0)
    cols = {n: t.columns for n, t in driver.placed.items()}
    rows = []
    for q in QUERIES:
        fn = driver.compile(q)
        plan_dt, _ = timeit(fn, cols, repeat=repeat)
        # the registry's explicit oracle binding handles variant suffixes
        oracle_dt, _ = timeit(lambda: driver.oracle(q), repeat=repeat,
                              warmup=0)
        rows.append({
            "query": q,
            "plan_ms": plan_dt * 1e3,
            "oracle_ms": oracle_dt * 1e3,
            "speedup": oracle_dt / plan_dt,
        })
    emit("table1_compiled_speedup", rows,
         ["query", "plan_ms", "oracle_ms", "speedup"])
    return rows


if __name__ == "__main__":
    run()
