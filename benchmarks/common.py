"""Shared benchmark utilities: timing, CSV/markdown emission."""
from __future__ import annotations

import json
import os
import time

import jax

RESULTS_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def timeit(fn, *args, repeat: int = 5, warmup: int = 1):
    """Best-of-N walltime (paper §5.1 measures walltime after a barrier —
    jax.block_until_ready is our barrier)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return min(times), out


def emit(name: str, rows: list[dict], columns: list[str]):
    """Print a markdown table and persist JSON."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n## {name}")
    print("| " + " | ".join(columns) + " |")
    print("|" + "|".join("---" for _ in columns) + "|")
    for r in rows:
        print("| " + " | ".join(_fmt(r.get(c)) for c in columns) + " |")


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
