"""Paper Fig. 4: Q15 top-k variants — (1) simple + library all-to-all,
(2) simple + 1-factor, (3) m-bit approximation — runtime and exchanged
bytes per node (the paper's 8x traffic reduction at m=8)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.tpch.driver import TPCHDriver

VARIANTS = ["q15", "q15_1factor", "q15_approx"]


def run(sf: float = 0.02, repeat: int = 3):
    driver = TPCHDriver(sf=sf, seed=0)
    cols = {n: t.columns for n, t in driver.placed.items()}
    rows = []
    naive_bits = None
    for v in VARIANTS:
        fn = driver.compile(v)
        dt, out = timeit(fn, cols, repeat=repeat)
        row = {"variant": v, "runtime_ms": dt * 1e3}
        if v == "q15_approx":
            stats = out["stats"]
            row["bits_per_node"] = float(np.asarray(stats.approx_bits_per_node))
            row["naive_bits_per_node"] = float(
                np.asarray(stats.naive_bits_per_node))
            row["traffic_reduction_x"] = (row["naive_bits_per_node"]
                                          / row["bits_per_node"])
            row["candidates"] = int(np.asarray(stats.num_candidates))
            naive_bits = row["naive_bits_per_node"]
        else:
            K = driver.ctx.part("supplier").total_rows
            row["bits_per_node"] = float(K * 32)  # full f32 partials
        rows.append(row)
    emit("fig4_q15_topk", rows,
         ["variant", "runtime_ms", "bits_per_node", "traffic_reduction_x",
          "candidates"])
    return rows


def sweep_m(sf: float = 0.02):
    """Extra ablation beyond the paper's single m=8 point: m in {4,8,16}."""
    rows = []
    for m in (4, 8, 16):
        driver = TPCHDriver(sf=sf, seed=0)
        cols = {n: t.columns for n, t in driver.placed.items()}
        from repro.core.plans.distributed_topk import q15_approx

        fn = driver.cluster.compile(
            lambda ctx, t, _m=m: q15_approx(ctx, t, m=_m),
            driver.ctx, driver.placed)
        dt, out = timeit(fn, cols, repeat=3)
        stats = out["stats"]
        ok = bool(np.asarray(out["valid"])[0])
        rows.append({
            "m": m, "runtime_ms": dt * 1e3,
            "bits_per_node": float(np.asarray(stats.approx_bits_per_node)),
            "candidates": int(np.asarray(stats.num_candidates)),
            "correct": ok,
        })
    emit("fig4b_m_sweep", rows,
         ["m", "runtime_ms", "bits_per_node", "candidates", "correct"])
    return rows


if __name__ == "__main__":
    run()
    sweep_m()
