"""§Roofline reporter: reads the dry-run sweep JSONs (experiments/dryrun/)
and renders the per-cell roofline table for EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_OUT", "experiments/dryrun")


def mitigation(r: dict, arch: str, shape: str) -> str:
    dom = r["dominant"]
    if dom == "compute":
        return "raise useful-FLOP ratio (less remat / padding) or add chips"
    if dom == "memory":
        if "decode" in shape or "long" in shape:
            return "shrink KV/state bytes: unpadded kv heads + int8 cache"
        return "fuse attention (Pallas flash) to stop materializing scores"
    return "overlap collectives with compute; shrink exchanged bytes (int8)"


def run():
    rows = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        if f.endswith("summary.json"):
            continue
        cell = json.load(open(f))
        if not cell.get("runnable"):
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": cell["mesh_desc"], "status": "SKIP",
                         "note": cell["skip_reason"][:60]})
            continue
        if cell.get("error"):
            rows.append({"arch": cell["arch"], "shape": cell["shape"],
                         "mesh": cell["mesh_desc"], "status": "FAIL",
                         "note": cell["error"][:60]})
            continue
        r = cell["roofline"]
        rows.append({
            "arch": cell["arch"], "shape": cell["shape"],
            "mesh": cell["mesh_desc"], "status": "OK",
            "compute_ms": r["compute_s"] * 1e3,
            "memory_ms": r["memory_s"] * 1e3,
            "collective_ms": r["collective_s"] * 1e3,
            "dominant": r["dominant"],
            "useful_flops": r["useful_flops_ratio"],
            "roofline_frac": r["roofline_fraction"],
            "note": mitigation(r, cell["arch"], cell["shape"]),
        })
    emit("roofline_table", rows,
         ["arch", "shape", "mesh", "status", "compute_ms", "memory_ms",
          "collective_ms", "dominant", "useful_flops", "roofline_frac",
          "note"])
    return rows


if __name__ == "__main__":
    run()
