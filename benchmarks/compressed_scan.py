"""Compressed-resident scan benchmark: execute directly on packed columns.

The resident format bit-packs dictionary/FOR codes at their required width
(``core.columnar.PackedColumn``); the lowering rewrites filter conjuncts
into code space, fuses same-column ranges, and scans the packed words
directly (``kernels/scan_filter``), decoding only surviving rows.  Gates:

1. **Bytes resident**: the TPC-H scan-predicate columns (the q1/q6 filter
   and group-key columns of lineitem) occupy >= 4x fewer resident bytes
   packed than raw — the "10x the scale factor a node can hold" lever.
   Whole-table and whole-database ratios are reported alongside (they
   include columns that stay raw by design, e.g. l_extendedprice).
2. **Scan latency**: predicate-on-packed is NOT a space/time trade-off in
   the regime the paper targets — large memory-resident partitions where
   scans are DRAM-bandwidth-bound.  At 8M rows the packed range scan must
   run <= 1.1x the raw int32 compare (median of paired ratios) at the
   dictionary/flag widths; it typically WINS there because it reads
   width/32 of the bytes.  (End-to-end query latencies at the small bench
   SF are also reported, unGATED: at ~7.5k rows/node everything is
   dispatch-bound and the packed path pays fixed per-op overheads the
   roofline model would route around on a calibrated machine —
   ``python -m repro.core.scancal`` to calibrate.)
3. **Parity**: lowered plans on packed residency match their float64
   numpy oracles on BOTH collective backends (xla, one_factor).

Bytes-scanned accounting (the roofline's prediction, surfaced by the
``storage.bytes_scanned`` counters) is reported per filter decision.

  PYTHONPATH=src python -m benchmarks.compressed_scan --sf 0.02
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import compression
from repro.core import plans as plan_registry
from repro.core.columnar import PackedColumn
from repro.kernels import ops, ref
from repro.query.lower import lower
from repro.tpch.driver import TPCHDriver

GATE_RESIDENT_REDUCTION = 4.0   # packed vs raw bytes, scan-predicate cols
GATE_LATENCY = 1.10             # packed scan vs raw compare, DRAM-bound

# the filter + group-key columns of the scan-bound queries (q1, q6)
SCAN_COLUMNS = ("l_shipdate", "l_discount", "l_quantity", "l_tax",
                "l_returnflag", "l_linestatus")
SCAN_ROWS = 1 << 23             # DRAM-bound: 32 MB raw, width/8 MB packed
GATED_WIDTHS = (1, 4, 8)        # flag/dictionary widths; wider ones report
REPORT_WIDTHS = (1, 4, 8, 12, 16)

LATENCY_QUERIES = ("q1", "q6")  # scan-bound lowered plans (reported)
PARITY = ("q1", "q4", "q6")
BACKENDS = ("xla", "one_factor")


def _compile(driver, q, *, backend: str = "xla"):
    plan = lower(q, driver.catalog)
    ctx = dataclasses.replace(driver.ctx, backend=backend)
    return driver.cluster.compile(plan, ctx, driver.placed)


def _clock(fn, *args) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def resident_report(packed: TPCHDriver, raw: TPCHDriver):
    """Per-column resident footprint of the scan table, plus totals."""
    rows, pb, rb, spb, srb = [], 0, 0, 0, 0
    for name, col in packed.resident["lineitem"].columns.items():
        if not isinstance(col, PackedColumn):
            continue
        gated = name in SCAN_COLUMNS
        rows.append({
            "table": "lineitem", "column": name, "width": col.width,
            "encoding": "dict" if col.values is not None else
            ("bool" if col.dtype == "bool" else "for"),
            "packed_bytes": col.nbytes, "raw_bytes": col.raw_nbytes,
            "reduction_x": col.raw_nbytes / max(col.nbytes, 1),
            "gated": gated,
        })
        pb += col.nbytes
        rb += col.raw_nbytes
        if gated:
            spb += col.nbytes
            srb += col.raw_nbytes
    reduction = srb / max(spb, 1)
    rows.append({
        "table": "lineitem", "column": "<scan-predicate cols>", "width": "",
        "encoding": "", "packed_bytes": spb, "raw_bytes": srb,
        "reduction_x": reduction, "gated": True,
    })
    rows.append({
        "table": "lineitem", "column": "<packed total>", "width": "",
        "encoding": "", "packed_bytes": pb, "raw_bytes": rb,
        "reduction_x": rb / max(pb, 1), "gated": False,
    })
    rows.append({
        "table": "<all tables>", "column": "<resident total>", "width": "",
        "encoding": "", "packed_bytes": packed.resident_bytes,
        "raw_bytes": raw.resident_bytes,
        "reduction_x": raw.resident_bytes / max(packed.resident_bytes, 1),
        "gated": False,
    })
    return rows, reduction


def scan_kernel_bench(repeat: int = 15, seed: int = 0):
    """Packed range scan vs raw int32 compare at DRAM-bound size, per
    width.  Single device, 8M rows: the raw compare reads 32 MB, the
    packed scan width/32 of that — bandwidth, not dispatch, decides."""
    rng = np.random.default_rng(seed)
    rows_out, ok = [], True
    n = SCAN_ROWS
    for width in REPORT_WIDTHS:
        codes = rng.integers(0, 1 << width, n, dtype=np.int64).astype(np.uint32)
        words = compression.pack_bits(jnp.asarray(codes), width)
        raw = jnp.asarray(codes.astype(np.int32))
        lo, hi = 1, max((1 << width) - 2, 1)

        @jax.jit
        def packed_scan(w, _width=width, _lo=lo, _hi=hi):
            return ops.scan_filter(w, _lo, _hi, rows=n, padded_rows=n,
                                   width=_width)

        @jax.jit
        def raw_scan(c, _lo=lo, _hi=hi):
            return compression.pack_bitset((c >= _lo) & (c <= _hi))

        # parity against the oracle before timing
        want = np.asarray(ref.scan_filter(words, lo, hi, n, n, width))
        parity = (np.array_equal(np.asarray(packed_scan(words)), want)
                  and np.array_equal(np.asarray(raw_scan(raw)), want))
        jax.block_until_ready(packed_scan(words))
        jax.block_until_ready(raw_scan(raw))
        raw_times, ratios = [], []
        for _ in range(max(repeat, 5)):
            r = _clock(raw_scan, raw)
            raw_times.append(r)
            ratios.append(_clock(packed_scan, words) / r)
        ratio = sorted(ratios)[len(ratios) // 2]
        raw_ms = min(raw_times) * 1e3
        gated = width in GATED_WIDTHS
        ok &= parity and (ratio <= GATE_LATENCY or not gated)
        rows_out.append({
            "rows": n, "width": width, "raw_ms": raw_ms,
            "packed_ms": raw_ms * ratio, "packed_vs_raw_x": ratio,
            "bytes_ratio_x": 32 / width, "gated": gated,
            "parity_ok": parity,
        })
    emit("compressed_scan_kernel", rows_out,
         ["rows", "width", "raw_ms", "packed_ms", "packed_vs_raw_x",
          "bytes_ratio_x", "gated", "parity_ok"])
    return rows_out, ok


def run(sf: float = 0.02, repeat: int = 30, seed: int = 0):
    packed = TPCHDriver(sf=sf, seed=seed)            # packed is the default
    raw = TPCHDriver(sf=sf, seed=seed, storage="raw")
    cols_p = {n: t.columns for n, t in packed.placed.items()}
    cols_r = {n: t.columns for n, t in raw.placed.items()}

    rows, reduction = resident_report(packed, raw)
    ok = reduction >= GATE_RESIDENT_REDUCTION

    # -- end-to-end query latency at bench SF (reported, ungated) -----------
    lat_rows = []
    for name in LATENCY_QUERIES:
        q = plan_registry.get(name).ir
        fn_p = _compile(packed, q)
        fn_r = _compile(raw, q)
        oracle = np.asarray(raw.oracle(name), np.float64)
        out_p = np.asarray(
            jax.tree.map(np.asarray, fn_p(cols_p))["value"], np.float64)
        parity = np.allclose(out_p.reshape(oracle.shape), oracle, rtol=2e-4)
        jax.block_until_ready(fn_p(cols_p))
        jax.block_until_ready(fn_r(cols_r))
        raw_times, ratios = [], []
        for _ in range(max(repeat, 5)):
            r = _clock(fn_r, cols_r)
            raw_times.append(r)
            ratios.append(_clock(fn_p, cols_p) / r)
        ratio = sorted(ratios)[len(ratios) // 2]
        raw_ms = min(raw_times) * 1e3
        ok &= parity
        plan = lower(q, packed.catalog)
        scans = " ".join(f"{d.column}:{d.mode}@w{d.width}={d.scan_bytes}B"
                         for d in plan.scans)
        lat_rows.append({
            "query": name, "raw_ms": raw_ms, "packed_ms": raw_ms * ratio,
            "packed_vs_raw_x": ratio, "scan_decisions": scans,
            "oracle_ok": parity,
        })

    # -- bytes-scanned accounting (the metrics the serving tier exports) ----
    m = packed.obs.metrics
    before = m.value("storage.bytes_scanned")
    prep = packed.prepare("q6")
    prep.execute()
    scanned = m.value("storage.bytes_scanned") - before
    raw_scanned = (sum(d.raw_bytes for d in prep.entry.scans)
                   * packed.catalog.num_nodes)
    rows.append({
        "table": "lineitem", "column": "<q6 bytes_scanned>", "width": "",
        "encoding": "", "packed_bytes": scanned, "raw_bytes": raw_scanned,
        "reduction_x": raw_scanned / max(scanned, 1), "gated": False,
    })

    emit("compressed_scan", rows,
         ["table", "column", "width", "encoding", "packed_bytes",
          "raw_bytes", "reduction_x", "gated"])
    emit("compressed_scan_latency", lat_rows,
         ["query", "raw_ms", "packed_ms", "packed_vs_raw_x",
          "scan_decisions", "oracle_ok"])

    # -- oracle parity on packed residency, both collective backends --------
    parity_rows = []
    for name in PARITY:
        q = plan_registry.get(name).ir
        oracle = packed.oracle(name)
        for backend in BACKENDS:
            out = jax.tree.map(np.asarray,
                               _compile(packed, q, backend=backend)(cols_p))
            if name == "q4":
                match = np.array_equal(out["value"][:, 0], oracle)
            else:
                match = np.allclose(
                    np.asarray(out["value"]).reshape(np.shape(oracle)),
                    oracle, rtol=2e-4)
            ok &= bool(match)
            parity_rows.append({"query": name, "backend": backend,
                                "storage": "packed",
                                "oracle_ok": bool(match)})
    emit("compressed_scan_parity", parity_rows,
         ["query", "backend", "storage", "oracle_ok"])
    return rows, lat_rows, parity_rows, ok, reduction


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.02)
    p.add_argument("--repeat", type=int, default=30)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--skip-kernel-bench", action="store_true")
    args = p.parse_args()
    _, _, _, ok, reduction = run(sf=args.sf, repeat=args.repeat,
                                 seed=args.seed)
    slowest = None
    if not args.skip_kernel_bench:
        krows, kernel_ok = scan_kernel_bench(seed=args.seed)
        ok = ok and kernel_ok
        slowest = max(r["packed_vs_raw_x"] for r in krows if r["gated"])
    status = "OK" if ok else "FAILED"
    lat = (f", DRAM-bound packed scan {slowest:.2f}x raw "
           f"(<= {GATE_LATENCY:.2f}x target)" if slowest is not None else "")
    print(f"\nscan-column residency reduction: {reduction:.1f}x "
          f"(>= {GATE_RESIDENT_REDUCTION:.0f}x target){lat}, oracle "
          f"parity on {'/'.join(PARITY)} x {'/'.join(BACKENDS)}: {status}")
    sys.exit(0 if ok else 1)
