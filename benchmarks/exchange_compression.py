"""Wire-format benchmark: packed vs raw exchange encodings (§3.2.1).

The exchange layer can ship its request buckets either as raw int32 keys +
a separate bool-mask all-to-all, or as the packed wire format (EF-coded
keys at catalog-derived widths, mask folded in, bitset replies).  This
benchmark proves the reduction FROM THE LOWERED HLO — the all-to-all
operand bytes of the compiled SPMD plan — on the q4/q18 semi-join
exchanges (the Q4/Q18 shapes forced through the §3.2.2 request exchange),
and checks that every lowered plan still matches its numpy oracle under
``wire="packed"`` on both collective backends.

Acceptance: packed reduces all-to-all bytes by >= 4x on q4_sj/q18_sj.
Paired raw/packed latencies land with the byte counts in
``experiments/bench/exchange_compression.json``.

  PYTHONPATH=src python -m benchmarks.exchange_compression --sf 0.02
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import plans as plan_registry
from repro.launch.roofline import parse_collective_bytes
from repro.query.lower import lower
from repro.tpch import queries as tq
from repro.tpch.driver import TPCHDriver
from repro.tpch.schema import DEFAULT_PARAMS as DP

GATE_REDUCTION = 4.0
SJ_QTY = 250.0  # q18_sj volume threshold (low enough to keep survivors)

# the oracle-parity set: every lowered-IR query with a numpy oracle
PARITY = ("q1", "q4", "q6", "q18")
BACKENDS = ("xla", "one_factor")


def _compile(driver, q, *, wire: str, backend: str = "xla"):
    """Lower + compile one IR query under an explicit wire format/backend
    (bypassing the driver's cached context)."""
    plan = lower(q, driver.catalog, wire=wire)
    ctx = dataclasses.replace(driver.ctx, wire=wire, backend=backend)
    return driver.cluster.compile(plan, ctx, driver.placed)


def _collectives(fn, cols):
    return parse_collective_bytes(fn.lower(cols).compile().as_text())


def _clock(fn, cols) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn(cols))
    return time.perf_counter() - t0


def _q18_sj_oracle(driver, qty: float, segment: int):
    o = driver.tables["orders"].columns
    li = driver.tables["lineitem"].columns
    c = driver.tables["customer"].columns
    sq = np.zeros(o["o_orderkey"].shape[0])
    np.add.at(sq, li["l_orderkey"], li["l_quantity"].astype(np.float64))
    sel = (sq > qty) & (c["c_mktsegment"][o["o_custkey"]] == segment)
    return np.array([sq[sel].sum(), sel.sum()])


def run(sf: float = 0.02, repeat: int = 30, seed: int = 0):
    driver = TPCHDriver(sf=sf, seed=seed)
    cols = {n: t.columns for n, t in driver.placed.items()}

    targets = [
        ("q4_sj", tq.q4_sj_ir(alt="request"),
         np.asarray(driver.oracle("q4"), np.float64),
         lambda out: np.asarray(out["value"], np.float64)[:, 0]),
        ("q18_sj", tq.q18_sj_ir(alt="request", qty=SJ_QTY),
         _q18_sj_oracle(driver, SJ_QTY, DP.q3_segment),
         lambda out: np.asarray(out["value"], np.float64).reshape(-1)),
    ]

    rows, ok = [], True
    for name, q, oracle, extract in targets:
        fns = {w: _compile(driver, q, wire=w) for w in ("raw", "packed")}
        coll = {w: _collectives(fns[w], cols) for w in fns}
        outs = {}
        for w, fn in fns.items():
            out = jax.tree.map(np.asarray, fn(cols))
            assert not out.get("overflow", False), f"{name}/{w} overflowed"
            outs[w] = extract(out)
        by_kind = {w: coll[w].by_kind() for w in fns}
        a2a = {w: by_kind[w].get("all-to-all", {}).get("bytes", 0)
               for w in fns}
        reduction = a2a["raw"] / max(a2a["packed"], 1)
        # paired warm latencies: median of back-to-back ratios (robust to
        # host drift, same protocol as benchmarks/ir_overhead.py)
        for fn in fns.values():
            jax.block_until_ready(fn(cols))
        raw_times, ratios = [], []
        for _ in range(max(repeat, 5)):
            r = _clock(fns["raw"], cols)
            p = _clock(fns["packed"], cols)
            raw_times.append(r)
            ratios.append(p / r)
        ratios.sort()
        raw_ms = min(raw_times) * 1e3
        packed_ms = raw_ms * ratios[len(ratios) // 2]
        oracle_ok = (np.allclose(outs["raw"], oracle, rtol=1e-4)
                     and np.allclose(outs["packed"], oracle, rtol=1e-4))
        ok &= oracle_ok and reduction >= GATE_REDUCTION
        for w in ("raw", "packed"):
            rows.append({
                "query": name, "wire": w,
                "all_to_all_bytes": a2a[w],
                "all_to_all_count": by_kind[w].get("all-to-all",
                                                   {}).get("count", 0),
                # labeled per-kind breakdown (CollectiveStats.by_kind): the
                # non-all-to-all collectives are invariant across wires, so
                # a reduction that moved bytes to another kind would show
                "collectives": " ".join(
                    f"{k}:{v['bytes']}Bx{v['count']}"
                    for k, v in by_kind[w].items()),
                "latency_ms": raw_ms if w == "raw" else packed_ms,
                "reduction_x": 1.0 if w == "raw" else reduction,
                "oracle_ok": oracle_ok,
            })
    emit("exchange_compression", rows,
         ["query", "wire", "all_to_all_bytes", "all_to_all_count",
          "collectives", "latency_ms", "reduction_x", "oracle_ok"])

    # oracle parity of the standard lowered queries under packed wire, on
    # both collective backends (one_factor lowers all-to-all to ppermutes)
    parity_rows = []
    for name in PARITY:
        q = plan_registry.get(name).ir
        ref = driver.oracle(name)
        for backend in BACKENDS:
            out = jax.tree.map(
                np.asarray,
                _compile(driver, q, wire="packed", backend=backend)(cols),
            )
            if name == "q18":
                ov, okeys = ref
                n = int(out["valid"].sum())
                match = (n == int(np.isfinite(ov).sum())
                         and np.allclose(out["values"][:n], ov[:n],
                                         rtol=2e-3, atol=1e-2)
                         and np.array_equal(out["keys"][:n], okeys[:n]))
            elif name == "q4":
                match = np.array_equal(out["value"][:, 0], ref)
            else:
                match = np.allclose(np.asarray(out["value"]).reshape(np.shape(ref)),
                                    ref, rtol=2e-4)
            ok &= bool(match)
            parity_rows.append({"query": name, "backend": backend,
                                "wire": "packed", "oracle_ok": bool(match)})
    emit("exchange_compression_parity", parity_rows,
         ["query", "backend", "wire", "oracle_ok"])

    worst = min(r["reduction_x"] for r in rows if r["wire"] == "packed")
    status = "OK" if ok else "FAILED"
    print(f"\npacked wire all-to-all reduction: {worst:.1f}x "
          f"(>= {GATE_REDUCTION:.0f}x target, oracle parity on "
          f"{'/'.join(PARITY)} x {'/'.join(BACKENDS)}: {status})")
    return rows, parity_rows, ok


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.02)
    p.add_argument("--repeat", type=int, default=30)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    _, _, ok = run(sf=args.sf, repeat=args.repeat, seed=args.seed)
    sys.exit(0 if ok else 1)
