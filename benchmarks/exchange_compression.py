"""Wire-format benchmark: raw vs packed exchange encodings, and the
codec that produces them (§3.2.1).

The exchange layer can ship its request buckets either as raw int32 keys +
a separate bool-mask all-to-all, or as the packed wire format (EF-coded
keys at catalog-derived widths, mask folded in, bitset replies).  This
benchmark proves the reduction FROM THE LOWERED HLO — the all-to-all
operand bytes of the compiled SPMD plan — on the q4/q18 semi-join
exchanges (the Q4/Q18 shapes forced through the §3.2.2 request exchange),
and checks that every lowered plan still matches its numpy oracle under
``wire="packed"`` on both collective backends.

The comparison is three-way: raw wire, packed wire on the baseline XLA
scatter/gather codec (``ops.use_kernels(False)``), and packed wire on
the kernel codec (the gather-light formulation behind the Pallas lane
kernels — the default).  Compression that only shrinks bytes is not
enough (Rödiger et al.): the packed-kernel column must also be FAST.

Acceptance: packed reduces all-to-all bytes by >= 4x AND the
packed-kernel latency is <= 1.05x raw on q4_sj/q18_sj.  A codec
microbenchmark (encode/decode rows/s per packed width) lands in
``experiments/bench/codec_microbench.json``; the three-way table in
``experiments/bench/exchange_compression.json`` (schema is a superset
of the old raw/packed one: the ``codec`` column is additive).

  PYTHONPATH=src python -m benchmarks.exchange_compression --sf 0.02
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import plans as plan_registry
from repro.core.compression import ef_params
from repro.kernels import ops
from repro.launch.roofline import parse_collective_bytes
from repro.query.lower import lower
from repro.tpch import queries as tq
from repro.tpch.driver import TPCHDriver
from repro.tpch.schema import DEFAULT_PARAMS as DP

GATE_REDUCTION = 4.0
GATE_LATENCY = 1.05   # packed-kernel warm latency vs raw, median ratio
SJ_QTY = 250.0  # q18_sj volume threshold (low enough to keep survivors)

# the oracle-parity set: every lowered-IR query with a numpy oracle
PARITY = ("q1", "q4", "q6", "q18")
BACKENDS = ("xla", "one_factor")


def _compile(driver, q, *, wire: str, backend: str = "xla"):
    """Lower + compile one IR query under an explicit wire format/backend
    (bypassing the driver's cached context)."""
    plan = lower(q, driver.catalog, wire=wire)
    ctx = dataclasses.replace(driver.ctx, wire=wire, backend=backend)
    return driver.cluster.compile(plan, ctx, driver.placed)


def _collectives(fn, cols):
    return parse_collective_bytes(fn.lower(cols).compile().as_text())


def _clock(fn, cols) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn(cols))
    return time.perf_counter() - t0


def _q18_sj_oracle(driver, qty: float, segment: int):
    o = driver.tables["orders"].columns
    li = driver.tables["lineitem"].columns
    c = driver.tables["customer"].columns
    sq = np.zeros(o["o_orderkey"].shape[0])
    np.add.at(sq, li["l_orderkey"], li["l_quantity"].astype(np.float64))
    sel = (sq > qty) & (c["c_mktsegment"][o["o_custkey"]] == segment)
    return np.array([sq[sel].sum(), sel.sum()])


def codec_microbench(repeat: int = 20, capacity: int = 4096, seed: int = 0):
    """Codec throughput in isolation (no exchange, no collectives):
    encode/decode keys/s per packed width, baseline XLA scatter codec
    ("xla" = ref.py, what ``use_kernels(False)`` selects) vs the kernel
    codec (gather-light formulation / Pallas lanes).  Synthetic sorted
    buckets, 8 destinations, 3/4 fill — the §3.2.2 request shape."""
    rng = np.random.default_rng(seed)
    P = 8
    n_valid = capacity * 3 // 4
    mask = np.broadcast_to(np.arange(capacity)[None, :] < n_valid,
                           (P, capacity))
    impls = (("xla", "ref"), ("kernel", ops._codec_impl()))
    rows, ok = [], True
    for domain in (8, 64, 512, 4096):  # l = 0, 2, 5, 8 low bits
        l, uw, lw = ef_params(capacity, domain)
        # row d holds sorted per-destination offsets rebased into d's
        # owned key range [d*domain, (d+1)*domain) — the encoder contract
        keys = (np.sort(rng.integers(0, domain, size=(P, capacity)), axis=1)
                + np.arange(P)[:, None] * domain)
        buckets = jnp.asarray(np.where(mask, keys, 0), dtype=jnp.int32)
        bmask = jnp.asarray(mask)
        for codec, impl in impls:
            t_enc, words = timeit(
                lambda b, m: ops._ef_encode(b, m, domain=domain, impl=impl),
                buckets, bmask, repeat=repeat)
            t_dec, (dkeys, dmask) = timeit(
                lambda w: ops._ef_decode(w, jnp.int32(0), capacity=capacity,
                                         domain=domain, impl=impl),
                words, repeat=repeat)
            # my_base=0 -> the decoder returns per-destination offsets
            offs = keys - np.arange(P)[:, None] * domain
            parity = (np.array_equal(np.asarray(dmask), mask)
                      and np.array_equal(np.where(mask, np.asarray(dkeys), 0),
                                         np.where(mask, offs, 0)))
            ok &= parity
            rows.append({
                "domain": domain, "l_bits": l, "capacity": capacity,
                "words_per_dest": uw + lw, "codec": codec,
                "encode_keys_per_s": P * capacity / max(t_enc, 1e-12),
                "decode_keys_per_s": P * capacity / max(t_dec, 1e-12),
                "parity_ok": parity,
            })
    emit("codec_microbench", rows,
         ["domain", "l_bits", "capacity", "words_per_dest", "codec",
          "encode_keys_per_s", "decode_keys_per_s", "parity_ok"])
    return rows, ok


def run(sf: float = 0.02, repeat: int = 30, seed: int = 0):
    driver = TPCHDriver(sf=sf, seed=seed)
    cols = {n: t.columns for n, t in driver.placed.items()}

    targets = [
        ("q4_sj", tq.q4_sj_ir(alt="request"),
         np.asarray(driver.oracle("q4"), np.float64),
         lambda out: np.asarray(out["value"], np.float64)[:, 0]),
        ("q18_sj", tq.q18_sj_ir(alt="request", qty=SJ_QTY),
         _q18_sj_oracle(driver, SJ_QTY, DP.q3_segment),
         lambda out: np.asarray(out["value"], np.float64).reshape(-1)),
    ]

    # (label, wire, codec column, kernel codec enabled while tracing)
    variants = (("raw", "raw", "none", True),
                ("packed_xla", "packed", "xla", False),
                ("packed_kernel", "packed", "kernel", True))

    rows, ok = [], True
    for name, q, oracle, extract in targets:
        fns, coll, outs = {}, {}, {}
        for label, wire, _, kern in variants:
            # the codec impl is resolved while TRACING (static jit arg),
            # so compile + first execution + HLO lowering all happen under
            # the toggle; the traced fn keeps its codec afterwards
            ops.use_kernels(kern)
            try:
                fn = _compile(driver, q, wire=wire)
                coll[label] = _collectives(fn, cols)
                out = jax.tree.map(np.asarray, fn(cols))
            finally:
                ops.use_kernels(True)
            assert not out.get("overflow", False), f"{name}/{label} overflowed"
            fns[label] = fn
            outs[label] = extract(out)
        by_kind = {lb: coll[lb].by_kind() for lb in fns}
        a2a = {lb: by_kind[lb].get("all-to-all", {}).get("bytes", 0)
               for lb in fns}
        reduction = a2a["raw"] / max(a2a["packed_kernel"], 1)
        # paired warm latencies: median of back-to-back ratios (robust to
        # host drift, same protocol as benchmarks/ir_overhead.py)
        for fn in fns.values():
            jax.block_until_ready(fn(cols))
        raw_times = []
        ratios = {"packed_xla": [], "packed_kernel": []}
        for _ in range(max(repeat, 5)):
            r = _clock(fns["raw"], cols)
            raw_times.append(r)
            for lb in ratios:
                ratios[lb].append(_clock(fns[lb], cols) / r)
        raw_ms = min(raw_times) * 1e3
        med = {lb: sorted(v)[len(v) // 2] for lb, v in ratios.items()}
        kernel_ratio = med["packed_kernel"]
        oracle_ok = all(np.allclose(outs[lb], oracle, rtol=1e-4)
                        for lb in fns)
        ok &= (oracle_ok and reduction >= GATE_REDUCTION
               and kernel_ratio <= GATE_LATENCY)
        for label, wire, codec, _ in variants:
            rows.append({
                "query": name, "wire": wire, "codec": codec,
                "all_to_all_bytes": a2a[label],
                "all_to_all_count": by_kind[label].get("all-to-all",
                                                       {}).get("count", 0),
                # labeled per-kind breakdown (CollectiveStats.by_kind): the
                # non-all-to-all collectives are invariant across wires, so
                # a reduction that moved bytes to another kind would show
                "collectives": " ".join(
                    f"{k}:{v['bytes']}Bx{v['count']}"
                    for k, v in by_kind[label].items()),
                "latency_ms": raw_ms if label == "raw"
                else raw_ms * med[label],
                "vs_raw_x": 1.0 if label == "raw" else med[label],
                "reduction_x": 1.0 if label == "raw" else reduction,
                "oracle_ok": oracle_ok,
            })
    emit("exchange_compression", rows,
         ["query", "wire", "codec", "all_to_all_bytes", "all_to_all_count",
          "collectives", "latency_ms", "vs_raw_x", "reduction_x",
          "oracle_ok"])

    # oracle parity of the standard lowered queries under packed wire, on
    # both collective backends (one_factor lowers all-to-all to ppermutes)
    parity_rows = []
    for name in PARITY:
        q = plan_registry.get(name).ir
        ref = driver.oracle(name)
        for backend in BACKENDS:
            out = jax.tree.map(
                np.asarray,
                _compile(driver, q, wire="packed", backend=backend)(cols),
            )
            if name == "q18":
                ov, okeys = ref
                n = int(out["valid"].sum())
                match = (n == int(np.isfinite(ov).sum())
                         and np.allclose(out["values"][:n], ov[:n],
                                         rtol=2e-3, atol=1e-2)
                         and np.array_equal(out["keys"][:n], okeys[:n]))
            elif name == "q4":
                match = np.array_equal(out["value"][:, 0], ref)
            else:
                match = np.allclose(np.asarray(out["value"]).reshape(np.shape(ref)),
                                    ref, rtol=2e-4)
            ok &= bool(match)
            parity_rows.append({"query": name, "backend": backend,
                                "wire": "packed", "oracle_ok": bool(match)})
    emit("exchange_compression_parity", parity_rows,
         ["query", "backend", "wire", "oracle_ok"])

    worst = min(r["reduction_x"] for r in rows if r["codec"] == "kernel")
    slowest = max(r["vs_raw_x"] for r in rows if r["codec"] == "kernel")
    status = "OK" if ok else "FAILED"
    print(f"\npacked wire all-to-all reduction: {worst:.1f}x "
          f"(>= {GATE_REDUCTION:.0f}x target), packed-kernel latency "
          f"{slowest:.2f}x raw (<= {GATE_LATENCY:.2f}x target), oracle "
          f"parity on {'/'.join(PARITY)} x {'/'.join(BACKENDS)}: {status}")
    return rows, parity_rows, ok


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.02)
    p.add_argument("--repeat", type=int, default=30)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--skip-microbench", action="store_true")
    args = p.parse_args()
    _, _, ok = run(sf=args.sf, repeat=args.repeat, seed=args.seed)
    if not args.skip_microbench:
        _, micro_ok = codec_microbench(seed=args.seed)
        ok = ok and micro_ok
    sys.exit(0 if ok else 1)
