"""Paper §3.2.2 cost model: Alt-1 (request) vs Alt-2 (bitset) — the analytic
bits-per-node curves and the MEASURED collective bytes of both plans on the
same data, verifying that the model picks the cheaper side."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit
from repro.core import Cluster, semijoin
from repro.core.partitioning import RangePartitioning
from repro.launch.roofline import parse_collective_bytes


def run():
    rows = []
    # analytic sweep (paper's model, SF-shaped numbers)
    m = 1_000_000
    for Pn in (16, 128, 512):
        for n in (1_000, 100_000, 10_000_000):
            for gamma in (1e-4, 0.01, 0.3):
                rows.append({
                    "P": Pn, "n_requests": n, "gamma": gamma,
                    "alt1_bits": semijoin.alt1_bits(n, m, Pn),
                    "alt2_bits": semijoin.alt2_bits(m, gamma),
                    "choice": semijoin.choose_alternative(n, m, gamma, Pn),
                })
    emit("semijoin_cost_model", rows,
         ["P", "n_requests", "gamma", "alt1_bits", "alt2_bits", "choice"])

    # measured collective bytes of both alternatives on one dataset
    cluster = Cluster()
    Pn = cluster.num_nodes
    rowsm = []
    total = Pn * 4096
    part = RangePartitioning(total, Pn)
    rng = np.random.default_rng(0)
    attr = jnp.asarray((rng.random(total) < 0.1).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, total, Pn * 512).astype(np.int32))
    mask = jnp.asarray(rng.random(Pn * 512) < 0.5)

    def alt1(k, mk, a):
        def pred(idx, m_):
            return (a[idx] == 1) & m_
        bits, _ = semijoin.alt1_request(k, mk, part, pred, capacity=512,
                                        axis="nodes")
        return bits

    def alt2(k, mk, a):
        words = semijoin.alt2_bitset(a == 1, axis="nodes")
        return semijoin.probe(words, k, part) & mk

    for name, fn in [("alt1_request", alt1), ("alt2_bitset", alt2)]:
        lowered = jax.jit(jax.shard_map(
            fn, mesh=cluster.mesh,
            in_specs=(P("nodes"), P("nodes"), P("nodes")),
            out_specs=P("nodes"), check_vma=False,
        )).lower(keys, mask, attr)
        coll = parse_collective_bytes(lowered.compile().as_text())
        rowsm.append({"alternative": name,
                      "collective_bytes_per_node": coll.total_bytes,
                      "ops": dict(coll.count_by_op)})
    emit("semijoin_measured_bytes", rowsm,
         ["alternative", "collective_bytes_per_node", "ops"])
    return rows, rowsm


if __name__ == "__main__":
    run()
