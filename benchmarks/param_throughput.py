"""Prepared-plan throughput: cold compile vs prepared vs vmap-batched.

The paper's core CPU-efficiency trick is compiling each query ONCE and
re-executing it with runtime parameters (§2, §3.1).  This benchmark
measures what that buys on TPC-H parameter sweeps (the §2.4 substitution
draws for Q1/Q6/Q14):

  cold      lower + compile + run a literal-bound plan per binding — what
            the engine paid for EVERY literal before runtime parameters,
  prepared  one ``prepare()``, then ``execute(binding)`` per draw — one
            XLA compile amortized over the stream,
  batched   ``execute_batch`` vmaps the compiled plan over a stacked
            parameter axis — N bindings per device dispatch.

Acceptance: over the full q1+q6+q14 sweep workload (>= 8 distinct
bindings each), batched execution delivers >= 3x the queries/sec of
sequential prepared execution on BOTH collective backends (xla /
one_factor) — the batched all-to-all must win too, not just the scan
queries.  Per-query speedups are reported alongside: the dispatch-bound
shapes (q6/q14) batch 5-15x, while q1's masked 36-cell aggregation is
compute-scaled (B lanes = B x the multiply-accumulates even through the
batched ``mask @ (onehot (x) measures)`` GEMM), so its lane win is the
amortized dispatch overhead only.  Results land in
``experiments/bench/param_throughput.json``.

  PYTHONPATH=src python -m benchmarks.param_throughput --sf 0.02
"""
from __future__ import annotations

import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from benchmarks.common import emit

GATE_SPEEDUP = 3.0
QUERIES = ("q1", "q6", "q14_promo")
BACKENDS = ("xla", "one_factor")


def _cold_qps(driver, qname, bindings, n_cold: int) -> float:
    """Compile-from-scratch latency per binding: lower + jit-trace + run a
    LITERAL plan (fresh function objects defeat the jit cache, like a
    plan cache keyed on literal values used to)."""
    from repro.query import bind_params, lower
    from repro.tpch import queries as tq

    cols = {n: t.columns for n, t in driver.placed.items()}
    times = []
    for b in bindings[:n_cold]:
        shape = tq.PARAM_QUERIES[qname]()
        prep = driver.prepare(shape)
        literal = bind_params(shape, prep.binding(b))
        t0 = time.perf_counter()
        fn = driver.cluster.compile(
            lower(literal, driver.catalog, wire=driver.wire),
            driver.ctx, driver.placed)
        jax.block_until_ready(fn(cols))
        times.append(time.perf_counter() - t0)
    return 1.0 / (sum(times) / len(times))


def run(sf: float = 0.05, batch: int = 16, repeat: int = 5, seed: int = 0):
    from repro.tpch import queries as tq
    from repro.tpch.driver import TPCHDriver

    rows, ok = [], True
    for backend in BACKENDS:
        driver = TPCHDriver(sf=sf, seed=seed, backend=backend)
        rng = np.random.default_rng(seed + 1)
        seq_total, batch_total = 0.0, 0.0
        for qname in QUERIES:
            bindings = [tq.random_binding(qname, rng) for _ in range(batch)]
            assert len({tuple(sorted(b.items())) for b in bindings}) >= 8

            prep = driver.prepare(tq.PARAM_QUERIES[qname]())
            prep.execute(bindings[0])             # pay the one compile
            prep.execute_batch(bindings)          # and the batched one
            label = prep.source

            # best-of-N for both modes: the sweep is the unit of repeat, so
            # host load spikes hit a whole pass, not one mode
            seq_times, batch_times = [], []
            for _ in range(repeat):
                t0 = time.perf_counter()
                for b in bindings:
                    prep.execute(b)
                seq_times.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                prep.execute_batch(bindings)
                batch_times.append(time.perf_counter() - t0)
            seq_t, batch_t = min(seq_times), min(batch_times)
            seq_total += seq_t
            batch_total += batch_t

            prepared_qps = batch / seq_t
            batched_qps = batch / batch_t
            cold_qps = _cold_qps(driver, qname, bindings, n_cold=2)
            compiles = driver.compile_events.count(label) \
                + driver.compile_events.count(f"{label}@batch")
            rows.append({
                "query": qname, "backend": backend, "batch": batch,
                "cold_qps": cold_qps, "prepared_qps": prepared_qps,
                "batched_qps": batched_qps,
                "batch_speedup_x": batched_qps / prepared_qps,
                "prepared_vs_cold_x": prepared_qps / cold_qps,
                "compiles": compiles,
            })
        sweep_speedup = seq_total / batch_total
        n_sweep = batch * len(QUERIES)
        ok &= sweep_speedup >= GATE_SPEEDUP
        rows.append({
            "query": "SWEEP", "backend": backend, "batch": batch,
            "prepared_qps": n_sweep / seq_total,
            "batched_qps": n_sweep / batch_total,
            "batch_speedup_x": sweep_speedup,
        })
    emit("param_throughput", rows,
         ["query", "backend", "batch", "cold_qps", "prepared_qps",
          "batched_qps", "batch_speedup_x", "prepared_vs_cold_x",
          "compiles"])
    worst = min(r["batch_speedup_x"] for r in rows if r["query"] == "SWEEP")
    status = "OK" if ok else "FAILED"
    print(f"\nbatched vs prepared queries/sec over the "
          f"{'+'.join(QUERIES)} sweep: worst backend {worst:.1f}x "
          f"(>= {GATE_SPEEDUP:.0f}x target on {BACKENDS}: {status})")
    return rows, ok


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.05)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--repeat", type=int, default=5)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    _, ok = run(sf=args.sf, batch=args.batch, repeat=args.repeat,
                seed=args.seed)
    sys.exit(0 if ok else 1)
