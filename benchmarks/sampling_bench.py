"""Beyond-paper benchmark: the §3.2.3 merging-reduction decode head vs the
naive allgather head, over the assigned archs' vocab sizes — runtime on the
host mesh plus the HLO collective bytes both schedules ship."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, timeit
from repro.launch.roofline import parse_collective_bytes
from repro.serve.sampling import naive_allgather_argmax, topk_logits

VOCABS = {
    "yi-34b": 64000, "qwen2.5-3b": 151936, "paligemma-3b": 257216,
    "recurrentgemma-2b": 256000,
}


def run(batch: int = 8, k: int = 8):
    mesh = jax.make_mesh((len(jax.devices()),), ("model",))
    tp = mesh.shape["model"]
    rows = []
    for arch, vocab in VOCABS.items():
        V = (vocab + tp - 1) // tp * tp
        logits = jnp.asarray(
            np.random.default_rng(0).normal(size=(batch, V)).astype(np.float32))

        def topk_head(x):
            return topk_logits(x, k, axis="model")[1][:, 0]

        def naive_head(x):
            return naive_allgather_argmax(x, axis="model")

        out = {}
        for name, head in [("topk_reduce", topk_head), ("allgather", naive_head)]:
            jitted = jax.jit(jax.shard_map(
                head, mesh=mesh, in_specs=P(None, "model"), out_specs=P(None),
                check_vma=False))
            dt, _ = timeit(jitted, logits, repeat=5)
            coll = parse_collective_bytes(jitted.lower(logits).compile().as_text())
            out[name] = (dt, coll.total_bytes)
        agree = bool(jnp.array_equal(
            jax.jit(jax.shard_map(topk_head, mesh=mesh, in_specs=P(None, "model"),
                                  out_specs=P(None), check_vma=False))(logits),
            jax.jit(jax.shard_map(naive_head, mesh=mesh, in_specs=P(None, "model"),
                                  out_specs=P(None), check_vma=False))(logits)))
        rows.append({
            "arch": arch, "vocab": vocab,
            "topk_ms": out["topk_reduce"][0] * 1e3,
            "allgather_ms": out["allgather"][0] * 1e3,
            "topk_bytes": out["topk_reduce"][1],
            "allgather_bytes": out["allgather"][1],
            "bytes_reduction_x": out["allgather"][1] / max(out["topk_reduce"][1], 1),
            "agree": agree,
        })
    emit("sampling_head", rows,
         ["arch", "vocab", "topk_ms", "allgather_ms", "topk_bytes",
          "allgather_bytes", "bytes_reduction_x", "agree"])
    return rows


if __name__ == "__main__":
    run()
