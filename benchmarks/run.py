"""Benchmark harness entrypoint — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick]

| section                 | paper ref | module                      |
|-------------------------|-----------|-----------------------------|
| fig2_weak_scaling       | Fig. 2/3  | benchmarks.weak_scaling     |
| fig4_q15_topk (+m sweep)| Fig. 4    | benchmarks.q15_topk         |
| table1_compiled_speedup | Table 1   | benchmarks.compiled_speedup |
| table2_power_test       | Table 2   | benchmarks.power_test       |
| semijoin cost model     | §3.2.2    | benchmarks.semijoin_cost    |
| roofline table          | (ours)    | benchmarks.roofline_report  |
| sampling head ablation  | (ours)    | benchmarks.sampling_bench   |
| cube tier-1 speedup     | (ours)    | benchmarks.cube_speedup     |
| lowered-IR overhead     | (ours)    | benchmarks.ir_overhead      |
| exchange wire formats   | §3.2.1    | benchmarks.exchange_compression |
| prepared-plan throughput| §2, §3.1  | benchmarks.param_throughput |
| plan-verifier latency   | (ours)    | benchmarks.verify_bench     |

Every section persists machine-readable JSON under ``experiments/bench/``
(via ``benchmarks.common.emit``) alongside the printed markdown table.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# the paper's benchmarks are DISTRIBUTED (weak scaling, collective
# schedules): give the bench process an 8-node host cluster — deliberately
# not the dry-run's 512 placeholder devices (launch/dryrun.py only).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="smaller SFs / fewer repeats")
    p.add_argument("--sections", nargs="*", default=None)
    args = p.parse_args(argv)

    from benchmarks import (compiled_speedup, cube_speedup,
                            exchange_compression, ir_overhead,
                            param_throughput, power_test, q15_topk,
                            roofline_report, sampling_bench, semijoin_cost,
                            verify_bench, weak_scaling)

    sections = {
        "cube_speedup": lambda: cube_speedup.run(
            sf=0.02 if args.quick else 0.05),
        "ir_overhead": lambda: ir_overhead.run(
            sf=0.02 if args.quick else 0.05,
            repeat=15 if args.quick else 60),
        "exchange_compression": lambda: exchange_compression.run(
            sf=0.02 if args.quick else 0.05,
            repeat=5 if args.quick else 30),
        "param_throughput": lambda: param_throughput.run(
            sf=0.02, repeat=3 if args.quick else 8),
        "verify_bench": lambda: verify_bench.run(
            sf=0.02, repeat=3 if args.quick else 10),
        "weak_scaling": lambda: weak_scaling.run(repeat=2 if args.quick else 3),
        "q15_topk": lambda: (q15_topk.run(sf=0.01 if args.quick else 0.02),
                             q15_topk.sweep_m(sf=0.01 if args.quick else 0.02)),
        "compiled_speedup": lambda: compiled_speedup.run(
            sf=0.01 if args.quick else 0.02),
        "power_test": lambda: power_test.run(sf=0.02 if args.quick else 0.05),
        "semijoin_cost": semijoin_cost.run,
        "sampling": lambda: sampling_bench.run(),
        "roofline": roofline_report.run,
    }
    todo = args.sections or list(sections)
    t0 = time.monotonic()
    for name in todo:
        print(f"\n{'='*70}\n== {name}\n{'='*70}")
        try:
            sections[name]()
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"SECTION FAILED: {name}: {type(e).__name__}: {e}")
    print(f"\ntotal {time.monotonic()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
