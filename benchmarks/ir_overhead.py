"""Lowered-IR vs hand-written plan latency (the IR's compile-time tax),
plus the observability layer's instrumentation tax.

The lowering pass must be a zero-cost abstraction: for every query with
both a registered hand plan and an IR definition we compile both through
the same ``Cluster.compile`` path and compare warm best-of-N latency.
Both arrive as one SPMD executable, so the overhead should be XLA noise —
the acceptance bar is <5% on Q1/Q6.  Results land in
``experiments/bench/ir_overhead.json`` so the perf trajectory captures IR
overhead over time.

The second section times the SAME prepared query through
``PreparedQuery.execute`` with tracing enabled vs disabled (the driver's
``Observer`` spans + metrics vs a disabled observer) under the identical
paired-ratio protocol; the observability layer's bar is <=2% median
overhead.  Both sections are report-only (trajectory data, no CI exit
gate).

  PYTHONPATH=src python -m benchmarks.ir_overhead --sf 0.05
"""
from __future__ import annotations

import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax

from benchmarks.common import emit
from repro.core import plans as plan_registry
from repro.tpch.driver import TPCHDriver

# queries with BOTH a hand plan and an IR definition
QUERIES = ("q1", "q6", "q4", "q18")
GATED = {"q1", "q6"}  # the <5% acceptance queries
GATE_PCT = 5.0
OBS_GATE_PCT = 2.0  # traced-vs-untraced PreparedQuery.execute budget


def _clock(fn, cols) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn(cols))
    return time.perf_counter() - t0


def run(sf: float = 0.05, repeat: int = 20, seed: int = 0):
    driver = TPCHDriver(sf=sf, seed=seed)
    cols = {n: t.columns for n, t in driver.placed.items()}
    rows = []
    for name in QUERIES:
        entry = plan_registry.get(name)
        assert entry.plan is not None and entry.ir is not None, name
        hand_fn, ir_fn = driver.compile(name), driver.compile_ir(name)
        jax.block_until_ready(hand_fn(cols))  # warm both executables
        jax.block_until_ready(ir_fn(cols))
        # interleave the two plans in back-to-back pairs so host load drift
        # hits both alike; the MEDIAN of per-pair ratios is robust to the
        # noise a best-of-N comparison of two separate runs is not
        hand_times, ratios = [], []
        for _ in range(max(repeat, 15)):
            h = _clock(hand_fn, cols)
            i = _clock(ir_fn, cols)
            hand_times.append(h)
            ratios.append(i / h)
        ratios.sort()
        ratio = ratios[len(ratios) // 2]
        hand_dt = min(hand_times)
        rows.append({
            "query": name,
            "hand_ms": hand_dt * 1e3,
            "ir_ms": hand_dt * ratio * 1e3,
            "overhead_pct": 100.0 * (ratio - 1.0),
            "gated": name in GATED,
        })
    emit("ir_overhead", rows,
         ["query", "hand_ms", "ir_ms", "overhead_pct", "gated"])
    worst = max((r["overhead_pct"] for r in rows if r["gated"]), default=0.0)
    status = "OK" if worst < GATE_PCT else "EXCEEDED"
    print(f"\nworst gated IR overhead (q1/q6): {worst:.2f}% "
          f"(<{GATE_PCT:.0f}% target: {status})")

    obs_rows = _run_obs_overhead(driver, repeat)
    emit("obs_overhead", obs_rows,
         ["query", "untraced_ms", "traced_ms", "overhead_pct"])
    worst_obs = max(r["overhead_pct"] for r in obs_rows)
    obs_status = "OK" if worst_obs <= OBS_GATE_PCT else "EXCEEDED"
    print(f"worst instrumentation overhead (traced vs untraced execute): "
          f"{worst_obs:.2f}% (<={OBS_GATE_PCT:.0f}% target: {obs_status})")
    return rows


def _run_obs_overhead(driver: TPCHDriver, repeat: int):
    """Traced vs untraced ``PreparedQuery.execute`` on the same prepared
    shapes: the observer's spans/counters are the ONLY difference between
    the two timings (one compiled executable underneath), so the paired
    median ratio isolates the instrumentation tax."""
    rows = []
    for name in QUERIES:
        prep = driver.prepare(name)
        prep.execute()  # warm: compile + first device dispatch
        # executes per timing sample, sized so each sample spans >=20ms:
        # the tax under test is ~10us/execute, which a single sub-2ms
        # execute cannot resolve against host jitter
        t0 = time.perf_counter()
        prep.execute()
        warm = time.perf_counter() - t0
        inner = max(4, int(0.02 / max(warm, 1e-4)))
        times, ratios = [], []
        for it in range(max(repeat, 15)):
            pair = {}
            # alternate which side runs first so host drift within a pair
            # cancels across iterations instead of biasing one side
            order = (False, True) if it % 2 == 0 else (True, False)
            for enabled in order:
                driver.obs.enabled = enabled
                t0 = time.perf_counter()
                for _ in range(inner):
                    prep.execute()
                pair[enabled] = (time.perf_counter() - t0) / inner
            driver.obs.enabled = True
            times.append(pair[False])
            ratios.append(pair[True] / pair[False])
        ratios.sort()
        ratio = ratios[len(ratios) // 2]
        base = min(times)
        rows.append({
            "query": name,
            "untraced_ms": base * 1e3,
            "traced_ms": base * ratio * 1e3,
            "overhead_pct": 100.0 * (ratio - 1.0),
        })
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.05)
    p.add_argument("--repeat", type=int, default=60)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    run(sf=args.sf, repeat=args.repeat, seed=args.seed)
    sys.exit(0)
