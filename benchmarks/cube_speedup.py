"""Two-tier serving economics: rollup-cube build cost vs per-query speedup.

For each cube-served IR query we compare Tier-1 latency (slice +
marginalize the pre-built rollup on the host) against the Tier-2 latency
of the SAME query as a compiled SPMD plan (hand-written if registered,
else lowered from the IR; warm, best-of-N — compile time excluded, so the
comparison is steady-state serving cost).  The build cost column is what a
deployment amortizes: ``amortize_after`` is the number of queries at which
the one-off distributed build pays for itself.

  PYTHONPATH=src python -m benchmarks.cube_speedup --sf 0.05

Tier-1 answers are validated against ``tpch/reference.py`` (Q1) before any
timing is reported.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from benchmarks.common import emit
from repro.cube.serving import measure_query
from repro.tpch import cubes as tpch_cubes
from repro.tpch.driver import TPCHDriver


def run(sf: float = 0.05, repeat: int = 20, seed: int = 0):
    driver = TPCHDriver(sf=sf, seed=seed)
    t0 = time.perf_counter()
    driver.build_cubes()
    build_total = time.perf_counter() - t0

    # correctness gate: tier-1 Q1 must match the float64 oracle
    q1 = tpch_cubes.q1_query()
    ans = driver.query(q1)
    assert ans.tier == 1, "Q1 must be cube-served"
    np.testing.assert_allclose(
        np.asarray(ans.value).reshape(6, 6), driver.oracle("q1"), rtol=2e-4
    )

    rows = []
    for name, make_query in tpch_cubes.SERVING_QUERIES.items():
        q = make_query() if callable(make_query) else make_query
        m = measure_query(driver, q, repeat=repeat)
        assert m is not None, f"{name} should be cube-covered"
        route, t1_dt, t2_dt = m["route"], m["tier1_s"], m["tier2_s"]
        cube = driver.cubes[route.cube.spec.name]
        rows.append({
            "query": name,
            "rollup": "x".join(route.rollup),
            "cells": route.cells,
            "tier1_us": t1_dt * 1e6,
            "tier2_ms": t2_dt * 1e3,
            "tier2_plan": m["plan"],
            "speedup": t2_dt / t1_dt,
            "build_s": cube.build_seconds,
            "amortize_after": int(np.ceil(cube.build_seconds / max(t2_dt - t1_dt, 1e-12))),
        })

    emit("cube_speedup", rows,
         ["query", "rollup", "cells", "tier1_us", "tier2_ms", "tier2_plan",
          "speedup", "build_s", "amortize_after"])
    print(f"\ntotal build time (all cubes, one distributed scan each): "
          f"{build_total:.2f}s at SF {sf}")
    worst = min(r["speedup"] for r in rows)
    print(f"minimum tier-1 speedup over tier-2: {worst:.0f}x")
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.05)
    p.add_argument("--repeat", type=int, default=20)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()
    run(sf=args.sf, repeat=args.repeat, seed=args.seed)
    sys.exit(0)
