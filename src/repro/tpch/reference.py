"""Pure-numpy oracle for the 12 implemented TPC-H queries (paper §4.3).

Operates on the GLOBAL (unpartitioned) tables in float64 — the correctness
baseline every distributed plan must match ("we check the query results for
correctness", §4.1).  Rankings use (value desc, key asc) exactly like the
plans so top-k sets compare deterministically.
"""
from __future__ import annotations

import numpy as np

from repro.tpch import schema as S
from repro.tpch.schema import DEFAULT_PARAMS as DP


def _topk(values, keys, k):
    """(value desc, key asc) ranking; returns (values, keys) padded with
    (-inf, -1) when fewer than k rows qualify."""
    values = np.asarray(values, np.float64)
    keys = np.asarray(keys, np.int64)
    order = np.lexsort((keys, -values))[:k]
    out_v = np.full(k, -np.inf)
    out_k = np.full(k, -1, np.int64)
    out_v[: len(order)] = values[order]
    out_k[: len(order)] = keys[order]
    return out_v, out_k


def q1(t, p=DP):
    li = t["lineitem"].columns
    sel = li["l_shipdate"] <= p.q1_shipdate_max
    rf = li["l_returnflag"][sel]
    ls = li["l_linestatus"][sel]
    g = rf * 2 + ls
    qty = li["l_quantity"][sel].astype(np.float64)
    price = li["l_extendedprice"][sel].astype(np.float64)
    disc = li["l_discount"][sel].astype(np.float64)
    tax = li["l_tax"][sel].astype(np.float64)
    disc_price = price * (1 - disc)
    charge = disc_price * (1 + tax)
    out = np.zeros((6, 6))
    for col, v in enumerate([qty, price, disc_price, charge, disc, np.ones_like(qty)]):
        np.add.at(out[:, col], g, v)
    return out  # [sum_qty, sum_base, sum_disc_price, sum_charge, sum_disc, count]


def q2(t, p=DP, k=100):
    part = t["part"].columns
    ps = t["partsupp"].columns
    sup = t["supplier"].columns
    psel = (part["p_size"] == p.q2_size) & (part["p_type"] % S.NUM_BRASS == p.q2_type_finish)
    s_in_region = S.nation_region(sup["s_nationkey"]) == p.q2_region
    ps_part_ok = psel[ps["ps_partkey"]]
    ps_sup_ok = s_in_region[ps["ps_suppkey"]]
    cand = ps_part_ok & ps_sup_ok
    cost = ps["ps_supplycost"].astype(np.float64)
    nparts = part["p_partkey"].shape[0]
    mincost = np.full(nparts, np.inf)
    np.minimum.at(mincost, ps["ps_partkey"][cand], cost[cand])
    is_min = cand & (cost <= mincost[ps["ps_partkey"]] + 1e-6) & (
        cost >= mincost[ps["ps_partkey"]] - 1e-6)
    # result rows: (acctbal of supplier, composite key part*NS+supp)
    num_sup = sup["s_suppkey"].shape[0]
    comp = ps["ps_partkey"][is_min].astype(np.int64) * num_sup + ps["ps_suppkey"][is_min]
    bal = sup["s_acctbal"].astype(np.float64)[ps["ps_suppkey"][is_min]]
    return _topk(bal, comp, k)


def q3(t, p=DP, k=10):
    cust = t["customer"].columns
    orders = t["orders"].columns
    li = t["lineitem"].columns
    c_ok = cust["c_mktsegment"] == p.q3_segment
    o_ok = (orders["o_orderdate"] < p.q3_date) & c_ok[orders["o_custkey"]]
    l_ok = li["l_shipdate"] > p.q3_date
    rev = np.zeros(orders["o_orderkey"].shape[0])
    lsel = l_ok & o_ok[li["l_orderkey"]]
    np.add.at(
        rev,
        li["l_orderkey"][lsel],
        (li["l_extendedprice"][lsel] * (1 - li["l_discount"][lsel])).astype(np.float64),
    )
    keys = orders["o_orderkey"][rev > 0]
    return _topk(rev[rev > 0], keys, k)


def q4(t, p=DP):
    orders = t["orders"].columns
    li = t["lineitem"].columns
    o_ok = (orders["o_orderdate"] >= p.q4_date_min) & (orders["o_orderdate"] < p.q4_date_max)
    late = li["l_commitdate"] < li["l_receiptdate"]
    has_late = np.zeros(orders["o_orderkey"].shape[0], bool)
    has_late[li["l_orderkey"][late]] = True
    sel = o_ok & has_late
    return np.bincount(orders["o_orderpriority"][sel], minlength=5).astype(np.float64)


def q5(t, p=DP):
    cust = t["customer"].columns
    orders = t["orders"].columns
    li = t["lineitem"].columns
    sup = t["supplier"].columns
    o_ok = (orders["o_orderdate"] >= p.q5_date_min) & (orders["o_orderdate"] < p.q5_date_max)
    s_nat = sup["s_nationkey"]
    s_ok = S.nation_region(s_nat) == p.q5_region
    c_nat = cust["c_nationkey"]
    l_sup_nat = s_nat[li["l_suppkey"]]
    l_cust = orders["o_custkey"][li["l_orderkey"]]
    sel = (
        o_ok[li["l_orderkey"]]
        & s_ok[li["l_suppkey"]]
        & (c_nat[l_cust] == l_sup_nat)
    )
    rev = np.zeros(25)
    np.add.at(
        rev,
        l_sup_nat[sel],
        (li["l_extendedprice"][sel] * (1 - li["l_discount"][sel])).astype(np.float64),
    )
    return rev  # revenue per nation (only the region's nations are nonzero)


def q6(t, p=DP):
    li = t["lineitem"].columns
    sel = (
        (li["l_shipdate"] >= p.q6_date_min)
        & (li["l_shipdate"] < p.q6_date_max)
        & (li["l_discount"] >= p.q6_disc_min)
        & (li["l_discount"] <= p.q6_disc_max)
        & (li["l_quantity"] < p.q6_quantity)
    )
    rev = li["l_extendedprice"].astype(np.float64) * li["l_discount"].astype(np.float64)
    return rev[sel].sum()


def q11(t, p=DP, sf: float = 1.0, cap: int = 128):
    ps = t["partsupp"].columns
    sup = t["supplier"].columns
    s_ok = sup["s_nationkey"] == p.q11_nation
    sel = s_ok[ps["ps_suppkey"]]
    value = (ps["ps_supplycost"].astype(np.float64) * ps["ps_availqty"]).astype(np.float64)
    nparts = t["part"].columns["p_partkey"].shape[0]
    per_part = np.zeros(nparts)
    np.add.at(per_part, ps["ps_partkey"][sel], value[sel])
    total = per_part.sum()
    thresh = total * p.q11_fraction / sf
    qualified = per_part > thresh
    return _topk(per_part[qualified], np.nonzero(qualified)[0], cap)


def q13(t, p=DP, hist_cap: int = 64):
    orders = t["orders"].columns
    cust = t["customer"].columns
    sel = ~orders["o_comment_special"]
    counts = np.bincount(
        orders["o_custkey"][sel], minlength=cust["c_custkey"].shape[0]
    )
    counts = np.minimum(counts, hist_cap - 1)
    return np.bincount(counts, minlength=hist_cap).astype(np.float64)


def q14(t, p=DP):
    li = t["lineitem"].columns
    part = t["part"].columns
    sel = (li["l_shipdate"] >= p.q14_date_min) & (li["l_shipdate"] < p.q14_date_max)
    promo = (part["p_type"] < S.PROMO_TYPES)[li["l_partkey"]]
    rev = (li["l_extendedprice"] * (1 - li["l_discount"])).astype(np.float64)
    total = rev[sel].sum()
    promo_rev = rev[sel & promo].sum()
    return np.array([100.0 * promo_rev / total, promo_rev, total])


def q15(t, p=DP, k=1):
    li = t["lineitem"].columns
    sup = t["supplier"].columns
    sel = (li["l_shipdate"] >= p.q15_date_min) & (li["l_shipdate"] < p.q15_date_max)
    rev = np.zeros(sup["s_suppkey"].shape[0])
    np.add.at(
        rev,
        li["l_suppkey"][sel],
        (li["l_extendedprice"][sel] * (1 - li["l_discount"][sel])).astype(np.float64),
    )
    return _topk(rev, np.arange(rev.shape[0]), k)


def q18(t, p=DP, k=100):
    li = t["lineitem"].columns
    orders = t["orders"].columns
    qty = np.zeros(orders["o_orderkey"].shape[0])
    np.add.at(qty, li["l_orderkey"], li["l_quantity"].astype(np.float64))
    sel = qty > p.q18_quantity
    return _topk(
        orders["o_totalprice"].astype(np.float64)[sel], orders["o_orderkey"][sel], k
    )


def q21(t, p=DP, k=100):
    li = t["lineitem"].columns
    orders = t["orders"].columns
    sup = t["supplier"].columns
    num_sup = sup["s_suppkey"].shape[0]
    delayed = li["l_receiptdate"] > li["l_commitdate"]
    lo = li["l_orderkey"].astype(np.int64)
    norders = orders["o_orderkey"].shape[0]
    cnt_lines = np.bincount(lo, minlength=norders)
    cnt_delayed = np.bincount(lo[delayed], minlength=norders)
    comp = lo * num_sup + li["l_suppkey"]
    uniq, inv, counts = np.unique(comp, return_inverse=True, return_counts=True)
    same_lines = counts[inv]
    uniq_d, counts_d = np.unique(comp[delayed], return_counts=True)
    same_delayed_u = np.zeros(len(uniq), np.int64)
    same_delayed_u[np.searchsorted(uniq, uniq_d)] = counts_d
    same_delayed = same_delayed_u[inv]
    status_f = orders["o_orderstatus"][lo] == 0
    nation_ok = (sup["s_nationkey"] == p.q21_nation)[li["l_suppkey"]]
    qualify = (
        delayed
        & status_f
        & nation_ok
        & (cnt_lines[lo] - same_lines > 0)
        & (cnt_delayed[lo] - same_delayed == 0)
    )
    numwait = np.bincount(li["l_suppkey"][qualify], minlength=num_sup)
    sel = numwait > 0
    return _topk(numwait[sel].astype(np.float64), np.nonzero(sel)[0], k)


ALL = {
    "q1": q1, "q2": q2, "q3": q3, "q4": q4, "q5": q5, "q6": q6, "q11": q11,
    "q13": q13, "q14": q14, "q15": q15, "q18": q18, "q21": q21,
}
