"""TPC-H substrate: deterministic sharded generator, schema/dictionaries,
and the numpy correctness oracle (paper §4.1)."""

from repro.tpch import dbgen, reference, schema  # noqa: F401
