"""TPC-H schema, dictionaries and query parameters (paper §4.1, Fig. 1).

Dense 0-based surrogate keys; strings dictionary-encoded; dates as int32
days since 1992-01-01.  Co-partitioned pairs (solid edges in Fig. 1):
lineitem-orders on orderkey, partsupp-part on partkey.  Remote edges
(dashed): orders->customer, lineitem->part, lineitem->supplier,
partsupp->supplier, customer/supplier->nation (nation/region replicated).
"""
from __future__ import annotations

import dataclasses
import datetime

EPOCH = datetime.date(1992, 1, 1)


def day(y: int, m: int, d: int) -> int:
    """Days since 1992-01-01 (TPC-H date domain)."""
    return (datetime.date(y, m, d) - EPOCH).days


MAX_DATE = day(1998, 12, 31)

# dictionaries ---------------------------------------------------------------
REGIONS = ("AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST")
NATIONS = tuple(f"NATION_{i:02d}" for i in range(25))  # region r owns nations 5r..5r+4
SEGMENTS = ("AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD")
PRIORITIES = ("1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW")
RETURNFLAGS = ("A", "N", "R")
LINESTATUS = ("F", "O")
ORDERSTATUS = ("F", "O", "P")
NUM_TYPES = 150      # p_type: 6 classes x 5 families x 5 finishes
NUM_BRASS = 5        # finish = p_type % 5; 'BRASS' finish index
PROMO_TYPES = 25     # p_type < 25 <=> 'PROMO%'
SUPPLIERS_PER_PART = 4
NATIONS_PER_REGION = 5


def nation_region(nationkey):
    return nationkey // NATIONS_PER_REGION


# base cardinalities at SF=1 (TPC-H §4.2.3); lineitem fanout is 1..7/order --
BASE_ROWS = {
    "orders": 1_500_000,
    "customer": 150_000,
    "part": 200_000,
    "supplier": 10_000,
}
LINEITEM_FANOUT_AVG = 4  # fixed per-node lineitem capacity = 4x orders


@dataclasses.dataclass(frozen=True)
class QueryParams:
    """TPC-H validation-run substitution parameters (§2.4 of the spec),
    mapped onto our dictionary codes / day numbers."""

    q1_shipdate_max: int = day(1998, 12, 1) - 90
    q2_size: int = 15
    q2_type_finish: int = 3                      # '%BRASS'
    q2_region: int = 3                           # EUROPE
    q3_segment: int = 1                          # BUILDING
    q3_date: int = day(1995, 3, 15)
    q4_date_min: int = day(1993, 7, 1)
    q4_date_max: int = day(1993, 10, 1)
    q5_region: int = 2                           # ASIA
    q5_date_min: int = day(1994, 1, 1)
    q5_date_max: int = day(1995, 1, 1)
    q6_date_min: int = day(1994, 1, 1)
    q6_date_max: int = day(1995, 1, 1)
    # discount window: DISCOUNT +/- 0.01 widened off the representable f32
    # grid (0.045/0.075) so f32 plan vs f64 oracle comparisons can't flip
    q6_disc_min: float = 0.045
    q6_disc_max: float = 0.075
    q6_quantity: float = 24.0
    q11_nation: int = 7                          # 'GERMANY'
    q11_fraction: float = 0.0001                 # / SF at runtime
    q14_date_min: int = day(1995, 9, 1)
    q14_date_max: int = day(1995, 10, 1)
    q15_date_min: int = day(1996, 1, 1)
    q15_date_max: int = day(1996, 4, 1)
    q18_quantity: float = 300.0
    q21_nation: int = 20                         # 'SAUDI ARABIA'


DEFAULT_PARAMS = QueryParams()
