"""End-to-end TPC-H driver: generate -> place -> run plan -> check vs oracle.

Used by tests, benchmarks and the serving example; this is the paper's
"prototype running a subset of TPC-H" in one object.
"""
from __future__ import annotations

import numpy as np

from repro.core import Cluster, Table
from repro.core.plans import PLANS
from repro.tpch import dbgen, reference
from repro.tpch.schema import DEFAULT_PARAMS

# default fixed-capacity knobs for small/medium scale factors; a production
# deployment derives them from the §3.2.2 selectivity model (see
# benchmarks/semijoin_cost.py)
DEFAULT_CAPACITIES = {
    "q2_request": 1024,
    "q2_owner": 1024,
    "q3_chunk": 256,
    "q3_rounds": 64,
    "q5_request": 8192,
    "q13_route": 8192,
    "q14_request": 8192,
    "q15_group": 1024,
    "q15_candidates": 256,
    "q21_request": 2048,
}


class TPCHDriver:
    def __init__(self, sf: float, cluster: Cluster | None = None, seed: int = 0,
                 capacities=None, backend: str = "xla"):
        self.cluster = cluster or Cluster()
        self.sf = sf
        self.seed = seed
        self.backend = backend
        self.capacities = dict(DEFAULT_CAPACITIES)
        self.capacities.update(capacities or {})
        self.tables = dbgen.generate(sf, self.cluster.num_nodes, seed)
        # pad the supplier key space so §3.2.5 groups divide evenly
        self._extend_derived_tables()
        self.placed = {n: self.cluster.load(t) for n, t in self.tables.items()}
        self.ctx = self.cluster.context(
            self.placed, self.capacities, backend=backend, scale_factor=sf
        )
        self._compiled = {}

    def _extend_derived_tables(self):
        # q3_repl needs the replicated remote join attribute, built at load
        # time (paper's 'repl' variant)
        cust = self.tables["customer"]
        self.tables["customer_seg_repl"] = Table(
            "customer_seg_repl",
            {"c_mktsegment": np.asarray(cust.columns["c_mktsegment"])},
            replicated=True,
        )

    def compile(self, name: str):
        if name not in self._compiled:
            plan = PLANS[name]
            self._compiled[name] = self.cluster.compile(plan, self.ctx, self.placed)
        return self._compiled[name]

    def run(self, name: str):
        fn = self.compile(name)
        columns = {n: t.columns for n, t in self.placed.items()}
        return fn(columns)

    def oracle(self, name: str, **kw):
        base = name.split("_")[0]
        if base == "q11":
            kw.setdefault("sf", self.sf)
        return reference.ALL[base](self.tables, **kw)
