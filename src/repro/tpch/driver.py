"""End-to-end TPC-H driver: generate -> place -> route/compile -> check.

Used by tests, benchmarks and the serving example; this is the paper's
"prototype running a subset of TPC-H" in one object, redesigned around the
declarative Query IR: ``query()`` takes ONE type (an IR ``Query``, or a
registered name as sugar for its definition) and routes it

  Tier 1  to the finest covering rollup cube (the router matches the
          ``GroupAgg`` root structurally — no hand-named fallback), else
  Tier 2  to the SPMD executable LOWERED from the IR itself, so one
          logical query has one result schema on every path (the
          hand-written plans stay reachable via ``run(name)``).

Exchange buffer capacities come from the §3.2.2 selectivity model
(``repro.tpch.capacities`` for the hand plans, ``repro.query.stats``
inside the lowering) instead of per-query magic constants; explicit
overrides still win.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import Cluster, Table
from repro.core import plans as plan_registry
from repro.cube import CubeRouter, build_cube
from repro.query import (
    LoweringError,
    Query,
    UncoveredQueryError,
    build_catalog,
    lower,
    same_query,
)
from repro.tpch import capacities as tpch_capacities
from repro.tpch import dbgen, reference


@dataclasses.dataclass
class QueryAnswer:
    """Result of router-first execution: which tier served the query."""

    value: object
    tier: int            # 1 = rollup cube, 2 = compiled SPMD plan
    source: str          # cube name (tier 1) or plan/query name (tier 2)
    overflow: bool = False  # a Tier-2 exchange buffer overflowed


def _split_overflow(out):
    """Surface a plan's exchange-overflow flag instead of leaving it buried
    in the raw result: hand plans return either a dict with an ``overflow``
    entry or an ``(value, overflow)`` pair (``bucket_by_destination``'s
    flag, threaded through every request/owner-routed exchange)."""
    if isinstance(out, dict):
        return out, bool(np.asarray(out.pop("overflow", False)))
    if (isinstance(out, tuple) and len(out) == 2
            and np.ndim(out[1]) == 0
            and np.asarray(out[1]).dtype == np.bool_):
        return out[0], bool(np.asarray(out[1]))
    return out, False


class TPCHDriver:
    def __init__(self, sf: float, cluster: Cluster | None = None, seed: int = 0,
                 capacities=None, backend: str = "xla", wire: str = "packed"):
        self.cluster = cluster or Cluster()
        self.sf = sf
        self.seed = seed
        self.backend = backend
        self.wire = wire
        # §3.2.2-derived capacities for the hand plans; explicit overrides win
        self.capacities = tpch_capacities.derive(sf, self.cluster.num_nodes)
        self.capacities.update(capacities or {})
        self.tables = dbgen.generate(sf, self.cluster.num_nodes, seed)
        # pad the supplier key space so §3.2.5 groups divide evenly
        self._extend_derived_tables()
        self.catalog = build_catalog(self.tables,
                                     num_nodes=self.cluster.num_nodes)
        self.placed = {n: self.cluster.load(t) for n, t in self.tables.items()}
        self.ctx = self.cluster.context(
            self.placed, self.capacities, backend=backend, scale_factor=sf,
            wire=wire,
            wires=tpch_capacities.wire_formats(self.tables,
                                               self.cluster.num_nodes),
        )
        self._compiled = {}       # registry name -> compiled hand plan
        self._compiled_ir = {}    # query name/id -> (query, compiled fn)
        self.cubes = {}
        self.router: CubeRouter | None = None

    def _extend_derived_tables(self):
        # q3_repl needs the replicated remote join attribute, built at load
        # time (paper's 'repl' variant)
        cust = self.tables["customer"]
        self.tables["customer_seg_repl"] = Table(
            "customer_seg_repl",
            {"c_mktsegment": np.asarray(cust.columns["c_mktsegment"])},
            replicated=True,
        )

    def _columns(self):
        return {n: t.columns for n, t in self.placed.items()}

    # -- physical layer (hand plans / lowered IR by registry name) ---------
    def compile(self, name: str):
        """Compiled plan for a registered query: the hand-written physical
        plan when one exists, else the lowered IR (shared with the
        structural query cache — one executable per query)."""
        if name not in self._compiled:
            entry = plan_registry.get(name)
            if entry.plan is not None:
                self._compiled[name] = self.cluster.compile(
                    entry.plan, self.ctx, self.placed)
            elif entry.ir is not None:
                self._compiled[name] = self.compile_query(entry.ir)
            else:  # pragma: no cover — registry invariant
                raise LoweringError(f"{name!r} has neither plan nor IR")
        return self._compiled[name]

    def run(self, name: str):
        return self.compile(name)(self._columns())

    def compile_ir(self, name: str):
        """Compiled LOWERED plan for a registered query's IR (even when a
        hand plan exists — used to compare the two)."""
        entry = plan_registry.get(name)
        if entry.ir is None:
            raise LoweringError(
                f"{name!r} has no IR definition — only the hand-written "
                f"plan; express it in the algebra first"
            )
        return self.compile_query(entry.ir)

    def run_ir(self, name: str):
        return self.compile_ir(name)(self._columns())

    IR_CACHE_MAX = 32  # compiled-executable LRU bound for ad-hoc queries

    def compile_query(self, q: Query):
        """Lower + compile an arbitrary IR query.  Cached structurally (a
        caller reconstructing the same query per request reuses the
        executable; ``same_query`` guards against repr-hash collisions and
        same-name variants), with an LRU bound so a stream of novel ad-hoc
        queries cannot pin executables without limit."""
        key = f"{q.name}@{hash(repr(q.root))}"
        hit = self._compiled_ir.get(key)
        if hit is not None and (hit[0] is q or same_query(hit[0], q)):
            self._compiled_ir[key] = self._compiled_ir.pop(key)  # LRU touch
            return hit[1]
        plan = lower(q, self.catalog, wire=self.wire)
        fn = self.cluster.compile(plan, self.ctx, self.placed)
        self._compiled_ir[key] = (q, fn)
        while len(self._compiled_ir) > self.IR_CACHE_MAX:
            self._compiled_ir.pop(next(iter(self._compiled_ir)))
        return fn

    # -- two-tier execution (repro.cube) -----------------------------------
    def build_cubes(self, specs=None):
        """Materialize Tier-1 rollup cubes (one distributed scan per spec)
        and install the query router.  Defaults to the TPC-H presets."""
        if specs is None:
            from repro.tpch import cubes as tpch_cubes

            specs = tpch_cubes.default_specs()
        for spec in specs:
            self.cubes[spec.name] = build_cube(
                self.cluster, self.ctx, self.placed, spec
            )
        self.router = CubeRouter(list(self.cubes.values()))
        return self.cubes

    def query(self, q) -> QueryAnswer:
        """Router-first execution of ONE query type.

        ``q`` is an IR ``Query`` (a registered name is accepted as sugar
        for its definition).  A ``GroupAgg`` root covered by a rollup is
        answered from the cube (Tier 1, host microseconds); anything else
        runs as the compiled SPMD plan lowered from the IR over the base
        tables (Tier 2).  Raises :class:`UncoveredQueryError` when no cube
        covers the query and the IR has no lowerable form (e.g. min/max
        measures off-edge)."""
        if isinstance(q, str):
            entry = plan_registry.get(q)
            if entry.ir is None:
                value, overflow = _split_overflow(jax.device_get(self.run(q)))
                return QueryAnswer(value, tier=2, source=q, overflow=overflow)
            q = entry.ir
        if not isinstance(q, Query):
            raise TypeError(
                f"query() takes a repro.query.Query (or a registered plan "
                f"name), got {type(q)}"
            )
        if self.router is not None:
            match = self.router.route_query(q)
            if match is not None:
                value = self.router.answer(match.query, match.route)
                value = np.asarray(value).reshape(-1, value.shape[-1])
                return QueryAnswer(value, tier=1,
                                   source=match.route.cube.spec.name)
        # Tier 2 of an IR query is ALWAYS the lowered IR, so one logical
        # query has one result schema regardless of parameters or coverage
        # (hand plans remain reachable via run(name) — the escape hatch).
        try:
            fn = self.compile_query(q)
        except LoweringError as e:
            raise UncoveredQueryError(
                f"no rollup cube covers query {q.name or '<anonymous>'} and "
                f"it has no lowerable Tier-2 form: {e}"
            ) from e
        out = jax.device_get(fn(self._columns()))
        overflow = bool(out.pop("overflow", False))
        value = out["value"] if set(out) == {"value"} else out
        return QueryAnswer(value, tier=2, source=q.name or "<lowered-ir>",
                           overflow=overflow)

    def oracle(self, name: str, **kw):
        """Float64 numpy reference via the registry's EXPLICIT oracle
        binding (``q15_1factor`` -> ``q15`` etc. — no name munging)."""
        entry = plan_registry.get(name)
        if entry.oracle is None:
            raise LoweringError(f"{name!r} has no oracle binding")
        if entry.oracle == "q11":
            kw.setdefault("sf", self.sf)
        return reference.ALL[entry.oracle](self.tables, **kw)
