"""End-to-end TPC-H driver: generate -> place -> route/compile -> check.

Used by tests, benchmarks and the serving example; this is the paper's
"prototype running a subset of TPC-H" in one object, redesigned around the
declarative Query IR: ``query()`` takes ONE type (an IR ``Query``, or a
registered name as sugar for its definition) and routes it

  Tier 1  to the finest covering rollup cube (the router matches the
          ``GroupAgg`` root structurally — no hand-named fallback), else
  Tier 2  to the SPMD executable LOWERED from the IR itself, so one
          logical query has one result schema on every path (the
          hand-written plans stay reachable via ``run(name)``).

Prepared statements (the paper's §2/§3.1 compile-once model): every IR
query is canonicalized into a parameterized SHAPE plus a literal binding
(``repro.query.params``), and the plan cache keys on the shape alone — two
queries differing only in predicate literals share ONE compiled executable
and differ only in the scalars passed at run time.  ``prepare()`` exposes
that seam directly: ``prepare(q).execute(binding)`` re-runs the compiled
plan for any literals (Tier-1 routing re-checks bin-edge exactness per
binding), and ``execute_batch`` vmaps the plan over a stacked parameter
axis so N instances of one prepared shape run as a single device dispatch.

Exchange buffer capacities come from the §3.2.2 selectivity model
(``repro.tpch.capacities`` for the hand plans, ``repro.query.stats``
inside the lowering) instead of per-query magic constants; explicit
overrides still win.  For a prepared shape the capacities are sized from
the prepare-time binding (auto-parameterized literals) or the worst
binding in each parameter's declared range — the runtime ``overflow`` flag
surfaces any binding that exceeds them.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Cluster, Table
from repro.core import plans as plan_registry
from repro.core import wirecal
from repro.core.columnar import PackedColumn
from repro.query.ir import PackedInfo
from repro.cube import CubeRouter, build_cube
from repro.obs import (
    ExplainReport,
    Observer,
    SemiJoinInfo,
    attribute_semijoin_bytes,
)
from repro.query import (
    LoweringError,
    Query,
    QueryError,
    UnboundParamError,
    UncoveredQueryError,
    build_catalog,
    explain_chain,
    lower,
    parameterize,
    query_params,
    same_query,
    validate,
)
from repro.tpch import capacities as tpch_capacities
from repro.tpch import dbgen, reference


class ResidentBudgetError(MemoryError):
    """The resident dataset exceeds the node memory budget
    (``REPRO_RESIDENT_BUDGET_BYTES`` / ``resident_budget=``) — the cluster
    cannot hold this scale factor in the chosen storage format.  The
    message reports both formats' footprints; switching to
    ``storage="packed"`` is the usual fix."""


def _resident_bytes(table: Table) -> int:
    """Resident footprint of one table (packed columns at their packed
    size, raw columns at array size)."""
    return sum(int(c.nbytes) for c in table.columns.values())


def _raw_bytes(table: Table) -> int:
    """What the same table would occupy fully decoded."""
    return sum(int(c.raw_nbytes) if isinstance(c, PackedColumn)
               else int(c.nbytes) for c in table.columns.values())


@dataclasses.dataclass
class QueryAnswer:
    """Result of router-first execution: which tier served the query.
    ``overflow`` is a scalar bool for single executions and a per-lane
    ``(B,)`` bool array for ``execute_batch`` (one overflowing lane never
    poisons its batch siblings)."""

    value: object
    tier: int            # 1 = rollup cube, 2 = compiled SPMD plan
    source: str          # cube name (tier 1) or plan/query name (tier 2)
    overflow: object = False  # a Tier-2 exchange buffer overflowed


def _split_overflow(out):
    """Surface a plan's exchange-overflow flag instead of leaving it buried
    in the raw result: hand plans return either a dict with an ``overflow``
    entry or an ``(value, overflow)`` pair (``bucket_by_destination``'s
    flag, threaded through every request/owner-routed exchange)."""
    if isinstance(out, dict):
        return out, bool(np.asarray(out.pop("overflow", False)))
    if (isinstance(out, tuple) and len(out) == 2
            and np.ndim(out[1]) == 0
            and np.asarray(out[1]).dtype == np.bool_):
        return out[0], bool(np.asarray(out[1]))
    return out, False


class _PlanEntry:
    """One cached prepared SHAPE: the parameterized canonical query, its
    ordered parameter signature, and the lazily compiled executables
    (scalar + vmap-batched).  Shared by every query that canonicalizes to
    this shape — the compile happens once.

    ``lock``/``warm`` serialize the FIRST call of each compiled
    specialization: ``jax.jit`` defers the XLA trace to the first call,
    so two threads racing into an un-warmed executable would both pay the
    trace (and double-count ``compile_events``).  Once a specialization
    ("scalar" or ``("batch", B)``) is in ``warm``, calls skip the entry
    lock (execution itself is serialized by the driver's dispatch gate —
    see ``TPCHDriver._guarded_call``)."""

    def __init__(self, shape: Query, stats_binding: dict):
        self.shape = shape
        self.params = query_params(shape.root)
        self.stats_binding = dict(stats_binding)
        self.fn = None          # compiled scalar executable
        self.batched_fn = None  # compiled vmapped executable (jit re-
                                # specializes per batch size)
        self.bound = {}         # binding signature -> fn(columns) closure
        self.route = (None, None)  # (router identity, Match|None) memo
        self.semijoins = ()     # static semi-join decisions of the lowering
        self.scans = ()         # static per-column scan strategies
        self.profile = None     # lazy HLO CollectiveStats (explain_analyze)
        self.lock = threading.Lock()  # guards lazy compile + first trace
        self.warm = set()       # specializations already traced once


class PreparedQuery:
    """A query prepared against one driver: compile once, execute for any
    parameter binding (``execute``), or run many bindings as one vmapped
    device dispatch (``execute_batch``).

    ``params`` is the ordered parameter signature; ``defaults`` carries the
    literal values extracted by auto-parameterization, so a prepared
    literal query executes with no arguments and any subset can be
    overridden per call.  Tier-1 cube routing happens at EXECUTE time —
    the shape is matched once, but bin-edge exactness is re-checked per
    binding, falling back to the compiled Tier-2 plan for off-edge or
    out-of-range values.
    """

    def __init__(self, driver: "TPCHDriver", entry: _PlanEntry,
                 defaults: dict, source: str, cache_hit: bool = False):
        self.driver = driver
        self.entry = entry
        self.defaults = dict(defaults)
        self.source = source
        self.cache_hit = cache_hit  # structural plan cache: shape was reused

    @property
    def params(self) -> tuple:
        return self.entry.params

    @property
    def query(self) -> Query:
        return self.entry.shape

    @property
    def shape_key(self) -> int:
        """Identity of the prepared shape: two handles carry the same key
        iff they share one ``_PlanEntry`` (and therefore one compiled
        executable).  The serving engine coalesces submissions by this
        key — same key means their bindings can stack into one
        ``execute_batch`` dispatch."""
        return id(self.entry)

    # -- binding ------------------------------------------------------------
    def binding(self, params=None) -> dict:
        """Defaults merged with per-call overrides; raises
        :class:`UnboundParamError` for missing or unknown names."""
        b = dict(self.defaults)
        if params:
            b.update(params)
        names = {p.name for p in self.entry.params}
        missing = sorted(names - set(b))
        if missing:
            raise UnboundParamError(
                f"missing binding(s) {missing} for prepared query "
                f"{self.source!r} (parameters: {sorted(names)})"
            )
        unknown = sorted(set(b) - names)
        if unknown:
            raise UnboundParamError(
                f"unknown parameter(s) {unknown} for prepared query "
                f"{self.source!r} (parameters: {sorted(names)})"
            )
        # eager castability: a bad value must fail HERE, naming the key,
        # not as a bare ValueError deep inside tracing
        for p in self.entry.params:
            try:
                np.asarray(b[p.name], np.dtype(p.dtype))
            except (TypeError, ValueError) as e:
                raise UnboundParamError(
                    f"binding {p.name}={b[p.name]!r} for prepared query "
                    f"{self.source!r} is not castable to {p.dtype}: {e}"
                ) from None
        return b

    def _cast(self, b: dict) -> dict:
        """Binding -> traced-argument pytree with STABLE dtypes (one aval
        set per shape, so re-executions never retrace)."""
        return {p.name: jnp.asarray(np.asarray(b[p.name], np.dtype(p.dtype)))
                for p in self.entry.params}

    # -- execution ----------------------------------------------------------
    def answer_tier1(self, b: dict) -> Optional[QueryAnswer]:
        """Tier-1 (rollup cube) answer for a FULL binding, or None when no
        cube covers this shape or the binding is off-edge/out-of-range.
        This is the serving engine's microsecond admission probe: pure
        host-side numpy, no device dispatch, safe to call inline on the
        event loop (the route match is memoized per entry; the
        re-assignment is an atomic tuple store, so concurrent probes at
        worst redo the match)."""
        router = self.driver.router
        if router is None:
            return None
        if self.entry.route[0] is not router:
            self.entry.route = (router, router.route_query(self.entry.shape))
        match = self.entry.route[1]
        if match is None:
            return None
        value = router.answer_bound(match, b)
        if value is None:  # off-edge / out-of-range binding -> Tier 2
            return None
        value = np.asarray(value).reshape(-1, value.shape[-1])
        return QueryAnswer(value, tier=1, source=match.route.cube.spec.name)

    _tier1 = answer_tier1

    def _tier2_fn(self):
        try:
            return self.driver._ensure_compiled(self.entry)
        except LoweringError as e:
            raise UncoveredQueryError(
                f"no rollup cube covers query {self.source} for this "
                f"binding and it has no lowerable Tier-2 form: {e}"
            ) from e

    def execute(self, params=None) -> QueryAnswer:
        obs = self.driver.obs
        mreg = obs.metrics
        t_start = time.perf_counter()
        with obs.span("query", source=self.source,
                      cache="hit" if self.cache_hit else "miss") as sp:
            b = self.binding(params)
            with obs.span("route", cat="route"):
                ans = self._tier1(b)
            if ans is not None:
                sp.set(tier=1, route=ans.source)
                mreg.counter("driver.tier1").inc()
                mreg.histogram("query.tier1_us").record(
                    (time.perf_counter() - t_start) * 1e6)
                return ans
            fn = self._tier2_fn()
            cols = self.driver._columns()
            with obs.span("execute", cat="exec"):
                if self.entry.params:
                    out = self.driver._guarded_call(
                        self.entry, "scalar", fn, cols, self._cast(b))
                else:
                    out = self.driver._guarded_call(
                        self.entry, "scalar", fn, cols)
                out = jax.device_get(out)
            overflow = bool(np.asarray(out.pop("overflow", False)))
            value = out["value"] if set(out) == {"value"} else out
            sp.set(tier=2, route=self.source, overflow=overflow)
            mreg.counter("driver.tier2").inc()
            self.driver._count_scan_bytes(self.entry)
            if overflow:
                mreg.counter("exchange.overflow").inc()
            mreg.histogram("query.tier2_us").record(
                (time.perf_counter() - t_start) * 1e6)
            return QueryAnswer(value, tier=2, source=self.source,
                               overflow=overflow)

    def execute_batch(self, param_table, pad_to: Optional[int] = None
                      ) -> QueryAnswer:
        """Run many bindings of this prepared shape as ONE vmapped SPMD
        dispatch.  ``param_table`` is a mapping name -> length-B sequence
        (missing names fall back to the defaults) or a sequence of B
        binding dicts.  Every output gains a leading lane axis; the
        ``overflow`` flag comes back per lane.  Batches always run the
        compiled Tier-2 plan (Tier-1 exactness is a per-binding decision —
        route single executions for that).

        ``pad_to`` pads the batch to a fixed lane count by repeating the
        last binding (outputs are sliced back to the real B).  The jitted
        batched executable re-specializes per DISTINCT lane count, so a
        continuous-batching caller whose batch sizes vary per tick pads
        to a few fixed bucket sizes instead of tracing one executable per
        observed size; the wasted duplicate lanes are counted in the
        ``driver.batch_pad_lanes`` metric."""
        if not self.entry.params:
            raise QueryError(
                f"prepared query {self.source!r} has no parameters — "
                f"execute_batch needs a parameterized shape"
            )
        if isinstance(param_table, Mapping):
            seqs = {k: list(v) for k, v in param_table.items()}
            sizes = {len(v) for v in seqs.values()}
            if len(sizes) != 1:
                raise QueryError(
                    f"ragged param_table: column lengths {sorted(sizes)}"
                )
            B = sizes.pop()
            rows = [{k: seqs[k][i] for k in seqs} for i in range(B)]
        else:
            rows = [dict(r) for r in param_table]
            B = len(rows)
        if B == 0:
            raise QueryError("execute_batch needs at least one binding")
        merged = [self.binding(r) for r in rows]
        obs = self.driver.obs
        mreg = obs.metrics
        lanes = B
        if pad_to is not None and pad_to > B:
            merged = merged + [merged[-1]] * (pad_to - B)
            lanes = pad_to
            mreg.counter("driver.batch_pad_lanes").inc(pad_to - B)
        stacked = {
            p.name: jnp.asarray(np.asarray([m[p.name] for m in merged],
                                           np.dtype(p.dtype)))
            for p in self.entry.params
        }
        with obs.span("query.batch", source=self.source, lanes=B,
                      padded=lanes) as sp:
            self._tier2_fn()  # surface LoweringError as UncoveredQueryError
            fn = self.driver._ensure_batched(self.entry)
            with obs.span("execute", cat="exec"):
                out = jax.device_get(self.driver._guarded_call(
                    self.entry, ("batch", lanes), fn,
                    self.driver._columns(), stacked))
            overflow = out.pop("overflow", None)
            overflow = (np.zeros(lanes, bool) if overflow is None
                        else np.asarray(overflow))
            value = out["value"] if set(out) == {"value"} else out
            if lanes != B:  # drop the padding lanes from every output
                value = jax.tree.map(lambda a: a[:B], value)
                overflow = overflow[:B]
            n_ovf = int(np.asarray(overflow).sum())
            sp.set(tier=2, overflow_lanes=n_ovf)
            mreg.counter("driver.batch").inc()
            mreg.counter("driver.batch_lanes").inc(B)
            self.driver._count_scan_bytes(self.entry, lanes=B)
            if n_ovf:
                mreg.counter("exchange.overflow").inc(n_ovf)
            return QueryAnswer(value, tier=2, source=self.source,
                               overflow=overflow)


class TPCHDriver:
    def __init__(self, sf: float, cluster: Cluster | None = None, seed: int = 0,
                 capacities=None, backend: str = "xla", wire: str = "packed",
                 obs: Observer | None = None, storage: str = "packed",
                 resident_budget: Optional[int] = None):
        self.cluster = cluster or Cluster()
        self.sf = sf
        self.seed = seed
        self.backend = backend
        self.wire = wire
        self.storage = storage
        # machine calibration for EXPLAIN's roofline predictions (persisted
        # by `python -m repro.core.wirecal`; builtin defaults otherwise)
        self.wire_cal = wirecal.load()
        # the observability hub: threaded (never global) through routing,
        # lowering and the exchange layer; on by default — pass
        # Observer(enabled=False) to drop tracing (metrics stay live)
        self.obs = obs if obs is not None else Observer()
        # §3.2.2-derived capacities for the hand plans; explicit overrides win
        self.capacities = tpch_capacities.derive(sf, self.cluster.num_nodes)
        self.capacities.update(capacities or {})
        # resident storage format: "packed" generates eligible columns
        # straight into the compressed PackedColumn form; self.tables stays
        # a DECODED host-side view (bit-identical to the packed codes) for
        # the oracle and catalog stats, while self.resident is what the
        # cluster actually holds and places
        self.resident = dbgen.generate(sf, self.cluster.num_nodes, seed,
                                       storage=storage)
        if storage == "packed":
            self.tables = {
                n: Table(n, {c: (np.asarray(col.decode())
                                 if isinstance(col, PackedColumn) else col)
                             for c, col in t.columns.items()},
                         t.dictionaries, t.replicated)
                for n, t in self.resident.items()
            }
        else:
            self.tables = self.resident
        # pad the supplier key space so §3.2.5 groups divide evenly
        self._extend_derived_tables()
        for extra in set(self.tables) - set(self.resident):
            self.resident[extra] = self.tables[extra]
        packed_meta = {
            n: {c: PackedInfo(width=col.width, offset=col.offset,
                              values=col.values, dtype=col.dtype)
                for c, col in t.columns.items()
                if isinstance(col, PackedColumn)}
            for n, t in self.resident.items()
        }
        self.catalog = build_catalog(self.tables,
                                     num_nodes=self.cluster.num_nodes,
                                     packed=packed_meta)
        # resident-footprint accounting + node memory budget: the budget
        # models per-node main memory; exceeding it is the OOM the packed
        # format exists to push out by ~the compression ratio
        if resident_budget is None:
            env = os.environ.get("REPRO_RESIDENT_BUDGET_BYTES")
            resident_budget = int(env) if env else None
        mreg = self.obs.metrics
        total = 0
        for n, t in self.resident.items():
            b = _resident_bytes(t)
            total += b
            mreg.gauge(f"storage.bytes_resident.{n}").set(b)
        mreg.gauge("storage.bytes_resident").set(total)
        self.resident_bytes = total
        if resident_budget is not None and total > resident_budget:
            raw = sum(_raw_bytes(t) for t in self.resident.values())
            raise ResidentBudgetError(
                f"resident dataset at sf={sf} needs {total} bytes in "
                f"{storage!r} storage but the node budget is "
                f"{resident_budget} bytes (fully decoded it would be "
                f"{raw}); use storage='packed' or a smaller scale factor")
        self.placed = {n: self.cluster.load(t)
                       for n, t in self.resident.items()}
        self.ctx = self.cluster.context(
            self.placed, self.capacities, backend=backend, scale_factor=sf,
            wire=wire,
            wires=tpch_capacities.wire_formats(self.tables,
                                               self.cluster.num_nodes),
            obs=self.obs,
        )
        self._compiled = {}       # registry name -> compiled hand plan
        self._prepared = {}       # STRUCTURAL shape key -> _PlanEntry (LRU)
        # one lock for every cache the driver mutates (_compiled,
        # _prepared + its LRU order, per-entry bound-closure LRUs): the
        # serving tier calls prepare()/query() from the event loop and
        # executor threads concurrently.  Reentrant because prepare() is
        # reached from compile()/compile_query() which may already hold it.
        self._lock = threading.RLock()
        # Device executions are globally serialized: XLA's host-platform
        # collectives rendezvous on the 8 shared device threads, so TWO
        # multi-device programs dispatched concurrently each wait for all
        # of their participants and neither set can assemble (observed as
        # "waiting for all participants to arrive at rendezvous" hangs).
        # One dispatch at a time is also the honest model of one shared
        # cluster — concurrency comes from batching lanes into a dispatch,
        # not from overlapping dispatches.
        self._dispatch_gate = threading.Lock()
        self._profiling = False   # True while explain_analyze dumps HLO —
                                  # that re-trace is an artifact, not a
                                  # compile event

        self.compile_events = []  # one label per XLA trace of a prepared
                                  # plan ("<shape>" / "<shape>@batch") —
                                  # the compile-once contract is testable
        self.cubes = {}
        self.router: CubeRouter | None = None

    def _extend_derived_tables(self):
        # q3_repl needs the replicated remote join attribute, built at load
        # time (paper's 'repl' variant)
        cust = self.tables["customer"]
        self.tables["customer_seg_repl"] = Table(
            "customer_seg_repl",
            {"c_mktsegment": np.asarray(cust.columns["c_mktsegment"])},
            replicated=True,
        )

    def _columns(self):
        return {n: t.columns for n, t in self.placed.items()}

    def _count_scan_bytes(self, entry: _PlanEntry, lanes: int = 1) -> None:
        """Account one execution's predicted scan traffic against the
        ``storage.bytes_scanned`` counters (cluster-wide bytes: per-node
        prediction x nodes x batch lanes)."""
        if not entry.scans:
            return
        mreg = self.obs.metrics
        nn = max(self.cluster.num_nodes, 1)
        total = 0
        for d in entry.scans:
            b = d.scan_bytes * nn * lanes
            mreg.counter(f"storage.bytes_scanned.{d.table}").inc(b)
            total += b
        mreg.counter("storage.bytes_scanned").inc(total)

    def _guarded_call(self, entry, key, fn, *args):
        """Run one device dispatch of ``entry``'s specialization ``key``.

        Two separate serializations, both required for threaded callers:
        the FIRST call per specialization holds ``entry.lock`` so exactly
        one thread pays the deferred XLA trace, and EVERY call holds the
        driver's ``_dispatch_gate`` so two collective programs never
        rendezvous concurrently on the shared host-platform devices (see
        the gate's comment in ``__init__``)."""
        if key in entry.warm:
            with self._dispatch_gate:
                return fn(*args)
        with entry.lock:
            with self._dispatch_gate:
                out = fn(*args)
            entry.warm.add(key)
            return out

    # -- physical layer (hand plans / lowered IR by registry name) ---------
    def compile(self, name: str):
        """Compiled plan for a registered query: the hand-written physical
        plan when one exists, else the lowered IR (shared with the
        structural query cache — one executable per query)."""
        with self._lock:
            if name not in self._compiled:
                entry = plan_registry.get(name)
                if entry.plan is not None:
                    self._compiled[name] = self.cluster.compile(
                        entry.plan, self.ctx, self.placed)
                elif entry.ir is not None:
                    self._compiled[name] = self.compile_query(entry.ir)
                else:  # pragma: no cover — registry invariant
                    raise LoweringError(f"{name!r} has neither plan nor IR")
            return self._compiled[name]

    def run(self, name: str):
        return self.compile(name)(self._columns())

    def compile_ir(self, name: str):
        """Compiled LOWERED plan for a registered query's IR (even when a
        hand plan exists — used to compare the two)."""
        entry = plan_registry.get(name)
        if entry.ir is None:
            raise LoweringError(
                f"{name!r} has no IR definition — only the hand-written "
                f"plan; express it in the algebra first"
            )
        return self.compile_query(entry.ir)

    def run_ir(self, name: str):
        return self.compile_ir(name)(self._columns())

    IR_CACHE_MAX = 32    # compiled-executable LRU bound for ad-hoc queries
    BOUND_CACHE_MAX = 8  # per-shape LRU bound for literal-bound closures

    # -- prepared statements (compile once, execute for any literals) ------
    def prepare(self, q) -> PreparedQuery:
        """Prepare an IR query (or a registered name): canonicalize it into
        a parameterized shape + default binding, and return the (possibly
        cached) :class:`PreparedQuery`.  The structural cache keys on the
        SHAPE alone, so queries differing only in predicate literals share
        one compiled executable; compilation itself is lazy — the first
        Tier-2 execution pays it, Tier-1-served queries never do."""
        if isinstance(q, str):
            entry = plan_registry.get(q)
            if entry.ir is None:
                raise LoweringError(
                    f"{q!r} has no IR definition — only the hand-written "
                    f"plan; express it in the algebra first"
                )
            q = entry.ir
        if not isinstance(q, Query):
            raise TypeError(
                f"prepare() takes a repro.query.Query (or a registered "
                f"plan name), got {type(q)}"
            )
        validate(q.root, self.catalog)  # typed errors at prepare time
        shape, defaults = parameterize(q, obs=self.obs)
        source = q.name or "<lowered-ir>"
        key = repr(shape.root)  # structural; same_query guards collisions
        # lookup-or-insert is atomic: two threads preparing the same shape
        # concurrently must converge on ONE entry (one miss, one hit), or
        # each would compile its own executable
        with self._lock:
            hit = self._prepared.get(key)
            if hit is not None and same_query(hit.shape, shape):
                self._prepared[key] = self._prepared.pop(key)  # LRU touch
                self.obs.metrics.counter("plan_cache.hit").inc()
                return PreparedQuery(self, hit, defaults, source,
                                     cache_hit=True)
            entry = _PlanEntry(shape, stats_binding=defaults)
            self._prepared[key] = entry
            while len(self._prepared) > self.IR_CACHE_MAX:
                self._prepared.pop(next(iter(self._prepared)))
            self.obs.metrics.counter("plan_cache.miss").inc()
            return PreparedQuery(self, entry, defaults, source)

    def _lowered_plan(self, entry: _PlanEntry, label: str,
                      batched: bool = False):
        """Lower the shape and wrap it so every XLA trace is counted in
        ``compile_events`` (jit executes the wrapper body only when it
        traces, i.e. exactly once per compiled specialization); the same
        wrapper feeds the ``plan.compile_events`` registry counter and an
        ``xla.trace`` event, so re-trace regressions show up in
        ``explain_analyze`` and ``--metrics``."""
        plan = lower(entry.shape, self.catalog, wire=self.wire,
                     binding=entry.stats_binding, batched=batched,
                     obs=self.obs)
        entry.semijoins = tuple(getattr(plan, "semijoins", ()))
        entry.scans = tuple(getattr(plan, "scans", ()))
        events = self.compile_events
        obs = self.obs
        drv = self

        def on_trace():
            if drv._profiling:
                return
            events.append(label)
            obs.metrics.counter("plan.compile_events").inc()
            obs.event("xla.trace", cat="plan", label=label)

        if plan.params:
            def wrapped(ctx, t, pvals):
                on_trace()
                return plan(ctx, t, pvals)
        else:
            def wrapped(ctx, t):
                on_trace()
                return plan(ctx, t)
        wrapped.params = plan.params
        return wrapped

    def _ensure_compiled(self, entry: _PlanEntry):
        if entry.fn is None:
            with entry.lock:  # double-checked: lower+jit-wrap once
                if entry.fn is None:
                    label = entry.shape.name or "<lowered-ir>"
                    with self.obs.span("lower", cat="plan", label=label):
                        entry.fn = self.cluster.compile(
                            self._lowered_plan(entry, label),
                            self.ctx, self.placed)
        return entry.fn

    def _ensure_batched(self, entry: _PlanEntry):
        if entry.batched_fn is None:
            with entry.lock:
                if entry.batched_fn is None:
                    label = f"{entry.shape.name or '<lowered-ir>'}@batch"
                    with self.obs.span("lower", cat="plan", label=label):
                        entry.batched_fn = self.cluster.compile(
                            self._lowered_plan(entry, label, batched=True),
                            self.ctx, self.placed, batch=True)
        return entry.batched_fn

    def compile_query(self, q: Query):
        """Lower + compile an arbitrary IR query, returning a plain
        ``fn(columns)`` with the query's own literals bound (the prepared
        executable is shared structurally; the returned closure is
        memoized per binding, so reconstructing the same query per request
        reuses BOTH).  Parameterized queries without full defaults need
        :meth:`prepare` instead."""
        prep = self.prepare(q)
        entry = prep.entry
        fn = self._ensure_compiled(entry)  # eager typed errors
        if not entry.params:
            return fn
        b = prep.binding()
        key = tuple(sorted(b.items()))
        with self._lock:
            if key in entry.bound:
                entry.bound[key] = entry.bound.pop(key)  # LRU touch
            else:
                pvals = prep._cast(b)
                entry.bound[key] = (
                    lambda columns, _fn=fn, _pv=pvals: _fn(columns, _pv))
                # closures hold device scalars; a literal-streaming caller
                # must not grow this without bound (the executable is shared
                # regardless — evicted bindings just rebuild a closure)
                while len(entry.bound) > self.BOUND_CACHE_MAX:
                    entry.bound.pop(next(iter(entry.bound)))
            return entry.bound[key]

    # -- two-tier execution (repro.cube) -----------------------------------
    def build_cubes(self, specs=None):
        """Materialize Tier-1 rollup cubes (one distributed scan per spec)
        and install the query router.  Defaults to the TPC-H presets."""
        if specs is None:
            from repro.tpch import cubes as tpch_cubes

            specs = tpch_cubes.default_specs()
        for spec in specs:
            with self.obs.span("cube.build", cat="plan", cube=spec.name):
                self.cubes[spec.name] = build_cube(
                    self.cluster, self.ctx, self.placed, spec
                )
        self.obs.metrics.gauge("router.cubes").set(len(self.cubes))
        self.router = CubeRouter(list(self.cubes.values()), obs=self.obs)
        return self.cubes

    def query(self, q, params=None) -> QueryAnswer:
        """Router-first execution of ONE query type.

        ``q`` is an IR ``Query`` (a registered name is accepted as sugar
        for its definition); ``params`` optionally binds/overrides its
        runtime parameters.  A ``GroupAgg`` root covered by a rollup is
        answered from the cube (Tier 1, host microseconds) with bin-edge
        exactness checked against THIS call's binding; anything else runs
        as the compiled SPMD plan lowered from the parameterized shape
        (Tier 2) — one executable per shape, re-executed for any literals.
        Raises :class:`UncoveredQueryError` when no cube covers the query
        and the IR has no lowerable form (e.g. min/max measures
        off-edge)."""
        if isinstance(q, str):
            entry = plan_registry.get(q)
            if entry.ir is None:
                if params:
                    raise UnboundParamError(
                        f"{q!r} resolves to a hand-written physical plan "
                        f"with no runtime parameters — binding(s) "
                        f"{sorted(params)} cannot be applied; use an IR "
                        f"form or drop params"
                    )
                value, overflow = _split_overflow(jax.device_get(self.run(q)))
                return QueryAnswer(value, tier=2, source=q, overflow=overflow)
            q = entry.ir
        if not isinstance(q, Query):
            raise TypeError(
                f"query() takes a repro.query.Query (or a registered plan "
                f"name), got {type(q)}"
            )
        return self.prepare(q).execute(params)

    # -- static verification (repro.query.verify) ---------------------------
    def check(self, q, params=None):
        """Statically verify a query (or registered IR name) against this
        driver's catalog, wire format, and capacity overrides — nothing is
        compiled or executed.  ``params`` optionally overrides the
        prepared defaults, so a binding can be vetted BEFORE
        ``prepare(q).execute(params)`` pays for it (an undersized exchange
        shows up as a ``CAP001`` error naming the worst-case binding).
        Returns a :class:`repro.query.verify.VerifyReport`; rule catalog
        in ``docs/RULES.md``."""
        from repro.query.verify import verify

        prep = self.prepare(q)
        if params:
            names = {p.name for p in prep.params}
            unknown = sorted(set(params) - names)
            if unknown:
                raise UnboundParamError(
                    f"unknown parameter(s) {unknown} for query "
                    f"{prep.source!r} (parameters: {sorted(names)})"
                )
        binding = dict(prep.defaults)
        binding.update(params or {})
        return verify(
            prep.entry.shape, self.catalog, wire=self.wire,
            binding=binding, stats_binding=prep.entry.stats_binding,
            capacities=self.capacities,
        )

    # -- EXPLAIN / EXPLAIN ANALYZE (repro.obs) ------------------------------
    def _explain(self, q, params=None):
        """Shared front half: prepare, route-match, predicted plan rows."""
        prep = self.prepare(q)
        entry = prep.entry
        binding = dict(prep.defaults)
        if params:
            binding.update(params)
        match = None
        if self.router is not None:
            if entry.route[0] is not self.router:
                entry.route = (self.router,
                               self.router.route_query(entry.shape))
            match = entry.route[1]
        tier = 1 if match is not None else 2
        source = (match.route.cube.spec.name if match is not None
                  else prep.source)
        rows, sjs, err = [], [], None
        try:
            rows = explain_chain(entry.shape, self.catalog, wire=self.wire,
                                 binding=binding, predict_cal=self.wire_cal)
        except (LoweringError, QueryError) as e:
            err = str(e)
        for r in rows:
            if r["op"] != "SemiJoin":
                continue
            wf = r["wire"]
            kind = "packed" if (self.wire != "raw" and wf.packed) else "raw"
            sjs.append(SemiJoinInfo(
                index=len(sjs), table=r["table"], alt=r["alt"],
                capacity=r["capacity"], capacity_key=r["capacity_key"],
                wire_kind=kind, key_bits=wf.key_bits, gamma=r["gamma"],
                codec_ms=r["codec_ms"], wire_ms=r["wire_ms"],
            ))
        diagnostics = []
        try:
            from repro.query.verify import verify

            diagnostics = list(verify(
                entry.shape, self.catalog, wire=self.wire, binding=binding,
                stats_binding=entry.stats_binding,
                capacities=self.capacities,
            ).diagnostics)
        except QueryError:
            pass  # plan_error already carries the lowering failure
        report = ExplainReport(
            query=prep.source, route_tier=tier, route_source=source,
            cache="hit" if prep.cache_hit else "miss", params=binding,
            plan_rows=rows, semijoins=sjs, plan_error=err,
            diagnostics=diagnostics,
        )
        return report, prep

    def explain(self, q, params=None) -> ExplainReport:
        """Static EXPLAIN: the route the query WOULD take (Tier-1 cube
        match vs Tier-2 compiled plan), plan-cache state, and the cost
        model's per-operator predictions — nothing is compiled or run."""
        report, _ = self._explain(q, params)
        return report

    def explain_analyze(self, q, params=None) -> ExplainReport:
        """EXPLAIN plus one traced execution: observed tier, compile vs
        execute milliseconds (the query runs cold, and again warm when the
        first run traced, so the difference isolates XLA compilation),
        per-execution overflow, registry counters, and — for Tier-2 runs —
        per-collective HLO bytes attributed to the plan's request
        semi-joins in program order."""
        report, prep = self._explain(q, params)
        entry = prep.entry
        mreg = self.obs.metrics
        ev0 = len(self.compile_events)
        t0 = time.perf_counter()
        ans = prep.execute(params)
        cold_s = time.perf_counter() - t0
        traces = len(self.compile_events) - ev0
        observed = {
            "tier": ans.tier,
            "source": ans.source,
            "overflow": bool(np.asarray(ans.overflow).any()),
        }
        if traces:
            t0 = time.perf_counter()
            ans = prep.execute(params)
            warm_s = time.perf_counter() - t0
            observed["compile_ms"] = max(cold_s - warm_s, 0.0) * 1e3
            observed["xla_traces"] = traces
            observed["execute_ms"] = warm_s * 1e3
        else:
            observed["compile_ms"] = None
            observed["xla_traces"] = 0
            observed["execute_ms"] = cold_s * 1e3
        # registry counters BEFORE the profiling compile below, so the
        # report reflects what the measured runs did
        observed["overflow_count"] = mreg.value("exchange.overflow")
        observed["compile_events"] = mreg.value("plan.compile_events")
        observed["bytes_scanned"] = mreg.value("storage.bytes_scanned")
        observed["bytes_resident"] = mreg.value("storage.bytes_resident")
        # trace-time codec predictions accumulated by the exchange layer
        # (one record per compiled exchange specialization)
        for hname in ("exchange.encode_ms", "exchange.decode_ms"):
            h = mreg.get(hname)
            if h is not None and h.count:
                observed[hname] = h.snapshot()
        if ans.tier == 2 and report.plan_error is None:
            try:
                prof = self._collective_profile(entry)
            except Exception as e:
                prof, observed["profile_error"] = None, str(e)
            if prof is not None:
                observed["collective_bytes_by_op"] = dict(prof.bytes_by_op)
                observed["collective_count_by_op"] = dict(prof.count_by_op)
                attribute_semijoin_bytes(prof.instructions, report.semijoins)
        report.observed = observed
        return report

    def _collective_profile(self, entry: _PlanEntry):
        """HLO collective stats of the compiled scalar plan, cached per
        entry.  Lazy on purpose: ``jit(...).lower().compile()`` is a second
        XLA compilation that plain query execution must never pay — only
        ``explain_analyze`` materializes it."""
        if entry.profile is None:
            from repro.launch.roofline import parse_collective_bytes

            fn = self._ensure_compiled(entry)
            cols = self._columns()
            self._profiling = True
            try:
                if entry.params:
                    pvals = {p.name: jax.ShapeDtypeStruct(
                        (), np.dtype(p.dtype)) for p in entry.params}
                    lowered = fn.lower(cols, pvals)
                else:
                    lowered = fn.lower(cols)
                entry.profile = parse_collective_bytes(
                    lowered.compile().as_text())
            finally:
                self._profiling = False
        return entry.profile

    def oracle(self, name: str, **kw):
        """Float64 numpy reference via the registry's EXPLICIT oracle
        binding (``q15_1factor`` -> ``q15`` etc. — no name munging)."""
        entry = plan_registry.get(name)
        if entry.oracle is None:
            raise LoweringError(f"{name!r} has no oracle binding")
        if entry.oracle == "q11":
            kw.setdefault("sf", self.sf)
        return reference.ALL[entry.oracle](self.tables, **kw)
