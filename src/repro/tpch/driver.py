"""End-to-end TPC-H driver: generate -> place -> run plan -> check vs oracle.

Used by tests, benchmarks and the serving example; this is the paper's
"prototype running a subset of TPC-H" in one object.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import Cluster, Table
from repro.core.plans import PLANS
from repro.cube import AggQuery, CubeRouter, build_cube
from repro.tpch import dbgen, reference
from repro.tpch.schema import DEFAULT_PARAMS

# default fixed-capacity knobs for small/medium scale factors; a production
# deployment derives them from the §3.2.2 selectivity model (see
# benchmarks/semijoin_cost.py)
DEFAULT_CAPACITIES = {
    "q2_request": 1024,
    "q2_owner": 1024,
    "q3_chunk": 256,
    "q3_rounds": 64,
    "q5_request": 8192,
    "q13_route": 8192,
    "q14_request": 8192,
    "q15_group": 1024,
    "q15_candidates": 256,
    "q21_request": 2048,
}


@dataclasses.dataclass
class QueryAnswer:
    """Result of router-first execution: which tier served the query."""

    value: object
    tier: int          # 1 = rollup cube, 2 = precompiled plan
    source: str        # cube name (tier 1) or plan name (tier 2)


class TPCHDriver:
    def __init__(self, sf: float, cluster: Cluster | None = None, seed: int = 0,
                 capacities=None, backend: str = "xla"):
        self.cluster = cluster or Cluster()
        self.sf = sf
        self.seed = seed
        self.backend = backend
        self.capacities = dict(DEFAULT_CAPACITIES)
        self.capacities.update(capacities or {})
        self.tables = dbgen.generate(sf, self.cluster.num_nodes, seed)
        # pad the supplier key space so §3.2.5 groups divide evenly
        self._extend_derived_tables()
        self.placed = {n: self.cluster.load(t) for n, t in self.tables.items()}
        self.ctx = self.cluster.context(
            self.placed, self.capacities, backend=backend, scale_factor=sf
        )
        self._compiled = {}
        self.cubes = {}
        self.router: CubeRouter | None = None

    def _extend_derived_tables(self):
        # q3_repl needs the replicated remote join attribute, built at load
        # time (paper's 'repl' variant)
        cust = self.tables["customer"]
        self.tables["customer_seg_repl"] = Table(
            "customer_seg_repl",
            {"c_mktsegment": np.asarray(cust.columns["c_mktsegment"])},
            replicated=True,
        )

    def compile(self, name: str):
        if name not in self._compiled:
            plan = PLANS[name]
            self._compiled[name] = self.cluster.compile(plan, self.ctx, self.placed)
        return self._compiled[name]

    def run(self, name: str):
        fn = self.compile(name)
        columns = {n: t.columns for n, t in self.placed.items()}
        return fn(columns)

    # -- two-tier execution (repro.cube) -----------------------------------
    def build_cubes(self, specs=None):
        """Materialize Tier-1 rollup cubes (one distributed scan per spec)
        and install the query router.  Defaults to the TPC-H presets."""
        if specs is None:
            from repro.tpch import cubes as tpch_cubes

            specs = tpch_cubes.default_specs()
        for spec in specs:
            self.cubes[spec.name] = build_cube(
                self.cluster, self.ctx, self.placed, spec
            )
        self.router = CubeRouter(list(self.cubes.values()))
        return self.cubes

    def query(self, q) -> QueryAnswer:
        """Router-first execution: serve from the finest covering rollup
        (Tier 1) when one exists, otherwise run the precompiled plan over
        the base tables (Tier 2).  ``q`` is an ``AggQuery`` or a plan name."""
        if isinstance(q, str):
            return QueryAnswer(self.run(q), tier=2, source=q)
        if not isinstance(q, AggQuery):
            raise TypeError(f"query() takes an AggQuery or plan name, got {type(q)}")
        if self.router is not None:
            route = self.router.route(q)
            if route is not None:
                value = self.router.answer(q, route)
                return QueryAnswer(value, tier=1, source=route.cube.spec.name)
        if q.fallback is None:
            raise LookupError(
                f"no cube covers the query over {q.table} and it names no "
                f"Tier-2 fallback plan"
            )
        return QueryAnswer(self.run(q.fallback), tier=2, source=q.fallback)

    def oracle(self, name: str, **kw):
        base = name.split("_")[0]
        if base == "q11":
            kw.setdefault("sf", self.sf)
        return reference.ALL[base](self.tables, **kw)
