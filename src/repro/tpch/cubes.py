"""TPC-H cube presets: the serving-workload rollups.

The lineitem cube is the Q1 workhorse: (returnflag × linestatus ×
ship-month) with all six Q1 measures, so the pricing summary report is a
slice + marginalize over a 516-cell array instead of a full scan.  The
ship-month dimension's bin edges are calendar month ends PLUS the Q1
cutoff date, making the ``l_shipdate <= cutoff`` predicate exactly
answerable (bins are ``(prev_edge, edge]``).

Measures are declared with the SAME IR expressions as the registry queries
(``repro.tpch.queries.REVENUE``/``CHARGE``), which is what lets the cube
router match a ``GroupAgg`` root against a spec structurally — one
definition of "revenue" across tiers.

The serving queries themselves live in ``repro.tpch.queries`` (they are
plain IR queries now); ``SERVING_QUERIES`` is re-exported here for the
launcher and benchmarks.
"""
from __future__ import annotations

from repro.cube import CubeSpec, Dimension, Measure
from repro.query import C
from repro.tpch import schema as S
from repro.tpch.queries import (  # noqa: F401  (re-exports)
    CHARGE,
    REVENUE,
    SERVING_QUERIES,
    month_edges,
    orders_by_priority_query,
    q1_query,
    revenue_by_shipmonth_query,
    uncovered_query,
)
from repro.tpch.schema import DEFAULT_PARAMS as DP


def lineitem_cube(params=DP) -> CubeSpec:
    return CubeSpec(
        name="lineitem_pricing",
        table="lineitem",
        dimensions=(
            Dimension("returnflag", "l_returnflag", len(S.RETURNFLAGS)),
            Dimension("linestatus", "l_linestatus", len(S.LINESTATUS)),
            Dimension("shipmonth", "l_shipdate", integral=True,
                      edges=month_edges(extra=(params.q1_shipdate_max,))),
        ),
        measures=(
            Measure("sum_qty", "sum", C("l_quantity")),
            Measure("sum_base_price", "sum", C("l_extendedprice")),
            Measure("sum_disc_price", "sum", REVENUE),
            Measure("sum_charge", "sum", CHARGE),
            Measure("sum_disc", "sum", C("l_discount")),
            Measure("count_order", "count"),
        ),
        rollups=(
            ("returnflag", "linestatus", "shipmonth"),
            ("returnflag", "linestatus"),
            ("shipmonth",),
        ),
    )


def orders_cube(params=DP) -> CubeSpec:
    return CubeSpec(
        name="orders_status",
        table="orders",
        dimensions=(
            Dimension("orderpriority", "o_orderpriority", len(S.PRIORITIES)),
            Dimension("orderstatus", "o_orderstatus", len(S.ORDERSTATUS)),
            Dimension("ordermonth", "o_orderdate", integral=True,
                      edges=month_edges(extra=(params.q4_date_min - 1,
                                               params.q4_date_max - 1,
                                               params.q5_date_min - 1,
                                               params.q5_date_max - 1))),
        ),
        measures=(
            Measure("count_orders", "count"),
            Measure("sum_totalprice", "sum", C("o_totalprice")),
            Measure("min_totalprice", "min", C("o_totalprice")),
            Measure("max_totalprice", "max", C("o_totalprice")),
        ),
        rollups=(
            ("orderpriority", "orderstatus", "ordermonth"),
            ("orderpriority",),
        ),
    )


def default_specs(params=DP) -> tuple:
    return (lineitem_cube(params), orders_cube(params))
