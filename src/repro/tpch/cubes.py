"""TPC-H cube presets: the serving-workload rollups and their queries.

The lineitem cube is the Q1 workhorse: (returnflag × linestatus ×
ship-month) with all six Q1 measures, so the pricing summary report is a
slice + marginalize over a 516-cell array instead of a full scan.  The
ship-month dimension's bin edges are calendar month ends PLUS the Q1
cutoff date, making the ``l_shipdate <= cutoff`` predicate exactly
answerable (bins are ``(prev_edge, edge]``).

The orders cube covers priority/status/order-month counting queries.
Queries outside cube coverage (Q4's EXISTS against lineitem, arbitrary-date
filters) route to the Tier-2 precompiled plans.
"""
from __future__ import annotations

from repro.cube import AggQuery, CubeSpec, Dimension, Filter, Measure
from repro.tpch import schema as S
from repro.tpch.schema import DEFAULT_PARAMS as DP


def month_edges(extra=()):
    """Last day (in TPC-H day numbers) of every month 1992-01..1998-12,
    plus any extra cut points (deduplicated, sorted)."""
    edges = set()
    for y in range(1992, 1999):
        for m in range(1, 13):
            nxt = (y + 1, 1) if m == 12 else (y, m + 1)
            edges.add(S.day(nxt[0], nxt[1], 1) - 1)
    edges.update(extra)
    return tuple(sorted(edges))


def _revenue(cols):
    return cols["l_extendedprice"] * (1.0 - cols["l_discount"])


def _charge(cols):
    return _revenue(cols) * (1.0 + cols["l_tax"])


def lineitem_cube(params=DP) -> CubeSpec:
    return CubeSpec(
        name="lineitem_pricing",
        table="lineitem",
        dimensions=(
            Dimension("returnflag", "l_returnflag", len(S.RETURNFLAGS)),
            Dimension("linestatus", "l_linestatus", len(S.LINESTATUS)),
            Dimension("shipmonth", "l_shipdate", integral=True,
                      edges=month_edges(extra=(params.q1_shipdate_max,))),
        ),
        measures=(
            Measure("sum_qty", "sum", "l_quantity"),
            Measure("sum_base_price", "sum", "l_extendedprice"),
            Measure("sum_disc_price", "sum", _revenue),
            Measure("sum_charge", "sum", _charge),
            Measure("sum_disc", "sum", "l_discount"),
            Measure("count_order", "count"),
        ),
        rollups=(
            ("returnflag", "linestatus", "shipmonth"),
            ("returnflag", "linestatus"),
            ("shipmonth",),
        ),
    )


def orders_cube(params=DP) -> CubeSpec:
    return CubeSpec(
        name="orders_status",
        table="orders",
        dimensions=(
            Dimension("orderpriority", "o_orderpriority", len(S.PRIORITIES)),
            Dimension("orderstatus", "o_orderstatus", len(S.ORDERSTATUS)),
            Dimension("ordermonth", "o_orderdate", integral=True,
                      edges=month_edges(extra=(params.q4_date_min - 1,
                                               params.q4_date_max - 1,
                                               params.q5_date_min - 1,
                                               params.q5_date_max - 1))),
        ),
        measures=(
            Measure("count_orders", "count"),
            Measure("sum_totalprice", "sum", "o_totalprice"),
            Measure("min_totalprice", "min", "o_totalprice"),
            Measure("max_totalprice", "max", "o_totalprice"),
        ),
        rollups=(
            ("orderpriority", "orderstatus", "ordermonth"),
            ("orderpriority",),
        ),
    )


def default_specs(params=DP) -> tuple:
    return (lineitem_cube(params), orders_cube(params))


# -- canonical serving queries ----------------------------------------------


def q1_query(params=DP) -> AggQuery:
    """TPC-H Q1 as a cube query: reshaping the (3, 2, 6) answer to (6, 6)
    reproduces ``tpch.reference.q1`` exactly (group id = returnflag*2 +
    linestatus is the C-order of the (returnflag, linestatus) axes)."""
    return AggQuery(
        table="lineitem",
        group_by=("returnflag", "linestatus"),
        measures=("sum_qty", "sum_base_price", "sum_disc_price", "sum_charge",
                  "sum_disc", "count_order"),
        filters=(Filter("shipmonth", "<=", params.q1_shipdate_max),),
        fallback="q1",
    )


def revenue_by_shipmonth_query() -> AggQuery:
    return AggQuery(
        table="lineitem",
        group_by=("shipmonth",),
        measures=("sum_disc_price", "count_order"),
    )


def orders_by_priority_query(params=DP) -> AggQuery:
    """Q4-shaped distribution (date-windowed priority counts) — answerable
    from the orders cube because the window bounds sit on bin edges; the
    EXISTS-filtered real Q4 still needs Tier 2."""
    return AggQuery(
        table="orders",
        group_by=("orderpriority",),
        measures=("count_orders", "sum_totalprice"),
        filters=(Filter("ordermonth", ">=", params.q4_date_min),
                 Filter("ordermonth", "<", params.q4_date_max)),
        fallback="q4",
    )


def uncovered_query(params=DP) -> AggQuery:
    """A Q1 variant whose shipdate bound is NOT a bin edge — must fall back
    to the Tier-2 compiled plan."""
    return AggQuery(
        table="lineitem",
        group_by=("returnflag", "linestatus"),
        measures=("sum_qty", "count_order"),
        filters=(Filter("shipmonth", "<=", params.q1_shipdate_max - 1),),
        fallback="q1",
    )


SERVING_QUERIES = {
    "q1_cube": q1_query,
    "revenue_by_shipmonth": revenue_by_shipmonth_query,
    "orders_by_priority": orders_by_priority_query,
}
