"""Deterministic synthetic TPC-H generator (paper §4.1).

The paper generates chunk i of every table directly in the memory of node i
(``dbgen -s SF -S rank -C P``).  We mirror that: ``generate_node`` builds the
partition of one node from a seed derived from (seed, table, node), so data
is identical no matter where/when a chunk is produced — the property the
paper relies on for shared-nothing loading, and the one our elastic restart
relies on for re-sharding.

Co-partitioning by construction: node i's lineitems reference node i's
orders; node i's partsupps reference node i's parts.  Remote foreign keys
(o_custkey, l_suppkey, l_partkey, ps_suppkey) are uniform over the global
key space, exactly the dashed edges of Fig. 1.

Only nation/region (25/5 rows) are replicated (paper: tables <= ~50 rows).
"""
from __future__ import annotations

import numpy as np

from repro.core.columnar import Table, concat_tables, pack_column, plan_packing
from repro.tpch import schema as S


def table_sizes(sf: float, num_nodes: int) -> dict:
    """Per-table GLOBAL row counts: scaled, rounded to multiples of P."""
    sizes = {}
    for name, base in S.BASE_ROWS.items():
        per_node = max(32, int(round(base * sf / num_nodes)))
        sizes[name] = per_node * num_nodes
    sizes["partsupp"] = sizes["part"] * S.SUPPLIERS_PER_PART
    sizes["lineitem"] = sizes["orders"] * S.LINEITEM_FANOUT_AVG
    sizes["nation"] = 25
    sizes["region"] = 5
    return sizes


def _rng(seed: int, table: str, node: int) -> np.random.Generator:
    ss = np.random.SeedSequence([seed, hash(table) & 0x7FFFFFFF, node])
    return np.random.default_rng(ss)


def _gen_supplier(rng, n, base):
    key = base + np.arange(n, dtype=np.int32)
    return {
        "s_suppkey": key,
        "s_nationkey": rng.integers(0, 25, n).astype(np.int32),
        "s_acctbal": (rng.uniform(-999.99, 9999.99, n)).astype(np.float32),
        "s_name_code": key,
        "s_address_code": rng.integers(0, 1 << 30, n).astype(np.int32),
        "s_phone_code": rng.integers(0, 1 << 30, n).astype(np.int32),
    }


def _gen_customer(rng, n, base):
    key = base + np.arange(n, dtype=np.int32)
    return {
        "c_custkey": key,
        "c_nationkey": rng.integers(0, 25, n).astype(np.int32),
        "c_mktsegment": rng.integers(0, len(S.SEGMENTS), n).astype(np.int32),
        "c_name_code": key,
        "c_acctbal": rng.uniform(-999.99, 9999.99, n).astype(np.float32),
    }


def _gen_part(rng, n, base):
    key = base + np.arange(n, dtype=np.int32)
    return {
        "p_partkey": key,
        "p_size": rng.integers(1, 51, n).astype(np.int32),
        "p_type": rng.integers(0, S.NUM_TYPES, n).astype(np.int32),
        "p_mfgr": rng.integers(0, 5, n).astype(np.int32),
        "p_retailprice": (900.0 + (key % 1000) + 100.0 * rng.random(n)).astype(np.float32),
        "p_name_code": key,
    }


def _gen_partsupp(rng, n_parts, part_base, num_suppliers):
    pk = np.repeat(part_base + np.arange(n_parts, dtype=np.int32), S.SUPPLIERS_PER_PART)
    n = pk.shape[0]
    return {
        "ps_partkey": pk,
        "ps_suppkey": rng.integers(0, num_suppliers, n).astype(np.int32),
        "ps_supplycost": rng.uniform(1.0, 1000.0, n).astype(np.float32),
        "ps_availqty": rng.integers(1, 10_000, n).astype(np.float32),
    }


def _gen_orders_and_lineitem(rng, n_orders, order_base, num_customers, num_parts,
                             num_suppliers):
    okey = order_base + np.arange(n_orders, dtype=np.int32)
    odate = rng.integers(0, S.day(1998, 8, 2), n_orders).astype(np.int32)

    # lineitem fanout 1..7 per order, then adjusted so the node total is
    # EXACTLY fanout_avg * n_orders (fixed shapes; see DESIGN.md §2 statics)
    target = S.LINEITEM_FANOUT_AVG * n_orders
    nl = rng.integers(1, 8, n_orders).astype(np.int64)
    diff = int(target - nl.sum())
    # distribute the correction over orders, respecting 1..7 bounds
    idx = 0
    order_ids = np.arange(n_orders)
    rng.shuffle(order_ids)
    step = 1 if diff > 0 else -1
    while diff != 0:
        o = order_ids[idx % n_orders]
        nv = nl[o] + step
        if 1 <= nv <= 7:
            nl[o] = nv
            diff -= step
        idx += 1
    assert nl.sum() == target

    l_order_local = np.repeat(np.arange(n_orders, dtype=np.int32), nl)
    n_li = l_order_local.shape[0]
    l_odate = odate[l_order_local]
    qty = rng.integers(1, 51, n_li).astype(np.float32)
    price_base = rng.uniform(900.0, 2000.0, n_li).astype(np.float32)
    extprice = (qty * price_base).astype(np.float32)
    disc = (rng.integers(0, 11, n_li) / 100.0).astype(np.float32)
    tax = (rng.integers(0, 9, n_li) / 100.0).astype(np.float32)
    shipdate = (l_odate + rng.integers(1, 122, n_li)).astype(np.int32)
    commitdate = (l_odate + rng.integers(30, 91, n_li)).astype(np.int32)
    receiptdate = (shipdate + rng.integers(1, 31, n_li)).astype(np.int32)
    linestatus = (shipdate > S.day(1995, 6, 17)).astype(np.int32)  # O after cutoff
    returnflag = np.where(
        receiptdate <= S.day(1995, 6, 17),
        rng.integers(0, 2, n_li),          # A or N for old receipts
        2 * np.ones(n_li, dtype=np.int64),  # R
    ).astype(np.int32)
    # TPC-H: returnflag in {R,A,N}; keep all three present:
    returnflag = np.where(rng.random(n_li) < 0.33, 1, returnflag).astype(np.int32)

    lineitem = {
        "l_orderkey": okey[l_order_local],
        "l_partkey": rng.integers(0, num_parts, n_li).astype(np.int32),
        "l_suppkey": rng.integers(0, num_suppliers, n_li).astype(np.int32),
        "l_quantity": qty,
        "l_extendedprice": extprice,
        "l_discount": disc,
        "l_tax": tax,
        "l_returnflag": returnflag,
        "l_linestatus": linestatus,
        "l_shipdate": shipdate,
        "l_commitdate": commitdate,
        "l_receiptdate": receiptdate,
    }

    # o_totalprice from the co-located lineitems (TPC-H semantics)
    charge = extprice * (1.0 - disc) * (1.0 + tax)
    totalprice = np.zeros(n_orders, np.float64)
    np.add.at(totalprice, l_order_local, charge.astype(np.float64))
    orders = {
        "o_orderkey": okey,
        "o_custkey": rng.integers(0, num_customers, n_orders).astype(np.int32),
        "o_orderdate": odate,
        "o_orderpriority": rng.integers(0, 5, n_orders).astype(np.int32),
        "o_orderstatus": rng.integers(0, 3, n_orders).astype(np.int32),
        "o_totalprice": totalprice.astype(np.float32),
        "o_comment_special": (rng.random(n_orders) < 0.02),
    }
    return orders, lineitem


def generate_node(sf: float, node: int, num_nodes: int, seed: int = 0) -> dict:
    """All table partitions of one node (the paper's `dbgen -S node -C P`)."""
    sizes = table_sizes(sf, num_nodes)
    out = {}
    n_sup = sizes["supplier"] // num_nodes
    out["supplier"] = _gen_supplier(_rng(seed, "supplier", node), n_sup, node * n_sup)
    n_cust = sizes["customer"] // num_nodes
    out["customer"] = _gen_customer(_rng(seed, "customer", node), n_cust, node * n_cust)
    n_part = sizes["part"] // num_nodes
    out["part"] = _gen_part(_rng(seed, "part", node), n_part, node * n_part)
    out["partsupp"] = _gen_partsupp(
        _rng(seed, "partsupp", node), n_part, node * n_part, sizes["supplier"]
    )
    n_ord = sizes["orders"] // num_nodes
    orders, lineitem = _gen_orders_and_lineitem(
        _rng(seed, "orders", node), n_ord, node * n_ord,
        sizes["customer"], sizes["part"], sizes["supplier"],
    )
    out["orders"] = orders
    out["lineitem"] = lineitem
    return out


def _replicated_tables() -> dict:
    nk = np.arange(25, dtype=np.int32)
    nation = Table(
        "nation",
        {"n_nationkey": nk, "n_regionkey": (nk // S.NATIONS_PER_REGION).astype(np.int32)},
        dictionaries={"n_nationkey": S.NATIONS},
        replicated=True,
    )
    rk = np.arange(5, dtype=np.int32)
    region = Table(
        "region",
        {"r_regionkey": rk},
        dictionaries={"r_regionkey": S.REGIONS},
        replicated=True,
    )
    return {"nation": nation, "region": region}


DICTIONARIES = {
    "customer": {"c_mktsegment": S.SEGMENTS},
    "orders": {"o_orderpriority": S.PRIORITIES, "o_orderstatus": S.ORDERSTATUS},
    "lineitem": {"l_returnflag": S.RETURNFLAGS, "l_linestatus": S.LINESTATUS},
}


def generate(sf: float, num_nodes: int, seed: int = 0,
             storage: str = "raw") -> dict:
    """Global tables assembled from per-node chunks (host-side; used by the
    driver to place data and by the oracle for correctness checks).

    ``storage="packed"`` generates eligible columns straight into the
    compressed-resident :class:`~repro.core.columnar.PackedColumn` format
    (dictionary / frame-of-reference bit-packing, globally consistent
    width/offset/dictionary across node chunks) — the raw global column is
    never materialized.  Ineligible columns (wide key spans, high-entropy
    floats) stay raw; replicated tables always stay raw."""
    if storage not in ("raw", "packed"):
        raise ValueError(f"storage must be 'raw' or 'packed', got {storage!r}")
    chunks = [generate_node(sf, node, num_nodes, seed) for node in range(num_nodes)]
    tables = {}
    for name in ("supplier", "customer", "part", "partsupp", "orders", "lineitem"):
        if storage == "packed":
            cols = {}
            for cname in chunks[0][name]:
                cchunks = [chunks[n][name][cname] for n in range(num_nodes)]
                spec = plan_packing(cchunks)
                cols[cname] = (pack_column(cchunks, spec)
                               if spec is not None
                               else np.concatenate(cchunks))
            tables[name] = Table(name, cols, DICTIONARIES.get(name, {}))
        else:
            parts = [
                Table(name, chunks[n][name], DICTIONARIES.get(name, {}))
                for n in range(num_nodes)
            ]
            tables[name] = concat_tables(parts)
    tables.update(_replicated_tables())
    return tables
