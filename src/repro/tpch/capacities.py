"""Hand-plan exchange capacities derived from the §3.2.2 selectivity model.

The hand-written physical plans (the escape hatch below the Query IR) need
static per-destination buffer capacities for their request/owner-routed
exchanges.  These used to be magic per-query constants; now each one is
``capacity_for(expected per-destination message count)`` where the expected
count comes from the SAME predicate-selectivity estimates the IR lowering
uses (``repro.query.stats``): requests after local filtering spread
uniformly over P destinations, mean ``rows_local * sel / P``, plus a
6-sigma binomial tail margin.  Run-time overflow flags in the exchange
layer catch any under-estimate.

Alongside the capacities, :func:`wire_formats` derives each hand-plan
exchange's PACKED wire format from the same catalog information (target
table rows → per-destination key domain → ``required_width``), so the hand
plans ship the compressed §3.2.1 encoding by default exactly like the
lowered IR does.

Knobs that are NOT exchange buffers (lazy-top-k chunk/round counts, the
§3.2.5 codec group/candidate sizes) remain explicit algorithm parameters.
"""
from __future__ import annotations

from repro.query.stats import capacity_for, wire_format_for
from repro.tpch import dbgen
from repro.tpch import schema as S
from repro.tpch.schema import DEFAULT_PARAMS


def _date_sel(lo: int, hi: int) -> float:
    """Selectivity of a [lo, hi) window on the uniform order-date domain."""
    span = S.day(1998, 8, 2)
    return max(0.0, min(1.0, (hi - lo) / span))


def derive(sf: float, num_nodes: int, params=DEFAULT_PARAMS) -> dict:
    """Per-plan capacities for a TPC-H instance of this size."""
    sizes = dbgen.table_sizes(sf, num_nodes)
    P = max(num_nodes, 1)

    def per_dest(table: str, sel: float) -> float:
        return sizes[table] / P * sel / P

    # Q2: partsupp survivors of the part filter (p_size == v: 1/50;
    # p_type % 5 == finish: 1/5) request the supplier-region bit (Alt-1);
    # the minima (~one per qualifying part, <= 4 with cost ties) are then
    # routed to their supplier owners.
    q2_sel = (1.0 / 50.0) * (1.0 / S.NUM_BRASS)
    q2_owner = per_dest("part", 1.0 / 50.0 / S.NUM_BRASS) * S.SUPPLIERS_PER_PART

    # Q5: date-qualified orders request their customer's nation.
    q5_sel = _date_sel(params.q5_date_min, params.q5_date_max)

    # Q13: nearly every order (2% comment filter) routes to its customer.
    q13_sel = 0.98

    # Q14: lineitems in the one-month ship window request the part type.
    q14_sel = _date_sel(params.q14_date_min, params.q14_date_max)

    # Q21 (late): one request per ACTIVE supplier key; keys are dense and
    # range-partitioned, so each node addresses at most rows_per_node keys
    # to any single owner — that hard bound is the capacity driver.
    q21_e = sizes["supplier"] / P

    return {
        "q2_request": capacity_for(per_dest("partsupp", q2_sel)),
        "q2_owner": capacity_for(q2_owner),
        "q5_request": capacity_for(per_dest("orders", q5_sel)),
        "q13_route": capacity_for(per_dest("orders", q13_sel)),
        "q14_request": capacity_for(per_dest("lineitem", q14_sel)),
        "q21_request": capacity_for(q21_e),
        # algorithm parameters (not exchange buffers):
        "q3_chunk": 256,       # §3.2.4 lazy top-k candidate chunk
        "q3_rounds": 64,       # lax.while_loop bound for the lazy rounds
        "q15_group": 1024,     # §3.2.5 codec group (shrunk to fit per-node)
        "q15_candidates": 256, # §3.2.5 exact-value candidate buffer
    }


# each hand-plan exchange -> the table whose owners it addresses (the wire
# codec packs keys to that table's per-destination domain width)
_EXCHANGE_TARGETS = {
    "q2_request": "supplier",
    "q2_owner": "supplier",
    "q3_request": "customer",
    "q5_request": "customer",
    "q13_route": "customer",
    "q14_request": "part",
    "q21_request": "supplier",
}


def wire_formats(tables, num_nodes: int) -> dict:
    """Packed §3.2.1 wire format per hand-plan exchange, derived from the
    ACTUAL loaded tables (``TPCHDriver.tables``) so the per-destination key
    domains match the execution context's partitionings exactly."""
    return {
        name: wire_format_for(int(tables[target].num_rows), num_nodes)
        for name, target in _EXCHANGE_TARGETS.items()
    }


def wire_predictions(tables, num_nodes: int, capacities: dict,
                     cal=None) -> dict:
    """Roofline latency predictions per hand-plan exchange: name ->
    ``{"kind", "codec_ms", "wire_ms"}`` under the machine calibration
    (``repro.core.wirecal``; builtin defaults when None).  ``kind`` is what
    the latency model would CHOOSE for that exchange — hand plans compiled
    with a fixed wire can be audited against it (rule WIRE001)."""
    from repro.core import wirecal

    cal = cal if cal is not None else wirecal.load()
    out = {}
    for name, target in _EXCHANGE_TARGETS.items():
        cap = int(capacities.get(name, 0))
        if cap <= 0:
            continue
        wf = wire_format_for(int(tables[target].num_rows), num_nodes)
        kind = wirecal.choose_wire_kind(cap, num_nodes, wf.domain, cal=cal)
        codec_ms, wire_ms = wirecal.predict_alt1_ms(
            cap, num_nodes, wf.domain, packed=kind == "packed", cal=cal)
        out[name] = {"kind": kind, "codec_ms": codec_ms, "wire_ms": wire_ms}
    return out
