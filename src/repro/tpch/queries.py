"""TPC-H queries expressed in the declarative IR (``repro.query``).

One definition per query serves every consumer: the registry
(``repro.core.plans.REGISTRY``) carries these next to the hand-written
physical plans, the lowering pass compiles them to SPMD executables, and
the cube router matches their ``GroupAgg`` roots against Tier-1 rollups.
The shared measure expressions (``REVENUE``, ``CHARGE``) and the
``month_edges`` bin grid are THE single source of truth — ``repro.tpch.
cubes`` builds its specs from the same objects, which is what makes
IR-vs-cube structural matching exact.
"""
from __future__ import annotations

from repro.query import Bin, C, Fetch, Param, Q, Query
from repro.tpch import schema as S
from repro.tpch.schema import DEFAULT_PARAMS as DP
from repro.tpch.schema import day

# shared measure expressions (the TPC-H pricing terms)
REVENUE = C("l_extendedprice") * (1.0 - C("l_discount"))
CHARGE = REVENUE * (1.0 + C("l_tax"))


def month_edges(extra=()) -> tuple:
    """Last day (in TPC-H day numbers) of every month 1992-01..1998-12,
    plus any extra cut points (deduplicated, sorted)."""
    edges = set()
    for y in range(1992, 1999):
        for m in range(1, 13):
            nxt = (y + 1, 1) if m == 12 else (y, m + 1)
            edges.add(S.day(nxt[0], nxt[1], 1) - 1)
    edges.update(extra)
    return tuple(sorted(edges))


# ---------------------------------------------------------------------------
# registry queries (the paper's §4.3 set that the algebra covers)
# ---------------------------------------------------------------------------


def q1_ir(p=DP, method: str = "auto") -> Query:
    """Pricing summary report: filter + 6-group aggregate.  The flattened
    (6, 6) result matches ``reference.q1`` (group id = returnflag*2 +
    linestatus is the row-major order of the two keys)."""
    return (
        Q.scan("lineitem")
        .filter(C("l_shipdate") <= p.q1_shipdate_max)
        .group_agg(
            keys=[("returnflag", C("l_returnflag"), len(S.RETURNFLAGS)),
                  ("linestatus", C("l_linestatus"), len(S.LINESTATUS))],
            aggs=[("sum_qty", "sum", C("l_quantity")),
                  ("sum_base_price", "sum", C("l_extendedprice")),
                  ("sum_disc_price", "sum", REVENUE),
                  ("sum_charge", "sum", CHARGE),
                  ("sum_disc", "sum", C("l_discount")),
                  ("count_order", "count")],
            method=method,
        )
        .named("q1" if method == "auto" else f"q1_{method}")
    )


def q4_ir(p=DP) -> Query:
    """Order priority checking: date window + EXISTS late-lineitem probe
    (co-partitioned scatter) + 5-group count."""
    return (
        Q.scan("orders")
        .filter((C("o_orderdate") >= p.q4_date_min)
                & (C("o_orderdate") < p.q4_date_max))
        .exists("lineitem", key="l_orderkey",
                pred=C("l_commitdate") < C("l_receiptdate"))
        .group_agg(
            keys=[("orderpriority", C("o_orderpriority"), len(S.PRIORITIES))],
            aggs=[("order_count", "count")],
        )
        .named("q4")
    )


def q6_ir(p=DP) -> Query:
    """Forecasting revenue change: pure filter + global sum (1-cell
    GroupAgg)."""
    return (
        Q.scan("lineitem")
        .filter((C("l_shipdate") >= p.q6_date_min)
                & (C("l_shipdate") < p.q6_date_max)
                & (C("l_discount") >= p.q6_disc_min)
                & (C("l_discount") <= p.q6_disc_max)
                & (C("l_quantity") < p.q6_quantity))
        .group_agg(
            aggs=[("revenue", "sum", C("l_extendedprice") * C("l_discount"))],
        )
        .named("q6")
    )


def q18_ir(p=DP, k: int = 100) -> Query:
    """Large volume customers: co-partitioned group-by onto orders, filter
    on the aggregate, global top-k, then §3.2.7 late materialization of the
    output-only attributes (customer name via the remote fetch)."""
    return (
        Q.scan("lineitem")
        .group_by_key(C("l_orderkey"), into="orders",
                      aggs=[("sum_qty", "sum", C("l_quantity"))])
        .filter(C("sum_qty") > p.q18_quantity)
        .top_k(
            value=C("o_totalprice"), k=k,
            fetch=(Fetch("o_custkey"), Fetch("o_orderdate"), Fetch("sum_qty"),
                   Fetch("c_name_code", table="customer", key="o_custkey")),
        )
        .named("q18")
    )


def q14_promo_ir(p=DP, alt: str = "auto") -> Query:
    """Promotion-effect numerator (the Q14 semi-join shape): month window
    on lineitem, remote part-type filter via the §3.2.2 semi-join — the
    lowering picks Alt-1/Alt-2 from the cost model and derives the request
    capacity from the selectivity model."""
    return (
        Q.scan("lineitem")
        .filter((C("l_shipdate") >= p.q14_date_min)
                & (C("l_shipdate") < p.q14_date_max))
        .semijoin("part", key=C("l_partkey"),
                  pred=C("p_type") < S.PROMO_TYPES, alt=alt)
        .group_agg(aggs=[("promo_revenue", "sum", REVENUE)])
        .named("q14_promo" if alt == "auto" else f"q14_promo_{alt}")
    )


def q4_sj_ir(p=DP, alt: str = "request") -> Query:
    """Q4 forced through the §3.2.2 exchange: instead of the co-partitioned
    EXISTS probe, every lineitem semi-joins its ORDER's date window
    remotely, then the late filter + a per-order count reproduce the exact
    Q4 result (count of window orders with >= 1 late lineitem, by
    priority).  The request keys span the ORDERS key domain — this is the
    wire-format benchmark's q4 exchange."""
    return (
        Q.scan("lineitem")
        .semijoin("orders", key=C("l_orderkey"),
                  pred=(C("o_orderdate") >= p.q4_date_min)
                       & (C("o_orderdate") < p.q4_date_max),
                  alt=alt)
        .filter(C("l_commitdate") < C("l_receiptdate"))
        .group_by_key(C("l_orderkey"), into="orders",
                      aggs=[("late_cnt", "count")])
        .filter(C("late_cnt") > 0)
        .group_agg(
            keys=[("orderpriority", C("o_orderpriority"), len(S.PRIORITIES))],
            aggs=[("order_count", "count")],
        )
        .named(f"q4_sj_{alt}")
    )


def q18_sj_ir(p=DP, alt: str = "request", qty: float = 250.0,
              segment: int = DP.q3_segment) -> Query:
    """Q18 shape with a remote CUSTOMER filter via the §3.2.2 semi-join:
    large-volume orders keep only customers of one market segment.  The
    request keys span the (small) CUSTOMER key domain — the wire-format
    benchmark's q18 exchange."""
    return (
        Q.scan("lineitem")
        .group_by_key(C("l_orderkey"), into="orders",
                      aggs=[("sum_qty", "sum", C("l_quantity"))])
        .filter(C("sum_qty") > qty)
        .semijoin("customer", key=C("o_custkey"),
                  pred=C("c_mktsegment") == segment, alt=alt)
        .group_agg(aggs=[("sum_qty_total", "sum", C("sum_qty")),
                         ("order_count", "count")])
        .named(f"q18_sj_{alt}")
    )


IR_QUERIES = {
    "q1": q1_ir(),
    "q1_kernel": q1_ir(method="kernel"),
    "q4": q4_ir(),
    "q6": q6_ir(),
    "q14_promo": q14_promo_ir(),
    "q18": q18_ir(),
}


# ---------------------------------------------------------------------------
# prepared-statement forms: the TPC-H §2.4 substitution parameters as
# explicit Params (compile once, execute for any validation-run binding).
# Declared lo/hi ranges span the spec's substitution intervals, so the
# lowering sizes exchange capacities for the WORST legal binding.
# ---------------------------------------------------------------------------

_Q1_CUT = day(1998, 12, 1)  # shipdate <= 1998-12-01 - DELTA, DELTA in 60..120


def q1_param_ir() -> Query:
    """Q1 with the DELTA substitution parameter as a runtime Param."""
    cutoff = Param("q1_shipdate_max", "int32",
                   lo=_Q1_CUT - 120, hi=_Q1_CUT - 60)
    return (
        Q.scan("lineitem")
        .filter(C("l_shipdate") <= cutoff)
        .group_agg(
            keys=[("returnflag", C("l_returnflag"), len(S.RETURNFLAGS)),
                  ("linestatus", C("l_linestatus"), len(S.LINESTATUS))],
            aggs=[("sum_qty", "sum", C("l_quantity")),
                  ("sum_base_price", "sum", C("l_extendedprice")),
                  ("sum_disc_price", "sum", REVENUE),
                  ("sum_charge", "sum", CHARGE),
                  ("sum_disc", "sum", C("l_discount")),
                  ("count_order", "count")],
        )
        .named("q1_param")
    )


def q6_param_ir() -> Query:
    """Q6 with DATE/DISCOUNT/QUANTITY as runtime Params (a one-year window
    starting 1993..1997, discount window +-0.01 around 0.02..0.09,
    quantity 24/25)."""
    return (
        Q.scan("lineitem")
        .filter((C("l_shipdate") >= Param("q6_date_min", "int32",
                                          lo=day(1993, 1, 1),
                                          hi=day(1997, 1, 1)))
                & (C("l_shipdate") < Param("q6_date_max", "int32",
                                           lo=day(1994, 1, 1),
                                           hi=day(1998, 1, 1)))
                & (C("l_discount") >= Param("q6_disc_min", "float32",
                                            lo=0.005, hi=0.085))
                & (C("l_discount") <= Param("q6_disc_max", "float32",
                                            lo=0.025, hi=0.105))
                & (C("l_quantity") < Param("q6_quantity", "float32",
                                           lo=24.0, hi=25.0)))
        .group_agg(
            aggs=[("revenue", "sum", C("l_extendedprice") * C("l_discount"))],
        )
        .named("q6_param")
    )


def q14_promo_param_ir(alt: str = "auto") -> Query:
    """The Q14 semi-join shape with the one-month DATE window as runtime
    Params (month start 1993-01..1997-12): the remote part-type filter
    crosses the exchange, so the derived request capacity must hold for
    the worst window in the declared range."""
    return (
        Q.scan("lineitem")
        .filter((C("l_shipdate") >= Param("q14_date_min", "int32",
                                          lo=day(1993, 1, 1),
                                          hi=day(1997, 12, 1)))
                & (C("l_shipdate") < Param("q14_date_max", "int32",
                                           lo=day(1993, 2, 1),
                                           hi=day(1998, 1, 1))))
        .semijoin("part", key=C("l_partkey"),
                  pred=C("p_type") < S.PROMO_TYPES, alt=alt)
        .group_agg(aggs=[("promo_revenue", "sum", REVENUE)])
        .named("q14_promo_param" if alt == "auto" else f"q14_promo_param_{alt}")
    )


PARAM_QUERIES = {
    "q1": q1_param_ir,
    "q6": q6_param_ir,
    "q14_promo": q14_promo_param_ir,
}


def default_binding(name: str, p=DP) -> dict:
    """The TPC-H validation-run substitution values for a PARAM_QUERIES
    entry (the binding under which it must reproduce the stock oracle)."""
    if name == "q1":
        return {"q1_shipdate_max": p.q1_shipdate_max}
    if name == "q6":
        return {"q6_date_min": p.q6_date_min, "q6_date_max": p.q6_date_max,
                "q6_disc_min": p.q6_disc_min, "q6_disc_max": p.q6_disc_max,
                "q6_quantity": p.q6_quantity}
    if name == "q14_promo":
        return {"q14_date_min": p.q14_date_min,
                "q14_date_max": p.q14_date_max}
    raise KeyError(name)


def random_binding(name: str, rng) -> dict:
    """One random TPC-H §2.4 substitution draw for a PARAM_QUERIES entry
    (``rng`` is a ``numpy.random.Generator``).  Discount bounds land on
    midpoints of the 0.01 grid (the schema's convention) so f32 plans and
    the f64 oracle can never disagree on a boundary row."""
    if name == "q1":
        return {"q1_shipdate_max": _Q1_CUT - int(rng.integers(60, 121))}
    if name == "q6":
        y = int(rng.integers(1993, 1998))
        disc = int(rng.integers(2, 10)) / 100.0
        return {"q6_date_min": day(y, 1, 1),
                "q6_date_max": day(y + 1, 1, 1),
                "q6_disc_min": disc - 0.015,
                "q6_disc_max": disc + 0.015,
                "q6_quantity": float(rng.integers(24, 26))}
    if name == "q14_promo":
        y, m = int(rng.integers(1993, 1998)), int(rng.integers(1, 13))
        nxt = (y + 1, 1) if m == 12 else (y, m + 1)
        return {"q14_date_min": day(y, m, 1),
                "q14_date_max": day(nxt[0], nxt[1], 1)}
    raise KeyError(name)


def oracle_params(name: str, binding: dict, p=DP):
    """Fold a PARAM_QUERIES binding back into a ``QueryParams`` so the
    stock numpy oracles evaluate the SAME instance as a prepared plan."""
    import dataclasses

    fields = {f.name for f in dataclasses.fields(p)}
    subs = {k: v for k, v in binding.items() if k in fields}
    unknown = set(binding) - fields
    if unknown:
        raise KeyError(f"binding keys {sorted(unknown)} are not QueryParams")
    return dataclasses.replace(p, **subs)


# ---------------------------------------------------------------------------
# serving queries (the cube workload; all are GroupAgg roots so the router
# can match them, and all lower to SPMD plans when no rollup covers them)
# ---------------------------------------------------------------------------


def q1_query(p=DP) -> Query:
    return q1_ir(p)


def revenue_by_shipmonth_query(p=DP) -> Query:
    return (
        Q.scan("lineitem")
        .group_agg(
            keys=[("shipmonth",
                   Bin(C("l_shipdate"), month_edges(extra=(p.q1_shipdate_max,))))],
            aggs=[("sum_disc_price", "sum", REVENUE),
                  ("count_order", "count")],
        )
        .named("revenue_by_shipmonth")
    )


def orders_by_priority_query(p=DP) -> Query:
    """Date-windowed priority counts.  Cube-covered because the window
    bounds sit on bin edges; off-edge windows lower to a fresh SPMD plan
    (no hand-written fallback needed — this used to mis-route to Q4)."""
    return (
        Q.scan("orders")
        .filter((C("o_orderdate") >= p.q4_date_min)
                & (C("o_orderdate") < p.q4_date_max))
        .group_agg(
            keys=[("orderpriority", C("o_orderpriority"), len(S.PRIORITIES))],
            aggs=[("count_orders", "count"),
                  ("sum_totalprice", "sum", C("o_totalprice"))],
        )
        .named("orders_by_priority")
    )


def uncovered_query(p=DP) -> Query:
    """A Q1 variant whose shipdate bound is NOT a bin edge — the router
    rejects it and the driver answers Tier 2 from the lowered IR."""
    return (
        Q.scan("lineitem")
        .filter(C("l_shipdate") <= p.q1_shipdate_max - 1)
        .group_agg(
            keys=[("returnflag", C("l_returnflag"), len(S.RETURNFLAGS)),
                  ("linestatus", C("l_linestatus"), len(S.LINESTATUS))],
            aggs=[("sum_qty", "sum", C("l_quantity")),
                  ("count_order", "count")],
        )
        .named("q1_offedge")
    )


SERVING_QUERIES = {
    "q1_cube": q1_query,
    "revenue_by_shipmonth": revenue_by_shipmonth_query,
    "orders_by_priority": orders_by_priority_query,
}
