"""Metrics registry: named counters, gauges, and log-bucketed histograms.

The paper's evaluation (§4) is built on per-query wall clock and per-node
communication volume; a serving tier additionally needs p50/p95/p99 gates.
This registry is the runtime home for those numbers — cheap enough to stay
on by default (a counter increment is one int add; a histogram record is
one ``math.log`` plus a dict increment), with no background threads and no
unbounded state (histograms hold one bucket counter per occupied
log-bucket, ~a few hundred entries across twelve orders of magnitude).

Histograms are log-bucketed at ``GROWTH = 2**(1/16)`` per bucket, so any
reported quantile is within ``sqrt(GROWTH) - 1`` ≈ 2.2% relative error of
the true order statistic — tight enough for latency gating, bounded
regardless of the distribution's range.

Every metric is safe to update from multiple threads: the serving tier
(``repro.serve.olap_engine``) records from the asyncio event loop AND its
dispatch executor concurrently, so counter increments and histogram
records are read-modify-write sequences that take a per-metric lock (an
uncontended ``threading.Lock`` costs tens of nanoseconds — still cheap
enough to stay on by default).
"""
from __future__ import annotations

import math
import threading
from typing import Mapping, Optional

GROWTH = 2.0 ** (1.0 / 16.0)
_LOG_G = math.log(GROWTH)


class Counter:
    """Monotonic named count (queries served, cache hits, overflows)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:  # += is a read-modify-write; callers race
            self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins named value (resident cubes, cache size)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Histogram:
    """Log-bucketed distribution with p50/p95/p99 snapshots.

    ``record(v)`` files ``v`` under bucket ``floor(log(v)/log(GROWTH))``;
    non-positive values land in a dedicated zero-bucket (quantiles report
    them as 0.0).  A quantile is answered by walking the cumulative bucket
    counts and returning the bucket's geometric midpoint, clamped to the
    observed min/max — the relative error is bounded by ``sqrt(GROWTH)``
    per the class invariant, independent of how many values were recorded.
    """

    __slots__ = ("name", "buckets", "zeros", "count", "total", "vmin",
                 "vmax", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.buckets: dict = {}  # bucket index -> count
        self.zeros = 0           # non-positive values
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._lock = threading.Lock()

    def record(self, v) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
            if v <= 0.0:
                self.zeros += 1
                return
            idx = int(math.floor(math.log(v) / _LOG_G))
            self.buckets[idx] = self.buckets.get(idx, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1] (0.5 = median), within the
        bucket relative-error bound; 0.0 for an empty histogram."""
        if self.count == 0:
            return 0.0
        rank = q * (self.count - 1)  # 0-indexed order statistic
        if rank < self.zeros:
            return 0.0
        seen = self.zeros
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if rank < seen:
                mid = math.exp((idx + 0.5) * _LOG_G)  # geometric midpoint
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax  # pragma: no cover — rank < count by construction

    def snapshot(self) -> dict:
        if self.count == 0:
            return {"count": 0}
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Get-or-create registry of named metrics, one flat namespace.

    Dotted names group related metrics (``driver.tier1``,
    ``exchange.overflow``); :meth:`report` renders them sorted so the
    grouping reads as sections.  Re-registering a name with a different
    metric type is a bug and raises immediately.
    """

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            # get-or-create must be atomic: two threads registering the
            # same counter must share ONE object, or increments vanish
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls(name)
        if type(m) is not cls:
            raise TypeError(
                f"metric {name!r} is a {type(m).__name__}, not {cls.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def get(self, name: str) -> Optional[object]:
        return self._metrics.get(name)

    def value(self, name: str, default=0):
        """Counter/gauge value by name (0 when never touched)."""
        m = self._metrics.get(name)
        return default if m is None else m.value

    def snapshot(self) -> Mapping[str, object]:
        """Plain-data view of every metric (JSON-serializable)."""
        return {name: m.snapshot() for name, m in sorted(self._metrics.items())}

    def report(self) -> str:
        """Aligned text report — the ``--metrics`` exit dump."""
        lines = ["metric" + " " * 30 + "value"]
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                s = m.snapshot()
                if s["count"] == 0:
                    val = "count=0"
                else:
                    val = (f"count={s['count']} mean={s['mean']:.4g} "
                           f"p50={s['p50']:.4g} p95={s['p95']:.4g} "
                           f"p99={s['p99']:.4g} max={s['max']:.4g}")
            elif isinstance(m, Gauge):
                val = f"{m.value:.6g}"
            else:
                val = str(m.value)
            lines.append(f"{name:<36s} {val}")
        return "\n".join(lines)

    def clear(self) -> None:
        self._metrics.clear()
