"""Structured trace layer: nested spans, Chrome-trace export, text render.

One :class:`Observer` object is threaded through the engine (driver,
router, lowering, exchange layer) instead of a global — tests construct
their own and assert on the exact spans a code path emitted.  A span is a
named, timed interval with attributes and children; an event is an
instant (zero-duration) child.  The driver records per-query spans (route
decision, plan-cache hit/miss, compile vs execute), the lowering records
its semi-join decisions, and the exchange layer emits one trace-time
event per collective exchange (fired during the XLA trace, i.e. once per
compiled specialization — static shapes, capacities and wire formats).

Export targets:

- :meth:`Observer.to_chrome_trace` — the Chrome trace-event JSON dict
  (``{"traceEvents": [...]}``; complete-``X`` spans, instant-``i``
  events, microsecond timestamps) that https://ui.perfetto.dev and
  ``chrome://tracing`` load directly; :meth:`Observer.save_chrome_trace`
  writes it to a file.
- :meth:`Observer.pretty` — an indented text tree for terminals/tests.

A disabled observer (``enabled=False``) swallows everything through a
shared null span, so instrumented code paths need no ``if`` guards; the
companion :class:`~repro.obs.metrics.MetricsRegistry` rides on the same
object (``obs.metrics``) so every instrumented site can emit both.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from typing import Optional

from repro.obs.metrics import MetricsRegistry

# root spans retained (FIFO): benchmark loops run thousands of queries and
# must not grow the trace without bound; exports see the most recent window
MAX_ROOT_SPANS = 1024


@dataclasses.dataclass
class Span:
    """One timed interval.  ``t0``/``dur`` are seconds relative to the
    observer's epoch; attributes are plain data (they land in the Chrome
    trace ``args`` field verbatim)."""

    name: str
    cat: str = "query"
    t0: float = 0.0
    dur: float = 0.0
    attrs: dict = dataclasses.field(default_factory=dict)
    children: list = dataclasses.field(default_factory=list)

    def set(self, **attrs) -> "Span":
        """Attach attributes mid-span (tier decided during execution)."""
        self.attrs.update(attrs)
        return self

    @property
    def instant(self) -> bool:
        return self.dur == 0.0 and not self.children

    def find(self, name: str) -> list:
        """All spans/events named ``name`` in this subtree (pre-order)."""
        out = [self] if self.name == name else []
        for c in self.children:
            out.extend(c.find(name))
        return out


class _NullSpan:
    """Shared do-nothing span handle for a disabled observer."""

    __slots__ = ()

    def set(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _SpanContext:
    """Context manager driving one live span on the observer's stack."""

    __slots__ = ("obs", "span")

    def __init__(self, obs: "Observer", span: Span):
        self.obs = obs
        self.span = span

    def __enter__(self) -> Span:
        self.obs._stack.append(self.span)
        return self.span

    def __exit__(self, exc_type, exc, tb):
        span = self.obs._stack.pop()
        span.dur = self.obs._now() - span.t0
        if exc_type is not None:
            span.attrs["error"] = f"{exc_type.__name__}: {exc}"
        if self.obs._stack:
            self.obs._stack[-1].children.append(span)
        else:
            self.obs.spans.append(span)
        return False


class Observer:
    """The engine's observability hub: a span stack plus a metrics
    registry, explicitly threaded (never a global).

    ``enabled=False`` turns the trace layer off (spans become no-ops and
    nothing is retained) while the metrics registry stays live — counters
    are the always-on tier, traces the on-by-default-but-droppable one.

    The span stack is PER-THREAD: the serving tier records spans from the
    asyncio event loop and its dispatch executor concurrently, and a
    shared stack would interleave their push/pop sequences (a worker's
    ``execute`` span would pop the event loop's half-open request span).
    Each thread nests independently; completed roots from every thread
    land in the one shared ``spans`` deque (append is atomic).
    """

    def __init__(self, enabled: bool = True,
                 metrics: Optional[MetricsRegistry] = None):
        self.enabled = enabled
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.spans: deque = deque(maxlen=MAX_ROOT_SPANS)  # completed roots
        self._tls = threading.local()
        self._epoch = time.perf_counter()

    @property
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    # -- recording ----------------------------------------------------------
    def span(self, name: str, cat: str = "query", **attrs):
        """``with obs.span("execute", source="q6") as sp: ...`` — nested
        spans attach to the innermost open span, top-level spans to
        ``obs.spans``."""
        if not self.enabled:
            return _NULL_SPAN
        return _SpanContext(self, Span(name=name, cat=cat, t0=self._now(),
                                       attrs=dict(attrs)))

    def event(self, name: str, cat: str = "query", **attrs) -> None:
        """Instant event, attached like a zero-duration child span."""
        if not self.enabled:
            return
        ev = Span(name=name, cat=cat, t0=self._now(), attrs=dict(attrs))
        if self._stack:
            self._stack[-1].children.append(ev)
        else:
            self.spans.append(ev)

    def open_span(self, name: str, cat: str = "query", **attrs):
        """Manually managed span for call sites that cannot scope a
        ``with`` block to one thread's stack — an asyncio task's request
        span stays open across ``await`` points while OTHER tasks on the
        same thread open and close theirs, so stack-nested spans would
        pop in the wrong order.  The returned span is detached (never on
        any stack); finish it with :meth:`close_span`."""
        if not self.enabled:
            return _NULL_SPAN
        return Span(name=name, cat=cat, t0=self._now(), attrs=dict(attrs))

    def close_span(self, span) -> None:
        """Finish a span from :meth:`open_span`: stamp its duration and
        retain it as a root."""
        if span is _NULL_SPAN or not self.enabled:
            return
        span.dur = self._now() - span.t0
        self.spans.append(span)

    def clear(self) -> None:
        self.spans.clear()
        self._tls = threading.local()  # drops every thread's open stack

    # -- querying (tests assert on these) -----------------------------------
    def find(self, name: str) -> list:
        """All recorded spans/events named ``name``, across all roots."""
        out = []
        for s in self.spans:
            out.extend(s.find(name))
        return out

    def last(self, name: str) -> Optional[Span]:
        hits = self.find(name)
        return hits[-1] if hits else None

    # -- export -------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome trace-event JSON (loads in Perfetto / chrome://tracing)."""
        events = []

        def _emit(span: Span, tid: int):
            e = {
                "name": span.name,
                "cat": span.cat,
                "ts": span.t0 * 1e6,
                "pid": 1,
                "tid": tid,
                "args": _plain(span.attrs),
            }
            if span.instant:
                e.update(ph="i", s="t")
            else:
                e.update(ph="X", dur=span.dur * 1e6)
            events.append(e)
            for c in span.children:
                _emit(c, tid)

        for root in self.spans:
            _emit(root, tid=1)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"source": "repro.obs"},
        }

    def save_chrome_trace(self, path: str) -> str:
        import os

        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)
        return path

    def pretty(self) -> str:
        """Indented text rendering of every retained root span."""
        lines = []

        def _fmt_attrs(attrs: dict) -> str:
            if not attrs:
                return ""
            body = ", ".join(f"{k}={v}" for k, v in attrs.items())
            return f"  [{body}]"

        def _walk(span: Span, depth: int):
            pad = "  " * depth
            if span.instant:
                lines.append(f"{pad}* {span.name}{_fmt_attrs(span.attrs)}")
            else:
                lines.append(f"{pad}{span.name}: {span.dur * 1e3:.3f} ms"
                             f"{_fmt_attrs(span.attrs)}")
            for c in span.children:
                _walk(c, depth + 1)

        for root in self.spans:
            _walk(root, 0)
        return "\n".join(lines)


def _plain(attrs: dict) -> dict:
    """JSON-safe attribute dict (numpy scalars -> python, objects -> str)."""
    out = {}
    for k, v in attrs.items():
        if hasattr(v, "item") and callable(v.item) and getattr(v, "ndim", 1) == 0:
            v = v.item()
        if not isinstance(v, (int, float, str, bool, type(None))):
            v = str(v)
        out[k] = v
    return out
