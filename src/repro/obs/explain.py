"""EXPLAIN / EXPLAIN ANALYZE: render a query's plan with the cost model's
predictions next to what a traced run actually observed.

``TPCHDriver.explain(q)`` asks the planning layer what it WOULD do —
route tier, per-operator predicted selectivities, the chosen semi-join
alternative / wire format / derived exchange capacity — without running
anything.  ``TPCHDriver.explain_analyze(q)`` additionally executes the
query under tracing and fills the observed side: tier actually served,
plan-cache hit/miss, compile vs execute milliseconds, per-execution
overflow, and per-semijoin all-to-all bytes parsed from the compiled
HLO (``launch/roofline.parse_collective_bytes``, attributed here to the
plan's request exchanges in program order).

This module is the pure rendering/attribution half; the driver owns the
execution and supplies the raw fields.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.query.ir import (
    Bin,
    BinOp,
    Col,
    Lit,
    Param,
    UnaryOp,
)


def fmt_expr(e) -> str:
    """Compact one-line rendering of an IR expression (params as ``:name``)."""
    if e is None:
        return "—"
    if isinstance(e, Col):
        return e.name
    if isinstance(e, Lit):
        return repr(e.value)
    if isinstance(e, Param):
        return f":{e.name}"
    if isinstance(e, BinOp):
        return f"({fmt_expr(e.lhs)} {e.op} {fmt_expr(e.rhs)})"
    if isinstance(e, UnaryOp):
        return f"{e.op} {fmt_expr(e.operand)}" if e.op == "not" \
            else f"-{fmt_expr(e.operand)}"
    if isinstance(e, Bin):
        return f"bin({fmt_expr(e.child)}, {len(e.edges) + 1} bins)"
    return str(e)


@dataclasses.dataclass
class SemiJoinInfo:
    """One semi-join's predicted plan plus (after analyze) observed bytes."""

    index: int
    table: str
    alt: str                    # local | request | bitset
    capacity: int
    capacity_key: str
    wire_kind: str              # raw | packed
    key_bits: int
    gamma: float                # predicted target-predicate selectivity
    codec_ms: Optional[float] = None     # roofline: predicted codec time
    wire_ms: Optional[float] = None      # roofline: link volume + msg latency
    a2a_bytes: Optional[int] = None      # observed, per device
    a2a_count: Optional[int] = None

    def describe(self) -> str:
        s = f"alt={self.alt}"
        if self.alt == "request":
            s += f" cap={self.capacity} wire={self.wire_kind}"
            if self.wire_kind == "packed":
                s += f"/{self.key_bits}b"
        s += f" gamma={self.gamma:.3g}"
        if self.codec_ms is not None and self.alt != "local":
            s += (f" predict codec {self.codec_ms:.3g}ms"
                  f"+wire {self.wire_ms:.3g}ms")
        if self.a2a_bytes is not None:
            s += (f" | observed all-to-all {_fmt_bytes(self.a2a_bytes)}"
                  f" in {self.a2a_count} collectives")
        return s


def attribute_semijoin_bytes(instructions, semijoins: list) -> bool:
    """Attribute the compiled plan's all-to-all instructions (program
    order) to its request semi-joins, in place on ``semijoins``.

    A request exchange is 2 all-to-alls on packed wire (fused request,
    bitset reply) and 3 on raw (key buckets, mask, reply); bitset/local
    semi-joins use none.  Returns False — leaving the infos untouched —
    when the instruction count doesn't match that accounting (a plan with
    extra all-to-alls, e.g. late materialization, or a non-XLA collective
    backend that lowers to ppermutes): the caller then reports totals
    only instead of guessing.
    """
    a2a = [i for i in instructions if i.kind == "all-to-all"]
    expected = [(2 if sj.wire_kind == "packed" else 3)
                if sj.alt == "request" else 0
                for sj in semijoins]
    if sum(expected) != len(a2a):
        return False
    pos = 0
    for sj, n in zip(semijoins, expected):
        chunk = a2a[pos:pos + n]
        pos += n
        if sj.alt == "request":
            sj.a2a_bytes = sum(i.bytes for i in chunk)
            sj.a2a_count = n
    return True


@dataclasses.dataclass
class ExplainReport:
    """Everything ``explain``/``explain_analyze`` knows about one query.

    ``plan_rows`` is the scan-first per-operator annotation list from
    ``repro.query.lower.explain_chain``; ``observed`` is None for a plain
    EXPLAIN and a dict of measured fields after EXPLAIN ANALYZE.
    """

    query: str
    route_tier: int                 # 1 = cube-covered, 2 = compiled plan
    route_source: str               # cube name / plan name
    cache: str                      # "hit" | "miss" (structural plan cache)
    params: dict                    # binding the run would use
    plan_rows: list = dataclasses.field(default_factory=list)
    semijoins: list = dataclasses.field(default_factory=list)
    plan_error: Optional[str] = None   # unlowerable Tier-2 form
    observed: Optional[dict] = None
    # static-verifier findings (repro.query.verify Diagnostic objects),
    # most-severe first; empty for a clean plan
    diagnostics: list = dataclasses.field(default_factory=list)

    @property
    def analyzed(self) -> bool:
        return self.observed is not None

    # -- rendering ----------------------------------------------------------
    def _plan_lines(self) -> list:
        lines = []
        sj_seen = 0
        for depth, row in enumerate(reversed(self.plan_rows)):
            pad = "  " * depth
            op = row["op"]
            extra = []
            if op == "Scan":
                body = f"Scan[{row['table']} rows={row['rows']}]"
                pc = row.get("packed_cols")
                if pc:
                    body += f" packed={len(pc)} cols"
            elif op == "Filter":
                body = (f"Filter[{fmt_expr(row['pred'])}] "
                        f"sel={row['sel']:.3g}")
                for d in row.get("scans") or []:
                    # one line per packed column the filter touches: the
                    # roofline's packed-vs-decode choice and its predicted
                    # per-node scan bytes (vs the raw-resident footprint)
                    extra.append(
                        pad + f"  scan {d.column}: {d.mode} w={d.width} "
                        f"bytes={_fmt_bytes(d.scan_bytes)}/node "
                        f"(raw {_fmt_bytes(d.raw_bytes)}) — {d.reason}")
            elif op == "Project":
                body = f"Project[{', '.join(row['cols'])}]"
            elif op == "SemiJoin":
                info = self.semijoins[len(self.semijoins) - 1 - sj_seen] \
                    if self.semijoins else None
                sj_seen += 1
                body = f"SemiJoin[{row['table']} key={fmt_expr(row['key'])}"
                if info is not None:
                    body += f" {info.describe()}"
                body += "]"
            elif op == "Exists":
                body = f"Exists[{row['table']} sel={row['sel']:.3g}]"
            elif op == "GroupAggByKey":
                body = f"GroupAggByKey[into={row['into']}]"
            elif op == "GroupAgg":
                body = (f"GroupAgg[groups={row['groups']} "
                        f"method={row['method']} "
                        f"aggs={', '.join(row['aggs'])}]")
            elif op == "TopK":
                body = f"TopK[k={row['k']}]"
            else:  # pragma: no cover — exhaustive over the algebra
                body = op
            lines.append(pad + body)
            lines.extend(extra)
        return lines

    def text(self) -> str:
        obs = self.observed
        head = "EXPLAIN ANALYZE" if self.analyzed else "EXPLAIN"
        tier = obs["tier"] if obs else self.route_tier
        source = obs["source"] if obs else self.route_source
        tier_desc = ("rollup cube" if tier == 1 else "compiled SPMD plan")
        lines = [
            f"{head} {self.query}",
            f"route: tier {tier} ({tier_desc}: {source}) | "
            f"plan cache {self.cache.upper()}",
        ]
        if self.params:
            body = " ".join(f"{k}={v}" for k, v in sorted(self.params.items()))
            lines.append(f"parameters: {body}")
        if self.plan_error:
            lines.append(f"tier-2 plan: unlowerable — {self.plan_error}")
        elif self.plan_rows:
            lines.append("plan (cost-model predictions"
                         + (" | observed bytes):" if self.analyzed else "):"))
            lines.extend("  " + l for l in self._plan_lines())
        if self.diagnostics:
            lines.append("diagnostics:")
            lines.extend("  " + d.format() for d in self.diagnostics)
        if obs:
            if obs.get("compile_ms") is not None:
                lines.append(
                    f"timings: compile {obs['compile_ms']:.2f} ms "
                    f"({obs['xla_traces']} XLA trace"
                    f"{'s' if obs['xla_traces'] != 1 else ''}) | "
                    f"execute {obs['execute_ms']:.3f} ms warm"
                )
            else:
                lines.append(f"timings: execute {obs['execute_ms']:.3f} ms "
                             f"(no compile — {obs['source']})")
            coll = obs.get("collective_bytes_by_op") or {}
            if coll:
                body = ", ".join(
                    f"{k} {_fmt_bytes(v)} x{obs['collective_count_by_op'][k]}"
                    for k, v in sorted(coll.items()))
                lines.append(f"collectives/device: {body}")
            enc = obs.get("exchange.encode_ms")
            dec = obs.get("exchange.decode_ms")
            if enc or dec:
                parts = []
                for tag, h in (("encode", enc), ("decode", dec)):
                    if h:
                        parts.append(f"{tag} mean {h['mean']:.3g} ms "
                                     f"(n={h['count']})")
                lines.append("codec predicted/exchange: " + ", ".join(parts))
            if obs.get("bytes_resident") or obs.get("bytes_scanned"):
                lines.append(
                    f"storage: resident "
                    f"{_fmt_bytes(obs.get('bytes_resident') or 0)} | "
                    f"scanned (cumulative) "
                    f"{_fmt_bytes(obs.get('bytes_scanned') or 0)}")
            lines.append(
                f"counters: exchange.overflow={obs['overflow_count']} "
                f"plan.compile_events={obs['compile_events']} "
                f"(this run overflowed: {obs['overflow']})"
            )
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.text()


def _fmt_bytes(n: int) -> str:
    n = int(n)
    if n >= 1 << 20:
        return f"{n / (1 << 20):.1f} MiB"
    if n >= 1 << 10:
        return f"{n / (1 << 10):.1f} KiB"
    return f"{n} B"
