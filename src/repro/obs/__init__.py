"""Runtime observability: structured traces, a metrics registry, and
EXPLAIN/EXPLAIN ANALYZE rendering.

  trace    nested spans + Chrome-trace/Perfetto export (``Observer``)
  metrics  named counters/gauges/log-bucketed histograms with p50/p95/p99
  explain  plan rendering with predicted-vs-observed fields

One :class:`Observer` object is threaded through the engine (driver,
cube router, lowering, exchange layer) — construct your own to assert on
emitted spans, or read ``driver.obs`` for the default always-on one.
"""
from repro.obs.explain import (  # noqa: F401
    ExplainReport,
    SemiJoinInfo,
    attribute_semijoin_bytes,
    fmt_expr,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import Observer, Span  # noqa: F401
