"""JAX version compatibility layer.

The codebase targets the current JAX API (``jax.shard_map`` with
``check_vma``, ``lax.axis_size``, ``jax.make_mesh(..., axis_types=...)``).
Older installs (<= 0.4.x) expose the same functionality under different
names (``jax.experimental.shard_map.shard_map`` with ``check_rep``,
``lax.psum(1, axis)``, ``jax.make_mesh`` without ``axis_types``).

``install()`` — run once from ``repro/__init__`` — fills in the missing
attributes with thin adapters so every module (and the tests, which call
``jax.shard_map`` directly) runs unmodified on either API.  Attributes that
already exist are never touched, so on a current JAX this is a no-op.
"""
from __future__ import annotations

import functools
import inspect

import jax
from jax import lax


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """``jax.make_mesh`` across API generations.

    Newer JAX accepts ``axis_types``; older versions don't have the kwarg
    (nor ``jax.sharding.AxisType``).  The Auto axis type is the default
    behaviour everywhere, so dropping the kwarg is semantics-preserving.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and "axis_types" in inspect.signature(
        jax.make_mesh
    ).parameters:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def _axis_size(axis_name):
    """``lax.axis_size`` for JAX versions that predate it: psum of 1 over the
    named axis (returns the static size under tracing)."""
    return lax.psum(1, axis_name)


def _make_shard_map_adapter(legacy_shard_map):
    @functools.wraps(legacy_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kwargs):
        # check_vma (varying-manual-axes check) is the renamed check_rep
        return legacy_shard_map(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=bool(check_vma),
            **kwargs,
        )

    return shard_map


_installed = False


def install():
    global _installed
    if _installed:
        return
    _installed = True
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _legacy

        jax.shard_map = _make_shard_map_adapter(_legacy)
    if not hasattr(lax, "axis_size"):
        lax.axis_size = _axis_size
