"""Mamba-2 (state-space duality) blocks — mamba2-2.7b.

Chunked SSD: within a chunk the recurrence is computed as a masked
(attention-like) contraction; across chunks a lax.scan carries the
(H, P, N) state.  Decode is the O(1) recurrence — the reason this arch
RUNS the long_500k cell that full-attention archs must skip.

Shapes: d_inner = expand*d_model, H = d_inner/head_dim heads (sharded over
``model``), N = d_state (replicated), G = 1 B/C group.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import ParamBuilder


class SSMState(NamedTuple):
    state: jax.Array     # (layers, B, H, P, N) running SSD state
    conv: jax.Array      # (layers, B, W-1, di + 2N) conv tail
    length: jax.Array


def dims(cfg):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    H = di // s.head_dim
    return di, H, s.d_state, s.head_dim, s.conv_width


def init_ssm_layer(rng, cfg):
    b = ParamBuilder(rng)
    di, H, N, P, W = dims(cfg)
    d = cfg.d_model
    return {
        "norm": L.init_norm(b, d, "rmsnorm"),
        "w_zx": b.p((d, 2 * di), ("embed", "mlp")),
        "w_bc": b.p((d, 2 * N), ("embed", None)),
        "w_dt": b.p((d, H), ("embed", "heads")),
        "dt_bias": b.p((H,), ("heads",), init="zeros"),
        "A_log": b.p((H,), ("heads",), init="zeros"),
        "D": b.p((H,), ("heads",), init="ones"),
        "conv": b.p((W, di + 2 * N), ("conv", "mlp"), init="normal", scale=0.1),
        "gated_norm": b.p((di,), ("mlp",), init="ones"),
        "out_proj": b.p((di, d), ("mlp", "embed")),
    }


def init_mamba(rng, cfg):
    from repro.models.transformer import stack_layer_params

    r_emb, r_layers, r_norm = jax.random.split(rng, 3)
    b = ParamBuilder(r_emb)
    return {
        "embedding": L.init_embedding(b, cfg.padded_vocab(), cfg.d_model),
        "layers": stack_layer_params(lambda k: init_ssm_layer(k, cfg), r_layers,
                                     cfg.n_layers),
        "final_norm": L.init_norm(ParamBuilder(r_norm), cfg.d_model, "rmsnorm"),
    }


def _causal_conv(x, kernel):
    """x: (B, S, C); kernel: (W, C) depthwise causal."""
    W = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for w in range(W):
        out = out + xp[:, w : w + x.shape[1]] * kernel[w][None, None, :]
    return out


def _fit_chunk(S: int, target: int) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return c


def _segsum_exp(a):
    """a: (..., Lc) log-decays -> lower-triangular exp(sum a[j+1..i]) matrix
    of shape (..., Lc, Lc)."""
    Lc = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]       # sum over (j, i]
    tri = jnp.tril(jnp.ones((Lc, Lc), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, B, C, chunk: int, state0=None):
    """SSD scan.  x: (b, S, H, P); dt: (b, S, H); A: (H,) negative;
    B, C: (b, S, N).  Returns (y (b,S,H,P), final state (b,H,P,N))."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)
    a = dtc * A[None, None, None, :]                  # (b,nc,Lc,H) log-decay
    a_cs = jnp.cumsum(a, axis=2)                      # within-chunk cumsum
    a_total = a_cs[:, :, -1]                          # (b,nc,H)

    io = x.dtype
    # intra-chunk: Lmat[b,c,h,i,j] = exp(a_cs[i]-a_cs[j]) for j<=i
    Lmat = _segsum_exp(a.transpose(0, 1, 3, 2))       # (b,nc,H,Lc,Lc)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc,
                        preferred_element_type=jnp.float32)
    gated = (scores[:, :, None] * Lmat).astype(io)    # (b,nc,H,Lc,Lc)
    xdt = (xc.astype(jnp.float32) * dtc[..., None]).astype(io)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", gated, xdt,
                         preferred_element_type=jnp.float32)

    # chunk-final states: sum_j B[j] exp(a_total - a_cs[j]) xdt[j]
    decay_to_end = jnp.exp(a_total[:, :, None] - a_cs)           # (b,nc,Lc,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc,
                        decay_to_end.astype(io), xdt,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence
    s0 = jnp.zeros((b, H, P, N), jnp.float32) if state0 is None else state0

    def step(s_prev, inputs):
        st, atot = inputs                              # (b,H,P,N), (b,H)
        s_new = s_prev * jnp.exp(atot)[..., None, None] + st
        return s_new, s_prev

    sT, s_prevs = lax.scan(
        step, s0.astype(jnp.float32),
        (states.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
         a_total.transpose(1, 0, 2)),
    )
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)        # (b,nc,H,P,N)
    decay_from_start = jnp.exp(a_cs)                  # (b,nc,Lc,H)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc,
                         s_prevs.astype(Cc.dtype),
                         decay_from_start.astype(Cc.dtype),
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y, sT


def apply_ssm_layer(p, x, cfg, *, chunk=None, bf16=False):
    di, H, N, P, W = dims(cfg)
    chunk = _fit_chunk(x.shape[1], chunk or cfg.ssm.chunk)
    cd = x.dtype
    h = L.apply_norm(p["norm"], x, "rmsnorm")
    zx = jnp.einsum("bsd,de->bse", h, p["w_zx"].astype(cd))
    z, xin = zx[..., :di], zx[..., di:]
    bc = jnp.einsum("bsd,de->bse", h, p["w_bc"].astype(cd))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["w_dt"].astype(cd)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv"].astype(cd)))
    xin, B, C = (conv_out[..., :di], conv_out[..., di : di + N],
                 conv_out[..., di + N :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(*xin.shape[:2], H, P)
    io_dtype = jnp.bfloat16 if bf16 else jnp.float32
    y, _ = ssd_chunked(xh.astype(io_dtype), dt, A,
                       B.astype(io_dtype), C.astype(io_dtype), chunk)
    y = y.astype(jnp.float32)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(*xin.shape[:2], di).astype(cd)
    y = y * jax.nn.silu(z)
    # gated RMSNorm over d_inner
    yf = y.astype(jnp.float32)
    y = (yf * lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["gated_norm"].astype(jnp.float32)).astype(cd)
    return x + jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))


def forward(params, tokens, cfg, *, chunk=None, bf16=False):
    cd = jnp.dtype(cfg.compute_dtype)
    from repro.models import runtime as RT

    x = RT.constrain(L.embed(params["embedding"], tokens, cd),
                     "batch", None, None)

    def body(carry, lp):
        return apply_ssm_layer(lp, carry, cfg, chunk=chunk, bf16=bf16), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(body_fn, x, params["layers"])
    return L.apply_norm(params["final_norm"], x, "rmsnorm")


# ---------------------------------------------------------------------------
# O(1) decode
# ---------------------------------------------------------------------------


def init_state(cfg, batch: int, dtype=jnp.float32):
    di, H, N, P, W = dims(cfg)
    return SSMState(
        state=jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        conv=jnp.zeros((cfg.n_layers, batch, W - 1, di + 2 * N), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def state_logical_axes():
    return SSMState(
        state=("layers", "batch", "heads", "head_dim", "state"),
        conv=("layers", "batch", "conv", "mlp"),
        length=(),
    )


def apply_ssm_decode(p, x, cfg, state, conv_tail):
    """x: (B, 1, d).  Returns (y, new_state, new_conv_tail)."""
    di, H, N, P, W = dims(cfg)
    cd = x.dtype
    h = L.apply_norm(p["norm"], x, "rmsnorm")
    zx = jnp.einsum("bsd,de->bse", h, p["w_zx"].astype(cd))
    z, xin = zx[..., :di], zx[..., di:]
    bc = jnp.einsum("bsd,de->bse", h, p["w_bc"].astype(cd))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["w_dt"].astype(cd)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )[:, 0]                                            # (B, H)
    conv_in = jnp.concatenate([xin, bc], axis=-1)      # (B, 1, di+2N)
    window = jnp.concatenate([conv_tail, conv_in], axis=1)   # (B, W, ·)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, p["conv"].astype(cd))
    )
    xin = conv_out[:, :di].reshape(-1, H, P)
    B_ = conv_out[:, di : di + N].astype(jnp.float32)
    C_ = conv_out[:, di + N :].astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])                   # (B, H)
    xdt = xin.astype(jnp.float32) * dt[..., None]
    new_state = (state * decay[..., None, None]
                 + jnp.einsum("bhp,bn->bhpn", xdt, B_))
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_)
    y = y + xin.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, 1, di).astype(cd) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["gated_norm"].astype(jnp.float32)).astype(cd)
    out = x + jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    return out, new_state, window[:, 1:]


def apply_ssm_layer_prefill(p, x, cfg, *, chunk=None):
    """Like apply_ssm_layer but also returns (final ssd state, conv tail)."""
    di, H, N, P, W = dims(cfg)
    chunk = _fit_chunk(x.shape[1], chunk or cfg.ssm.chunk)
    cd = x.dtype
    h = L.apply_norm(p["norm"], x, "rmsnorm")
    zx = jnp.einsum("bsd,de->bse", h, p["w_zx"].astype(cd))
    z, xin = zx[..., :di], zx[..., di:]
    bc = jnp.einsum("bsd,de->bse", h, p["w_bc"].astype(cd))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", h, p["w_dt"].astype(cd)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_tail = conv_in[:, -(W - 1):]
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv"].astype(cd)))
    xin, B, C = (conv_out[..., :di], conv_out[..., di : di + N],
                 conv_out[..., di + N :])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(*xin.shape[:2], H, P)
    y, sT = ssd_chunked(xh.astype(jnp.float32), dt, A,
                        B.astype(jnp.float32), C.astype(jnp.float32), chunk)
    y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(*xin.shape[:2], di).astype(cd)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["gated_norm"].astype(jnp.float32)).astype(cd)
    return x + jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd)), sT, conv_tail


def prefill(params, tokens, cfg, state: SSMState, *, chunk=None):
    """Run the prompt, capture per-layer SSD state + conv tail, return
    last-position logits."""
    from repro.models.transformer import logits_from_hidden

    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embedding"], tokens, cd)

    def body(carry, lp):
        h, st, cv = apply_ssm_layer_prefill(lp, carry, cfg, chunk=chunk)
        return h, (st, cv.astype(state.conv.dtype))

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (s_new, c_new) = lax.scan(body_fn, x, params["layers"])
    h = L.apply_norm(params["final_norm"], x[:, -1:], "rmsnorm")
    logits = logits_from_hidden(params, h, cfg)
    return logits[:, 0], SSMState(s_new, c_new, jnp.int32(tokens.shape[1]))


def decode_step(params, state: SSMState, token, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embedding"], token, cd)

    def body(carry, scanned):
        h = carry
        lp, st, cv = scanned
        h, st, cv = apply_ssm_decode(lp, h, cfg, st, cv)
        return h, (st, cv)

    x, (s_new, c_new) = lax.scan(body, x, (params["layers"], state.state,
                                           state.conv))
    h = L.apply_norm(params["final_norm"], x, "rmsnorm")
    from repro.models.transformer import logits_from_hidden

    logits = logits_from_hidden(params, h, cfg)
    return logits[:, 0], SSMState(s_new, c_new, state.length + 1)
