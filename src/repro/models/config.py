"""Model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma block pattern: `period` layers per cycle, attention at
    positions where (layer % period) in attn_positions."""
    lru_width: int = 0            # 0 -> d_model
    period: int = 3
    attn_position: int = 2        # (rec, rec, attn) cycles
    window: int = 2048


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int
    enc_seq: int = 1500           # whisper: 30 s of audio at 50 Hz after conv


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 256        # SigLIP 224px/14 -> 16x16 patches
    patch_dim: int = 1152         # frontend embedding width (stub input)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "swiglu"           # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0    # chatglm3 "2d rope": 0.5
    tie_embeddings: bool = False
    attn_window: Optional[int] = None
    max_seq: int = 4096
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    # numerics / scale
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # whether decode state is bounded (sub-quadratic long-context decode)
    # -> eligible for the long_500k shape cell
    sub_quadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def padded_heads(self, tp: int, tp_kv: int | None = None) -> Tuple[int, int]:
        """(q heads, kv heads) padded up to their shard degrees.  tp shards
        q heads (and is the default for kv); a smaller tp_kv (the decode-
        optimized layout's `model_kv` axis) avoids the kv-padding waste the
        §Roofline table shows for GQA/MQA decode cells."""
        tp_kv = tp if tp_kv is None else tp_kv
        hp = math.ceil(self.n_heads / tp) * tp
        kvp = math.ceil(self.n_kv_heads / tp_kv) * tp_kv if self.n_kv_heads else 0
        # GQA requires q-heads divisible by kv-heads after padding
        while kvp and hp % kvp:
            hp += tp
        return hp, kvp

    def padded_vocab(self, multiple: int = 2048) -> int:
        return math.ceil(self.vocab_size / multiple) * multiple

    def num_params(self, include_embeddings: bool = True) -> int:
        """Analytic parameter count (logical, unpadded) for MODEL_FLOPS.
        include_embeddings=False gives the matmul-participating count the
        roofline charges per token (embedding lookups are gathers; the LM
        head runs once per SEQUENCE at prefill) — the MaxText convention."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv = self.n_heads, self.n_kv_heads
        emb = (v * d * (1 if self.tie_embeddings else 2)
               if include_embeddings else 0)
        if self.family == "ssm":
            s = self.ssm
            di = s.expand * d
            nh = di // s.head_dim
            per_layer = (
                d * (2 * di + 2 * s.d_state + nh)   # in_proj (z,x,B,C,dt)
                + s.conv_width * (di + 2 * s.d_state)
                + nh + nh                            # A_log, D
                + di                                 # gated norm
                + di * d                             # out_proj
            )
            return emb + self.n_layers * per_layer  # (tied embedding)
        hd = self.resolved_head_dim
        att = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.act in ("swiglu", "geglu"):
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            m = self.moe
            mlp = m.num_experts * 3 * d * m.d_ff_expert + d * m.num_experts
        per_layer = att + mlp + 2 * d
        if self.family == "hybrid":
            hy = self.hybrid
            lw = hy.lru_width or d
            n_attn = sum(
                1 for i in range(self.n_layers) if i % hy.period == hy.attn_position
            )
            n_rec = self.n_layers - n_attn
            rec_layer = d * lw * 2 + lw * d + hy.window * 0 + 3 * lw + mlp + 2 * d
            return emb + n_attn * per_layer + n_rec * rec_layer
        if self.family == "encdec":
            cross = att  # cross-attention block per decoder layer
            return (
                emb
                + self.encdec.n_enc_layers * per_layer
                + self.n_layers * (per_layer + cross)
            )
        return emb + self.n_layers * per_layer

    def active_params(self, include_embeddings: bool = True) -> int:
        """Activated parameters per token (MoE: only top_k experts)."""
        if self.family != "moe":
            return self.num_params(include_embeddings)
        m = self.moe
        d = self.d_model
        dense_per_layer = (
            d * self.n_heads * self.resolved_head_dim
            + 2 * d * self.n_kv_heads * self.resolved_head_dim
            + self.n_heads * self.resolved_head_dim * d
            + m.top_k * 3 * d * m.d_ff_expert
            + d * m.num_experts
            + 2 * d
        )
        emb = 2 * self.vocab_size * d if include_embeddings else 0
        return emb + self.n_layers * dense_per_layer
