"""PaliGemma-3B backbone: gemma decoder with a bidirectional image prefix.

The SigLIP vision tower is a STUB per the brief — ``input_specs`` provides
precomputed patch embeddings (B, num_patches, patch_dim); this module owns
only the projection into d_model and the prefix-LM attention pattern
(bidirectional over the image tokens, causal over text).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L, transformer as T
from repro.models.params import ParamBuilder


def init_vlm(rng, cfg, tp: int = 1, tp_kv: int | None = None):
    r_proj, r_back = jax.random.split(rng)
    params = T.init_transformer(r_back, cfg, tp, tp_kv)
    b = ParamBuilder(r_proj)
    params["patch_proj"] = {
        "w": b.p((cfg.vlm.patch_dim, cfg.d_model), (None, "embed")),
        "b": b.p((cfg.d_model,), ("embed_no_fsdp",), init="zeros"),
    }
    return params


def project_patches(params, patches, cfg):
    cd = jnp.dtype(cfg.compute_dtype)
    pp = params["patch_proj"]
    return (jnp.einsum("bpe,ed->bpd", patches.astype(cd), pp["w"].astype(cd))
            + pp["b"].astype(cd))


def forward(params, tokens, patches, cfg, *, chunk_q=1024, chunk_k=1024,
            attn_impl="xla"):
    """Prefix-LM forward over [image tokens ; text tokens]."""
    emb = project_patches(params, patches, cfg)
    S_total = emb.shape[1] + tokens.shape[1]
    cq = _chunk(S_total, chunk_q)
    mask = L.AttnMask(causal=True, prefix=cfg.vlm.num_patches)
    return T.forward(params, tokens, cfg, embeddings=emb, mask=mask,
                     chunk_q=cq, chunk_k=cq, attn_impl=attn_impl)


def _chunk(S: int, target: int) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return c


def prefill(params, tokens, patches, cfg, cache, *, chunk_q=1024,
            chunk_k=1024, attn_impl="xla"):
    emb = project_patches(params, patches, cfg)
    S_total = emb.shape[1] + tokens.shape[1]
    cq = _chunk(S_total, chunk_q)
    return T.prefill(params, tokens, cfg, cache, embeddings=emb,
                     chunk_q=cq, chunk_k=cq, attn_impl=attn_impl)
