"""Logical-axis -> mesh-axis sharding rules (GSPMD distribution layer).

The production meshes (launch/mesh.py) expose axes:
  single pod : (data=16, model=16)
  multi-pod  : (pod=2, data=16, model=16)

Rules (MaxText-style):
  batch           -> (pod, data)     data parallelism over pods x data rows
  embed / d_model -> data            FSDP: parameter shards gathered per layer
  heads/kv_heads/mlp/vocab/expert -> model   tensor/expert parallelism
  everything else -> replicated

The OLAP engine flattens (data x model) [x pod] into its 1-D ``nodes`` axis —
the paper's P-node shared-nothing cluster view of the same hardware.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (None = replicated)
DEFAULT_RULES: dict[str, Optional[str]] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": "data",          # fsdp shard of the d_model dim
    "embed_no_fsdp": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "expert_mlp": None,
    "state": None,
    "conv": None,
    "layers": None,           # scanned-stack leading axis
}


def mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def resolve(axes: Tuple[Optional[str], ...], mesh: Mesh,
            rules: dict | None = None) -> P:
    """Logical axis tuple -> PartitionSpec valid for ``mesh`` (axes absent
    from the mesh degrade to replicated — e.g. 'pod' on the single-pod
    mesh, or everything on a single-device test mesh)."""
    rules = rules or DEFAULT_RULES
    names = set(mesh.axis_names)
    spec = []
    for ax in axes:
        tgt = rules.get(ax) if ax is not None else None
        if isinstance(tgt, tuple):
            tgt = tuple(t for t in tgt if t in names) or None
            if tgt is not None and len(tgt) == 1:
                tgt = tgt[0]
        elif tgt is not None and tgt not in names:
            tgt = None
        spec.append(tgt)
    return P(*spec)


def _is_axes_leaf(x) -> bool:
    """An axes tuple is a plain tuple of axis names/None — NamedTuple pytree
    nodes (TrainState, KVCache, ...) must NOT match."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(e is None or isinstance(e, str) for e in x))


def sharding_tree(axes_tree, mesh: Mesh, rules: dict | None = None):
    """Logical-axes tree -> NamedSharding tree (for in_shardings)."""
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, resolve(axes, mesh, rules)),
        axes_tree,
        is_leaf=_is_axes_leaf,
    )


def spec_tree(axes_tree, mesh: Mesh, rules: dict | None = None):
    return jax.tree.map(
        lambda axes: resolve(axes, mesh, rules),
        axes_tree,
        is_leaf=_is_axes_leaf,
    )


def constrain(x, mesh: Mesh, *axes, rules: dict | None = None):
    """with_sharding_constraint by logical axes (no-op off-mesh)."""
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(tuple(axes), mesh, rules))
    )
