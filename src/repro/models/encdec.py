"""Whisper-medium encoder-decoder backbone.

Per the brief the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, enc_seq, d) and this module consumes them.
Encoder: bidirectional self-attention, learned positions, layernorm/gelu.
Decoder: causal self-attention + cross-attention over encoder states.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import ParamBuilder
from repro.models.transformer import KVCache, stack_layer_params, logits_from_hidden


class EncDecCache(NamedTuple):
    self_k: jax.Array     # (L, B, Smax, KV, hd)
    self_v: jax.Array
    cross_k: jax.Array    # (L, B, enc_seq, KV, hd)
    cross_v: jax.Array
    length: jax.Array


def init_enc_layer(rng, cfg, tp: int, tp_kv=None):
    b = ParamBuilder(rng)
    return {
        "ln1": L.init_norm(b, cfg.d_model, "layernorm"),
        "attn": L.init_attention(b, cfg, tp, tp_kv),
        "ln2": L.init_norm(b, cfg.d_model, "layernorm"),
        "mlp": L.init_mlp(b, cfg.d_model, cfg.d_ff, "gelu"),
    }


def init_dec_layer(rng, cfg, tp: int, tp_kv=None):
    b = ParamBuilder(rng)
    return {
        "ln1": L.init_norm(b, cfg.d_model, "layernorm"),
        "self_attn": L.init_attention(b, cfg, tp, tp_kv),
        "ln_cross": L.init_norm(b, cfg.d_model, "layernorm"),
        "cross_attn": L.init_attention(b, cfg, tp, tp_kv),
        "ln2": L.init_norm(b, cfg.d_model, "layernorm"),
        "mlp": L.init_mlp(b, cfg.d_model, cfg.d_ff, "gelu"),
    }


def init_encdec(rng, cfg, tp: int = 1, tp_kv=None):
    r_emb, r_enc, r_dec, r_pe, r_pd, r_n1, r_n2 = jax.random.split(rng, 7)
    b = ParamBuilder(r_emb)
    bpe, bpd = ParamBuilder(r_pe), ParamBuilder(r_pd)
    return {
        "embedding": L.init_embedding(b, cfg.padded_vocab(), cfg.d_model),
        "enc_pos": bpe.p((cfg.encdec.enc_seq, cfg.d_model), ("seq", "embed_no_fsdp"),
                         init="embed", scale=0.02),
        "dec_pos": bpd.p((cfg.max_seq, cfg.d_model), ("seq", "embed_no_fsdp"),
                         init="embed", scale=0.02),
        "enc_layers": stack_layer_params(
            lambda k: init_enc_layer(k, cfg, tp, tp_kv), r_enc,
            cfg.encdec.n_enc_layers
        ),
        "dec_layers": stack_layer_params(
            lambda k: init_dec_layer(k, cfg, tp, tp_kv), r_dec, cfg.n_layers
        ),
        "enc_norm": L.init_norm(ParamBuilder(r_n1), cfg.d_model, "layernorm"),
        "final_norm": L.init_norm(ParamBuilder(r_n2), cfg.d_model, "layernorm"),
    }


def encode(params, frames, cfg, *, chunk=512, attn_impl="xla"):
    """frames: (B, enc_seq, d) stub frontend embeddings -> encoder states."""
    cd = jnp.dtype(cfg.compute_dtype)
    S = frames.shape[1]
    x = frames.astype(cd) + params["enc_pos"].astype(cd)[None, :S]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    mask = L.AttnMask(causal=False)
    cq = _pick_chunk(S, chunk)

    def body(carry, lp):
        h = L.apply_norm(lp["ln1"], carry, "layernorm")
        q, k, v = L.qkv(lp["attn"], h, cfg, positions, rope=False)
        o = L.attention(q, k, v, mask, impl=attn_impl, chunk_q=cq, chunk_k=cq)
        x = carry + L.attn_out(lp["attn"], o)
        h = L.apply_norm(lp["ln2"], x, "layernorm")
        return x + L.apply_mlp(lp["mlp"], h, "gelu"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(body_fn, x, params["enc_layers"])
    return L.apply_norm(params["enc_norm"], x, "layernorm")


def _pick_chunk(S: int, target: int) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return c


def decode_train(params, tokens, enc_states, cfg, *, chunk_q=1024,
                 chunk_k=1024, attn_impl="xla"):
    """Teacher-forced decoder pass -> hidden states (B, S, d)."""
    cd = jnp.dtype(cfg.compute_dtype)
    S = tokens.shape[1]
    x = L.embed(params["embedding"], tokens, cd)
    x = x + params["dec_pos"].astype(cd)[None, :S]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    self_mask = L.AttnMask(causal=True)
    cross_mask = L.AttnMask(causal=False)
    Se = enc_states.shape[1]
    cq = _pick_chunk(S, chunk_q)
    ck = _pick_chunk(Se, chunk_k)

    def body(carry, lp):
        h = L.apply_norm(lp["ln1"], carry, "layernorm")
        q, k, v = L.qkv(lp["self_attn"], h, cfg, positions, rope=False)
        o = L.attention(q, k, v, self_mask, impl=attn_impl, chunk_q=cq,
                        chunk_k=cq)
        x = carry + L.attn_out(lp["self_attn"], o)
        h = L.apply_norm(lp["ln_cross"], x, "layernorm")
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"].astype(cd))
        ek = jnp.einsum("bsd,dhk->bshk", enc_states, lp["cross_attn"]["wk"].astype(cd))
        ev = jnp.einsum("bsd,dhk->bshk", enc_states, lp["cross_attn"]["wv"].astype(cd))
        o = L.attention(q, ek, ev, cross_mask, impl=attn_impl, chunk_q=cq,
                        chunk_k=ck)
        x = x + L.attn_out(lp["cross_attn"], o)
        h = L.apply_norm(lp["ln2"], x, "layernorm")
        return x + L.apply_mlp(lp["mlp"], h, "gelu"), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(body_fn, x, params["dec_layers"])
    return L.apply_norm(params["final_norm"], x, "layernorm")


def forward(params, tokens, frames, cfg, attn_impl="xla", **kw):
    enc = encode(params, frames, cfg, attn_impl=attn_impl)
    return decode_train(params, tokens, enc, cfg, attn_impl=attn_impl, **kw)


def init_cache(cfg, batch: int, max_len: int, tp: int = 1, dtype=jnp.bfloat16,
               tp_kv=None):
    _, KV = cfg.padded_heads(tp, tp_kv)
    hd = cfg.resolved_head_dim
    return EncDecCache(
        self_k=jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), dtype),
        self_v=jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), dtype),
        cross_k=jnp.zeros((cfg.n_layers, batch, cfg.encdec.enc_seq, KV, hd), dtype),
        cross_v=jnp.zeros((cfg.n_layers, batch, cfg.encdec.enc_seq, KV, hd), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def cache_logical_axes():
    ax = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return EncDecCache(self_k=ax, self_v=ax, cross_k=ax, cross_v=ax, length=())


def fill_cross_cache(params, enc_states, cfg, cache: EncDecCache):
    """Precompute per-layer cross K/V from encoder states (once per request)."""
    cd = enc_states.dtype

    def body(_, lp):
        ek = jnp.einsum("bsd,dhk->bshk", enc_states, lp["cross_attn"]["wk"].astype(cd))
        ev = jnp.einsum("bsd,dhk->bshk", enc_states, lp["cross_attn"]["wv"].astype(cd))
        return (), (ek, ev)

    _, (ck, cv) = lax.scan(body, (), params["dec_layers"])
    return cache._replace(cross_k=ck.astype(cache.cross_k.dtype),
                          cross_v=cv.astype(cache.cross_v.dtype))


def prefill(params, tokens, frames, cfg, cache: EncDecCache, *,
            chunk_q=1024, chunk_k=1024, attn_impl="xla"):
    """Encode frames, fill the cross cache, run the prompt through the
    decoder writing self K/V; returns last-position logits + cache."""
    cd = jnp.dtype(cfg.compute_dtype)
    enc = encode(params, frames, cfg)
    S = tokens.shape[1]
    x = L.embed(params["embedding"], tokens, cd)
    x = x + params["dec_pos"].astype(cd)[None, :S]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    self_mask = L.AttnMask(causal=True)
    cross_mask = L.AttnMask(causal=False)
    Se = enc.shape[1]
    cq = _pick_chunk(S, chunk_q)
    ckk = _pick_chunk(Se, chunk_k)

    def body(carry, scanned):
        h0 = carry
        lp, sk, sv = scanned
        h = L.apply_norm(lp["ln1"], h0, "layernorm")
        q, k, v = L.qkv(lp["self_attn"], h, cfg, positions, rope=False)
        sk = lax.dynamic_update_slice_in_dim(sk, k.astype(sk.dtype), 0, axis=1)
        sv = lax.dynamic_update_slice_in_dim(sv, v.astype(sv.dtype), 0, axis=1)
        o = L.attention(q, k, v, self_mask, impl=attn_impl, chunk_q=cq,
                        chunk_k=cq)
        h0 = h0 + L.attn_out(lp["self_attn"], o)
        h = L.apply_norm(lp["ln_cross"], h0, "layernorm")
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"].astype(cd))
        ek = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wk"].astype(cd))
        ev = jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wv"].astype(cd))
        o = L.attention(q, ek, ev, cross_mask, impl=attn_impl, chunk_q=cq,
                        chunk_k=ckk)
        h0 = h0 + L.attn_out(lp["cross_attn"], o)
        h = L.apply_norm(lp["ln2"], h0, "layernorm")
        h0 = h0 + L.apply_mlp(lp["mlp"], h, "gelu")
        return h0, (sk, sv, ek.astype(sk.dtype), ev.astype(sv.dtype))

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (sk_n, sv_n, ck_n, cv_n) = lax.scan(
        body_fn, x, (params["dec_layers"], cache.self_k, cache.self_v)
    )
    h = L.apply_norm(params["final_norm"], x[:, -1:], "layernorm")
    logits = logits_from_hidden(params, h, cfg)
    return logits[:, 0], EncDecCache(sk_n, sv_n, ck_n, cv_n, jnp.int32(S))


def decode_step(params, cache: EncDecCache, token, cfg):
    """One decoder token against self+cross caches."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embedding"], token, cd)
    new_len = cache.length + 1
    x = x + params["dec_pos"].astype(cd)[new_len - 1][None, None, :]

    def body(carry, scanned):
        h0 = carry
        lp, sk, sv, ck, cv = scanned
        h = L.apply_norm(lp["ln1"], h0, "layernorm")
        q = jnp.einsum("bsd,dhk->bshk", h, lp["self_attn"]["wq"].astype(cd))
        k = jnp.einsum("bsd,dhk->bshk", h, lp["self_attn"]["wk"].astype(cd))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["self_attn"]["wv"].astype(cd))
        sk = lax.dynamic_update_slice_in_dim(sk, k.astype(sk.dtype), new_len - 1, axis=1)
        sv = lax.dynamic_update_slice_in_dim(sv, v.astype(sv.dtype), new_len - 1, axis=1)
        o = L.decode_attention(q, sk, sv, new_len)
        h0 = h0 + L.attn_out(lp["self_attn"], o)
        h = L.apply_norm(lp["ln_cross"], h0, "layernorm")
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"].astype(cd))
        o = L.decode_attention(q, ck, cv, jnp.int32(ck.shape[1]))
        h0 = h0 + L.attn_out(lp["cross_attn"], o)
        h = L.apply_norm(lp["ln2"], h0, "layernorm")
        h0 = h0 + L.apply_mlp(lp["mlp"], h, "gelu")
        return h0, (sk, sv)

    x, (sk_n, sv_n) = lax.scan(
        body, x, (params["dec_layers"], cache.self_k, cache.self_v,
                  cache.cross_k, cache.cross_v)
    )
    h = L.apply_norm(params["final_norm"], x, "layernorm")
    logits = logits_from_hidden(params, h, cfg)
    return logits[:, 0], cache._replace(self_k=sk_n, self_v=sv_n, length=new_len)
