"""Parameter trees with logical sharding axes.

Every parameter leaf is created through ``ParamBuilder.p`` which records a
tuple of LOGICAL axis names alongside the array.  ``logical_axes`` extracts a
parallel tree of axis tuples, and ``repro.models.sharding`` maps logical axes
to mesh axes (the MaxText "logical axis rules" pattern).  Because init
functions are pure jax, ``jax.eval_shape(init)`` yields the same tree as
ShapeDtypeStructs — which is exactly what the multi-pod dry-run feeds to
``jit(...).lower`` without allocating 34B parameters on a CPU container.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Param:
    """A parameter leaf: the array plus its logical axis names."""

    value: jax.Array
    axes: Tuple[Optional[str], ...] = dataclasses.field(metadata=dict(static=True))


class ParamBuilder:
    """Collects parameters for one module; usable under jax.eval_shape."""

    def __init__(self, rng: jax.Array, dtype=jnp.float32):
        self.rng = rng
        self.dtype = dtype

    def fork(self) -> "ParamBuilder":
        self.rng, sub = jax.random.split(self.rng)
        return ParamBuilder(sub, self.dtype)

    def p(self, shape, axes, *, init: str = "normal", scale: float | None = None,
          dtype=None) -> Param:
        assert len(shape) == len(axes), f"{shape} vs {axes}"
        dtype = dtype or self.dtype
        self.rng, key = jax.random.split(self.rng)
        if init == "zeros":
            v = jnp.zeros(shape, dtype)
        elif init == "ones":
            v = jnp.ones(shape, dtype)
        elif init == "normal":
            # fan-in scaled init (truncated-normal-free to stay eval_shape-cheap)
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            s = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
            v = (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
        elif init == "embed":
            s = scale if scale is not None else 1.0
            v = (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)
        else:
            raise ValueError(init)
        return Param(v, tuple(axes))


def values(tree):
    """Param tree -> raw array tree (same structure)."""
    return jax.tree.map(lambda p: p.value, tree,
                        is_leaf=lambda x: isinstance(x, Param))


def logical_axes(tree):
    """Param tree -> logical-axes tree (same structure, tuples as leaves)."""
    return jax.tree.map(lambda p: p.axes, tree,
                        is_leaf=lambda x: isinstance(x, Param))


def unbox(tree):
    """(values, axes) pair from a Param tree."""
    return values(tree), logical_axes(tree)
