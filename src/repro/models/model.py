"""Family-dispatched model facade: one object per architecture exposing
init / loss / prefill / decode, used by the trainer, the server and the
multi-pod dry-run.

The loss computes cross-entropy in SEQUENCE CHUNKS (scan + remat) so the
(B, S, vocab) logits tensor — up to 257k-wide for paligemma — is never
materialized; this is what keeps the dry-run's memory_analysis inside HBM
for the large-vocab cells.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import encdec, hybrid, layers as L, ssm, transformer as T, vlm
from repro.models.config import ModelConfig
from repro.models.params import logical_axes, values


def chunked_cross_entropy(hidden, labels, cfg, params, *, chunk: int = 512):
    """Mean next-token CE without materializing full logits.

    hidden: (B, S, d) — position t predicts labels[t]; labels: (B, S) int32,
    -1 = masked.  Returns (mean_nll, token_count).
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nch = S // chunk
    tied = params["embedding"]["table"] if cfg.tie_embeddings else None
    head = params.get("head")

    hs = hidden.reshape(B, nch, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nch, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        h, lab = xs
        logits = L.lm_logits(head, h, tied_table=tied).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1
        )[..., 0]
        mask = lab >= 0
        nll = jnp.where(mask, lse - picked, 0.0)
        return (tot + jnp.sum(nll), cnt + jnp.sum(mask)), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (tot, cnt), _ = lax.scan(body_fn, (jnp.float32(0), jnp.int32(0)), (hs, ls))
    return tot / jnp.maximum(cnt, 1), cnt


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    tp: int = 1
    tp_kv: int | None = None       # kv-head shard degree (decode-opt layout)
    cache_quant: bool = False      # int8 KV cache (decode cells)

    # ---- parameters -------------------------------------------------------
    def init(self, rng):
        cfg = self.cfg
        if cfg.family == "ssm":
            boxed = ssm.init_mamba(rng, cfg)
        elif cfg.family == "hybrid":
            boxed = hybrid.init_hybrid(rng, cfg, self.tp, self.tp_kv)
        elif cfg.family == "encdec":
            boxed = encdec.init_encdec(rng, cfg, self.tp, self.tp_kv)
        elif cfg.family == "vlm":
            boxed = vlm.init_vlm(rng, cfg, self.tp, self.tp_kv)
        else:
            boxed = T.init_transformer(rng, cfg, self.tp, self.tp_kv)
        return boxed

    def param_axes(self):
        """Logical-axes tree without allocating parameters (eval_shape)."""
        boxed = jax.eval_shape(self.init, jax.random.key(0))
        return logical_axes(boxed)

    def param_shapes(self):
        boxed = jax.eval_shape(self.init, jax.random.key(0))
        return jax.tree.map(lambda p: p.value, boxed,
                            is_leaf=lambda x: hasattr(x, "axes"))

    # ---- training forward / loss -----------------------------------------
    def hidden(self, params, batch, *, chunk_q=1024, chunk_k=1024,
               causal_skip=False, attn_impl="xla", remat_policy="full",
               ssm_chunk=None, ssm_bf16=False):
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.family == "ssm":
            return ssm.forward(params, tokens, cfg, chunk=ssm_chunk,
                               bf16=ssm_bf16)
        if cfg.family == "hybrid":
            return hybrid.forward(params, tokens, cfg, chunk_q=chunk_q,
                                  chunk_k=chunk_k, attn_impl=attn_impl)
        if cfg.family == "encdec":
            return encdec.forward(params, tokens, batch["frames"], cfg,
                                  chunk_q=chunk_q, chunk_k=chunk_k,
                                  attn_impl=attn_impl)
        if cfg.family == "vlm":
            return vlm.forward(params, tokens, batch["patches"], cfg,
                               chunk_q=chunk_q, chunk_k=chunk_k,
                               attn_impl=attn_impl)
        return T.forward(params, tokens, cfg, chunk_q=chunk_q, chunk_k=chunk_k,
                         causal_skip=causal_skip, attn_impl=attn_impl,
                         remat_policy=remat_policy)

    def loss(self, params, batch, **fwd_kw):
        cfg = self.cfg
        h = self.hidden(params, batch, **fwd_kw)
        labels = batch["labels"]
        if cfg.family == "vlm":
            h = h[:, cfg.vlm.num_patches:]  # no loss on image positions
        nll, cnt = chunked_cross_entropy(h, labels, cfg, params)
        return nll

    # ---- serving -----------------------------------------------------------
    def init_decode_state(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.family == "ssm":
            return ssm.init_state(cfg, batch, dtype)
        if cfg.family == "hybrid":
            return hybrid.init_state(cfg, batch, self.tp, dtype,
                                     tp_kv=self.tp_kv)
        if cfg.family == "encdec":
            return encdec.init_cache(cfg, batch, max_len, self.tp, dtype,
                                     tp_kv=self.tp_kv)
        if cfg.family == "vlm":
            max_len += cfg.vlm.num_patches  # cache holds the image prefix too
        if self.cache_quant:
            return T.init_quant_cache(cfg, batch, max_len, self.tp,
                                      tp_kv=self.tp_kv)
        return T.init_cache(cfg, batch, max_len, self.tp, dtype,
                            tp_kv=self.tp_kv)

    def decode_state_axes(self):
        cfg = self.cfg
        if cfg.family == "ssm":
            return ssm.state_logical_axes()
        if cfg.family == "hybrid":
            return hybrid.state_logical_axes()
        if cfg.family == "encdec":
            return encdec.cache_logical_axes()
        if self.cache_quant:
            return T.quant_cache_logical_axes()
        return T.cache_logical_axes()

    def decode_step(self, params, state, token):
        cfg = self.cfg
        if cfg.family == "ssm":
            return ssm.decode_step(params, state, token, cfg)
        if cfg.family == "hybrid":
            return hybrid.decode_step(params, state, token, cfg)
        if cfg.family == "encdec":
            return encdec.decode_step(params, state, token, cfg)
        return T.decode_step(params, state, token, cfg)

    def prefill(self, params, batch, state, *, chunk_q=1024, chunk_k=1024,
                attn_impl="xla", ssm_chunk=None, ssm_bf16=False):
        cfg = self.cfg
        tokens = batch["tokens"]
        if cfg.family == "ssm":
            return ssm.prefill(params, tokens, cfg, state, chunk=ssm_chunk)
        if cfg.family == "hybrid":
            return hybrid.prefill(params, tokens, cfg, state,
                                  chunk_q=chunk_q, chunk_k=chunk_k,
                                  attn_impl=attn_impl)
        if cfg.family == "encdec":
            return encdec.prefill(params, tokens, batch["frames"], cfg, state,
                                  chunk_q=chunk_q, chunk_k=chunk_k,
                                  attn_impl=attn_impl)
        if cfg.family == "vlm":
            return vlm.prefill(params, tokens, batch["patches"], cfg, state,
                               chunk_q=chunk_q, chunk_k=chunk_k,
                               attn_impl=attn_impl)
        return T.prefill(params, tokens, cfg, state, chunk_q=chunk_q,
                         chunk_k=chunk_k, attn_impl=attn_impl)


def build(cfg: ModelConfig, tp: int = 1, **kw) -> Model:
    return Model(cfg, tp, **kw)
