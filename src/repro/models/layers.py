"""Shared transformer building blocks (pure functions over param dicts).

Everything here is jit/eval_shape-friendly and shape-polymorphic over batch
and sequence.  Attention is *chunked* (two-level scan with online softmax) so
the compiled program's live memory is O(S·chunk) rather than O(S²) — the
property the dry-run's memory_analysis must certify for the 32k cells.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.params import Param, ParamBuilder

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(b: ParamBuilder, d: int, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": b.p((d,), ("embed_no_fsdp",), init="ones")}
    return {
        "scale": b.p((d,), ("embed_no_fsdp",), init="ones"),
        "bias": b.p((d,), ("embed_no_fsdp",), init="zeros"),
    }


def apply_norm(p, x, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        out = (xf - mu) * lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings (full or partial/"2d" — chatglm3 rotates half)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return rot, jnp.asarray(inv)


def apply_rope(x, positions, *, fraction: float = 1.0, theta: float = 10_000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    rot, inv = rope_frequencies(d, fraction, theta)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # (..., S, 1, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1 = xr[..., 0::2].astype(jnp.float32)
    x2 = xr[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# chunked attention with online softmax
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnMask:
    """Positional mask family: causal, optionally windowed, optionally with a
    bidirectional prefix (PaliGemma) or fully bidirectional (encoder)."""

    causal: bool = True
    window: Optional[int] = None     # local attention: k > q - window
    prefix: int = 0                  # first `prefix` kv positions all-visible

    def __call__(self, q_pos, k_pos):
        ok = jnp.ones(jnp.broadcast_shapes(q_pos.shape, k_pos.shape), bool)
        if self.causal:
            vis = k_pos <= q_pos
            if self.window is not None:
                vis &= k_pos > q_pos - self.window
            if self.prefix:
                vis |= k_pos < self.prefix
            ok &= vis
        return ok


def _gqa_scores(q, k):
    """q: (B, Sq, H, D), k: (B, Sk, KV, D) -> (B, KV, H/KV, Sq, Sk)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, Sq, KV, g, D)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(probs, v):
    """probs: (B, KV, g, Sq, Sk), v: (B, Sk, KV, D) -> (B, Sq, H, D)."""
    B, KV, g, Sq, _ = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(probs.dtype),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, KV * g, v.shape[-1])


def chunked_attention(
    q, k, v, mask: AttnMask, *,
    q_offset=0,
    chunk_q: int = 1024,
    chunk_k: int = 1024,
    causal_skip: bool = False,
):
    """Memory-efficient attention: outer scan over query chunks, inner scan
    over key chunks, online-softmax accumulation.  Never materializes more
    than (chunk_q x chunk_k) scores per (batch, head).

    causal_skip: statically skip key chunks strictly above the diagonal
    (valid when q_offset==0 and mask.causal and no prefix) — halves attention
    FLOPs; the §Perf log measures exactly this switch.
    """
    B, S, H, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / np.sqrt(D)
    chunk_q = min(chunk_q, S)
    chunk_k = min(chunk_k, Sk)
    assert S % chunk_q == 0 and Sk % chunk_k == 0, (S, Sk, chunk_q, chunk_k)
    nq, nk = S // chunk_q, Sk // chunk_k
    KV = k.shape[2]
    g = H // KV

    kc = k.reshape(B, nk, chunk_k, KV, D)
    vc = v.reshape(B, nk, chunk_k, KV, D)

    def one_q_chunk(qi_static, qblk, nk_eff):
        """qblk: (B, chunk_q, H, D); iterate nk_eff key chunks."""
        q_pos = q_offset + qi_static * chunk_q + jnp.arange(chunk_q)

        def inner(carry, kj):
            m, l, acc = carry
            kblk = lax.dynamic_index_in_dim(kc, kj, axis=1, keepdims=False)
            vblk = lax.dynamic_index_in_dim(vc, kj, axis=1, keepdims=False)
            k_pos = kj * chunk_k + jnp.arange(chunk_k)
            s = _gqa_scores(qblk, kblk) * scale          # (B,KV,g,cq,ck) f32
            ok = mask(q_pos[:, None], k_pos[None, :])    # (cq, ck)
            s = jnp.where(ok[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows (m_new == -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(ok[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vblk.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, acc), None

        m0 = jnp.full((B, KV, g, chunk_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((B, KV, g, chunk_q, D), jnp.float32)
        (m, l, acc), _ = lax.scan(
            lambda c, kj: inner(c, kj), (m0, l0, a0),
            jnp.arange(nk_eff, dtype=jnp.int32),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, chunk_q, H, D)

    skip_ok = causal_skip and mask.causal and mask.prefix == 0 and (
        isinstance(q_offset, int) and q_offset == 0 and S == Sk and nq == nk
    )
    if skip_ok:
        # static triangular schedule: q chunk i sees key chunks [0, i]
        outs = []
        for qi in range(nq):
            qblk = lax.dynamic_slice_in_dim(q, qi * chunk_q, chunk_q, axis=1)
            outs.append(one_q_chunk(qi, qblk, qi + 1))
        out = jnp.concatenate(outs, axis=1)
    elif nq == 1:
        out = one_q_chunk(0, q, nk)
    else:
        qr = q.reshape(B, nq, chunk_q, H, D)

        def outer(qi, _):
            qblk = qr[:, qi]
            return qi + 1, one_q_chunk_traced(qi, qblk)

        # traced q index variant (mask handles positions dynamically)
        def one_q_chunk_traced(qi, qblk):
            q_pos = q_offset + qi * chunk_q + jnp.arange(chunk_q)

            def inner(carry, kj):
                m, l, acc = carry
                kblk = lax.dynamic_index_in_dim(kc, kj, axis=1, keepdims=False)
                vblk = lax.dynamic_index_in_dim(vc, kj, axis=1, keepdims=False)
                k_pos = kj * chunk_k + jnp.arange(chunk_k)
                s = _gqa_scores(qblk, kblk) * scale
                ok = mask(q_pos[:, None], k_pos[None, :])
                s = jnp.where(ok[None, None, None], s, -jnp.inf)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
                p = jnp.exp(s - m_safe[..., None])
                p = jnp.where(ok[None, None, None], p, 0.0)
                corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
                l = l * corr + jnp.sum(p, axis=-1)
                acc = acc * corr[..., None] + jnp.einsum(
                    "bkgqs,bskd->bkgqd", p, vblk.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )
                return (m_new, l, acc), None

            m0 = jnp.full((B, KV, g, chunk_q), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((B, KV, g, chunk_q), jnp.float32)
            a0 = jnp.zeros((B, KV, g, chunk_q, D), jnp.float32)
            (m, l, acc), _ = lax.scan(inner, (m0, l0, a0),
                                      jnp.arange(nk, dtype=jnp.int32))
            out = acc / jnp.maximum(l[..., None], 1e-30)
            return out.transpose(0, 3, 1, 2, 4).reshape(B, chunk_q, H, D)

        _, outs = lax.scan(outer, jnp.int32(0), None, length=nq)
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    return out.astype(q.dtype)


def attention(q, k, v, mask: AttnMask, *, impl: str = "xla",
              chunk_q: int = 1024, chunk_k: int = 1024,
              causal_skip: bool = False, q_offset=0):
    """Attention dispatcher.

    impl="xla":   pure-JAX chunked online-softmax (baseline — XLA
                  materializes the (cq x ck) score tiles to HBM).
    impl="flash": Pallas flash kernel (fwd+bwd in VMEM — the §Perf
                  optimization; HBM traffic is q+k+v+out only).
    """
    if impl == "flash":
        from repro.kernels import ops

        o = ops.flash_attention(
            q, k, v, causal=mask.causal, window=mask.window,
            prefix=mask.prefix, bq=min(512, chunk_q), bk=min(512, chunk_k))
        return jax.ad_checkpoint.checkpoint_name(o, "attn_out")
    return chunked_attention(q, k, v, mask, q_offset=q_offset,
                             chunk_q=chunk_q, chunk_k=chunk_k,
                             causal_skip=causal_skip)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=None, prefix=0):
    """Single-token attention against a cache.

    q: (B, 1, H, D); caches: (B, Smax, KV, D); cache_len: scalar int —
    number of valid cache positions (new token already written at
    cache_len-1).
    """
    B, _, H, D = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    scale = 1.0 / np.sqrt(D)
    s = _gqa_scores(q, k_cache) * scale        # (B, KV, g, 1, Smax)
    k_pos = jnp.arange(Smax)
    vis = k_pos < cache_len
    if window is not None:
        vis &= (k_pos >= cache_len - window) | (k_pos < prefix)
    s = jnp.where(vis[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = _gqa_out(p, v_cache)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------


def init_attention(b: ParamBuilder, cfg, tp: int = 1, tp_kv: int | None = None):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KV = cfg.padded_heads(tp, tp_kv)
    p = {
        "wq": b.p((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": b.p((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": b.p((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": b.p((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = b.p((H, hd), ("heads", "head_dim"), init="zeros")
        p["bk"] = b.p((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = b.p((KV, hd), ("kv_heads", "head_dim"), init="zeros")
    return p


def qkv(p, x, cfg, positions, *, rope: bool = True):
    cd = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if rope:
        q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    return q, k, v


def attn_out(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(b: ParamBuilder, d: int, f: int, act: str):
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": b.p((d, f), ("embed", "mlp")),
            "w_up": b.p((d, f), ("embed", "mlp")),
            "w_down": b.p((f, d), ("mlp", "embed")),
        }
    return {
        "w_up": b.p((d, f), ("embed", "mlp")),
        "b_up": b.p((f,), ("mlp",), init="zeros"),
        "w_down": b.p((f, d), ("mlp", "embed")),
        "b_down": b.p((d,), ("embed_no_fsdp",), init="zeros"),
    }


def apply_mlp(p, x, act: str):
    cd = x.dtype
    if act in ("swiglu", "geglu"):
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cd))
        up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cd))
        g = jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate)
        h = jax.ad_checkpoint.checkpoint_name(g * up, "mlp_hidden")
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cd))
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cd))
                    + p["b_up"].astype(cd))
    h = jax.ad_checkpoint.checkpoint_name(h, "mlp_hidden")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cd)) + p["b_down"].astype(cd)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------


def init_embedding(b: ParamBuilder, vocab: int, d: int):
    return {"table": b.p((vocab, d), ("vocab", "embed"), init="embed", scale=0.02)}


def embed(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def init_lm_head(b: ParamBuilder, d: int, vocab: int):
    return {"w": b.p((d, vocab), ("embed", "vocab"), init="normal")}


def lm_logits(head, x, *, tied_table=None):
    if tied_table is not None:
        return jnp.einsum("bsd,vd->bsv", x, tied_table.astype(x.dtype))
    return jnp.einsum("bsd,dv->bsv", x, head["w"].astype(x.dtype))
