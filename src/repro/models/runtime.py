"""Ambient (mesh, logical-rules) context for kernel sharding.

GSPMD treats a pallas_call as an opaque op and REPLICATES its operands (the
dry-run HLO showed the whole int8 cache all-gathered into every chip).  The
fix is standard: run Pallas kernels inside shard_map so each device executes
the kernel on its local shard.  The model layers don't carry the mesh, so
the step builders (launch/cells.py, train/trainer.py, serve/engine.py) set
it here and ops.py wraps kernels when a mesh is active.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

_CTX: contextvars.ContextVar = contextvars.ContextVar("mesh_rules",
                                                      default=None)


@contextlib.contextmanager
def mesh_rules(mesh, rules=None):
    from repro.models.sharding import DEFAULT_RULES

    token = _CTX.set((mesh, dict(rules or DEFAULT_RULES)))
    try:
        yield
    finally:
        _CTX.reset(token)


def current() -> Optional[Tuple]:
    return _CTX.get()


def axes_for(logical: str):
    """Mesh axes for a logical axis under the current rules (tuple, possibly
    empty)."""
    ctx = current()
    if ctx is None:
        return ()
    mesh, rules = ctx
    tgt = rules.get(logical)
    if tgt is None:
        return ()
    axes = (tgt,) if isinstance(tgt, str) else tuple(tgt)
    return tuple(a for a in axes if a in mesh.axis_names)


def fused_bkv_spec():
    """PartitionSpec entry for the grouped kernels' fused (B*KV) dim:
    batch axes (outer) then kv axes (inner) — matching the row-major
    (B, KV) -> B*KV reshape."""
    axes = axes_for("batch") + axes_for("kv_heads")
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical axes under the ambient context —
    no-op when no mesh is active.  Used to pin gather/scatter outputs whose
    sharding GSPMD otherwise resolves with full-rematerialization permutes
    (the embedding-lookup warnings in the dry-run log)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    ctx = current()
    if ctx is None:
        return x
    mesh, rules = ctx
    from repro.models.sharding import resolve

    spec = resolve(tuple(logical_axes), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
