"""Mixture-of-Experts block (qwen3-moe 128e top-8, phi3.5-moe 16e top-2).

Formulation: capacity-bounded top-k routing with sort-based dispatch,
expressed as gathers/scatters + one batched einsum so GSPMD can shard the
expert dim over ``model`` while tokens stay replicated across it:

  1. router logits -> top-k (expert, prob) per token,
  2. tokens sorted by expert; each expert keeps its first C tokens
     (GShard-style capacity C = ceil(topk*N/E)*cf — overflow is dropped),
  3. gather x rows into an (E, C, d) buffer (E sharded over ``model``:
     each rank gathers only its experts' rows — no communication because
     activations are replicated over ``model``),
  4. batched expert FFN (E,C,d)x(E,d,f) — fully local per rank,
  5. scatter-add prob-weighted outputs back to (N, d) — GSPMD inserts the
     psum over ``model``, the same reduction the dense TP mlp needs.

This is the paper's "route work to its owner" pattern (§3.1/§3.2) with the
expert id as the partitioning key.  An explicit all-to-all dispatch variant
(tokens sequence-sharded over ``model``, exchanged with the §3.2.6 1-factor
or XLA schedule) lives in the serve/perf experiments.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import ParamBuilder


def init_moe(b: ParamBuilder, cfg):
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert
    E = m.num_experts
    return {
        "router": b.p((d, E), ("embed_no_fsdp", "expert")),
        "w_gate": b.p((E, d, f), ("expert", "embed", "expert_mlp")),
        "w_up": b.p((E, d, f), ("expert", "embed", "expert_mlp")),
        "w_down": b.p((E, f, d), ("expert", "expert_mlp", "embed")),
    }


def capacity(n_tokens: int, num_experts: int, top_k: int, cf: float) -> int:
    c = int(math.ceil(top_k * n_tokens / num_experts * cf))
    return max(8, int(math.ceil(c / 8)) * 8)


def apply_moe(p, x, cfg, mesh=None):
    """x: (B, S, d) -> (B, S, d).  Router in f32 for stable softmax."""
    m = cfg.moe
    B, S, d = x.shape
    N = B * S
    E, K = m.num_experts, m.top_k
    C = capacity(N, E, K, m.capacity_factor)
    xt = x.reshape(N, d)

    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)              # (N, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- sort-based dispatch: (token, k) pairs ordered by expert ---------
    flat_e = top_e.reshape(N * K)
    flat_t = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)
    flat_p = top_p.reshape(N * K)
    order = jnp.argsort(flat_e, stable=True)
    se, stok, sp = flat_e[order], flat_t[order], flat_p[order]
    # position of each pair within its expert's run
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    pos = jnp.arange(N * K, dtype=jnp.int32) - starts[se]
    keep = pos < C
    slot = jnp.where(keep, pos, C - 1)

    # (E, C) token ids + probs; dropped pairs scatter to a dead row
    dest_e = jnp.where(keep, se, E)
    tok_buf = jnp.zeros((E, C), jnp.int32).at[dest_e, slot].set(stok, mode="drop")
    prob_buf = jnp.zeros((E, C), jnp.float32).at[dest_e, slot].set(
        jnp.where(keep, sp, 0.0), mode="drop")
    valid = jnp.zeros((E, C), bool).at[dest_e, slot].set(keep, mode="drop")

    # ---- expert FFN on gathered tokens (E sharded over `model`) ----------
    cd = x.dtype
    xe = xt[tok_buf.reshape(-1)].reshape(E, C, d)
    xe = jnp.where(valid[..., None], xe, 0)
    gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(cd))
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(cd))
    h = jax.nn.silu(gate) * up
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cd))
    out_e = out_e * prob_buf[..., None].astype(cd)

    # ---- combine: scatter-add back to token order -------------------------
    y = jnp.zeros((N, d), cd).at[tok_buf.reshape(-1)].add(
        jnp.where(valid[..., None], out_e, 0).reshape(E * C, d)
    )
    return y.reshape(B, S, d)


def load_balance_stats(p, x, cfg):
    """Aux metrics: per-expert load fraction and dropped-token fraction."""
    m = cfg.moe
    B, S, d = x.shape
    N = B * S
    E, K = m.num_experts, m.top_k
    C = capacity(N, E, K, m.capacity_factor)
    logits = jnp.einsum("nd,de->ne", x.reshape(N, d).astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    top_e = jax.lax.top_k(jax.nn.softmax(logits, -1), K)[1]
    counts = jnp.zeros(E, jnp.int32).at[top_e.reshape(-1)].add(1)
    dropped = jnp.sum(jnp.maximum(counts - C, 0))
    return {"expert_load": counts / (N * K), "drop_frac": dropped / (N * K)}
