"""RecurrentGemma (recurrentgemma-2b): RG-LRU recurrent blocks + local
sliding-window attention in a (rec, rec, attn) pattern.

The RG-LRU recurrence h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t*x_t) is a
first-order linear recurrence -> computed with lax.associative_scan
(log-depth, TPU-friendly).  Decode state is the (B, lru_width) hidden plus a
window-bounded KV cache, so the long_500k cell RUNS for this arch.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.params import ParamBuilder

_C = 8.0  # RG-LRU temperature


class HybridState(NamedTuple):
    lru: jax.Array       # (layers, B, lru_width) recurrent hidden
    conv: jax.Array      # (layers, B, W-1, lru_width) conv tail
    k: jax.Array         # (layers, B, window, KV, hd) rolling attn cache
    v: jax.Array
    length: jax.Array


def is_attn_layer(cfg, i: int) -> bool:
    hy = cfg.hybrid
    return i % hy.period == hy.attn_position


def init_rec_layer(rng, cfg):
    b = ParamBuilder(rng)
    d = cfg.d_model
    lw = cfg.hybrid.lru_width or d
    W = 4
    return {
        "norm": L.init_norm(b, d, "rmsnorm"),
        "w_x": b.p((d, lw), ("embed", "mlp")),
        "w_gate": b.p((d, lw), ("embed", "mlp")),
        "conv": b.p((W, lw), ("conv", "mlp"), init="normal", scale=0.1),
        "lambda_p": b.p((lw,), ("mlp",), init="ones"),
        "w_a": b.p((lw, lw), ("mlp", None)),
        "b_a": b.p((lw,), (None,), init="zeros"),
        "w_i": b.p((lw, lw), ("mlp", None)),
        "b_i": b.p((lw,), (None,), init="zeros"),
        "out_proj": b.p((lw, d), ("mlp", "embed")),
    }


def init_hybrid_layer(rng, cfg, tp: int, tp_kv=None):
    """Every layer carries BOTH block param sets stacked uniformly (scan needs
    homogeneous pytrees); the unused half is inert per layer index."""
    from repro.models.transformer import init_layer

    r1, r2 = jax.random.split(rng)
    return {"attn_block": init_layer(r1, cfg, tp, tp_kv),
            "rec_block": init_rec_layer(r2, cfg)}


def init_hybrid(rng, cfg, tp: int = 1, tp_kv=None):
    from repro.models.transformer import stack_layer_params

    r_emb, r_layers, r_norm = jax.random.split(rng, 3)
    b = ParamBuilder(r_emb)
    return {
        "embedding": L.init_embedding(b, cfg.padded_vocab(), cfg.d_model),
        "layers": stack_layer_params(
            lambda k: init_hybrid_layer(k, cfg, tp, tp_kv), r_layers,
            cfg.n_layers
        ),
        "final_norm": L.init_norm(ParamBuilder(r_norm), cfg.d_model, "rmsnorm"),
    }


def _lru_scan(a, bx, h0=None):
    """h_t = a_t * h_{t-1} + bx_t along axis 1 via associative_scan.
    a, bx: (B, S, lw)."""
    if h0 is not None:
        bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, bl * ar + br

    _, h = lax.associative_scan(combine, (a, bx), axis=1)
    return h


def apply_rec_block(p, x, cfg, *, state=None, conv_tail=None):
    """RG-LRU block.  Train: state=None, full sequence.  Decode: x (B,1,d)
    with carried state/conv_tail.  Returns (y, new_state, new_conv_tail)."""
    cd = x.dtype
    lw = cfg.hybrid.lru_width or cfg.d_model
    h = L.apply_norm(p["norm"], x, "rmsnorm")
    xin = jnp.einsum("bsd,dl->bsl", h, p["w_x"].astype(cd))
    gate = jnp.einsum("bsd,dl->bsl", h, p["w_gate"].astype(cd))
    W = p["conv"].shape[0]
    if conv_tail is None:
        xp = jnp.pad(xin, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_tail, xin], axis=1)
    conv = jnp.zeros_like(xin)
    for w in range(W):
        conv = conv + xp[:, w : w + xin.shape[1]] * p["conv"].astype(cd)[w][None, None]
    new_tail = xp[:, -(W - 1):] if W > 1 else xp[:, :0]
    u = conv.astype(jnp.float32)
    r = jax.nn.sigmoid(u @ p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(u @ p["w_i"].astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lambda_p"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u)
    if x.shape[1] == 1 and state is not None:
        hseq = a[:, 0] * state + gated_in[:, 0]
        new_state = hseq
        hseq = hseq[:, None]
    else:
        hseq = _lru_scan(a, gated_in, h0=state)
        new_state = hseq[:, -1]
    y = (hseq.astype(cd) * jax.nn.gelu(gate))
    out = jnp.einsum("bsl,ld->bsd", y, p["out_proj"].astype(cd))
    return x + out, new_state, new_tail


def forward(params, tokens, cfg, *, chunk_q=1024, chunk_k=1024,
            attn_impl="xla"):
    from repro.models.transformer import apply_layer

    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embedding"], tokens, cd)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    mask = L.AttnMask(causal=True, window=cfg.attn_window)

    # layer pattern is static -> unrolled python loop over gathered slices
    # would break scan; instead scan with a per-layer selector
    def body(carry, inputs):
        lp, idx = inputs
        h = carry
        attn_out = apply_layer(lp["attn_block"], h, cfg, positions, mask=mask,
                               chunk_q=chunk_q, chunk_k=chunk_k,
                               attn_impl=attn_impl)
        rec_out, _, _ = apply_rec_block(lp["rec_block"], h, cfg)
        hy = cfg.hybrid
        use_attn = (idx % hy.period) == hy.attn_position
        h = jnp.where(use_attn, attn_out, rec_out)
        return h, None

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = lax.scan(body_fn, x, (params["layers"], idxs))
    return L.apply_norm(params["final_norm"], x, "rmsnorm")


def init_state(cfg, batch: int, tp: int = 1, dtype=jnp.bfloat16, tp_kv=None):
    lw = cfg.hybrid.lru_width or cfg.d_model
    _, KV = cfg.padded_heads(tp, tp_kv)
    hd = cfg.resolved_head_dim
    Wd = cfg.hybrid.window
    Wc = 4
    return HybridState(
        lru=jnp.zeros((cfg.n_layers, batch, lw), jnp.float32),
        conv=jnp.zeros((cfg.n_layers, batch, Wc - 1, lw), dtype),
        k=jnp.zeros((cfg.n_layers, batch, Wd, KV, hd), dtype),
        v=jnp.zeros((cfg.n_layers, batch, Wd, KV, hd), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def state_logical_axes():
    return HybridState(
        lru=("layers", "batch", "mlp"),
        conv=("layers", "batch", "conv", "mlp"),
        k=("layers", "batch", "seq", "kv_heads", "head_dim"),
        v=("layers", "batch", "seq", "kv_heads", "head_dim"),
        length=(),
    )


def prefill(params, tokens, cfg, state: HybridState, *, chunk_q=1024,
            chunk_k=1024, attn_impl="xla"):
    """Run the prompt, capture per-layer LRU state / conv tail / the last
    ``window`` K,V at their ring-buffer slots; return last-token logits."""
    from repro.models import transformer as T

    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embedding"], tokens, cd)
    S = x.shape[1]
    Wd = cfg.hybrid.window
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    mask = L.AttnMask(causal=True, window=cfg.attn_window)
    # ring-buffer layout: slot s holds the latest position p < S with
    # p % Wd == s (static arithmetic — S and Wd are compile-time)
    slots = jnp.arange(Wd)
    ring_pos = jnp.where(
        slots < (S % Wd if Wd else 0),
        (S - (S % Wd)) + slots,
        S - Wd - (S % Wd) + slots if S >= Wd else slots,
    ) if Wd else slots
    ring_pos = jnp.clip(ring_pos, 0, S - 1)
    ring_valid = (jnp.arange(Wd) < S) if S < Wd else jnp.ones(Wd, bool)

    def body(carry, scanned):
        h = carry
        lp, idx = scanned
        # attention branch (also computes the cacheable K/V)
        hn = L.apply_norm(lp["attn_block"]["ln1"], h, cfg.norm)
        q, k, v = L.qkv(lp["attn_block"]["attn"], hn, cfg, positions)
        o = L.attention(q, k, v, mask, impl=attn_impl,
                        chunk_q=min(chunk_q, S), chunk_k=min(chunk_k, S))
        ah = h + L.attn_out(lp["attn_block"]["attn"], o)
        hn2 = L.apply_norm(lp["attn_block"]["ln2"], ah, cfg.norm)
        ah = ah + L.apply_mlp(lp["attn_block"]["mlp"], hn2, cfg.act)
        kc = jnp.where(ring_valid[None, :, None, None], k[:, ring_pos], 0)
        vc = jnp.where(ring_valid[None, :, None, None], v[:, ring_pos], 0)
        # recurrent branch
        rh, lru, cv = apply_rec_block(lp["rec_block"], h, cfg)
        hy = cfg.hybrid
        use_attn = (idx % hy.period) == hy.attn_position
        h = jnp.where(use_attn, ah, rh)
        return h, (lru, cv.astype(jnp.bfloat16), kc.astype(jnp.bfloat16),
                   vc.astype(jnp.bfloat16))

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (lru_n, cv_n, k_n, v_n) = lax.scan(body_fn, x, (params["layers"], idxs))
    h = L.apply_norm(params["final_norm"], x[:, -1:], "rmsnorm")
    logits = T.logits_from_hidden(params, h, cfg)
    return logits[:, 0], HybridState(
        lru_n, cv_n.astype(state.conv.dtype), k_n.astype(state.k.dtype),
        v_n.astype(state.v.dtype), jnp.int32(S)
    )


def decode_step(params, state: HybridState, token, cfg):
    """Rolling-window decode: attention caches hold the last `window`
    positions (ring buffer via roll-free modular write)."""
    from repro.models import transformer as T

    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embedding"], token, cd)
    Wd = cfg.hybrid.window
    pos = state.length                       # absolute position of new token
    slot = pos % Wd

    def body(carry, scanned):
        h = carry
        lp, lru, cv, kc, vc, idx = scanned
        # attention path (ring-buffer cache)
        hn = L.apply_norm(lp["attn_block"]["ln1"], h, cfg.norm)
        q, k, v = L.qkv(lp["attn_block"]["attn"], hn, cfg, pos[None, None])
        kc2 = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, axis=1)
        vc2 = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, axis=1)
        n_valid = jnp.minimum(pos + 1, Wd)
        s = L._gqa_scores(q, kc2) / jnp.sqrt(jnp.float32(q.shape[-1]))
        kpos = jnp.arange(Wd)
        vis = kpos < n_valid
        s = jnp.where(vis[None, None, None, None, :], s, -jnp.inf)
        o = L._gqa_out(jax.nn.softmax(s.astype(jnp.float32), -1), vc2)
        attn_h = h + L.attn_out(lp["attn_block"]["attn"], o.astype(cd))
        hn2 = L.apply_norm(lp["attn_block"]["ln2"], attn_h, cfg.norm)
        attn_h = attn_h + L.apply_mlp(lp["attn_block"]["mlp"], hn2, cfg.act)
        # recurrent path
        rec_h, lru2, cv2 = apply_rec_block(lp["rec_block"], h, cfg,
                                           state=lru, conv_tail=cv)
        hy = cfg.hybrid
        use_attn = (idx % hy.period) == hy.attn_position
        h = jnp.where(use_attn, attn_h, rec_h)
        lru2 = jnp.where(use_attn, lru, lru2)
        return h, (lru2, cv2, kc2, vc2)

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    x, (lru_n, cv_n, k_n, v_n) = lax.scan(
        body, x, (params["layers"], state.lru, state.conv, state.k, state.v, idxs)
    )
    h = L.apply_norm(params["final_norm"], x, "rmsnorm")
    logits = T.logits_from_hidden(params, h, cfg)
    return logits[:, 0], HybridState(lru_n, cv_n, k_n, v_n, state.length + 1)
