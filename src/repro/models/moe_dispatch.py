"""Expert-parallel MoE dispatch over the paper's exchange layer.

The default MoE block (models/moe.py) keeps tokens replicated across the
``model`` axis and lets each rank gather its experts' tokens locally.  This
module is the SEQUENCE-SHARDED alternative: tokens are sharded over the
expert axis, and routing becomes a personalized all-to-all — exactly the
paper's §3.1 "route work to its owner" with the §3.2.6 schedule selectable
(fused XLA all-to-all vs the 1-factor ppermute rounds).  Runs inside
shard_map; used by tests/benchmarks as the explicit-collective variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import exchange


def moe_block_sharded(p, x_local, cfg, *, axis: str = "model",
                      backend: str = "xla", capacity_factor: float = 2.0):
    """x_local: (N_local, d) tokens of THIS rank (sequence-sharded).
    p holds the LOCAL expert shard: w_* (E_local, d, f), router (d, E).
    Returns (y_local (N_local, d), overflow flag)."""
    m = cfg.moe
    P = lax.axis_size(axis)
    E = m.num_experts
    E_local = E // P
    N_local, dm = x_local.shape

    logits = jnp.einsum("nd,de->ne", x_local.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_e = top_e.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(N_local, dtype=jnp.int32), m.top_k)
    flat_p = top_p.reshape(-1)
    mask = jnp.ones_like(flat_e, bool)
    owner = flat_e // E_local
    cap = int(N_local * m.top_k * capacity_factor // P) + 8

    # ship (expert_id, token_vector) to the expert's owner — the paper's
    # personalized all-to-all (backend: "xla" | "one_factor")
    re, rx, rmask, (dest, slot), ovf = exchange.exchange_vectors_by_owner(
        flat_e, x_local[flat_t], mask, owner, capacity=cap, axis=axis,
        backend=backend,
    )
    # local expert FFN on received tokens
    local_e = jnp.where(rmask, re % E_local, 0)
    onehot = jax.nn.one_hot(local_e, E_local, dtype=rx.dtype)
    # gather each token's expert weights via one-hot contraction
    wg = jnp.einsum("pce,edf->pcdf", onehot, p["w_gate"].astype(rx.dtype))
    wu = jnp.einsum("pce,edf->pcdf", onehot, p["w_up"].astype(rx.dtype))
    wd = jnp.einsum("pce,efd->pcfd", onehot, p["w_down"].astype(rx.dtype))
    gate = jnp.einsum("pcd,pcdf->pcf", rx, wg)
    up = jnp.einsum("pcd,pcdf->pcf", rx, wu)
    out = jnp.einsum("pcf,pcfd->pcd", jax.nn.silu(gate) * up, wd)
    out = jnp.where(rmask[..., None], out, 0)
    # ship results back (second personalized all-to-all), weight, combine
    back = exchange.all_to_all(out, axis, backend=backend)
    contrib = back[dest, slot] * flat_p[:, None].astype(back.dtype)
    contrib = jnp.where(mask[:, None], contrib, 0)
    y = jnp.zeros_like(x_local).at[flat_t].add(contrib.astype(x_local.dtype))
    return y, ovf
