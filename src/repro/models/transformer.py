"""Unified decoder-only transformer (dense GQA + MoE + local-window +
prefix-LM), scan-over-layers with optional remat.

Covers: yi-34b, qwen2.5-3b (qkv bias), chatglm3-6b (partial rope),
mistral-nemo-12b, qwen3-moe, phi3.5-moe, the paligemma decoder (prefix) and
the whisper decoder (via models/encdec.py which reuses these blocks).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L, moe as moe_mod
from repro.models.params import Param, ParamBuilder, logical_axes, values


class KVCache(NamedTuple):
    k: jax.Array       # (L, B, Smax, KV, hd)
    v: jax.Array
    length: jax.Array  # scalar int32 — valid positions


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(position, head) scales — §Perf decode
    optimization (cache HBM reads halve vs bf16); layout (L, B, KV, S, hd)
    so the grouped Pallas decode kernel gets a free reshape."""

    k: jax.Array        # (L, B, KV, Smax, hd) int8
    v: jax.Array
    k_scale: jax.Array  # (L, B, KV, Smax) f32
    v_scale: jax.Array
    length: jax.Array


def stack_layer_params(init_one, rng, n_layers: int):
    """vmap a per-layer init over layer keys; prepend the 'layers' logical
    axis to every leaf (the scan dimension)."""
    keys = jax.random.split(rng, n_layers)
    stacked = jax.vmap(lambda k: init_one(k))(keys)
    return jax.tree.map(
        lambda p: Param(p.value, ("layers",) + p.axes),
        stacked,
        is_leaf=lambda x: isinstance(x, Param),
    )


def init_layer(rng, cfg, tp: int, tp_kv: int | None = None):
    b = ParamBuilder(rng)
    p = {
        "ln1": L.init_norm(b, cfg.d_model, cfg.norm),
        "attn": L.init_attention(b, cfg, tp, tp_kv),
        "ln2": L.init_norm(b, cfg.d_model, cfg.norm),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.init_moe(b, cfg)
        # phi3.5-style models keep no dense mlp; qwen3-moe neither
    else:
        p["mlp"] = L.init_mlp(b, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def init_transformer(rng, cfg, tp: int = 1, tp_kv: int | None = None):
    r_emb, r_layers, r_head, r_norm = jax.random.split(rng, 4)
    b = ParamBuilder(r_emb)
    params = {
        "embedding": L.init_embedding(b, cfg.padded_vocab(), cfg.d_model),
        "layers": stack_layer_params(
            lambda k: init_layer(k, cfg, tp, tp_kv), r_layers, cfg.n_layers
        ),
        "final_norm": L.init_norm(ParamBuilder(r_norm), cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.init_lm_head(
            ParamBuilder(r_head), cfg.d_model, cfg.padded_vocab()
        )
    return params


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------


def _layer_mask(cfg) -> L.AttnMask:
    window = cfg.attn_window
    prefix = cfg.vlm.num_patches if (cfg.family == "vlm" and cfg.vlm) else 0
    return L.AttnMask(causal=True, window=window, prefix=prefix)


def apply_layer(p, x, cfg, positions, *, mask=None, chunk_q=1024, chunk_k=1024,
                causal_skip=False, attn_impl="xla"):
    mask = mask or _layer_mask(cfg)
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    q, k, v = L.qkv(p["attn"], h, cfg, positions)
    o = L.attention(q, k, v, mask, impl=attn_impl, chunk_q=chunk_q,
                    chunk_k=chunk_k, causal_skip=causal_skip)
    x = x + L.attn_out(p["attn"], o)
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    if cfg.family == "moe":
        x = x + moe_mod.apply_moe(p["moe"], h, cfg)
    else:
        x = x + L.apply_mlp(p["mlp"], h, cfg.act)
    return x


def apply_layer_decode(p, x, cfg, k_cache, v_cache, cache_len):
    """One-token decode step for a single layer.

    x: (B, 1, d); caches: (B, Smax, KV, hd).  Returns (x, new_k, new_v) where
    the caches have the new position written at cache_len - 1.
    """
    positions = (cache_len - 1)[None].astype(jnp.int32)  # (1,) broadcast to (B,1)
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    q, k, v = L.qkv(p["attn"], h, cfg, positions[None, :])
    idx = cache_len - 1
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k, idx, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v, idx, axis=1)
    prefix = cfg.vlm.num_patches if (cfg.family == "vlm" and cfg.vlm) else 0
    o = L.decode_attention(q, k_cache, v_cache, cache_len,
                           window=cfg.attn_window, prefix=prefix)
    x = x + L.attn_out(p["attn"], o)
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    if cfg.family == "moe":
        x = x + moe_mod.apply_moe(p["moe"], h, cfg)
    else:
        x = x + L.apply_mlp(p["mlp"], h, cfg.act)
    return x, k_cache, v_cache


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------


def remat_wrap(body, cfg, remat_policy: str = "full"):
    """Remat policy for the layer scan:
      full          — checkpoint everything (lowest memory, 2N recompute)
      save_hot      — keep mlp hidden + attention outputs (skips the most
                      expensive recompute dots; ~170 MB/layer/microbatch)
      none          — no remat (only viable for tiny configs/tests)
    """
    if not cfg.remat or remat_policy == "none":
        return body
    if remat_policy == "save_hot":
        policy = jax.checkpoint_policies.save_only_these_names(
            "mlp_hidden", "attn_out")
        return jax.checkpoint(body, policy=policy)
    return jax.checkpoint(body)


def forward(params, tokens, cfg, *, embeddings=None, mask=None,
            chunk_q=1024, chunk_k=1024, causal_skip=False, attn_impl="xla",
            remat_policy="full"):
    """Training/prefill forward -> final hidden states (B, S, d).

    embeddings: optional (B, S_extra, d) prefix embeddings prepended to the
    token embeddings (VLM patch embeds / audio frames for enc-dec handled in
    their own modules).
    """
    cd = jnp.dtype(cfg.compute_dtype)
    from repro.models import runtime as RT

    x = RT.constrain(L.embed(params["embedding"], tokens, cd),
                     "batch", None, None)
    if embeddings is not None:
        x = jnp.concatenate([embeddings.astype(cd), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(carry, lp):
        h = apply_layer(lp, carry, cfg, positions, mask=mask,
                        chunk_q=chunk_q, chunk_k=chunk_k,
                        causal_skip=causal_skip, attn_impl=attn_impl)
        return h, None

    body_fn = remat_wrap(body, cfg, remat_policy)
    x, _ = lax.scan(body_fn, x, params["layers"])
    return L.apply_norm(params["final_norm"], x, cfg.norm)


def logits_from_hidden(params, hidden, cfg):
    tied = params["embedding"]["table"] if cfg.tie_embeddings else None
    head = params.get("head")
    return L.lm_logits(head, hidden, tied_table=tied)


def init_cache(cfg, batch: int, max_len: int, tp: int = 1, dtype=jnp.bfloat16,
               tp_kv: int | None = None):
    _, KV = cfg.padded_heads(tp, tp_kv)
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, KV, hd)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def cache_logical_axes():
    return KVCache(
        k=("layers", "batch", "seq", "kv_heads", "head_dim"),
        v=("layers", "batch", "seq", "kv_heads", "head_dim"),
        length=(),
    )


def init_quant_cache(cfg, batch: int, max_len: int, tp: int = 1,
                     tp_kv: int | None = None):
    _, KV = cfg.padded_heads(tp, tp_kv)
    hd = cfg.resolved_head_dim
    return QuantKVCache(
        k=jnp.zeros((cfg.n_layers, batch, KV, max_len, hd), jnp.int8),
        v=jnp.zeros((cfg.n_layers, batch, KV, max_len, hd), jnp.int8),
        k_scale=jnp.zeros((cfg.n_layers, batch, KV, max_len), jnp.float32),
        v_scale=jnp.zeros((cfg.n_layers, batch, KV, max_len), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )


def quant_cache_logical_axes():
    kv = ("layers", "batch", "kv_heads", "seq", "head_dim")
    sc = ("layers", "batch", "kv_heads", "seq")
    return QuantKVCache(k=kv, v=kv, k_scale=sc, v_scale=sc, length=())


def _quantize_kv(x):
    """x: (B, 1, KV, hd) -> ((B, KV, 1, hd) int8, (B, KV, 1) f32 scale)."""
    xt = x.transpose(0, 2, 1, 3).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xt), axis=-1) + 1e-8
    q = jnp.clip(jnp.round(xt / amax[..., None] * 127.0), -127, 127)
    return q.astype(jnp.int8), (amax / 127.0)


def apply_layer_decode_quant(p, x, cfg, kq, ks, vq, vs, cache_len,
                             interpret_hint=None):
    """Decode layer against the int8 cache via the Pallas decode kernel."""
    from repro.kernels.decode_attention import decode_attention as pallas_da

    assert cfg.attn_window is None, "quant decode kernel: no window support"
    positions = (cache_len - 1)[None].astype(jnp.int32)
    h = L.apply_norm(p["ln1"], x, cfg.norm)
    q, k, v = L.qkv(p["attn"], h, cfg, positions[None, :])
    idx = cache_len - 1
    nk, nks = _quantize_kv(k)
    nv, nvs = _quantize_kv(v)
    B, KV, Smax, hd = kq.shape
    kq = lax.dynamic_update_slice(kq, nk, (0, 0, idx, 0))
    vq = lax.dynamic_update_slice(vq, nv, (0, 0, idx, 0))
    ks = lax.dynamic_update_slice(ks, nks, (0, 0, idx))
    vs = lax.dynamic_update_slice(vs, nvs, (0, 0, idx))
    H = q.shape[2]
    G = H // KV
    qg = q[:, 0].reshape(B, KV, G, hd).reshape(B * KV, G, hd)

    def da(qq, kk, vv, kks, vvs, ln):
        return pallas_da(qq, kk, vv, ln[0], k_scale=kks, v_scale=vvs,
                         interpret=jax.default_backend() != "tpu")

    from jax.sharding import PartitionSpec as P

    from repro.models import runtime

    ctx = runtime.current()
    if ctx is not None:
        bkv = runtime.fused_bkv_spec()
        da = jax.shard_map(
            da, mesh=ctx[0],
            in_specs=(P(bkv, None, None), P(bkv, None, None),
                      P(bkv, None, None), P(bkv, None), P(bkv, None), P()),
            out_specs=P(bkv, None, None), check_vma=False)
    o = da(qg, kq.reshape(B * KV, Smax, hd), vq.reshape(B * KV, Smax, hd),
           ks.reshape(B * KV, Smax), vs.reshape(B * KV, Smax),
           cache_len[None])
    o = o.reshape(B, KV * G, hd)[:, None].reshape(B, 1, H, hd)
    x = x + L.attn_out(p["attn"], o.astype(x.dtype))
    h = L.apply_norm(p["ln2"], x, cfg.norm)
    if cfg.family == "moe":
        x = x + moe_mod.apply_moe(p["moe"], h, cfg)
    else:
        x = x + L.apply_mlp(p["mlp"], h, cfg.act)
    return x, kq, ks, vq, vs


def decode_step(params, cache, token, cfg):
    """One decode step: token (B, 1) int32 -> (logits (B, vocab), new cache).
    Dispatches on the cache flavor (bf16 baseline vs int8+Pallas)."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embedding"], token, cd)
    new_len = cache.length + 1

    if isinstance(cache, QuantKVCache):
        def qbody(carry, scanned):
            h = carry
            lp, kq, ks, vq, vs = scanned
            h, kq, ks, vq, vs = apply_layer_decode_quant(
                lp, h, cfg, kq, ks, vq, vs, new_len)
            return h, (kq, ks, vq, vs)

        x, (kq, ks, vq, vs) = lax.scan(
            qbody, x, (params["layers"], cache.k, cache.k_scale,
                       cache.v, cache.v_scale))
        h = L.apply_norm(params["final_norm"], x, cfg.norm)
        logits = logits_from_hidden(params, h, cfg)
        return logits[:, 0], QuantKVCache(kq, vq, ks, vs, new_len)

    def body(carry, scanned):
        h = carry
        lp, kc, vc = scanned
        h, kc, vc = apply_layer_decode(lp, h, cfg, kc, vc, new_len)
        return h, (kc, vc)

    x, (k_new, v_new) = lax.scan(body, x, (params["layers"], cache.k, cache.v))
    h = L.apply_norm(params["final_norm"], x, cfg.norm)
    logits = logits_from_hidden(params, h, cfg)
    return logits[:, 0], KVCache(k_new, v_new, new_len)


def prefill(params, tokens, cfg, cache: KVCache, *, embeddings=None,
            chunk_q=1024, chunk_k=1024, attn_impl="xla"):
    """Run the full prompt, fill the cache, return last-position logits."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = L.embed(params["embedding"], tokens, cd)
    if embeddings is not None:
        x = jnp.concatenate([embeddings.astype(cd), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    mask = _layer_mask(cfg)

    def body(carry, scanned):
        h = carry
        lp, kc, vc = scanned
        hn = L.apply_norm(lp["ln1"], h, cfg.norm)
        q, k, v = L.qkv(lp["attn"], hn, cfg, positions)
        kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), 0, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), 0, axis=1)
        o = L.attention(q, k, v, mask, impl=attn_impl, chunk_q=chunk_q,
                        chunk_k=chunk_k)
        h = h + L.attn_out(lp["attn"], o)
        hn = L.apply_norm(lp["ln2"], h, cfg.norm)
        if cfg.family == "moe":
            h = h + moe_mod.apply_moe(lp["moe"], hn, cfg)
        else:
            h = h + L.apply_mlp(lp["mlp"], hn, cfg.act)
        return h, (kc, vc)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (k_new, v_new) = lax.scan(body_fn, x, (params["layers"], cache.k, cache.v))
    h = L.apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
    logits = logits_from_hidden(params, h, cfg)
    return logits[:, 0], KVCache(k_new, v_new, jnp.int32(S))
