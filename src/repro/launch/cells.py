"""Cell lowering: (architecture x input shape x mesh) -> lowered/compiled
XLA executable + roofline terms.  Pure library (no env side effects) so
tests can drive it on small meshes; launch/dryrun.py is the 512-device
entrypoint.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ShapeCell, SHAPES, cell_runnable, get_arch
from repro.data.synthetic import batch_specs
from repro.launch import flops as FL
from repro.launch import roofline as RL
from repro.models import runtime, sharding as SH
from repro.models.model import build
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import (init_train_state, make_train_step,
                                    train_state_axes)


def _batch_shards(mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            n *= mesh.shape[ax]
    return n


def _rules_for(mesh, batch: int) -> dict:
    """Degrade the batch rule to replication when the batch doesn't divide
    the dp shards (long_500k: b=1)."""
    rules = dict(SH.DEFAULT_RULES)
    if batch % max(_batch_shards(mesh), 1):
        rules["batch"] = None
    return rules


def _batch_shardings(cfg, shape: ShapeCell, mesh, rules):
    specs = batch_specs(cfg, shape)
    out = {}
    for k, sds in specs.items():
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        out[k] = NamedSharding(mesh, SH.resolve(axes, mesh, rules))
    return out


def pick_microbatches(cfg, shape: ShapeCell, mesh,
                      target_tokens_per_device: int = 8192) -> int:
    if shape.kind != "train":
        return 1
    shards = _batch_shards(mesh)
    tokens_per_device = shape.global_batch * shape.seq_len // shards
    mb = max(1, tokens_per_device // target_tokens_per_device)
    # mb must divide global batch and keep >= 1 row per shard
    while mb > 1 and (shape.global_batch % mb
                      or (shape.global_batch // mb) % shards):
        mb -= 1
    return mb


def choose_decode_layout(cfg, shape: ShapeCell, *, chips: int = 256,
                         data: int = 16):
    """Pure selection math for the decode layout: the kv shard degree is the
    smallest-padding power of two whose freed ranks still divide the batch.
    Returns (mesh_shape, kv_shard, model_b)."""
    model = chips // data
    kv = max(cfg.n_kv_heads, 1)
    best = None
    ks = 1
    while ks <= model:
        model_b = model // ks
        if shape.global_batch % (data * model_b) == 0:
            pad = (ks - kv % ks) % ks if kv % ks else 0
            score = (pad, -ks)
            if best is None or score < best[0]:
                best = (score, ks, model_b)
        ks *= 2
    assert best is not None, "no valid decode layout"
    _, kv_shard, model_b = best
    return (data, kv_shard, model_b), kv_shard, model_b


def decode_opt_layout(cfg, shape: ShapeCell, *, chips: int = 256,
                      data: int = 16):
    """§Perf decode layout: split the 16-way model axis into
    (model_kv x model_b) so kv heads shard at their natural degree and the
    freed ranks absorb BATCH instead of reading padded cache copies.

    Returns (mesh, rules, tp, tp_kv)."""
    import jax

    model = chips // data
    mesh_shape, kv_shard, model_b = choose_decode_layout(
        cfg, shape, chips=chips, data=data)
    mesh = jax.make_mesh(mesh_shape, ("data", "model_kv", "model_b"))
    rules = dict(SH.DEFAULT_RULES)
    rules.update({
        "batch": ("data", "model_b"),
        "kv_heads": "model_kv",
        # weight TP dims use model_kv ONLY: activations occupy model_b with
        # their batch dim, so (kv, b)-sharded weights would be re-gathered
        # every decode step (the §Perf log shows those gathers dominating
        # once the cache shrank).  model_b-replicated dense weights cost
        # ~0.1-0.4 GB/chip — traded for zero per-step weight collectives.
        "heads": "model_kv",
        "vocab": "model_kv",
        "mlp": "model_kv",
        # expert buffers carry no batch dim -> the expert dim can keep the
        # full 2-D shard (dispatch stays collective-free)
        "expert": ("model_kv", "model_b"),
        "embed": "data",
    })
    return mesh, rules, model, kv_shard


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh_desc: str
    kind: str
    runnable: bool
    skip_reason: str = ""
    microbatches: int = 1
    flops_per_device: float = 0.0
    memory_per_device_bytes: float = 0.0
    roofline: Optional[dict] = None
    memory_analysis: str = ""
    error: str = ""


def lower_cell(arch: str, shape_name: str, mesh, *,
               microbatches: int | None = None,
               fwd_kw: dict | None = None, compile_: bool = True,
               layout: str = "default", cache_quant: bool = False):
    """Lower (and compile) one cell.  Returns (lowered, compiled, meta).

    layout="decode_opt": ignore ``mesh`` and build the (data, model_kv,
    model_b) decode layout (decode cells only).  cache_quant: int8 KV."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_runnable(cfg, shape)
    if not ok:
        raise ValueError(f"cell skipped: {why}")
    if layout == "decode_opt":
        assert shape.kind == "decode"
        chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        mesh, rules, tp, tp_kv = decode_opt_layout(cfg, shape, chips=chips)
        model = build(cfg, tp=tp, tp_kv=tp_kv, cache_quant=cache_quant)
    else:
        tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
        model = build(cfg, tp=tp, cache_quant=cache_quant)
        rules = _rules_for(mesh, shape.global_batch)
    fwd_kw = dict(fwd_kw or {})

    if shape.kind == "train":
        mb = microbatches or pick_microbatches(cfg, shape, mesh)
        state_sds = jax.eval_shape(
            lambda: init_train_state(model, jax.random.key(0)))
        axes = train_state_axes(model)
        state_sh = SH.sharding_tree(axes, mesh, rules)
        batch_sh = _batch_shardings(cfg, shape, mesh, rules)
        batch_sds = batch_specs(cfg, shape)
        step = make_train_step(model, AdamWConfig(), microbatches=mb,
                               fwd_kw=fwd_kw)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        with mesh, runtime.mesh_rules(mesh, rules):
            lowered = jitted.lower(state_sds, batch_sds)
            counts = FL.count(step, state_sds, batch_sds)
        meta = {"microbatches": mb, "counts": counts}
    elif shape.kind == "prefill":
        paxes = model.param_axes()
        psh = SH.sharding_tree(paxes, mesh, rules)
        psds = jax.tree.map(lambda p: p.value,
                            jax.eval_shape(model.init, jax.random.key(0)),
                            is_leaf=lambda x: hasattr(x, "axes"))
        batch_sh = _batch_shardings(cfg, shape, mesh, rules)
        batch_sds = batch_specs(cfg, shape)
        saxes = model.decode_state_axes()
        ssh = SH.sharding_tree(saxes, mesh, rules)

        def prefill_step(params, batch):
            state = model.init_decode_state(shape.global_batch, shape.seq_len)
            logits, new_state = model.prefill(params, batch, state, **fwd_kw)
            return logits, new_state

        jitted = jax.jit(prefill_step, in_shardings=(psh, batch_sh),
                         out_shardings=(None, ssh))
        with mesh, runtime.mesh_rules(mesh, rules):
            lowered = jitted.lower(psds, batch_sds)
            counts = FL.count(prefill_step, psds, batch_sds)
        meta = {"counts": counts}
    else:  # decode
        from repro.serve.engine import make_serve_step

        paxes = model.param_axes()
        psh = SH.sharding_tree(paxes, mesh, rules)
        psds = jax.tree.map(lambda p: p.value,
                            jax.eval_shape(model.init, jax.random.key(0)),
                            is_leaf=lambda x: hasattr(x, "axes"))
        saxes = model.decode_state_axes()
        ssh = SH.sharding_tree(saxes, mesh, rules)
        ssds = jax.eval_shape(
            lambda: model.init_decode_state(shape.global_batch, shape.seq_len))
        tok_sh = NamedSharding(mesh, SH.resolve(("batch",), mesh, rules))
        tok_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        rng_sds = jax.eval_shape(lambda: jax.random.key(0))
        step = make_serve_step(model, mesh, k=8, rules=rules)
        jitted = jax.jit(step, in_shardings=(psh, ssh, tok_sh, None),
                         out_shardings=(tok_sh, ssh), donate_argnums=(1,))
        with mesh, runtime.mesh_rules(mesh, rules):
            lowered = jitted.lower(psds, ssds, tok_sds, rng_sds)
            counts = FL.count(step, psds, ssds, tok_sds, rng_sds)
        meta = {"counts": counts}

    compiled = lowered.compile() if compile_ else None
    return lowered, compiled, meta


def run_cell(arch: str, shape_name: str, mesh, mesh_desc: str,
             **kw) -> CellResult:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_runnable(cfg, shape)
    res = CellResult(arch=arch, shape=shape_name, mesh_desc=mesh_desc,
                     kind=shape.kind, runnable=ok, skip_reason=why)
    if not ok:
        return res
    try:
        lowered, compiled, meta = lower_cell(arch, shape_name, mesh, **kw)
        res.microbatches = meta.get("microbatches", 1)
        chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
        mf = RL.model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
        roof = RL.analyze(compiled, chips, model_flops_global=mf,
                          counts=meta.get("counts"))
        res.roofline = roof.to_dict()
        res.flops_per_device = roof.flops_per_device
        try:
            ma = compiled.memory_analysis()
            res.memory_analysis = str(ma)
            for attr in ("temp_size_in_bytes",):
                if hasattr(ma, attr):
                    res.memory_per_device_bytes = float(getattr(ma, attr))
        except Exception as e:  # noqa: BLE001 — backend-dependent
            res.memory_analysis = f"unavailable: {e}"
    except Exception as e:  # noqa: BLE001 — recorded, sweep continues
        res.error = f"{type(e).__name__}: {e}"
    return res
