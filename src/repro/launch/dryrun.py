import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run entrypoint (the ONLY place that asks for 512 placeholder
devices — smoke tests and benches see the real device count).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod/--single-pod]

Per cell: jit(step).lower(input_specs).compile() on the production mesh,
print memory_analysis() + cost_analysis(), dump the roofline terms as JSON
under experiments/dryrun/.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", type=str, default=None)
    p.add_argument("--shape", type=str, default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--out", type=str, default="experiments/dryrun")
    p.add_argument("--microbatches", type=int, default=None)
    args = p.parse_args(argv)

    from repro.configs.registry import ARCHS, SHAPES
    from repro.launch.cells import run_cell
    from repro.launch.mesh import make_production_mesh

    meshes = []
    if args.both_meshes:
        meshes = [(False, "pod16x16"), (True, "multipod2x16x16")]
    else:
        meshes = [(args.multi_pod,
                   "multipod2x16x16" if args.multi_pod else "pod16x16")]

    cells = []
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for multi_pod, desc in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            for shape in shapes:
                t0 = time.monotonic()
                res = run_cell(arch, shape, mesh, desc,
                               microbatches=args.microbatches)
                dt = time.monotonic() - t0
                tag = f"{arch}:{shape}:{desc}"
                if not res.runnable:
                    print(f"SKIP {tag}  ({res.skip_reason})")
                elif res.error:
                    failures += 1
                    print(f"FAIL {tag}  {res.error}")
                else:
                    r = res.roofline
                    print(f"OK   {tag}  [{dt:.0f}s]  "
                          f"compute {r['compute_s']*1e3:.2f}ms  "
                          f"memory {r['memory_s']*1e3:.2f}ms  "
                          f"collective {r['collective_s']*1e3:.2f}ms  "
                          f"dominant={r['dominant']}  "
                          f"roofline_frac={r['roofline_fraction']:.3f}")
                    print(f"     memory_analysis: {res.memory_analysis[:300]}")
                cells.append(dataclasses.asdict(res))
                fname = os.path.join(
                    args.out, f"{arch}__{shape}__{desc}.json".replace("/", "_"))
                with open(fname, "w") as f:
                    json.dump(dataclasses.asdict(res), f, indent=1)
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(cells, f, indent=1)
    print(f"\n{len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
