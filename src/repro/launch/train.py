"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 200 --mesh 2x2 --batch 8 --seq 128

Production invocation uses --mesh 16x16 (or 2x16x16 on two pods); the CI/
example path uses the smoke configs on host devices.  Checkpoint/restart:
re-running with the same --ckpt dir resumes from the latest atomic step.
"""
from __future__ import annotations

import argparse
import sys


def parse_mesh(spec: str):
    import jax

    dims = tuple(int(x) for x in spec.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="reduced config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--mesh", type=str, default="1")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt", type=str, default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    from repro.configs import get_arch
    from repro.data.synthetic import SyntheticLM
    from repro.models.model import build
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    mesh = parse_mesh(args.mesh)
    cfg = get_arch(args.arch, smoke=args.smoke)
    tp = mesh.shape.get("model", 1)
    model = build(cfg, tp=tp)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed)
    trainer = Trainer(
        model, data, mesh,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps),
        TrainerConfig(steps=args.steps, checkpoint_dir=args.ckpt,
                      checkpoint_every=args.ckpt_every,
                      microbatches=args.microbatches, seed=args.seed),
    )
    state, history = trainer.run()
    print(f"final loss {history[-1]['loss']:.4f} after {len(history)} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
