import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Optimized-variant sweep: every train/prefill cell with the §Perf
optimizations on (flash attention for attention archs; tuned bf16 SSD for
mamba2), decode cells with the decode_opt layout + int8 cache.  Writes
experiments/dryrun_opt/ — the 'optimized' column of EXPERIMENTS.md §Perf."""
import dataclasses
import json
import sys
import time


def main():
    from repro.configs.registry import ARCHS, SHAPES, get_arch, cell_runnable
    from repro.launch.cells import run_cell
    from repro.launch.mesh import make_production_mesh

    out = "experiments/dryrun_opt"
    os.makedirs(out, exist_ok=True)
    mesh = make_production_mesh()
    cells = []
    for arch in ARCHS:
        cfg = get_arch(arch)
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            sh = SHAPES[shape]
            if not cell_runnable(cfg, sh)[0]:
                continue
            kw = {}
            if sh.kind in ("train", "prefill"):
                if cfg.family == "ssm":
                    kw = dict(fwd_kw={"ssm_chunk": 128, "ssm_bf16": True})
                else:
                    kw = dict(fwd_kw={"attn_impl": "flash"})
            else:
                if cfg.family in ("dense", "moe", "vlm"):
                    kw = dict(layout="decode_opt", cache_quant=True)
                else:
                    continue  # ssm/hybrid/encdec decode already state-bound
            t0 = time.monotonic()
            res = run_cell(arch, shape, mesh, "opt_pod256", **kw)
            dt = time.monotonic() - t0
            tag = f"{arch}:{shape}"
            if res.error:
                print(f"FAIL {tag} {res.error[:160]}", flush=True)
            else:
                r = res.roofline
                print(f"OK   {tag} [{dt:.0f}s] dom={r['dominant']} "
                      f"frac={r['roofline_fraction']:.4f} "
                      f"(c {r['compute_s']*1e3:.1f} m {r['memory_s']*1e3:.1f} "
                      f"x {r['collective_s']*1e3:.1f} ms)", flush=True)
            with open(os.path.join(out, f"{arch}__{shape}__opt.json"), "w") as f:
                json.dump(dataclasses.asdict(res), f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
