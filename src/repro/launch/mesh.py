"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Meshes:
  single pod : (16, 16)    axes (data, model)   = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16) axes (pod, data, model) = 512 chips

The OLAP engine views the same devices as a flat P-way "nodes" axis (the
paper's shared-nothing cluster); `olap_cluster` builds that view.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False, devices=None):
    """Scaled-down mesh for CI (8 host devices): (2,2,2) or (4,2)."""
    devices = devices if devices is not None else jax.devices()[:8]
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, devices=devices)


def olap_cluster(devices=None):
    """The paper's P-node shared-nothing view: a 1-D 'nodes' mesh over the
    same chips the LM meshes use."""
    from repro.core import Cluster

    return Cluster(devices=devices)


def hardware_constants():
    """TPU v5e targets used by the roofline (per chip)."""
    return {
        "peak_flops_bf16": 197e12,   # FLOP/s
        "hbm_bandwidth": 819e9,      # B/s
        "ici_link_bandwidth": 50e9,  # B/s per link
        "hbm_bytes": 16 * 2**30,
    }
