"""OLAP serving launcher: load a TPC-H instance onto the cluster and serve
queries interactively or as a batch (the paper's evaluation driver).

  PYTHONPATH=src python -m repro.launch.serve_olap --sf 0.05 \
      --queries q1 q3 q15_approx --repeat 3
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.05)
    p.add_argument("--queries", nargs="*", default=None)
    p.add_argument("--repeat", type=int, default=3)
    p.add_argument("--backend", choices=["xla", "one_factor"], default="xla")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    import jax
    import numpy as np

    from repro.core.plans import PLANS
    from repro.tpch.driver import TPCHDriver

    d = TPCHDriver(sf=args.sf, seed=args.seed, backend=args.backend)
    names = args.queries or list(PLANS)
    print(f"cluster: {d.cluster.num_nodes} nodes | SF {args.sf} | "
          f"backend {args.backend}")
    print(f"{'query':>14s} {'compile[s]':>10s} {'run[ms]':>9s}")
    for name in names:
        t0 = time.monotonic()
        fn = d.compile(name)
        compile_s = time.monotonic() - t0
        cols = {n: t.columns for n, t in d.placed.items()}
        out = fn(cols)  # warmup (first execute)
        jax.block_until_ready(out)
        times = []
        for _ in range(args.repeat):
            t0 = time.monotonic()
            out = fn(cols)
            jax.block_until_ready(out)
            times.append(time.monotonic() - t0)
        print(f"{name:>14s} {compile_s:10.2f} {min(times)*1e3:9.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
