"""OLAP serving launcher: load a TPC-H instance onto the cluster and serve
queries interactively or as a batch (the paper's evaluation driver).

  PYTHONPATH=src python -m repro.launch.serve_olap --sf 0.05 \
      --queries q1 q3 q15_approx --repeat 3

--cubes enables two-tier serving: the Tier-1 rollup cubes are materialized
up front (one distributed scan each) and every cube-covered serving query
is reported with both its Tier-1 (rollup slice) and Tier-2 (precompiled
plan) latency, now with p99 tails next to the trimmed-median centers.

--serve runs the continuous-batching engine (``repro.serve.olap_engine``)
under a concurrent load generator: cubes are built, a mixed
Tier-1/Tier-2/parameterized request stream is generated
(``repro.serve.workload``), and the report shows per-class p50/p99
latency, sustained q/s, and the engine's batching stats.  ``--clients N``
picks a closed loop (N clients back-to-back); ``--rate QPS`` an open loop
(Poisson arrivals).

--metrics dumps the driver's metrics registry (tier counters, plan-cache
hit/miss, latency histograms) on exit; --trace PATH writes the structured
trace as Chrome-trace JSON loadable in https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# serve on the standard 8-node host cluster unless the caller pinned a mesh
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def _lint(d) -> int:
    """--lint: statically verify every registry IR query, parameterized
    TPC-H form, and cube serving preset against the generated catalog
    (``repro.query.verify``); nothing is compiled or executed.  Exit
    nonzero on any error or warning — info advisories are allowed (CI
    gates on this)."""
    from repro.core.plans import REGISTRY
    from repro.query.ir import QueryError
    from repro.tpch import queries as tq

    targets = [(name, qd.ir) for name, qd in REGISTRY.items()
               if qd.ir is not None]
    targets += [(f"{name}_param", make()) for name, make
                in tq.PARAM_QUERIES.items()]
    targets += [(name, make()) for name, make in tq.SERVING_QUERIES.items()]
    failed = 0
    for label, q in targets:
        try:
            rep = d.check(q)
        except QueryError as e:
            print(f"{label:>22s}  ERROR  verify failed: {e}")
            failed += 1
            continue
        status = "clean" if rep.clean else ("WARN" if rep.ok else "FAIL")
        print(f"{label:>22s}  {status}")
        for x in rep.diagnostics:
            print(f"{'':>24s}{x.format()}")
        if not rep.clean:
            failed += 1
    print(f"\n{len(targets)} plans verified, {failed} with errors/warnings")
    return 1 if failed else 0


def _speedup_str(tier2_s: float, tier1_s: float) -> str:
    """Tier-2/Tier-1 ratio for the --cubes table.  A trimmed-median Tier-1
    time can underflow to 0.0 on a fast box (perf_counter granularity vs a
    sub-microsecond rollup slice) — report ``inf`` instead of crashing the
    table, and ``--`` when BOTH are 0 (no information either way)."""
    if tier1_s <= 0.0:
        return f"{'--':>7s} " if tier2_s <= 0.0 else f"{'inf':>7s}x"
    return f"{tier2_s / tier1_s:7.0f}x"


def _serve_cubes(d, repeat: int):
    from repro.cube.serving import measure_query
    from repro.tpch import cubes as tpch_cubes

    t0 = time.monotonic()
    d.build_cubes()
    build_s = time.monotonic() - t0
    for name, cube in d.cubes.items():
        print(f"cube {name}: {cube.num_values} values from "
              f"{cube.rows_scanned} rows in {cube.build_seconds:.2f}s")
    print(f"tier-1 materialization total: {build_s:.2f}s\n")

    print(f"{'query':>22s} {'tier1[us]':>10s} {'p99[us]':>9s} "
          f"{'tier2[ms]':>10s} {'p99[ms]':>9s} {'speedup':>8s}  tier2 plan")
    for name, make_query in tpch_cubes.SERVING_QUERIES.items():
        q = make_query()
        m = measure_query(d, q, repeat=repeat)
        if m is None:
            print(f"{name:>22s} {'--':>10s} (not cube-covered; tier 2 only)")
            continue
        print(f"{name:>22s} {m['tier1_s']*1e6:10.1f} "
              f"{m['tier1_p99_s']*1e6:9.1f} {m['tier2_s']*1e3:10.2f} "
              f"{m['tier2_p99_s']*1e3:9.2f} "
              f"{_speedup_str(m['tier2_s'], m['tier1_s'])}  {m['plan']}")
    return 0


def _serve_engine(d, args):
    """--serve: drive the continuous-batching engine under concurrent
    load and report per-class latency, throughput, and batching stats."""
    import asyncio

    from repro.serve import workload as wl
    from repro.serve.olap_engine import OLAPEngine

    t0 = time.monotonic()
    d.build_cubes()
    print(f"tier-1 cubes built in {time.monotonic() - t0:.2f}s")
    items = wl.mixed_workload(d, args.requests, seed=args.seed)
    sizes = sorted({2 ** i for i in range(args.max_batch.bit_length())
                    if 2 ** i <= args.max_batch} | {args.max_batch})
    t0 = time.monotonic()
    wl.warm_workload(d, items, batch_sizes=sizes)
    n_kind = {k: sum(1 for i in items if i.kind == k)
              for k in ("tier1", "param", "tier2")}
    print(f"warmed {len({i.prep.shape_key for i in items})} shapes "
          f"(batch lanes {sizes}) in {time.monotonic() - t0:.2f}s")
    print(f"workload: {len(items)} requests "
          f"(tier1 {n_kind['tier1']} / param {n_kind['param']} / "
          f"tier2 {n_kind['tier2']}), "
          f"{'open loop @ %g q/s' % args.rate if args.rate else 'closed loop, %d clients' % args.clients}")

    async def go():
        engine = OLAPEngine(d, max_batch=args.max_batch,
                            max_wait_us=args.max_wait_us)
        async with engine:
            t0 = time.perf_counter()
            if args.rate:
                res = await wl.run_open_loop(engine, items,
                                             rate_qps=args.rate,
                                             seed=args.seed)
            else:
                res = await wl.run_closed_loop(engine, items,
                                               clients=args.clients)
            wall = time.perf_counter() - t0
        return res, wall, engine.stats()

    res, wall, stats = asyncio.run(go())
    rep = wl.summarize(res, wall)
    print(f"\n{'class':>8s} {'n':>6s} {'p50[ms]':>9s} {'p95[ms]':>9s} "
          f"{'p99[ms]':>9s} {'mean[ms]':>9s}")
    for kind, s in rep["kinds"].items():
        print(f"{kind:>8s} {s['n']:6d} {s['p50_ms']:9.2f} "
              f"{s['p95_ms']:9.2f} {s['p99_ms']:9.2f} {s['mean_ms']:9.2f}")
    bs = stats.get("serve.batch_size", {})
    print(f"\nsustained: {rep['qps']:.0f} q/s over {wall:.2f}s "
          f"({rep['failed']} failed)")
    print(f"batches: {stats['batches']} "
          f"({stats['coalesced_lanes']} coalesced lanes, "
          f"mean size {bs.get('mean', 0):.1f}, p95 {bs.get('p95', 0):.0f}); "
          f"tier1 inline {stats['tier1']}, solo {stats['solo']}, "
          f"rejected {stats['rejected']}")
    return 0


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--sf", type=float, default=0.05)
    p.add_argument("--queries", nargs="*", default=None)
    p.add_argument("--repeat", type=int, default=3)
    p.add_argument("--backend", choices=["xla", "one_factor"], default="xla")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--lint", action="store_true",
                   help="statically verify every registry IR query + cube "
                        "serving preset (repro.query.verify rule catalog: "
                        "docs/RULES.md); exit nonzero on errors/warnings")
    p.add_argument("--cubes", action="store_true",
                   help="two-tier mode: build rollup cubes, report tier-1 vs "
                        "tier-2 latency per serving query")
    p.add_argument("--serve", action="store_true",
                   help="continuous-batching mode: build cubes, run the "
                        "async serving engine under a concurrent "
                        "mixed-workload load generator")
    p.add_argument("--requests", type=int, default=256,
                   help="--serve: number of requests in the load run")
    p.add_argument("--clients", type=int, default=16,
                   help="--serve: closed-loop client count")
    p.add_argument("--rate", type=float, default=None,
                   help="--serve: open-loop Poisson arrival rate (q/s); "
                        "overrides --clients")
    p.add_argument("--max-batch", type=int, default=16,
                   help="--serve: continuous-batching lane cap")
    p.add_argument("--max-wait-us", type=float, default=2000.0,
                   help="--serve: batching window — a batch launches at "
                        "max-batch lanes or when its oldest request has "
                        "waited this long")
    p.add_argument("--metrics", action="store_true",
                   help="print the driver's metrics-registry report on exit")
    p.add_argument("--trace", metavar="PATH", default=None,
                   help="write the structured trace as Chrome-trace JSON "
                        "(loadable in Perfetto) on exit")
    args = p.parse_args(argv)

    import jax
    import numpy as np

    from repro.core.plans import PLANS
    from repro.tpch.driver import TPCHDriver

    # validate query names BEFORE paying for data generation + placement:
    # an unknown name used to surface as a bare KeyError from deep inside
    # the PLANS lookup after the driver was already built
    if args.queries:
        unknown = sorted(set(args.queries) - set(PLANS))
        if unknown:
            print(f"unknown query name(s): {', '.join(unknown)}",
                  file=sys.stderr)
            print(f"valid --queries names: {', '.join(sorted(PLANS))}",
                  file=sys.stderr)
            return 2

    d = TPCHDriver(sf=args.sf, seed=args.seed, backend=args.backend)
    try:
        if args.lint:
            print(f"cluster: {d.cluster.num_nodes} nodes | SF {args.sf} | "
                  f"static plan verify")
            return _lint(d)
        if args.serve:
            print(f"cluster: {d.cluster.num_nodes} nodes | SF {args.sf} | "
                  f"continuous-batching serving")
            return _serve_engine(d, args)
        if args.cubes:
            print(f"cluster: {d.cluster.num_nodes} nodes | SF {args.sf} | "
                  f"two-tier serving")
            if args.queries:
                print("note: --queries is ignored with --cubes (the fixed "
                      "tpch.cubes.SERVING_QUERIES set is measured)")
            return _serve_cubes(d, args.repeat)
        names = args.queries or list(PLANS)
        print(f"cluster: {d.cluster.num_nodes} nodes | SF {args.sf} | "
              f"backend {args.backend}")
        print(f"{'query':>14s} {'compile[s]':>10s} {'run[ms]':>9s}")
        run_hist = d.obs.metrics.histogram("serve.run_us")
        for name in names:
            with d.obs.span("serve", cat="serve", query=name) as sp:
                t0 = time.monotonic()
                fn = d.compile(name)
                compile_s = time.monotonic() - t0
                cols = {n: t.columns for n, t in d.placed.items()}
                with d.obs.span("warmup", cat="exec"):
                    jax.block_until_ready(fn(cols))  # first execute
                times = []
                for _ in range(args.repeat):
                    with d.obs.span("execute", cat="exec"):
                        t0 = time.monotonic()
                        jax.block_until_ready(fn(cols))
                        times.append(time.monotonic() - t0)
                    run_hist.record(times[-1] * 1e6)
                sp.set(compile_s=compile_s, best_ms=min(times) * 1e3)
            print(f"{name:>14s} {compile_s:10.2f} {min(times)*1e3:9.2f}")
        return 0
    finally:
        if args.metrics:
            print("\n" + d.obs.metrics.report())
        if args.trace:
            print(f"\ntrace written to {d.obs.save_chrome_trace(args.trace)}")


if __name__ == "__main__":
    sys.exit(main())
