"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):
  compute    = HLO_FLOPs / (chips * 197e12)        [bf16 peak]
  memory     = HLO_bytes / (chips * 819e9)         [HBM]
  collective = collective_bytes / (chips * 50e9)   [ICI]

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (the partitioned
module is per-device; we scale by chip count where a global number is
reported).  collective_bytes is parsed out of the optimized HLO text: the
sum of operand bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (per device, i.e. what one
chip injects into the ICI).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"([\w\-]+)\((.*)$"
)


def shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass(frozen=True)
class CollectiveInstr:
    """One collective instruction of the optimized module, in program
    order — the unit the observability layer attributes to a plan's
    exchanges (the i-th request semi-join owns a known, contiguous run of
    all-to-alls)."""

    name: str   # HLO instruction name
    kind: str   # base op: all-to-all / all-reduce / all-gather / ...
    bytes: int  # operand bytes (per device)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    count_by_op: dict
    # program-ordered instruction records; defaults to () so callers that
    # build CollectiveStats by hand (tests) stay valid
    instructions: tuple = ()

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    def by_kind(self) -> dict:
        """Labeled per-kind breakdown: ``{kind: {"bytes": b, "count": c}}``
        over every collective kind seen (all-to-all / all-reduce /
        all-gather / reduce-scatter / collective-permute)."""
        return {
            k: {"bytes": self.bytes_by_op[k], "count": self.count_by_op[k]}
            for k in sorted(self.bytes_by_op)
        }


_HLO_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in the (partitioned) module.

    Two passes: (1) instruction name -> result shape, (2) for collectives,
    add up their operands' shapes (operands referenced by name; start ops
    like all-reduce-start are counted, matching -done ops are not).
    Inline ``/*index=N*/`` comments are stripped first — wide tuple shapes
    (e.g. an 8-way decomposed all-to-all) embed them, and the '=' inside
    would otherwise stop the instruction regex from matching at all."""
    hlo_text = _HLO_COMMENT_RE.sub("", hlo_text)
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            name, shape = m.group(1), m.group(2)
            shapes[name] = shape
    bytes_by_op: dict[str, int] = {}
    count_by_op: dict[str, int] = {}
    instructions: list[CollectiveInstr] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_shape, op, rest = m.groups()
        base = None
        for c in COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None:
            continue
        # operand bytes: resolve %refs from the operand list
        operand_names = re.findall(r"%([\w.\-]+)", rest)
        obytes = 0
        for on in operand_names:
            if on in shapes:
                obytes += shape_bytes(shapes[on])
        if obytes == 0:
            # fallback: result bytes (all-reduce in == out; all-gather
            # overestimates by P/(P-1) which we accept)
            obytes = shape_bytes(result_shape)
        bytes_by_op[base] = bytes_by_op.get(base, 0) + obytes
        count_by_op[base] = count_by_op.get(base, 0) + 1
        instructions.append(CollectiveInstr(name=name, kind=base, bytes=obytes))
    return CollectiveStats(bytes_by_op, count_by_op, tuple(instructions))


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    peak_flops: float
    hbm_bw: float
    link_bw: float
    model_flops_global: float = 0.0
    collectives: Optional[CollectiveStats] = None
    xla_cost_flops: float = 0.0
    xla_cost_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / self.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips) — remat/padding waste shows up
        here as a ratio below ~0.33 (fwd+bwd+remat ~ 4/6 thirds useful)."""
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs MFU bound: model FLOPs / (chips x peak x bound time).
        This is the score-style number: how close the compiled program's
        bottleneck lets the chip get to peak on USEFUL work."""
        t = self.bound_s
        if t <= 0:
            return 0.0
        return self.model_flops_global / (self.chips * self.peak_flops * t)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_bytes_by_op": dict(
                self.collectives.bytes_by_op) if self.collectives else {},
            "collective_count_by_op": dict(
                self.collectives.count_by_op) if self.collectives else {},
            "xla_cost_flops": self.xla_cost_flops,
            "xla_cost_bytes": self.xla_cost_bytes,
        }


def analyze(compiled, chips: int, *, model_flops_global: float = 0.0,
            hw: dict | None = None, counts=None) -> Roofline:
    """counts: optional launch.flops.Counts from the GLOBAL jaxpr — used in
    preference to cost_analysis() (which counts scan bodies once, a ~1000x
    undercount on scanned-layer models; the raw numbers are still recorded
    in to_dict for reference)."""
    from repro.launch.mesh import hardware_constants

    hw = hw or hardware_constants()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older JAX: one dict per program
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    if xla_bytes == 0.0:
        xla_bytes = sum(float(v) for k, v in cost.items()
                        if k.startswith("bytes accessed"))
    if counts is not None:
        flops = counts.flops / chips
        hbm = counts.traffic / chips
    else:
        flops, hbm = xla_flops, xla_bytes
    text = compiled.as_text()
    coll = parse_collective_bytes(text)
    r = Roofline(
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        collective_bytes_per_device=float(coll.total_bytes),
        chips=chips,
        peak_flops=hw["peak_flops_bf16"],
        hbm_bw=hw["hbm_bandwidth"],
        link_bw=hw["ici_link_bandwidth"],
        model_flops_global=model_flops_global,
        collectives=coll,
    )
    r.xla_cost_flops = xla_flops  # raw reference values
    r.xla_cost_bytes = xla_bytes
    return r


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """MODEL_FLOPS per the brief: 6·N·D (train) / 2·N_active·D (inference),
    with N the NON-EMBEDDING active params (lookups are gathers, not
    matmuls) plus the LM-head term charged for the positions that actually
    compute logits: every position at train, the last position at prefill,
    the single token at decode.  Enc-dec charges each stack for its own
    sequence length."""
    n = (cfg.active_params(include_embeddings=False) if cfg.family == "moe"
         else cfg.num_params(include_embeddings=False))
    head = cfg.vocab_size * cfg.d_model  # logits matmul params
    B, S = global_batch, seq_len
    if cfg.family == "encdec":
        # split the per-layer count between stacks by their share
        e = cfg.encdec
        dec_frac = cfg.n_layers * 2.2 / (cfg.n_layers * 2.2 + e.n_enc_layers)
        n_dec, n_enc = n * dec_frac, n * (1 - dec_frac)
        if shape_kind == "train":
            return 6.0 * (n_dec * S + n_enc * e.enc_seq + head * S) * B
        if shape_kind == "prefill":
            return 2.0 * (n_dec * S + n_enc * e.enc_seq + head) * B
        return 2.0 * (n_dec + head) * B
    if shape_kind == "train":
        return 6.0 * (n + head) * S * B
    if shape_kind == "prefill":
        return 2.0 * (n * S + head) * B
    return 2.0 * (n + head) * B  # decode: one token per sequence
