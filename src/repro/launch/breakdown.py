"""Traffic/FLOP breakdown by primitive and by op shape — the hillclimb's
profiling instrument (the CPU container's stand-in for a TPU profile).

Usage:
    from repro.launch.breakdown import breakdown
    rows = breakdown(step_fn, *args)     # list of (label, flops, bytes)
"""
from __future__ import annotations

import jax
import numpy as np

from repro.launch import flops as FL


def breakdown(fn, *args, top: int = 20):
    jaxpr = jax.make_jaxpr(fn)(*args)
    acc: dict[str, list] = {}

    def add(label, f, t, scale):
        e = acc.setdefault(label, [0.0, 0.0, 0])
        e[0] += f * scale
        e[1] += t * scale
        e[2] += scale

    def walk(j, scale=1.0):
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name == "dot_general":
                f = FL._dot_flops(eqn)
                t = sum(FL._bytes(v.aval) for v in
                        list(eqn.invars) + list(eqn.outvars))
                shapes = "x".join(str(tuple(v.aval.shape)) for v in eqn.invars)
                add(f"dot {shapes}", f, t, scale)
            elif name == "conv_general_dilated":
                add("conv", FL._conv_flops(eqn),
                    sum(FL._bytes(v.aval) for v in
                        list(eqn.invars) + list(eqn.outvars)), scale)
            elif name == "scan":
                walk(eqn.params["jaxpr"].jaxpr, scale * eqn.params["length"])
                L = eqn.params["length"]
                nc, ncar = eqn.params["num_consts"], eqn.params["num_carry"]
                per = sum(FL._bytes(v.aval) // max(L, 1)
                          for v in eqn.invars[nc + ncar:])
                per += sum(FL._bytes(v.aval) // max(L, 1)
                           for v in eqn.outvars[ncar:])
                add("scan_io", 0.0, per * L, scale)
            elif name == "pallas_call":
                ce = eqn.params.get("cost_estimate")
                if ce is not None:
                    add(f"pallas:{eqn.params.get('name')}",
                        float(ce.flops), float(ce.bytes_accessed), scale)
            elif name in ("pjit", "jit", "closed_call", "custom_jvp_call",
                          "custom_vjp_call", "remat2", "remat", "checkpoint",
                          "custom_lin", "shard_map", "custom_vjp_call_jaxpr"):
                inner = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                         or eqn.params.get("fun_jaxpr"))
                if inner is not None:
                    walk(inner.jaxpr if hasattr(inner, "jaxpr") else inner,
                         scale)
            elif name == "gather":
                t = _g = FL._bytes(eqn.outvars[0].aval) + sum(
                    FL._bytes(v.aval) for v in eqn.invars[1:])
                add(f"gather {tuple(eqn.outvars[0].aval.shape)}", 0.0, t, scale)
            elif name == "dynamic_slice":
                add("dynamic_slice", 0.0, FL._bytes(eqn.outvars[0].aval), scale)
            elif name == "dynamic_update_slice":
                add("dynamic_update_slice", 0.0,
                    2 * FL._bytes(eqn.invars[1].aval), scale)
            elif name in ("scatter", "scatter-add", "scatter_add"):
                add("scatter", 0.0, 3 * FL._bytes(eqn.invars[-1].aval), scale)
            elif name in FL.HEAVY:
                add(name, 0.0, sum(FL._bytes(v.aval) for v in
                                   list(eqn.invars) + list(eqn.outvars)), scale)

    walk(jaxpr.jaxpr)
    inputs = sum(FL._bytes(v.aval) for v in jaxpr.jaxpr.invars)
    acc["(program inputs)"] = [0.0, float(inputs), 1]
    rows = sorted(
        [(k, v[0], v[1], v[2]) for k, v in acc.items()], key=lambda r: -r[2]
    )[:top]
    return rows


def print_breakdown(fn, *args, top: int = 20, chips: int = 1):
    rows = breakdown(fn, *args, top=top)
    print(f"{'label':58s} {'GFLOP/chip':>11s} {'GB/chip':>9s} {'count':>7s}")
    for label, f, t, n in rows:
        print(f"{label[:58]:58s} {f/1e9/chips:11.2f} {t/1e9/chips:9.3f} {n:7.0f}")
    return rows
