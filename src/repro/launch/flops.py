"""Jaxpr-based FLOP / HBM-traffic counting for the roofline.

XLA's ``compiled.cost_analysis()`` counts a ``while``/``scan`` BODY once —
a 60-layer scanned transformer at 32 microbatches is undercounted ~2000x.
This walker multiplies through scan trip counts and recurses into call
primitives, giving:

- flops: 2*M*N*K for every dot_general (+conv, counted as dots), the
  dominant term on an MXU machine;
- hbm_traffic: a fusion-aware estimate — operand+result bytes of HEAVY ops
  only (dot/conv/gather/scatter/dynamic-update/reduce/sort/scan carries),
  on the model that XLA fuses elementwise chains into their consumers so
  only heavy-op boundaries hit HBM.  Documented as a first-order model in
  EXPERIMENTS.md; the collective term comes from the partitioned HLO
  instead (launch/roofline.py).

Counts are GLOBAL (the unpartitioned program); callers divide by chip count
— which assumes even sharding and no GSPMD-introduced redundant compute
(padding waste IS included because padded shapes are in the jaxpr).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import numpy as np
from jax import core


def _bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 — abstract tokens etc.
        return 0


HEAVY = {
    "sort", "reduce_sum", "reduce_max", "reduce_min", "argmax", "argmin",
    "cumsum", "cumlogsumexp", "top_k", "rev",
}

# ops whose HBM traffic is NOT their full operand set:
#   gather reads only the gathered rows (+indices), not the whole table;
#   scatter/dus does a read-modify-write of the touched region only.
SPARSE_ACCESS = {"gather", "scatter", "scatter-add", "scatter_add",
                 "dynamic_update_slice", "dynamic_slice"}


@dataclasses.dataclass
class Counts:
    flops: float = 0.0
    traffic: float = 0.0

    def __iadd__(self, o):
        self.flops += o.flops
        self.traffic += o.traffic
        return self

    def scaled(self, k: float) -> "Counts":
        return Counts(self.flops * k, self.traffic * k)


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    m = int(np.prod([d for i, d in enumerate(a.shape)
                     if i not in lc and i not in lb]))
    k = int(np.prod([a.shape[i] for i in lc]))
    batch = int(np.prod([a.shape[i] for i in lb])) if lb else 1
    n = int(np.prod([d for i, d in enumerate(b.shape)
                     if i not in rc and i not in rb]))
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel spatial * in_channels)
    kernel = int(np.prod(rhs.shape[:-1]))
    return 2.0 * int(np.prod(out.shape)) * kernel


def count_jaxpr(jaxpr) -> Counts:
    c = Counts()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            c.flops += _dot_flops(eqn)
            c.traffic += sum(_bytes(v.aval) for v in eqn.invars)
            c.traffic += sum(_bytes(v.aval) for v in eqn.outvars)
        elif name == "conv_general_dilated":
            c.flops += _conv_flops(eqn)
            c.traffic += sum(_bytes(v.aval) for v in eqn.invars)
            c.traffic += sum(_bytes(v.aval) for v in eqn.outvars)
        elif name == "scan":
            body = count_jaxpr(eqn.params["jaxpr"].jaxpr)
            length = eqn.params["length"]
            c += body.scaled(length)
            # xs slices are views consumed by inner ops (counted at their
            # use); ys stacking is the inner producers' writes (counted at
            # the producer).  Counting them here double-counted the KV cache
            # and layer params once per step — see EXPERIMENTS.md §Perf
            # (instrument-fix iteration).
        elif name == "while":
            # bounded loops only appear in OLAP plans; use 1 iteration as the
            # conservative floor (documented)
            c += count_jaxpr(eqn.params["body_jaxpr"].jaxpr)
        elif name in ("pjit", "jit", "closed_call", "core_call", "remat_call",
                      "xla_call", "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "checkpoint", "remat",
                      "remat2", "custom_lin", "shard_map"):
            inner = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                c += count_jaxpr(ij)
        elif name == "cond":
            branches = eqn.params.get("branches", ())
            if branches:
                sub = [count_jaxpr(b.jaxpr) for b in branches]
                c += max(sub, key=lambda s: s.flops)
        elif name == "pallas_call":
            ce = eqn.params.get("cost_estimate")
            if ce is not None:
                # kernel-author-declared cost (flash attention kernels):
                # bytes_accessed is the HBM traffic, VMEM tiles excluded
                c.flops += float(ce.flops)
                c.traffic += float(ce.bytes_accessed)
            else:
                inner = eqn.params.get("jaxpr")
                gm = eqn.params.get("grid_mapping")
                grid = int(np.prod(getattr(gm, "grid", (1,)) or (1,)))
                if inner is not None:
                    ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                    c += count_jaxpr(ij).scaled(grid)
        elif name in SPARSE_ACCESS:
            if name == "gather":
                # output rows + indices
                c.traffic += _bytes(eqn.outvars[0].aval)
                c.traffic += sum(_bytes(v.aval) for v in eqn.invars[1:])
            elif name in ("dynamic_slice",):
                c.traffic += _bytes(eqn.outvars[0].aval)
            elif name == "dynamic_update_slice":
                # write the update region (aliased buffer elsewhere)
                c.traffic += 2 * _bytes(eqn.invars[1].aval)
            else:  # scatter*: RMW of touched region ~ 2x updates + indices
                upd = eqn.invars[-1].aval
                c.traffic += 3 * _bytes(upd)
        elif name in HEAVY:
            c.traffic += sum(_bytes(v.aval) for v in eqn.invars)
            c.traffic += sum(_bytes(v.aval) for v in eqn.outvars)
        elif name in ("all_to_all", "ppermute", "all_gather", "psum",
                      "reduce_scatter"):
            c.traffic += sum(_bytes(v.aval) for v in eqn.outvars)
    return c


def count(fn, *args, **kw) -> Counts:
    """Program-input bytes are NOT added here: heavy ops count their operand
    reads at each use site (a param read by a dot is counted by the dot),
    so adding inputs again double-counts — fused elementwise-only consumers
    are the (small) undercounted remainder."""
    jaxpr = jax.make_jaxpr(fn, **kw)(*args)
    return count_jaxpr(jaxpr.jaxpr)
