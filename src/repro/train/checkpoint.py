"""Mesh-agnostic, atomic checkpointing (fault-tolerance substrate).

Design for thousands of nodes:
- arrays are saved per LOGICAL leaf (full logical value assembled via
  process-local addressable shards here; on a real multi-host deployment
  each host writes only its addressable shards and the manifest records the
  global shape + logical axes) — restore re-shards onto WHATEVER mesh the
  restarted job has (elastic re-mesh: lose a pod, restart on one pod),
- two-phase atomic commit: write to `step_XXXX.tmp/`, fsync, rename —
  a crash mid-save never corrupts the latest checkpoint,
- manifest carries (step, data offset, rng seed) so the data pipeline
  resumes exactly (deterministic sharded generator, see data/synthetic.py),
- saves run on a background thread (snapshot to host, then async write) so
  the step loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, state, step: int, *, data_state: dict | None = None,
         blocking: bool = True):
    """Two-phase atomic save of a pytree of jax/np arrays."""
    os.makedirs(path, exist_ok=True)
    tmp = os.path.join(path, f"step_{step:08d}.tmp")
    final = os.path.join(path, f"step_{step:08d}")
    # snapshot to host memory synchronously (cheap vs the device step),
    # then write (optionally) in the background
    leaves, treedef = _flatten(state)
    host_leaves = [np.asarray(x) for x in leaves]

    def _write():
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        manifest = {
            "step": step,
            "num_leaves": len(host_leaves),
            "data_state": data_state or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        _gc(path, keep=3)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t


def _gc(path: str, keep: int):
    steps = sorted(
        d for d in os.listdir(path)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [
        int(d.split("_")[1]) for d in os.listdir(path)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(path: str, like, step: int | None = None, *, shardings=None):
    """Restore a pytree; re-shard onto `shardings` (possibly for a DIFFERENT
    mesh than the one that saved — the elastic-restart path).

    like: a pytree with the right treedef (e.g. from eval_shape).
    Returns (state, step, data_state).
    """
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = _flatten(like)
    assert manifest["num_leaves"] == len(leaves), (
        f"checkpoint has {manifest['num_leaves']} leaves, model expects "
        f"{len(leaves)} — architecture mismatch"
    )
    host = [arrays[f"leaf_{i}"] for i in range(len(leaves))]
    for h, l in zip(host, leaves):
        assert h.shape == tuple(l.shape), f"shape mismatch {h.shape} vs {l.shape}"
    if shardings is not None:
        shard_leaves = treedef.flatten_up_to(shardings)
        out = [jax.device_put(h, s) for h, s in zip(host, shard_leaves)]
    else:
        out = host
    return treedef.unflatten(out), step, manifest["data_state"]
