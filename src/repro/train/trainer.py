"""The training loop: jitted step + checkpoint/restart + heartbeat +
straggler hooks.  This is the piece `launch/train.py` drives.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import sharding as SH
from repro.optim.adamw import AdamWConfig
from repro.train import checkpoint as ckpt
from repro.train.elastic import Heartbeat, StragglerMonitor
from repro.train.train_step import (TrainState, init_train_state,
                                    make_train_step, train_state_axes)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    microbatches: int = 1
    seed: int = 0


class Trainer:
    def __init__(self, model, data, mesh, opt_cfg: AdamWConfig,
                 tc: TrainerConfig):
        self.model = model
        self.data = data
        self.mesh = mesh
        self.opt_cfg = opt_cfg
        self.tc = tc
        self.heartbeat = Heartbeat()
        self.stragglers = StragglerMonitor()

        axes = train_state_axes(model)
        self.state_shardings = SH.sharding_tree(axes, mesh)
        self.batch_sharding = {
            "tokens": NamedSharding(mesh, SH.resolve(("batch", "seq"), mesh)),
            "labels": NamedSharding(mesh, SH.resolve(("batch", "seq"), mesh)),
        }
        step_fn = make_train_step(model, opt_cfg, microbatches=tc.microbatches)
        self.step_fn = jax.jit(
            step_fn,
            in_shardings=(self.state_shardings, self.batch_sharding),
            out_shardings=(self.state_shardings, None),
            donate_argnums=(0,),
        )

    def init_or_restore(self) -> tuple[TrainState, int]:
        tc = self.tc
        if tc.checkpoint_dir and ckpt.latest_step(tc.checkpoint_dir) is not None:
            like = jax.eval_shape(
                lambda: init_train_state(self.model, jax.random.key(tc.seed))
            )
            state, step, _ = ckpt.restore(
                tc.checkpoint_dir, like, shardings=self.state_shardings
            )
            return state, step
        with jax.default_device(jax.devices()[0]):
            state = init_train_state(self.model, jax.random.key(tc.seed))
        state = jax.device_put(state, self.state_shardings)
        return state, 0

    def run(self, state=None, start_step: int = 0):
        tc = self.tc
        if state is None:
            state, start_step = self.init_or_restore()
        history = []
        pending_save = None
        for step in range(start_step, tc.steps):
            batch = self.data.device_batch(step)
            batch = jax.device_put(batch, self.batch_sharding)
            t0 = time.monotonic()
            state, metrics = self.step_fn(state, batch)
            metrics = jax.tree.map(float, jax.device_get(metrics))
            dt = time.monotonic() - t0
            self.heartbeat.beat()
            self.stragglers.record(0, dt)
            history.append({"step": step + 1, "sec": dt, **metrics})
            if (step + 1) % tc.log_every == 0:
                print(f"step {step+1:5d}  loss {metrics['loss']:.4f}  "
                      f"gnorm {metrics['grad_norm']:.3f}  {dt*1e3:.0f} ms")
            if tc.checkpoint_dir and (step + 1) % tc.checkpoint_every == 0:
                if pending_save is not None:
                    pending_save.join()
                pending_save = ckpt.save(
                    tc.checkpoint_dir, state, step + 1,
                    data_state={"seed": self.data.seed, "next_step": step + 1},
                    blocking=False,
                )
        if pending_save is not None:
            pending_save.join()
        return state, history
