"""The jitted train step: loss -> grad -> AdamW, with optional microbatch
accumulation (lax.scan over microbatches, f32 accumulator) and optional
int8+error-feedback gradient compression on the cross-pod reduction.

Distribution is GSPMD: the caller jits this with in_shardings derived from
the logical-axes trees (models/sharding.py); XLA inserts the FSDP
all-gathers, TP reductions and DP gradient psums.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: object
    opt: AdamWState


def init_train_state(model, rng) -> TrainState:
    from repro.models.params import values

    params = values(model.init(rng))
    return TrainState(params=params, opt=adamw_init(params))


def train_state_axes(model):
    """Logical-axes tree for the whole TrainState (opt state mirrors
    params; scalars replicated)."""
    from repro.models.params import logical_axes

    paxes = model.param_axes()
    return TrainState(
        params=paxes,
        opt=AdamWState(step=(), mu=paxes, nu=paxes),
    )


def _split_microbatches(batch, n: int):
    return jax.tree.map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch
    )


def make_train_step(model, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    fwd_kw: dict | None = None):
    fwd_kw = dict(fwd_kw or {})

    def loss_fn(params, mb):
        return model.loss(params, mb, **fwd_kw)

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )

            def acc_body(carry, mb):
                tot_loss, acc = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), acc, g
                )
                return (tot_loss + l, acc), None

            (loss, grads), _ = lax.scan(acc_body, (jnp.float32(0), zero), mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_opt, metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg
        )
        metrics["loss"] = loss
        return TrainState(new_params, new_opt), metrics

    return train_step
