from repro.train.train_step import TrainState, make_train_step, train_state_axes  # noqa: F401
