"""Elastic scaling + straggler mitigation policy (the paper's 'Future
Work: fault tolerance ... introduce some redundancy without excessive
cost', built into this framework as first-class machinery).

The mechanism rests on three properties the substrates already have:
1. deterministic sharded data (data/synthetic.py, tpch/dbgen): shard i of
   step t is a pure function of (seed, t, i) — any node can regenerate any
   shard, so a replacement node needs NO state transfer beyond the
   checkpoint,
2. mesh-agnostic checkpoints (train/checkpoint.py): saved per logical
   leaf, restorable onto any mesh whose axes divide the shapes,
3. jit re-lowering: the train step recompiles for the new mesh (the cost
   is one compile, ~minutes, amortized over hours of training).

`plan_restart` chooses the largest valid mesh from the surviving device
count; `StragglerMonitor` implements step-time-based detection: a node
whose step time exceeds `threshold x median` over a window is flagged for
eviction (on TPU pods the symptom is usually host-side input stalls —
which deterministic on-device data generation already minimizes).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axes: tuple
    devices_used: int


def plan_restart(num_devices: int, *, model_parallel: int = 16,
                 want_pods: int | None = None) -> MeshPlan:
    """Largest (pod, data, model) mesh embeddable in the surviving devices.
    model parallelism is pinned (param shards must divide); data/pod axes
    absorb the loss — e.g. 512 -> 496 survivors restarts as (1, 31, 16)."""
    assert num_devices >= model_parallel, "fewer devices than model shards"
    rows = num_devices // model_parallel
    if want_pods and rows % want_pods == 0 and rows // want_pods > 0:
        return MeshPlan((want_pods, rows // want_pods, model_parallel),
                        ("pod", "data", "model"),
                        want_pods * (rows // want_pods) * model_parallel)
    return MeshPlan((rows, model_parallel), ("data", "model"),
                    rows * model_parallel)


def rebalance_batch(global_batch: int, data_shards: int) -> int:
    """Per-shard batch after a re-mesh; keeps the GLOBAL batch stable by
    rounding the shard batch up and truncating the final shard (documented
    drop <1/shards)."""
    return -(-global_batch // data_shards)


class StragglerMonitor:
    """Step-time watchdog: flags ranks whose rolling step time exceeds
    threshold x the cluster median (the classic TPU-pod straggler signal)."""

    def __init__(self, window: int = 16, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: dict[int, deque] = {}

    def record(self, rank: int, step_seconds: float):
        self.times.setdefault(rank, deque(maxlen=self.window)).append(step_seconds)

    def medians(self) -> dict[int, float]:
        out = {}
        for r, d in self.times.items():
            s = sorted(d)
            out[r] = s[len(s) // 2]
        return out

    def stragglers(self) -> list[int]:
        med = self.medians()
        if not med:
            return []
        cluster = sorted(med.values())[len(med) // 2]
        return [r for r, m in med.items() if m > self.threshold * cluster]


class Heartbeat:
    """Step-level liveness: the trainer calls beat() every step; a deadline
    miss marks the run for checkpoint-restart (the launcher polls is_alive).
    On a real cluster this is the coordinator RPC; here it is the same
    policy object the tests drive."""

    def __init__(self, deadline_seconds: float = 300.0, clock=time.monotonic):
        self.deadline = deadline_seconds
        self._clock = clock
        self.last = clock()

    def beat(self):
        self.last = self._clock()

    def is_alive(self) -> bool:
        return (self._clock() - self.last) < self.deadline
