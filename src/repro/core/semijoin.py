"""Remote-attribute filters / semi-joins (paper §3.2.2).

A query's WHERE clause references an attribute of a remote relation
("x.nation = :n" with x on another node).  Two alternatives:

Alt-1 (request): after all local filtering, ship the still-needed keys to
their owners; owners answer one bit per key.  ~n/P·log2(mP/n) bits per node.

Alt-2 (bitset): owners evaluate the predicate over their whole partition and
allgather the resulting bitset (packed, so the volume is visible in HLO);
every node then probes locally.  ~γm·log2(1/γ) bits.

``choose_alternative`` applies the paper's cost model; the plans pin the
choice the paper made per query and the benchmark sweeps both.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
from jax import lax

from repro.core import compression, exchange
from repro.core.partitioning import RangePartitioning


def alt1_request(
    keys,
    mask,
    part: RangePartitioning,
    local_predicate: Callable,
    *,
    capacity: int,
    axis: str = "nodes",
    backend: str = "xla",
    wire=None,
    observer=None,
    label: str = "",
):
    """Request-based semi-join: returns (bits aligned with keys, overflow).

    ``local_predicate(local_indices, mask) -> bool bits`` evaluates the
    remote predicate on the OWNER's partition, given local row indices.
    ``wire`` selects the exchange encoding (``exchange.WireFormat``;
    default raw) — a packed format ships EF-coded requests with the mask
    folded in and bitset-packed reply bits.  ``observer``/``label`` are
    forwarded to the exchange layer, which emits one trace-time event per
    compiled specialization.
    """
    def lookup(req_keys, req_mask):
        local_idx = part.local_index(req_keys)
        return local_predicate(local_idx, req_mask)

    bits, overflow = exchange.request_reply(
        keys,
        mask,
        part.owner(keys),
        lookup,
        capacity=capacity,
        axis=axis,
        backend=backend,
        reply_dtype=jnp.bool_,
        wire=wire,
        observer=observer,
        label=label,
    )
    return bits & mask, overflow


def alt2_bitset(
    local_bits,
    *,
    axis: str = "nodes",
):
    """Bitset-replication semi-join: every node contributes the predicate
    bits of its own partition; the packed bitset is allgathered so any node
    can probe any key locally.  Returns packed uint32 words covering the
    GLOBAL key space (row-major by node)."""
    n = local_bits.shape[0]
    pad = (-n) % 32
    if pad:
        local_bits = jnp.concatenate([local_bits, jnp.zeros(pad, bool)])
    packed = compression.pack_bitset(local_bits)
    return lax.all_gather(packed, axis, tiled=True)


def probe(global_bitset_words, keys, part: RangePartitioning):
    """Probe the replicated bitset for arbitrary global keys."""
    rows = part.rows_per_node
    padded = rows + ((-rows) % 32)
    owner = part.owner(keys)
    local = part.local_index(keys)
    bit_index = owner * padded + local
    return compression.probe_bitset(global_bitset_words, bit_index)


# re-export the paper's cost model (info-theoretic + byte-accurate wire)
alt1_bits = compression.alt1_bits
alt2_bits = compression.alt2_bits
choose_alternative = compression.choose_semijoin
alt1_wire_bytes = compression.alt1_wire_bytes
alt2_wire_bytes = compression.alt2_wire_bytes
choose_alternative_wire = compression.choose_semijoin_wire
