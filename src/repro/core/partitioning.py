"""Range partitioning and co-partitioning (paper §3.1).

All tables are range-partitioned on their primary key: node i owns keys
``[i * rows_per_node, (i+1) * rows_per_node)`` (0-based dense keys — the
TPC-H generator emits dense 1-based keys which we shift to 0-based at load).

Co-partitioning: two tables related by a foreign key store corresponding
tuples on the same node (lineitem–orders, partsupp–part), so equi-joins on
those edges are local.  The generator enforces this by construction; the
helpers here map keys to owners and to local indices, which is all a plan
needs to route a remote request (paper Fig. 1 dashed edges).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class RangePartitioning:
    """Partitioning metadata for one table: ``total_rows`` dense keys split
    evenly over ``num_nodes`` (every node holds exactly rows_per_node —
    synthetic data is balanced, matching the paper's use of range
    partitioning for TPC-H)."""

    total_rows: int
    num_nodes: int

    @property
    def rows_per_node(self) -> int:
        assert self.total_rows % self.num_nodes == 0, (
            f"range partitioning requires divisible sizes, got "
            f"{self.total_rows} rows over {self.num_nodes} nodes"
        )
        return self.total_rows // self.num_nodes

    def owner(self, key):
        """Node that stores the row with this 0-based dense key."""
        return key // self.rows_per_node

    def local_index(self, key):
        """Row index of ``key`` within its owner's partition."""
        return key % self.rows_per_node

    def base(self, node):
        """First key owned by ``node``."""
        return node * self.rows_per_node

    def my_base(self, axis: str = "nodes"):
        """First key owned by the calling device (inside shard_map)."""
        return lax.axis_index(axis) * self.rows_per_node

    def global_keys(self, axis: str = "nodes"):
        """Dense keys of the local partition (inside shard_map)."""
        return self.my_base(axis) + jnp.arange(self.rows_per_node, dtype=jnp.int32)


def copartitioned(parent: RangePartitioning, child_fanout: int) -> RangePartitioning:
    """Partitioning of a child table co-partitioned with ``parent`` where each
    parent row has exactly ``child_fanout`` child rows (partsupp: 4 per part).
    For variable fanout (lineitem per order) the generator pads to a fixed
    per-node row count instead and this helper is not used."""
    return RangePartitioning(parent.total_rows * child_fanout, parent.num_nodes)
