"""Communication compression (paper §3.2.1).

The paper compresses exchanged integer sets (keys, dictionary positions,
sparse bitsets) with delta encoding + vectorized variable-length codes
(FastPFor) and LZ4 for unsorted data.  On TPU we keep the paper's two cheap,
branch-free building blocks and drop the exception path of PFor (replaced by
a widened fixed width — the branchless variant):

- ``delta_encode / delta_decode``: increasing key sequences -> small deltas.
- ``pack_bits / unpack_bits``: fixed-width bit packing of non-negative ints
  into uint32 words (the "frame" part of PFor).  Packed words are what the
  exchange layer actually ships, so the byte reduction is visible in the
  lowered HLO, not just in an analytic model.

Also provides the paper's §3.2.2 analytic cost model for choosing between
semi-join alternatives (information-theoretic bits communicated).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# delta coding for sorted key sets
# ---------------------------------------------------------------------------


def delta_encode(sorted_vals):
    """First element kept, then differences.  Input must be non-decreasing
    (the engine sorts key sets before shipping them, as the paper does for
    better compression — §5.3 discusses exactly this trade-off)."""
    first = sorted_vals[:1]
    deltas = sorted_vals[1:] - sorted_vals[:-1]
    return jnp.concatenate([first, deltas])


def delta_decode(deltas):
    return jnp.cumsum(deltas)


# ---------------------------------------------------------------------------
# fixed-width bit packing into uint32 words
# ---------------------------------------------------------------------------


def packed_words(n: int, width: int) -> int:
    """Number of uint32 words needed for n values of `width` bits."""
    return (n * width + 31) // 32


def _width_mask(width: int):
    return jnp.uint32((1 << width) - 1 if width < 32 else 0xFFFFFFFF)


def pack_bits(vals, width: int):
    """Pack ``vals`` (non-negative int32/uint32, < 2**width) into uint32
    words, little-endian bit order.  Values may straddle a word boundary;
    both halves are deposited with disjoint-bit scatters (adds of disjoint
    bits == or, which keeps this a pure vectorized gather/scatter — the
    TPU-friendly reformulation of SIMD shuffles).

    ``width == 0`` is the constant-column degenerate: every value is 0
    (after frame-of-reference subtraction) and the packed form is the
    empty word array — it round-trips through :func:`unpack_bits`."""
    assert 0 <= width <= 32
    n = vals.shape[0]
    if width == 0:
        return jnp.zeros(0, jnp.uint32)
    v = vals.astype(jnp.uint32) & _width_mask(width)
    bitpos = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(width)
    word = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    nwords = packed_words(n, width)
    lo = (v << off).astype(jnp.uint32)
    # high part: bits that spill into the next word; shift by (32 - off)
    # guarded against off == 0 (shift by 32 is undefined) via two-step shift
    hi = jnp.where(off > 0, (v >> (jnp.uint32(32) - jnp.where(off > 0, off, 1))), 0)
    words = jnp.zeros(nwords, jnp.uint32)
    words = words.at[word].add(lo)  # disjoint bits -> add == or
    words = words.at[jnp.minimum(word + 1, nwords - 1)].add(
        jnp.where(word + 1 < nwords, hi, 0)
    )
    return words


def gather_bits(words, idx, width: int):
    """Random-access extract: value at each row index ``idx`` of a
    :func:`pack_bits` stream (the late-materialization primitive — decode
    only the surviving rows, never the full column)."""
    assert 0 <= width <= 32
    if width == 0:
        return jnp.zeros(idx.shape, jnp.uint32)
    bitpos = idx.astype(jnp.uint32) * jnp.uint32(width)
    word = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    nwords = words.shape[0]
    lo = words[word] >> off
    nxt = words[jnp.minimum(word + 1, nwords - 1)]
    hi = jnp.where(off > 0, nxt << (jnp.uint32(32) - jnp.where(off > 0, off, 1)), 0)
    return (lo | hi) & _width_mask(width)


def unpack_bits(words, n: int, width: int):
    """Inverse of pack_bits; returns uint32 array of length n."""
    assert 0 <= width <= 32
    if width == 0:
        return jnp.zeros(n, jnp.uint32)
    return gather_bits(words, jnp.arange(n, dtype=jnp.uint32), width)


def required_width(max_val: int) -> int:
    """Smallest width that can represent max_val (host-side helper).
    ``required_width(0) == 0``: a constant-zero column needs no bits —
    width-0 columns round-trip through pack/unpack as empty word arrays."""
    return int(max_val).bit_length()


# ---------------------------------------------------------------------------
# packed bitsets (paper §3.2.2 Alt-2 ships compressed bitsets)
# ---------------------------------------------------------------------------


def pack_bitset(bits):
    """bool[n] -> uint32[ceil(n/32)] (n must be a multiple of 32 for the
    engine's fixed shapes; callers pad)."""
    n = bits.shape[0]
    assert n % 32 == 0, f"bitset length must be multiple of 32, got {n}"
    b = bits.reshape(n // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, :]
    return jnp.sum(b * weights, axis=1, dtype=jnp.uint32)


def unpack_bitset(words, n: int):
    w = words[:, None]
    bits = (w >> jnp.arange(32, dtype=jnp.uint32)[None, :]) & jnp.uint32(1)
    return bits.reshape(-1)[:n].astype(bool)


def probe_bitset(words, idx):
    """Test bit ``idx`` of a packed bitset (vectorized)."""
    word = words[idx >> 5]
    return ((word >> (idx.astype(jnp.uint32) & jnp.uint32(31))) & jnp.uint32(1)).astype(bool)


# ---------------------------------------------------------------------------
# §3.2.2 analytic cost model (bits communicated per node)
# ---------------------------------------------------------------------------


def alt1_bits(n: float, m: float, P: int) -> float:
    """Request-based semi-join: n requests after local filtering (n/P per
    node), remote table of m rows: n/P * log2(m*P/n) bits per node."""
    if n <= 0:
        return 0.0
    return (n / P) * float(np.log2(max(m * P / n, 2.0)))


def alt2_bits(m: float, gamma: float) -> float:
    """Replicated-bitset semi-join: γm qualifying rows of an m-row table:
    γ·m·log2(1/γ) bits (information content of the bitset).

    Degenerate selectivities are explicit branches, not a fused ternary:
    γ <= 0 selects nothing — an all-zero bitset carries no information,
    0 bits; γ >= 1 selects everything — the entropy is also ~0, but the
    engine still ships the m-bit bitset, so the model charges the m raw
    bits actually communicated (the paper's curve is only defined on the
    open interval)."""
    if gamma <= 0:
        return 0.0
    if gamma >= 1:
        return float(m)
    return gamma * m * float(np.log2(1.0 / gamma))


def choose_semijoin(n: float, m: float, gamma: float, P: int) -> int:
    """Return 1 or 2 — the cheaper alternative under the paper's model.
    (Footnote 2: for n/P > m Alternative 2 is better anyway.)"""
    if n / P > m:
        return 2
    return 1 if alt1_bits(n, m, P) <= alt2_bits(m, gamma) else 2


# ---------------------------------------------------------------------------
# packed wire format parameters (shared by the exchange codec and the
# byte-accurate cost model, so the model is exact by construction)
# ---------------------------------------------------------------------------


def bitset_words(n: int) -> int:
    """uint32 words of an n-bit packed bitset."""
    return (max(n, 0) + 31) // 32


# The EF high parts live in a BOUNDED universe: the split always leaves at
# most EF_UNIVERSE distinct high values, so a decoder can reconstruct every
# high part from a fixed EF_UNIVERSE-1 zero-rank queries over the upper
# bitvector — static shape AND constant query count, no per-bit rank pass.
EF_UNIVERSE = 16


def ef_params(capacity: int, domain: int) -> tuple:
    """Elias–Fano split for ``capacity`` SORTED keys drawn from a
    per-destination domain of ``domain`` values: returns
    ``(l, upper_words, lower_words)``.

    Each key splits into ``l = max(0, ceil(log2(domain)) - 4)`` low bits
    (fixed-width packed — the "catalog-derived width" part) and a high
    part in the bounded universe ``[0, (domain-1) >> l] ⊆ [0, 15]``,
    encoded in unary in a bitvector of ``capacity + high_domain + 1``
    bits (the delta part: ~1 bit/key + at most 16 zero markers).  The
    bitvector keeps ``EF_UNIVERSE - 1`` structural spare zeros so the
    v-th-zero decode query always has an answer, for ANY capacity and
    ANY bucket fill.  Static shapes by construction — valid for any
    sorted bucket content, no exception path."""
    c = max(1, int(capacity))
    d = max(1, int(domain))
    l = max(0, (d - 1).bit_length() - 4) if d > 1 else 0
    hd = (d - 1) >> l  # largest high part, < EF_UNIVERSE by construction
    upper_bits = c + hd + 1 + (EF_UNIVERSE - 1)
    lw = packed_words(c, l) if l else 0
    return l, (upper_bits + 31) // 32, lw


def packed_request_words(capacity: int, domain: int) -> int:
    """uint32 words of one packed request row: EF upper bitvector + EF
    lower bits + the folded validity-mask bitset."""
    l, uw, lw = ef_params(capacity, domain)
    return uw + lw + bitset_words(capacity)


# ---------------------------------------------------------------------------
# byte-accurate §3.2.2 model: STATIC wire bytes of the compiled exchanges
# (what the lowered HLO actually ships), not the information bound above
# ---------------------------------------------------------------------------


def alt1_wire_bytes(capacity: int, P: int, domain: int = 0, *,
                    packed: bool = True, reply_bytes: int = 1) -> float:
    """Per-node bytes injected by the Alt-1 request/reply exchange at the
    plan's static buffer shapes: P-1 remote destination rows of
    ``capacity`` slots, requests plus replies.  raw = int32 key + bool
    mask + reply byte(s) per slot; packed = EF-coded keys with the mask
    folded in.  On packed wire only 1-byte (boolean) replies ship as a
    bitset — wider replies travel raw, exactly as ``request_reply``
    compiles them."""
    rows = max(P - 1, 1)
    if packed and domain > 0:
        reply_words = (bitset_words(capacity) if reply_bytes == 1
                       else -(-capacity * reply_bytes // 4))
        words = packed_request_words(capacity, domain) + reply_words
        return float(rows * words * 4)
    return float(rows * capacity * (4 + 1 + reply_bytes))


def alt2_wire_bytes(m: float, P: int) -> float:
    """Per-node bytes of the Alt-2 replicated bitset: the local partition's
    packed predicate bits (m/P rows), allgathered to the other P-1 nodes.
    Identical under raw and packed wire — Alt-2 always ships packed words."""
    local = (int(m) + max(P, 1) - 1) // max(P, 1)
    return float(max(P - 1, 1) * bitset_words(local) * 4)


def choose_semijoin_wire(capacity: int, m: float, P: int, *,
                         domain: int = 0, packed: bool = True,
                         cal=None) -> int:
    """Alternative choice at the plan's STATIC exchange shapes.  Returns
    1 or 2.

    Without a calibration this is the byte-accurate model: compare the
    wire bytes of the compiled Alt-1 exchange (at its derived capacity and
    actual packed widths) against the Alt-2 bitset allgather.  With a
    :class:`repro.core.wirecal.WireCalibration` it is LATENCY-accurate:
    codec time + link time + per-collective latency on both sides, so a
    cheap-bytes-but-extra-collectives alternative no longer wins on a
    latency-dominated link."""
    if cal is not None:
        from repro.core import wirecal

        c1, w1 = wirecal.predict_alt1_ms(capacity, P, domain,
                                         packed=packed and domain > 0,
                                         cal=cal)
        c2, w2 = wirecal.predict_alt2_ms(m, P, cal=cal)
        return 1 if c1 + w1 <= c2 + w2 else 2
    a1 = alt1_wire_bytes(capacity, P, domain, packed=packed)
    return 1 if a1 <= alt2_wire_bytes(m, P) else 2
