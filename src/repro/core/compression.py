"""Communication compression (paper §3.2.1).

The paper compresses exchanged integer sets (keys, dictionary positions,
sparse bitsets) with delta encoding + vectorized variable-length codes
(FastPFor) and LZ4 for unsorted data.  On TPU we keep the paper's two cheap,
branch-free building blocks and drop the exception path of PFor (replaced by
a widened fixed width — the branchless variant):

- ``delta_encode / delta_decode``: increasing key sequences -> small deltas.
- ``pack_bits / unpack_bits``: fixed-width bit packing of non-negative ints
  into uint32 words (the "frame" part of PFor).  Packed words are what the
  exchange layer actually ships, so the byte reduction is visible in the
  lowered HLO, not just in an analytic model.

Also provides the paper's §3.2.2 analytic cost model for choosing between
semi-join alternatives (information-theoretic bits communicated).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# delta coding for sorted key sets
# ---------------------------------------------------------------------------


def delta_encode(sorted_vals):
    """First element kept, then differences.  Input must be non-decreasing
    (the engine sorts key sets before shipping them, as the paper does for
    better compression — §5.3 discusses exactly this trade-off)."""
    first = sorted_vals[:1]
    deltas = sorted_vals[1:] - sorted_vals[:-1]
    return jnp.concatenate([first, deltas])


def delta_decode(deltas):
    return jnp.cumsum(deltas)


# ---------------------------------------------------------------------------
# fixed-width bit packing into uint32 words
# ---------------------------------------------------------------------------


def packed_words(n: int, width: int) -> int:
    """Number of uint32 words needed for n values of `width` bits."""
    return (n * width + 31) // 32


def pack_bits(vals, width: int):
    """Pack ``vals`` (non-negative int32/uint32, < 2**width) into uint32
    words, little-endian bit order.  Values may straddle a word boundary;
    both halves are deposited with disjoint-bit scatters (adds of disjoint
    bits == or, which keeps this a pure vectorized gather/scatter — the
    TPU-friendly reformulation of SIMD shuffles)."""
    assert 1 <= width <= 32
    n = vals.shape[0]
    v = vals.astype(jnp.uint32) & jnp.uint32((1 << width) - 1 if width < 32 else 0xFFFFFFFF)
    bitpos = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(width)
    word = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    nwords = packed_words(n, width)
    lo = (v << off).astype(jnp.uint32)
    # high part: bits that spill into the next word; shift by (32 - off)
    # guarded against off == 0 (shift by 32 is undefined) via two-step shift
    hi = jnp.where(off > 0, (v >> (jnp.uint32(32) - jnp.where(off > 0, off, 1))), 0)
    words = jnp.zeros(nwords, jnp.uint32)
    words = words.at[word].add(lo)  # disjoint bits -> add == or
    words = words.at[jnp.minimum(word + 1, nwords - 1)].add(
        jnp.where(word + 1 < nwords, hi, 0)
    )
    return words


def unpack_bits(words, n: int, width: int):
    """Inverse of pack_bits; returns uint32 array of length n."""
    assert 1 <= width <= 32
    bitpos = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(width)
    word = (bitpos >> 5).astype(jnp.int32)
    off = bitpos & jnp.uint32(31)
    nwords = words.shape[0]
    lo = words[word] >> off
    nxt = words[jnp.minimum(word + 1, nwords - 1)]
    hi = jnp.where(off > 0, nxt << (jnp.uint32(32) - jnp.where(off > 0, off, 1)), 0)
    mask = jnp.uint32((1 << width) - 1 if width < 32 else 0xFFFFFFFF)
    return (lo | hi) & mask


def required_width(max_val: int) -> int:
    """Smallest width that can represent max_val (host-side helper)."""
    return max(1, int(max_val).bit_length())


# ---------------------------------------------------------------------------
# packed bitsets (paper §3.2.2 Alt-2 ships compressed bitsets)
# ---------------------------------------------------------------------------


def pack_bitset(bits):
    """bool[n] -> uint32[ceil(n/32)] (n must be a multiple of 32 for the
    engine's fixed shapes; callers pad)."""
    n = bits.shape[0]
    assert n % 32 == 0, f"bitset length must be multiple of 32, got {n}"
    b = bits.reshape(n // 32, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, :]
    return jnp.sum(b * weights, axis=1, dtype=jnp.uint32)


def unpack_bitset(words, n: int):
    w = words[:, None]
    bits = (w >> jnp.arange(32, dtype=jnp.uint32)[None, :]) & jnp.uint32(1)
    return bits.reshape(-1)[:n].astype(bool)


def probe_bitset(words, idx):
    """Test bit ``idx`` of a packed bitset (vectorized)."""
    word = words[idx >> 5]
    return ((word >> (idx.astype(jnp.uint32) & jnp.uint32(31))) & jnp.uint32(1)).astype(bool)


# ---------------------------------------------------------------------------
# §3.2.2 analytic cost model (bits communicated per node)
# ---------------------------------------------------------------------------


def alt1_bits(n: float, m: float, P: int) -> float:
    """Request-based semi-join: n requests after local filtering (n/P per
    node), remote table of m rows: n/P * log2(m*P/n) bits per node."""
    if n <= 0:
        return 0.0
    return (n / P) * float(np.log2(max(m * P / n, 2.0)))


def alt2_bits(m: float, gamma: float) -> float:
    """Replicated-bitset semi-join: γm qualifying rows of an m-row table:
    γ·m·log2(1/γ) bits (information content of the bitset)."""
    if gamma <= 0 or gamma >= 1:
        return float(m) if 0 < gamma < 1 else (0.0 if gamma <= 0 else float(m))
    return gamma * m * float(np.log2(1.0 / gamma))


def choose_semijoin(n: float, m: float, gamma: float, P: int) -> int:
    """Return 1 or 2 — the cheaper alternative under the paper's model.
    (Footnote 2: for n/P > m Alternative 2 is better anyway.)"""
    if n / P > m:
        return 2
    return 1 if alt1_bits(n, m, P) <= alt2_bits(m, gamma) else 2
