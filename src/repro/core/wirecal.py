"""Latency calibration for the wire-format choice (paper §3.2.1, §5.3).

The byte-accurate model in :mod:`repro.core.compression` says how many
bytes each wire format ships; whether the PACKED format is actually
*faster* depends on where the exchange is bottlenecked.  Compression pays
only when the codec's throughput exceeds the network's — the classic
result (Rödiger et al.) that motivates the paper's vectorized codecs.
This module holds the three calibrated rates that settle the question and
a roofline predictor over them:

  ``predicted_ms = codec_bytes / codec_GBps            (encode + decode)
                 + wire_bytes  / link_GBps             (serialized volume)
                 + collectives * msg_ms``              (per-message latency)

``raw`` wire has no codec term but ships ~4–6x the bytes in 3 collectives;
``packed`` pays the codec term, ships the Elias–Fano words in 2.  The
crossover is a property of the MACHINE, not the plan, so the rates are
calibrated once (``python -m repro.core.wirecal``), persisted under
``experiments/bench/`` and loaded by the planner; builtin defaults model
the paper's GbE cluster (link far slower than the codec → packed wins),
keeping plans deterministic when no calibration file exists.

Codec throughput is MEASURED by timing the jit'd kernels on a
representative shape.  Link bandwidth and per-message latency cannot be
measured on simulated devices (host-local "collectives" move memory, not
packets), so they are deployment knobs: override them in the calibration
file or via ``REPRO_WIRE_CAL`` when targeting real interconnect.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

from repro.core import compression

# calibration file location: env override, else the repo's bench artifacts
ENV_VAR = "REPRO_WIRE_CAL"
DEFAULT_PATH = os.path.join("experiments", "bench", "wire_calibration.json")


@dataclasses.dataclass(frozen=True)
class WireCalibration:
    """Machine rates of the roofline model (GB/s and ms).

    ``encode_gbps``/``decode_gbps``: packed-codec throughput in wire bytes
    produced/consumed per second.  ``link_gbps``: per-node all-to-all
    bandwidth.  ``msg_ms``: fixed per-collective latency (startup + sync).
    """

    encode_gbps: float = 1.0
    decode_gbps: float = 1.0
    link_gbps: float = 0.125   # the paper's GbE cluster: ~1 Gbit/s links
    msg_ms: float = 0.05
    source: str = "builtin"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "WireCalibration":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


BUILTIN = WireCalibration()


class WireCalError(RuntimeError):
    """An explicitly requested calibration file is missing or unusable.

    Raised only when the caller POINTED at a file (a ``path`` argument or
    $REPRO_WIRE_CAL): silently planning on builtin GbE rates after the
    operator stated a machine model would make every wire-format choice
    quietly wrong.  The implicit default location still falls back to
    :data:`BUILTIN` — absence there just means "never calibrated"."""


def load(path: Optional[str] = None, *,
         strict: Optional[bool] = None) -> WireCalibration:
    """Calibration from ``path`` / $REPRO_WIRE_CAL / the default location.

    An EXPLICIT source (argument or env var) that is missing or corrupt
    raises :class:`WireCalError`; only the implicit default path falls
    back to :data:`BUILTIN`.  ``strict`` overrides that default (e.g.
    ``strict=False`` for calibrate-then-overwrite flows where a missing
    target is the expected fresh-machine state)."""
    explicit = path or os.environ.get(ENV_VAR)
    if strict is None:
        strict = explicit is not None
    target = explicit or DEFAULT_PATH
    try:
        with open(target) as f:
            return WireCalibration.from_json(json.load(f))
    except (OSError, ValueError, TypeError, AttributeError) as e:
        if strict:
            origin = ("argument" if path else f"${ENV_VAR}")
            kind = ("unreadable" if isinstance(e, OSError)
                    else "not a calibration JSON object")
            raise WireCalError(
                f"wire calibration file {target!r} (from {origin}) is "
                f"{kind}: {e}"
            ) from e
        return BUILTIN


_CACHED: Optional[WireCalibration] = None


def cached() -> WireCalibration:
    """Process-cached :func:`load` — for per-trace instrumentation sites
    that must not re-read the calibration file on every event."""
    global _CACHED
    if _CACHED is None:
        _CACHED = load()
    return _CACHED


def save(cal: WireCalibration, path: Optional[str] = None) -> str:
    path = path or os.environ.get(ENV_VAR) or DEFAULT_PATH
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(cal.to_json(), f, indent=1)
    return path


# ---------------------------------------------------------------------------
# roofline predictor (ms; bytes / GBps / 1e6 == ms)
# ---------------------------------------------------------------------------


def alt1_codec_bytes(capacity: int, P: int, domain: int) -> float:
    """Bytes the packed codec touches for one Alt-1 exchange: the EF
    request rows (encoded at the sender, decoded at the receiver) plus the
    folded boolean reply bitsets."""
    rows = max(P - 1, 1)
    return float(rows * (compression.packed_request_words(capacity, domain)
                         + compression.bitset_words(capacity)) * 4)


def predict_codec_ms(capacity: int, P: int, domain: int, *,
                     cal: Optional[WireCalibration] = None):
    """(encode_ms, decode_ms) of the packed codec for one Alt-1 exchange —
    the two halves of the roofline's codec term, split out so the exchange
    layer can attribute them separately (spans/histograms)."""
    cal = cal or BUILTIN
    cb = alt1_codec_bytes(capacity, P, domain)
    return cb / (cal.encode_gbps * 1e6), cb / (cal.decode_gbps * 1e6)


def predict_alt1_ms(capacity: int, P: int, domain: int, *, packed: bool,
                    cal: Optional[WireCalibration] = None):
    """(codec_ms, wire_ms) of one Alt-1 request/reply exchange.  ``wire_ms``
    is link volume plus per-collective latency at the format's collective
    count (2 packed / 1+2 raw — the request key+mask pair and the reply)."""
    cal = cal or BUILTIN
    nbytes = compression.alt1_wire_bytes(capacity, P, domain, packed=packed)
    if packed and domain > 0:
        codec_ms = sum(predict_codec_ms(capacity, P, domain, cal=cal))
        collectives = 2
    else:
        codec_ms = 0.0
        collectives = 3
    wire_ms = nbytes / (cal.link_gbps * 1e6) + collectives * cal.msg_ms
    return codec_ms, wire_ms


def predict_alt2_ms(m: float, P: int, *,
                    cal: Optional[WireCalibration] = None):
    """(codec_ms, wire_ms) of the Alt-2 replicated-bitset allgather (one
    collective; the bitset is packed on both wire kinds)."""
    cal = cal or BUILTIN
    nbytes = compression.alt2_wire_bytes(m, P)
    codec_ms = (nbytes / (cal.encode_gbps * 1e6)
                + nbytes / (cal.decode_gbps * 1e6))
    wire_ms = nbytes / (cal.link_gbps * 1e6) + cal.msg_ms
    return codec_ms, wire_ms


def choose_wire_kind(capacity: int, P: int, domain: int,
                     cal: Optional[WireCalibration] = None) -> str:
    """'packed' iff the roofline predicts the packed Alt-1 exchange is at
    least as fast as raw: the codec only pays when the exchange is
    network-bound (slow link / fast codec), never on codec-bound setups."""
    pc, pw = predict_alt1_ms(capacity, P, domain, packed=True, cal=cal)
    _, rw = predict_alt1_ms(capacity, P, domain, packed=False, cal=cal)
    return "packed" if pc + pw <= rw else "raw"


# ---------------------------------------------------------------------------
# codec-throughput calibration (run once per machine)
# ---------------------------------------------------------------------------


def calibrate(*, capacity: int = 4096, domain: int = 3750, nodes: int = 8,
              repeat: int = 20, cal: Optional[WireCalibration] = None
              ) -> WireCalibration:
    """Measure the jit'd kernel codec's encode/decode throughput on a
    representative shape and return a calibration carrying the measured
    rates (link parameters inherited from ``cal`` / builtin — they are
    deployment knobs, see module docstring)."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    base = cal or BUILTIN
    rng = np.random.default_rng(0)
    fill = int(capacity * 0.8)
    buckets = np.zeros((nodes, capacity), np.int32)
    mask = np.zeros((nodes, capacity), bool)
    for p in range(nodes):
        buckets[p, :fill] = np.sort(
            rng.integers(0, domain, size=fill)) + p * domain
        mask[p, :fill] = True
    buckets, mask = jnp.asarray(buckets), jnp.asarray(mask)
    words = ops.ef_encode(buckets, mask, domain=domain)
    jax.block_until_ready(
        ops.ef_decode(words, jnp.int32(0), capacity=capacity, domain=domain))

    def best(fn):
        times = []
        for _ in range(repeat):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        return min(times)

    nbytes = nodes * compression.packed_request_words(capacity, domain) * 4
    t_enc = best(lambda: ops.ef_encode(buckets, mask, domain=domain))
    t_dec = best(lambda: ops.ef_decode(words, jnp.int32(0),
                                       capacity=capacity, domain=domain))
    return dataclasses.replace(
        base,
        encode_gbps=nbytes / t_enc / 1e9,
        decode_gbps=nbytes / t_dec / 1e9,
        source=f"calibrated(capacity={capacity},domain={domain},"
               f"nodes={nodes})",
    )


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--capacity", type=int, default=4096)
    ap.add_argument("--domain", type=int, default=3750)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--repeat", type=int, default=20)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)
    # tolerant load: calibrating INTO a path that doesn't exist yet is the
    # normal fresh-machine flow, not a misconfiguration — inherit the link
    # knobs from whatever is there, else builtin
    cal = calibrate(capacity=args.capacity, domain=args.domain,
                    nodes=args.nodes, repeat=args.repeat,
                    cal=load(args.out, strict=False))
    path = save(cal, args.out)
    print(f"wrote {path}: encode {cal.encode_gbps:.3f} GB/s, "
          f"decode {cal.decode_gbps:.3f} GB/s, link {cal.link_gbps} GB/s, "
          f"msg {cal.msg_ms} ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
