"""Distributed top-k selection (paper §3.2.3, §3.2.4).

- ``local_topk``: per-node top-k (step 1 of the paper's scheme).
- ``topk_allreduce``: the paper's merging reduction — sorted k-vectors are
  combined pairwise, keeping the best k, in a log2(P)-depth butterfly
  (Θ(k log P) bottleneck volume vs Θ(kP) for the naive gather).
- ``topk_gather``: the naive gather baseline the paper compares against.
- ``lazy_filtered_topk``: §3.2.4 — when a remote filter disqualifies keys,
  request filter bits only for chunks of locally-best candidates until k
  survivors are found (expected k/p keys communicated instead of all).

Ties: ranking uses (value desc, tiebreak asc) so results are deterministic
and match the numpy oracle — the paper sorts output rows the same way.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import exchange

NEG_INF = jnp.float32(-jnp.inf)


class TopK(NamedTuple):
    values: jax.Array  # (k,) f32, descending
    keys: jax.Array    # (k,) i32 — payload (row key) per entry
    valid: jax.Array   # (k,) bool


def _rank_order(values, tiebreak, valid):
    """Sort order: valid desc, value desc, tiebreak asc."""
    v = jnp.where(valid, values.astype(jnp.float32), NEG_INF)
    # composite: sort by (-v, tiebreak) — use lexsort via argsort of keys
    order = jnp.lexsort((tiebreak, -v, ~valid))
    return order


def local_topk(values, keys, k: int, mask=None) -> TopK:
    """Top-k rows of the local partition by value (desc), key asc tiebreak."""
    n = values.shape[0]
    valid = jnp.ones(n, bool) if mask is None else mask
    order = _rank_order(values, keys, valid)[:k]
    return TopK(
        values=jnp.where(valid[order], values[order].astype(jnp.float32), NEG_INF),
        keys=keys[order],
        valid=valid[order],
    )


def merge_topk(a: TopK, b: TopK) -> TopK:
    """The paper's user-defined reduce operator: merge two sorted k-lists,
    keep the best k."""
    k = a.values.shape[0]
    values = jnp.concatenate([a.values, b.values])
    keys = jnp.concatenate([a.keys, b.keys])
    valid = jnp.concatenate([a.valid, b.valid])
    order = _rank_order(values, keys, valid)[:k]
    return TopK(values[order], keys[order], valid[order])


def topk_allreduce(local: TopK, axis: str = "nodes") -> TopK:
    """§3.2.3 merging reduction as a recursive-doubling butterfly; every node
    ends with the global top-k."""
    return exchange.butterfly_allreduce(local, merge_topk, axis)


def topk_gather(local: TopK, axis: str = "nodes") -> TopK:
    """Naive baseline: allgather all P·k candidates, then select k."""
    k = local.values.shape[0]
    values = lax.all_gather(local.values, axis, tiled=True)
    keys = lax.all_gather(local.keys, axis, tiled=True)
    valid = lax.all_gather(local.valid, axis, tiled=True)
    order = _rank_order(values, keys, valid)[:k]
    return TopK(values[order], keys[order], valid[order])


def lazy_filtered_topk(
    values,
    keys,
    mask,
    remote_filter: Callable,
    k: int,
    *,
    chunk: int,
    max_rounds: int,
    axis: str = "nodes",
) -> TopK:
    """§3.2.4: top-k where a remote predicate disqualifies keys.

    ``remote_filter(keys, mask) -> (bits, overflow)`` evaluates the remote
    predicate for a masked chunk of keys (an Alt-1 request under the hood).
    Rounds proceed over chunks of locally-best unfiltered candidates until k
    local survivors are found (or the candidate pool is exhausted), then one
    merging reduction finds the global winners.

    Static shapes: the candidate pool is fully sorted once; round i examines
    slots [i*chunk, (i+1)*chunk).  max_rounds bounds the lax.while_loop.
    """
    n = values.shape[0]
    order = _rank_order(values, keys, mask)
    sv = jnp.where(mask[order], values[order].astype(jnp.float32), NEG_INF)
    sk = keys[order]
    svalid = mask[order]

    pass_bits = jnp.zeros(n, bool)     # passed remote filter
    examined = jnp.zeros(n, bool)

    def cond(state):
        i, pass_bits, examined, overflow = state
        survivors = jnp.sum((pass_bits & examined).astype(jnp.int32))
        # every node keeps requesting until IT has k survivors or no
        # unexamined valid candidates remain; all nodes iterate in lockstep
        # (collectives inside), so reduce the condition globally.
        more_local = (survivors < k) & jnp.any(svalid & ~examined)
        more = lax.psum(more_local.astype(jnp.int32), axis) > 0
        return (i < max_rounds) & more

    def body(state):
        i, pass_bits, examined, overflow = state
        start = i * chunk
        idx = start + jnp.arange(chunk, dtype=jnp.int32)
        idx = jnp.minimum(idx, n - 1)
        ck = sk[idx]
        cm = svalid[idx] & (start + jnp.arange(chunk) < n)
        # nodes that already found k survivors still participate with an
        # empty request (collectives must be uniform)
        done_local = jnp.sum((pass_bits & examined).astype(jnp.int32)) >= k
        cm = cm & ~done_local
        bits, ovf = remote_filter(ck, cm)
        pass_bits = pass_bits.at[idx].set(jnp.where(cm, bits, pass_bits[idx]))
        examined = examined.at[idx].set(examined[idx] | cm)
        return i + 1, pass_bits, examined, overflow | ovf

    i0 = jnp.int32(0)
    _, pass_bits, examined, overflow = lax.while_loop(
        cond, body, (i0, pass_bits, examined, jnp.bool_(False))
    )
    final_mask = pass_bits & examined & svalid
    local = local_topk(sv, sk, k, final_mask)
    return topk_allreduce(local, axis), overflow
