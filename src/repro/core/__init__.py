"""The paper's primary contribution: communication-efficient distributed
OLAP query execution (Hespe/Weidner/Dees/Sanders), as a composable JAX
library.  See DESIGN.md for the paper->TPU mapping.

Submodules:
  columnar      sharded main-memory column store
  partitioning  range + co-partitioning (§3.1)
  exchange      collectives incl. 1-factor all-to-all (§3.2.6), request/reply
  compression   delta + bit packing, §3.2.2 cost model (§3.2.1)
  semijoin      remote-attribute filters Alt-1 / Alt-2 (§3.2.2)
  topk          merging-reduction & lazy filtered top-k (§3.2.3-4)
  topk_approx   m-bit approximate distributed top-k (§3.2.5)
  aggregation   one-hot MXU & dense grouped aggregation
  late_materialization  output-only attribute fetch (§3.2.7)
  engine        Cluster driver: plan -> shard_map -> jit
  plans         the TPC-H query plans (one precompiled function per query)
"""

from repro.core.columnar import Table, shard_table, concat_tables  # noqa: F401
from repro.core.engine import Cluster, PlanContext  # noqa: F401
from repro.core.partitioning import RangePartitioning  # noqa: F401
