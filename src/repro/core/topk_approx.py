"""Top-k selection on distributed partial aggregates (paper §3.2.5).

The hard case: aggregate values are NOT partitioned by key — every node
holds a partial sum for (potentially) every key, and the total per key is
the sum over all nodes.  Threshold algorithms (Fagin's TA, TPUT) degrade to
shipping nearly everything when partial sums are i.i.d. across nodes, so the
paper contributes a new algorithm that ships only a few BITS per partial sum:

  1. encode each partial sum with m bits starting at a bit offset shared by a
     group of keys (group = 1024); the offset is the highest one-bit position
     of the group maximum,
  2. personalized all-to-all routes the codes to each key's owner node,
  3. owners decode per-source lower/upper bounds and sum them per key,
  4. a merging reduction finds the global k-th highest LOWER bound — every
     key whose UPPER bound is below it can never reach the top-k and is
     pruned (safe: the k highest lower bounds witness k totals >= threshold),
  5. exact partial sums are fetched only for the few surviving candidates,
  6. a final merging reduction selects the global top-k.

Float adaptation: the paper's values are fixed-point integers (TPC-H money
in cents).  Our engine stores f32, so the codec first derives a fixed-point
scale from the global max partial (one scalar pmax — negligible traffic),
quantizes each partial to a 30-bit integer, and applies the paper's integer
scheme verbatim; the quantization error is absorbed into the lower/upper
bounds (widened by one quantum + a float-rounding epsilon), so pruning
remains SAFE for float totals.

The m-bit codes are physically bit-packed (``repro.core.compression``) before
the all-to-all, so the communication-volume reduction (8x at m=8 vs 64-bit
values in the paper; 4x vs our f32) is visible in the lowered HLO.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compression, exchange, topk as topk_mod


class ApproxTopKStats(NamedTuple):
    naive_bits_per_node: jax.Array   # what the simple solution ships
    approx_bits_per_node: jax.Array  # step-2 codes + step-5 exact fetch
    num_candidates: jax.Array        # survivors after pruning (global)


def _significant_bits(x_u32):
    """Number of significant bits of a uint32 (0 for 0)."""
    # floor(log2(x)) + 1 via bit-length: count leading zeros through shifts
    x = x_u32
    bits = jnp.zeros_like(x)
    for shift in (16, 8, 4, 2, 1):
        above = x >= (jnp.uint32(1) << shift)
        bits = jnp.where(above, bits + shift, bits)
        x = jnp.where(above, x >> shift, x)
    return bits + (x > 0).astype(jnp.uint32)


def encode_partials(partials_u32, m: int, group: int):
    """Step 1: m-bit codes with a group-shared shift.

    partials_u32: (K,) uint32, monotone encoding of the values.
    Returns codes (K,) uint32 in [0, 2^m) and shifts (K//group,) uint32.
    """
    K = partials_u32.shape[0]
    assert K % group == 0
    g = partials_u32.reshape(K // group, group)
    gmax = jnp.max(g, axis=1)
    nbits = _significant_bits(gmax)
    shift = jnp.maximum(nbits.astype(jnp.int32) - m, 0).astype(jnp.uint32)
    codes = (g >> shift[:, None]).reshape(K)
    return codes, shift


def decode_bounds(codes, shifts, group: int):
    """Lower/upper uint32 bounds from codes + group shifts."""
    K = codes.shape[0]
    s = jnp.repeat(shifts, group, total_repeat_length=K)
    lower = codes << s
    upper = lower + ((jnp.uint32(1) << s) - jnp.uint32(1))
    return lower, upper


_QUANT_BITS = 30
_EPS = jnp.float32(1e-6)


def approx_topk_distributed(
    partials,
    k: int,
    *,
    m: int = 8,
    group: int = 1024,
    candidate_capacity: int,
    axis: str = "nodes",
    backend: str = "xla",
):
    """§3.2.5 end to end, inside shard_map.

    partials: (K,) f32 per node, NON-NEGATIVE partial sums over the global
        key space (K divisible by P*group, keys range-partitioned).
    Returns (TopK over global totals, stats, overflow).
    """
    K = partials.shape[0]
    P = lax.axis_size(axis)
    assert K % P == 0, "key space must be divisible by node count"
    Kp = K // P
    assert Kp % group == 0, "per-node key range must hold whole groups"

    # ---- step 0: fixed-point quantization (float adaptation) ------------
    # one scalar pmax fixes the quantum; q <= 2^30 always fits uint32
    partials = partials.astype(jnp.float32)
    gmax = lax.pmax(jnp.max(partials), axis)
    scale = jnp.float32(1 << _QUANT_BITS) / jnp.maximum(gmax, jnp.float32(1e-30))
    q = jnp.clip(jnp.floor(partials * scale), 0, float(1 << _QUANT_BITS)).astype(
        jnp.uint32
    )

    # ---- step 1: encode -------------------------------------------------
    codes, shifts = encode_partials(q, m, group)

    # ---- step 2: pack + personalized all-to-all by key range ------------
    codes_by_dest = codes.reshape(P, Kp)
    shifts_by_dest = shifts.reshape(P, Kp // group)
    packed = jax.vmap(lambda c: compression.pack_bits(c, m))(codes_by_dest)
    recv_packed = exchange.all_to_all(packed, axis, backend=backend)
    recv_shifts = exchange.all_to_all(shifts_by_dest, axis, backend=backend)
    recv_codes = jax.vmap(lambda w: compression.unpack_bits(w, Kp, m))(recv_packed)

    # ---- step 3: per-source bounds, summed per key ----------------------
    lo_q, hi_q = jax.vmap(lambda c, s: decode_bounds(c, s, group))(
        recv_codes, recv_shifts
    )
    # back to value space; widen by one quantum (+float eps) so bounds stay
    # valid despite the floor() quantization and f32 rounding
    inv = jnp.float32(1.0) / scale
    lo = jnp.sum(lo_q.astype(jnp.float32) * inv, axis=0) * (1.0 - _EPS)
    hi = jnp.sum((hi_q.astype(jnp.float32) + 1.0) * inv, axis=0) * (1.0 + _EPS)

    # ---- step 4: global k-th highest lower bound ------------------------
    my_keys = lax.axis_index(axis) * Kp + jnp.arange(Kp, dtype=jnp.int32)
    local_lo_topk = topk_mod.local_topk(lo, my_keys, k)
    global_lo_topk = topk_mod.topk_allreduce(local_lo_topk, axis)
    threshold = global_lo_topk.values[k - 1]

    # ---- step 5: prune, fetch exact partials for survivors --------------
    cand_mask = hi >= threshold
    num_candidates = lax.psum(jnp.sum(cand_mask.astype(jnp.int32)), axis)
    C = min(candidate_capacity, Kp)
    # stable left-pack candidate keys into a fixed buffer
    order = jnp.argsort(~cand_mask, stable=True)
    cand_keys = jnp.where(cand_mask[order], my_keys[order], 0)[:C]
    cand_valid = cand_mask[order][:C]
    overflow = jnp.sum(cand_mask.astype(jnp.int32)) > C
    # everyone learns everyone's candidates, answers with its exact partials
    all_cand = lax.all_gather(cand_keys, axis)          # (P, C) key ids
    all_valid = lax.all_gather(cand_valid, axis)        # (P, C)
    replies = jnp.where(all_valid, partials[all_cand.reshape(-1)].reshape(P, C), 0.0)
    exact_parts = exchange.all_to_all(replies, axis, backend=backend)  # (P, C) from each source
    exact_totals = jnp.sum(exact_parts, axis=0)         # (C,) totals for my candidates

    # ---- step 6: global top-k over exact candidate totals ---------------
    local_exact = topk_mod.local_topk(exact_totals, cand_keys, k, cand_valid)
    result = topk_mod.topk_allreduce(local_exact, axis)

    stats = ApproxTopKStats(
        naive_bits_per_node=jnp.float32(K * 32),
        approx_bits_per_node=jnp.float32(K * m + (K // group) * 8)
        + jnp.float32(C * 32) * 2.0,
        num_candidates=num_candidates,
    )
    return result, stats, overflow


def simple_topk_distributed(
    partials,
    k: int,
    *,
    axis: str = "nodes",
    backend: str = "xla",
):
    """The paper's naive baseline (Q15 variants 1/2): all_to_all ALL partial
    sums to each key's owner, aggregate, then select the top-k (backend
    chooses the library all-to-all vs the 1-factor schedule)."""
    K = partials.shape[0]
    P = lax.axis_size(axis)
    Kp = K // P
    by_dest = partials.reshape(P, Kp)
    recv = exchange.all_to_all(by_dest, axis, backend=backend)   # (P, Kp)
    totals = jnp.sum(recv, axis=0)
    my_keys = lax.axis_index(axis) * Kp + jnp.arange(Kp, dtype=jnp.int32)
    local = topk_mod.local_topk(totals, my_keys, k)
    return topk_mod.topk_allreduce(local, axis)
