"""Query execution driver.

The paper's runtime is "a precompiled function per query, run on every node,
synchronized by collectives".  Here: a plan is a Python function taking
(ctx, **local_table_columns) and running INSIDE shard_map over the ``nodes``
axis; ``Cluster.compile`` wraps it in shard_map + jit — XLA plays the role of
the paper's C++ compiler (and of the commercial JIT query compilers discussed
in §2), so a compiled plan is one SPMD executable, exactly the paper's model.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import jax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.columnar import Table, decode_columns, shard_table
from repro.core.exchange import WireFormat
from repro.core.partitioning import RangePartitioning


@dataclasses.dataclass(frozen=True)
class PlanContext:
    """Static execution context threaded through every plan."""

    num_nodes: int
    axis: str
    parts: Mapping[str, RangePartitioning]  # table name -> partitioning
    capacities: Mapping[str, int]            # plan-specific buffer capacities
    backend: str = "xla"                     # all-to-all backend
    scale_factor: float = 1.0
    wire: str = "packed"                     # exchange wire format selector
    wires: Mapping[str, WireFormat] = dataclasses.field(default_factory=dict)
    # observability hub (repro.obs.Observer) threaded to the exchange
    # layer: collective exchanges emit one trace-time event per compiled
    # specialization.  None = uninstrumented (hand-built contexts).
    obs: object = None

    def part(self, table: str) -> RangePartitioning:
        return self.parts[table]

    def cap(self, name: str, default: int = 4096) -> int:
        return int(self.capacities.get(name, default))

    def wire_fmt(self, name: str) -> WireFormat:
        """Wire format of the named exchange (derived in
        ``repro.tpch.capacities`` for the hand plans, ``repro.query.stats``
        inside the lowering); raw when the context disables packing or no
        format was derived for this exchange."""
        if self.wire != "packed":
            return WireFormat.raw()
        return self.wires.get(name, WireFormat.raw())


class Cluster:
    """A shared-nothing cluster on a 1-D device mesh."""

    def __init__(self, devices=None, axis: str = "nodes"):
        devices = list(devices if devices is not None else jax.devices())
        self.axis = axis
        axis_types = getattr(jax.sharding, "AxisType", None)
        self.mesh = compat.make_mesh(
            (len(devices),),
            (axis,),
            axis_types=(axis_types.Auto,) if axis_types is not None else None,
            devices=devices,
        )
        self.num_nodes = len(devices)

    # -- data placement ----------------------------------------------------
    def load(self, table: Table) -> Table:
        return shard_table(table, self.mesh, self.axis)

    def context(self, tables: Mapping[str, Table], capacities=None, *,
                backend: str = "xla", scale_factor: float = 1.0,
                wire: str = "packed", wires=None, obs=None) -> PlanContext:
        parts = {
            name: RangePartitioning(t.num_rows, 1 if t.replicated else self.num_nodes)
            for name, t in tables.items()
        }
        return PlanContext(
            num_nodes=self.num_nodes,
            axis=self.axis,
            parts=parts,
            capacities=dict(capacities or {}),
            backend=backend,
            scale_factor=scale_factor,
            wire=wire,
            wires=dict(wires or {}),
            obs=obs,
        )

    # -- compilation -------------------------------------------------------
    def compile(self, plan: Callable, ctx: PlanContext, tables: Mapping[str, Table],
                *, batch: bool = False):
        """Bind a plan to this mesh: returns a jitted function of the sharded
        column pytree.  Partitioned tables are P('nodes') on axis 0;
        replicated tables (and all outputs) are replicated.

        A PARAMETERIZED plan (``plan.params`` non-empty, the lowered form of
        a query with :class:`~repro.query.ir.Param` placeholders) compiles
        to ``fn(columns, params)`` where ``params`` maps each name to a
        replicated scalar — the paper's compile-once/execute-many model:
        the values are traced jit arguments, so ONE executable serves every
        binding.  With ``batch=True`` the params are instead stacked along
        a leading batch axis and the plan body is ``vmap``-ed over it
        INSIDE shard_map — N query instances of the same prepared shape run
        as one SPMD dispatch (collectives batch along the lane axis), and
        every output gains a leading lane axis."""

        in_specs = {
            name: {col: (P() if t.replicated else P(self.axis)) for col in t.columns}
            for name, t in tables.items()
        }
        params = tuple(getattr(plan, "params", ()) or ())
        if batch and not params:
            raise ValueError("batch=True requires a parameterized plan")

        # compressed residency: tables may hold PackedColumn entries.  A
        # plan that declares ``handles_packed`` (the IR lowering) receives
        # them as-is and scans the packed words directly; every other plan
        # (hand plans, cube builds) gets a full decode at plan entry —
        # inside shard_map, so only the local shard is ever decoded.
        if getattr(plan, "handles_packed", False):
            def entry(columns):
                return columns
        else:
            def entry(columns):
                return {t: decode_columns(c) for t, c in columns.items()}

        if params:
            param_specs = {p.name: P() for p in params}

            def run(columns, pvals):
                columns = entry(columns)
                if batch:
                    return jax.vmap(lambda pv: plan(ctx, columns, pv))(pvals)
                return plan(ctx, columns, pvals)

            sharded = jax.shard_map(
                run,
                mesh=self.mesh,
                in_specs=(in_specs, param_specs),
                out_specs=P(),
                check_vma=False,
            )
            return jax.jit(sharded)

        def run(columns):
            return plan(ctx, entry(columns))

        sharded = jax.shard_map(
            run,
            mesh=self.mesh,
            in_specs=(in_specs,),
            out_specs=P(),
            check_vma=False,
        )
        return jax.jit(sharded)

    def run(self, plan: Callable, tables: Mapping[str, Table], capacities=None,
            *, backend: str = "xla", scale_factor: float = 1.0,
            wire: str = "packed", wires=None):
        """Convenience: shard, compile, execute; returns host results."""
        placed = {name: self.load(t) for name, t in tables.items()}
        ctx = self.context(placed, capacities, backend=backend,
                           scale_factor=scale_factor, wire=wire, wires=wires)
        fn = self.compile(plan, ctx, placed)
        columns = {name: t.columns for name, t in placed.items()}
        return jax.tree.map(lambda x: jax.device_get(x), fn(columns))
