"""Late materialization of output-only attributes (paper §3.2.7).

Result sets are human-readable (small k), so attributes that never feed the
computation (s_name, s_address, s_phone in Q15) are fetched only for the
final k rows.  With k replicated after the merging reduction, every owner
contributes its owned rows and one allreduce (O(log P), same depth as the
paper's scatter+gather pair) assembles the k x A attribute block.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.partitioning import RangePartitioning


def materialize(
    keys,
    valid,
    part: RangePartitioning,
    local_columns,
    *,
    axis: str = "nodes",
):
    """Fetch attribute values for k replicated keys.

    keys: (k,) global keys (replicated — e.g. a TopK result).
    local_columns: dict name -> (rows_per_node,) local attribute shards.
    Returns dict name -> (k,) materialized values (replicated).
    """
    from repro.core.columnar import PackedColumn

    mine = valid & (part.owner(keys) == lax.axis_index(axis))
    local_idx = jnp.where(mine, part.local_index(keys), 0)
    out = {}
    for name, col in local_columns.items():
        # compressed-resident attributes gather k codes and decode only
        # those — the column itself is never expanded
        if isinstance(col, PackedColumn):
            vals = col.gather(local_idx)
        else:
            vals = col[local_idx]
        contrib = jnp.where(mine, vals, jnp.zeros_like(vals))
        out[name] = lax.psum(contrib, axis)
    return out
