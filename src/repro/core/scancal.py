"""Latency calibration for the scan-strategy choice (compressed residency).

Sequential scans are bandwidth-bound ("Micro-architectural Analysis of
OLAP"): packing a column at ``width`` bits streams ``width/32`` of the raw
bytes but pays lane-parallel ALU work to test predicates in code space.
This module is :mod:`repro.core.wirecal`'s sibling for the MEMORY
hierarchy — three machine rates and a roofline over them decide, per
scanned column, whether to evaluate the predicate on packed words or to
decode the column and filter raw:

  ``packed_ms = packed_bytes / mem_GBps + rows / scan_gvps``
  ``decode_ms = packed_bytes / mem_GBps + rows / unpack_gvps
              + raw_bytes / mem_GBps``       (write + re-read decoded)

Packed wins when the saved bandwidth (raw bytes never streamed) exceeds
the extra ALU cost of the in-place code test — the same
codec-must-outrun-the-medium discipline the wire chooser applies to the
network.  The crossover is a property of the MACHINE, so the rates are
calibrated once (``python -m repro.core.scancal``), persisted under
``experiments/bench/`` and loaded by the lowering; builtin defaults model
the paper's bandwidth-bound nodes (memory far slower than the VPU →
packed wins at every realistic width).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional

ENV_VAR = "REPRO_SCAN_CAL"
DEFAULT_PATH = os.path.join("experiments", "bench", "scan_calibration.json")


@dataclasses.dataclass(frozen=True)
class ScanCalibration:
    """Machine rates of the scan roofline (GB/s and Gvalues/s).

    ``mem_gbps``: resident-column streaming bandwidth.  ``scan_gvps``:
    predicate-on-packed throughput (values tested per second, SWAR
    kernel).  ``unpack_gvps``: full-column unpack throughput."""

    mem_gbps: float = 6.0
    scan_gvps: float = 4.0
    unpack_gvps: float = 4.0
    source: str = "builtin"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "ScanCalibration":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


BUILTIN = ScanCalibration()


class ScanCalError(RuntimeError):
    """An explicitly requested calibration file is missing or unusable
    (same contract as :class:`repro.core.wirecal.WireCalError`)."""


def load(path: Optional[str] = None, *,
         strict: Optional[bool] = None) -> ScanCalibration:
    """Calibration from ``path`` / $REPRO_SCAN_CAL / the default location;
    explicit sources raise on failure, the implicit default falls back to
    :data:`BUILTIN`."""
    explicit = path or os.environ.get(ENV_VAR)
    if strict is None:
        strict = explicit is not None
    target = explicit or DEFAULT_PATH
    try:
        with open(target) as f:
            return ScanCalibration.from_json(json.load(f))
    except (OSError, ValueError, TypeError, AttributeError) as e:
        if strict:
            origin = "argument" if path else f"${ENV_VAR}"
            kind = ("unreadable" if isinstance(e, OSError)
                    else "not a calibration JSON object")
            raise ScanCalError(
                f"scan calibration file {target!r} (from {origin}) is "
                f"{kind}: {e}") from e
        return BUILTIN


def save(cal: ScanCalibration, path: Optional[str] = None) -> str:
    path = path or os.environ.get(ENV_VAR) or DEFAULT_PATH
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(cal.to_json(), f, indent=1)
    return path


# ---------------------------------------------------------------------------
# roofline predictors (ms; bytes / GBps / 1e6 == ms, rows / Gvps / 1e6 == ms)
# ---------------------------------------------------------------------------


def packed_scan_bytes(rows: int, width: int) -> int:
    """Bytes streamed by predicate-on-packed: the packed words plus the
    emitted validity bitset."""
    from repro.core import compression

    return (compression.packed_words(rows, width)
            + compression.bitset_words(rows)) * 4


def decode_scan_bytes(rows: int, width: int, itemsize: int = 4) -> int:
    """Bytes touched by decode-then-filter: packed words in, decoded
    column out + re-read, bitset out."""
    from repro.core import compression

    return (compression.packed_words(rows, width) * 4
            + 2 * rows * itemsize + compression.bitset_words(rows) * 4)


def predict_packed_ms(rows: int, width: int, *,
                      cal: Optional[ScanCalibration] = None) -> float:
    cal = cal or BUILTIN
    return (packed_scan_bytes(rows, width) / (cal.mem_gbps * 1e6)
            + rows / (cal.scan_gvps * 1e6))


def predict_decode_ms(rows: int, width: int, itemsize: int = 4, *,
                      cal: Optional[ScanCalibration] = None) -> float:
    cal = cal or BUILTIN
    return (decode_scan_bytes(rows, width, itemsize) / (cal.mem_gbps * 1e6)
            + rows / (cal.unpack_gvps * 1e6))


def choose_scan_mode(rows: int, width: int, itemsize: int = 4, *,
                     cal: Optional[ScanCalibration] = None) -> str:
    """'packed' iff the roofline predicts the in-place code-space test is
    at least as fast as decoding the column and filtering raw."""
    packed = predict_packed_ms(rows, width, cal=cal)
    decode = predict_decode_ms(rows, width, itemsize, cal=cal)
    return "packed" if packed <= decode else "decode"


# ---------------------------------------------------------------------------
# calibration (run once per machine)
# ---------------------------------------------------------------------------


def calibrate(*, rows: int = 1 << 20, width: int = 12, repeat: int = 20,
              cal: Optional[ScanCalibration] = None) -> ScanCalibration:
    """Measure streaming bandwidth, the jit'd predicate-on-packed kernel,
    and the full unpack on a representative shape."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import compression
    from repro.kernels import ops

    base = cal or BUILTIN
    padded = -(-rows // 32) * 32
    rng = np.random.default_rng(0)
    codes = jnp.asarray(
        rng.integers(0, 1 << width, size=padded).astype(np.uint32))
    words = compression.pack_bits(codes, width)
    raw = codes.astype(jnp.int32)

    stream = jax.jit(jnp.sum)
    unpack = jax.jit(lambda w: compression.unpack_bits(w, padded, width))
    jax.block_until_ready(stream(raw))
    jax.block_until_ready(unpack(words))
    jax.block_until_ready(ops.scan_filter(
        words, 1, 100, rows=rows, padded_rows=padded, width=width))

    def best(fn):
        times = []
        for _ in range(repeat):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            times.append(time.perf_counter() - t0)
        return min(times)

    t_mem = best(lambda: stream(raw))
    t_scan = best(lambda: ops.scan_filter(
        words, 1, 100, rows=rows, padded_rows=padded, width=width))
    t_unpack = best(lambda: unpack(words))
    return dataclasses.replace(
        base,
        mem_gbps=rows * 4 / t_mem / 1e9,
        scan_gvps=rows / t_scan / 1e9,
        unpack_gvps=rows / t_unpack / 1e9,
        source=f"calibrated(rows={rows},width={width})",
    )


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=1 << 20)
    ap.add_argument("--width", type=int, default=12)
    ap.add_argument("--repeat", type=int, default=20)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)
    cal = calibrate(rows=args.rows, width=args.width, repeat=args.repeat,
                    cal=load(args.out, strict=False))
    path = save(cal, args.out)
    print(f"wrote {path}: mem {cal.mem_gbps:.2f} GB/s, "
          f"scan {cal.scan_gvps:.2f} Gv/s, unpack {cal.unpack_gvps:.2f} Gv/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
