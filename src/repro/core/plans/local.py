"""Queries whose joins are fully local thanks to co-partitioning (paper
§4.3: Q1, Q4, Q18, plus join-free Q6) — local aggregation + one collective
reduce; constant weak-scaling runtime in the paper's Fig. 2."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import aggregation, late_materialization, topk
from repro.core.plans.common import (
    DEFAULT_PARAMS as DP,
    dense_local_sum,
    local_index,
    my_keys,
    revenue,
)


def q1(ctx, t, p=DP):
    """Pricing summary report: 6-group aggregate over lineitem, merged with a
    collective reduction (custom reduce op in the paper = psum of the dense
    6x6 partial result here)."""
    li = t["lineitem"]
    sel = li["l_shipdate"] <= p.q1_shipdate_max
    group = li["l_returnflag"] * 2 + li["l_linestatus"]
    disc_price = revenue(li)
    charge = disc_price * (1.0 + li["l_tax"])
    measures = jnp.stack(
        [
            li["l_quantity"],
            li["l_extendedprice"],
            disc_price,
            charge,
            li["l_discount"],
            jnp.ones_like(disc_price),
        ],
        axis=1,
    )
    local = aggregation.group_sum_onehot(measures, group, 6, sel)
    return lax.psum(local, ctx.axis)


def q1_kernel(ctx, t, p=DP):
    """Q1 with the fused filter+aggregate Pallas kernel (repro.kernels.
    grouped_agg) as the local scan — the TPU-native hot loop."""
    from repro.kernels import ops

    li = t["lineitem"]
    disc_price = revenue(li)
    charge = disc_price * (1.0 + li["l_tax"])
    measures = jnp.stack(
        [
            li["l_quantity"],
            li["l_extendedprice"],
            disc_price,
            charge,
            li["l_discount"],
            jnp.ones_like(disc_price),
        ],
        axis=1,
    )
    group = li["l_returnflag"] * 2 + li["l_linestatus"]
    local = ops.filtered_group_sum(
        measures, group, li["l_shipdate"],
        cutoff=int(p.q1_shipdate_max), num_groups=6,
    )
    return lax.psum(local, ctx.axis)


def q6(ctx, t, p=DP):
    """Forecasting revenue change: fully local scan-filter-sum over lineitem
    plus one scalar psum — the simplest plan shape (and the IR lowering's
    1-group GroupAgg baseline)."""
    li = t["lineitem"]
    sel = (
        (li["l_shipdate"] >= p.q6_date_min)
        & (li["l_shipdate"] < p.q6_date_max)
        & (li["l_discount"] >= p.q6_disc_min)
        & (li["l_discount"] <= p.q6_disc_max)
        & (li["l_quantity"] < p.q6_quantity)
    )
    rev = li["l_extendedprice"] * li["l_discount"]
    return lax.psum(jnp.sum(jnp.where(sel, rev, 0.0)), ctx.axis)


def q4(ctx, t, p=DP):
    """Order priority checking: per-priority count of orders (date-filtered)
    having a late lineitem.  lineitem-orders are co-partitioned, so the
    EXISTS probe is a local scatter; one psum merges the 5 counters."""
    o = t["orders"]
    li = t["lineitem"]
    o_ok = (o["o_orderdate"] >= p.q4_date_min) & (o["o_orderdate"] < p.q4_date_max)
    late = li["l_commitdate"] < li["l_receiptdate"]
    rows = ctx.part("orders").rows_per_node
    has_late = jnp.zeros(rows, bool).at[local_index(ctx, "orders", li["l_orderkey"])].max(late)
    counts = aggregation.group_count(o["o_orderpriority"], 5, o_ok & has_late)
    return lax.psum(counts, ctx.axis)


def q18(ctx, t, p=DP, k: int = 100):
    """Large volume customers: local group-by (co-partitioned), local top-k,
    merging reduction (§3.2.3), then late materialization (§3.2.7) of the
    output-only attributes (c_name via remote fetch, order columns local)."""
    o = t["orders"]
    li = t["lineitem"]
    qty = dense_local_sum(ctx, "orders", li["l_orderkey"], li["l_quantity"])
    sel = qty > p.q18_quantity
    local = topk.local_topk(o["o_totalprice"], my_keys(ctx, "orders"), k, sel)
    winners = topk.topk_allreduce(local, ctx.axis)
    # late materialization: order-side attributes from order owners…
    order_attrs = late_materialization.materialize(
        winners.keys,
        winners.valid,
        ctx.part("orders"),
        {"o_custkey": o["o_custkey"], "o_orderdate": o["o_orderdate"], "sum_qty": qty},
        axis=ctx.axis,
    )
    # …then customer names from customer owners (the remote join path)
    cust_attrs = late_materialization.materialize(
        order_attrs["o_custkey"],
        winners.valid,
        ctx.part("customer"),
        {"c_name_code": t["customer"]["c_name_code"]},
        axis=ctx.axis,
    )
    return {
        "o_totalprice": winners.values,
        "o_orderkey": winners.keys,
        "valid": winners.valid,
        "o_custkey": order_attrs["o_custkey"],
        "o_orderdate": order_attrs["o_orderdate"],
        "sum_qty": order_attrs["sum_qty"],
        "c_name_code": cust_attrs["c_name_code"],
    }
