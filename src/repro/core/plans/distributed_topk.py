"""Queries aggregating on a NON-co-partitioned key (paper §4.3: Q15, Q21) —
every node holds a partial aggregate for every key; the total requires an
exchange.  Q15 is the paper's showcase for the §3.2.5 approximate top-k."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import exchange, late_materialization, semijoin, topk
from repro.core.topk_approx import approx_topk_distributed, simple_topk_distributed
from repro.core.plans.common import (
    DEFAULT_PARAMS as DP,
    dense_partials,
    local_index,
    my_keys,
    revenue,
)


# ---------------------------------------------------------------------------
# Q15 — top supplier (three variants, paper Fig. 4)
# ---------------------------------------------------------------------------


def _q15_partials(ctx, t, p):
    li = t["lineitem"]
    sel = (li["l_shipdate"] >= p.q15_date_min) & (li["l_shipdate"] < p.q15_date_max)
    return dense_partials(ctx, "supplier", li["l_suppkey"], revenue(li), sel)


def _q15_materialize(ctx, t, winners):
    sup = t["supplier"]
    attrs = late_materialization.materialize(
        winners.keys, winners.valid, ctx.part("supplier"),
        {
            "s_name_code": sup["s_name_code"],
            "s_address_code": sup["s_address_code"],
            "s_phone_code": sup["s_phone_code"],
        },
        axis=ctx.axis,
    )
    return {"total_revenue": winners.values, "s_suppkey": winners.keys,
            "valid": winners.valid, **attrs}


def q15(ctx, t, p=DP, k: int = 1):
    """Variant 1 (paper): ship ALL partial sums to each key's owner with the
    library all-to-all, aggregate, select the max."""
    winners = simple_topk_distributed(_q15_partials(ctx, t, p), k,
                                      axis=ctx.axis, backend="xla")
    return _q15_materialize(ctx, t, winners)


def q15_1factor(ctx, t, p=DP, k: int = 1):
    """Variant 2 (paper): same, but the exchange uses the 1-factor schedule
    (§3.2.6)."""
    winners = simple_topk_distributed(_q15_partials(ctx, t, p), k,
                                      axis=ctx.axis, backend="one_factor")
    return _q15_materialize(ctx, t, winners)


def _approx_group(ctx, requested: int) -> int:
    """Largest power-of-two group <= requested that divides the per-node key
    range (the paper's 1024, shrunk for tiny test tables)."""
    kp = ctx.part("supplier").total_rows // ctx.num_nodes
    g = 1
    while g * 2 <= min(requested, kp) and kp % (g * 2) == 0:
        g *= 2
    return g


def q15_approx(ctx, t, p=DP, k: int = 1, m: int = 8):
    """Variant 3 (paper §3.2.5): ship m-bit approximations of every partial
    sum; exact values only for the pruned candidate set (8x less traffic)."""
    winners, stats, overflow = approx_topk_distributed(
        _q15_partials(ctx, t, p), k, m=m,
        group=_approx_group(ctx, ctx.cap("q15_group", 1024)),
        candidate_capacity=ctx.cap("q15_candidates", 256),
        axis=ctx.axis, backend=ctx.backend,
    )
    out = _q15_materialize(ctx, t, winners)
    out["stats"] = stats
    out["overflow"] = overflow
    return out


# ---------------------------------------------------------------------------
# Q21 — suppliers who kept orders waiting (two variants)
# ---------------------------------------------------------------------------


def _q21_qualify(ctx, t):
    """Per-lineitem EXISTS / NOT EXISTS logic — local thanks to the
    lineitem-orders co-partitioning.  'exists another supplier's lineitem in
    this order' and 'no other supplier was late' are answered with sorted
    composite keys + run-length probes (the column-store formulation of the
    paper's per-order scan)."""
    li = t["lineitem"]
    o = t["orders"]
    rows = ctx.part("orders").rows_per_node
    num_sup = ctx.part("supplier").total_rows
    l_order_local = local_index(ctx, "orders", li["l_orderkey"])
    delayed = li["l_receiptdate"] > li["l_commitdate"]
    cnt_lines = jnp.zeros(rows, jnp.int32).at[l_order_local].add(1)
    cnt_delayed = jnp.zeros(rows, jnp.int32).at[l_order_local].add(delayed.astype(jnp.int32))
    comp = l_order_local * num_sup + li["l_suppkey"]
    sorted_comp = jnp.sort(comp)
    same_lines = (
        jnp.searchsorted(sorted_comp, comp, side="right")
        - jnp.searchsorted(sorted_comp, comp, side="left")
    ).astype(jnp.int32)
    delayed_comp = jnp.where(delayed, comp, jnp.iinfo(jnp.int32).max)
    sorted_delayed = jnp.sort(delayed_comp)
    same_delayed = (
        jnp.searchsorted(sorted_delayed, comp, side="right")
        - jnp.searchsorted(sorted_delayed, comp, side="left")
    ).astype(jnp.int32)
    status_f = o["o_orderstatus"][l_order_local] == 0
    return (
        delayed
        & status_f
        & (cnt_lines[l_order_local] - same_lines > 0)
        & (cnt_delayed[l_order_local] - same_delayed == 0)
    )


def _q21_finish(ctx, t, partials, k):
    """Route dense per-supplier partial counts to their owners, aggregate,
    global top-k by (numwait desc, suppkey asc)."""
    P = ctx.num_nodes
    NS = ctx.part("supplier").total_rows
    recv = exchange.all_to_all(partials.reshape(P, NS // P), ctx.axis,
                               backend=ctx.backend)
    numwait = jnp.sum(recv, axis=0)
    local = topk.local_topk(numwait, my_keys(ctx, "supplier"), k, numwait > 0)
    return topk.topk_allreduce(local, ctx.axis)


def q21(ctx, t, p=DP, k: int = 100):
    """Version 1 (paper): the supplier-nation filter is evaluated up front
    and replicated as a bitset (Alt-2); the group-by then counts only
    qualified suppliers."""
    li = t["lineitem"]
    sup = t["supplier"]
    qualify = _q21_qualify(ctx, t)
    words = semijoin.alt2_bitset(sup["s_nationkey"] == p.q21_nation, axis=ctx.axis)
    nation_ok = semijoin.probe(words, li["l_suppkey"], ctx.part("supplier"))
    partials = dense_partials(ctx, "supplier", li["l_suppkey"],
                              jnp.ones_like(li["l_suppkey"], jnp.float32),
                              qualify & nation_ok)
    return _q21_finish(ctx, t, partials, k)


def q21_late(ctx, t, p=DP, k: int = 100):
    """Version 2 (paper 'late'): aggregate WITHOUT the nation filter, then
    request the filter bits (Alt-1) only for suppliers that actually hold a
    delayed shipment."""
    li = t["lineitem"]
    sup = t["supplier"]
    qualify = _q21_qualify(ctx, t)
    partials = dense_partials(ctx, "supplier", li["l_suppkey"],
                              jnp.ones_like(li["l_suppkey"], jnp.float32), qualify)
    active = partials > 0
    sup_part = ctx.part("supplier")
    all_sup_keys = jnp.arange(sup_part.total_rows, dtype=jnp.int32)

    def nation_pred(local_idx, mask):
        return (sup["s_nationkey"][local_idx] == p.q21_nation) & mask

    bits, ovf = semijoin.alt1_request(
        all_sup_keys, active, sup_part, nation_pred,
        capacity=ctx.cap("q21_request", 1024), axis=ctx.axis, backend=ctx.backend,
        wire=ctx.wire_fmt("q21_request"),
    )
    partials = jnp.where(bits, partials, 0.0)
    winners = _q21_finish(ctx, t, partials, k)
    return winners, ovf
