"""Queries with remote filter attributes (paper §4.3: Q2, Q3, Q5, Q11, Q13,
Q14) — each exercises one of the §3.2.2 semi-join alternatives, the §3.2.4
lazy top-k, or the owner-routed group-by."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import aggregation, exchange, semijoin, topk
from repro.core.plans.common import (
    DEFAULT_PARAMS as DP,
    dense_local_sum,
    local_index,
    my_keys,
    revenue,
)
from repro.tpch import schema as S


# ---------------------------------------------------------------------------
# Q2 — minimum cost supplier (remote filter on supplier region, Alt-1)
# ---------------------------------------------------------------------------


def q2(ctx, t, p=DP, k: int = 100):
    part = t["part"]
    ps = t["partsupp"]
    sup = t["supplier"]
    sup_part = ctx.part("supplier")
    # local filters on part; partsupp co-partitioned with part
    psel = (part["p_size"] == p.q2_size) & (part["p_type"] % S.NUM_BRASS == p.q2_type_finish)
    ps_part_ok = psel[local_index(ctx, "part", ps["ps_partkey"])]
    # remote region filter on supplier — the paper requests it explicitly
    # (Alt-1: only ~0.4% of partsupps survive the local filter)
    def region_pred(local_idx, mask):
        return (S.nation_region(sup["s_nationkey"][local_idx]) == p.q2_region) & mask

    bits, ovf1 = semijoin.alt1_request(
        ps["ps_suppkey"], ps_part_ok, sup_part, region_pred,
        capacity=ctx.cap("q2_request", 512), axis=ctx.axis, backend=ctx.backend,
        wire=ctx.wire_fmt("q2_request"),
    )
    cand = ps_part_ok & bits
    # min supplycost per part (local: partsupp co-partitioned with part)
    rows = ctx.part("part").rows_per_node
    ps_local_part = local_index(ctx, "part", ps["ps_partkey"])
    cost = ps["ps_supplycost"]
    mincost = jnp.full(rows, jnp.inf, jnp.float32).at[ps_local_part].min(
        jnp.where(cand, cost, jnp.inf)
    )
    is_min = cand & (cost == mincost[ps_local_part])
    # ship (suppkey -> partkey) pairs to supplier owners; owners rank by
    # their local s_acctbal (paper: "send this information to the
    # corresponding nodes, sort by account balance")
    recv_sup, recv_part, recv_mask, ovf2 = exchange.exchange_by_owner(
        ps["ps_suppkey"], ps["ps_partkey"].astype(jnp.float32), is_min,
        sup_part.owner(ps["ps_suppkey"]),
        capacity=ctx.cap("q2_owner", 512), axis=ctx.axis, backend=ctx.backend,
        wire=ctx.wire_fmt("q2_owner"),
    )
    rs = recv_sup.reshape(-1)
    rp = recv_part.reshape(-1).astype(jnp.int32)
    rm = recv_mask.reshape(-1)
    bal = sup["s_acctbal"][local_index(ctx, "supplier", jnp.where(rm, rs, sup_part.my_base(ctx.axis)))]
    comp = rp * sup_part.total_rows + rs          # (partkey, suppkey) tiebreak
    local = topk.local_topk(bal, comp, k, rm)
    winners = topk.topk_allreduce(local, ctx.axis)
    return {
        "s_acctbal": winners.values,
        "part_supp_key": winners.keys,
        "valid": winners.valid,
        "overflow": ovf1 | ovf2,
    }


# ---------------------------------------------------------------------------
# Q3 — shipping priority: Alt-2 bitset version + §3.2.4 lazy version
# ---------------------------------------------------------------------------


def _q3_revenue_per_order(ctx, t, p, order_mask):
    li = t["lineitem"]
    l_ok = li["l_shipdate"] > p.q3_date
    l_order_local = local_index(ctx, "orders", li["l_orderkey"])
    sel = l_ok & order_mask[l_order_local]
    return dense_local_sum(ctx, "orders", li["l_orderkey"], revenue(li), sel)


def q3(ctx, t, p=DP, k: int = 10):
    """Version 1 (paper): evaluate the customer-segment filter once,
    replicate the bitset (Alt-2 / §3.2.2), then aggregate fully locally."""
    cust = t["customer"]
    o = t["orders"]
    c_bits = cust["c_mktsegment"] == p.q3_segment
    words = semijoin.alt2_bitset(c_bits, axis=ctx.axis)
    o_ok = (o["o_orderdate"] < p.q3_date) & semijoin.probe(
        words, o["o_custkey"], ctx.part("customer")
    )
    rev = _q3_revenue_per_order(ctx, t, p, o_ok)
    local = topk.local_topk(rev, my_keys(ctx, "orders"), k, rev > 0)
    return topk.topk_allreduce(local, ctx.axis)


def q3_lazy(ctx, t, p=DP, k: int = 10):
    """Version 2 (paper §3.2.4): aggregate on local data only, then lazily
    request the remote customer filter for chunks of locally-best orders."""
    o = t["orders"]
    cust = t["customer"]
    o_date_ok = o["o_orderdate"] < p.q3_date
    rev = _q3_revenue_per_order(ctx, t, p, o_date_ok)
    cust_part = ctx.part("customer")

    def seg_pred(local_idx, mask):
        return (cust["c_mktsegment"][local_idx] == p.q3_segment) & mask

    def remote_filter(order_keys, mask):
        custkeys = o["o_custkey"][local_index(ctx, "orders", order_keys)]
        return semijoin.alt1_request(
            custkeys, mask, cust_part, seg_pred,
            capacity=ctx.cap("q3_chunk", 256), axis=ctx.axis, backend=ctx.backend,
            wire=ctx.wire_fmt("q3_request"),
        )

    winners, overflow = topk.lazy_filtered_topk(
        rev, my_keys(ctx, "orders"), rev > 0, remote_filter, k,
        chunk=ctx.cap("q3_chunk", 256),
        max_rounds=ctx.cap("q3_rounds", 64),
        axis=ctx.axis,
    )
    return winners, overflow


def q3_repl(ctx, t, p=DP, k: int = 10):
    """Version 3 (paper 'repl'): the remote join attribute (c_mktsegment) is
    replicated at load time — fully local evaluation, constant runtime."""
    o = t["orders"]
    seg_all = t["customer_seg_repl"]["c_mktsegment"]  # replicated column
    o_ok = (o["o_orderdate"] < p.q3_date) & (seg_all[o["o_custkey"]] == p.q3_segment)
    rev = _q3_revenue_per_order(ctx, t, p, o_ok)
    local = topk.local_topk(rev, my_keys(ctx, "orders"), k, rev > 0)
    return topk.topk_allreduce(local, ctx.axis)


# ---------------------------------------------------------------------------
# Q5 — local supplier volume (replicated small column + Alt-1 request)
# ---------------------------------------------------------------------------


def q5(ctx, t, p=DP):
    o = t["orders"]
    li = t["lineitem"]
    sup = t["supplier"]
    cust = t["customer"]
    # supplier table is small: replicate its nation column (paper: "we
    # distribute their nation over all nodes")
    s_nat_all = lax.all_gather(sup["s_nationkey"], ctx.axis, tiled=True)
    o_ok = (o["o_orderdate"] >= p.q5_date_min) & (o["o_orderdate"] < p.q5_date_max)

    # request customer nation for date-qualified orders (Alt-1 reply is a
    # value, not a bit — same request/reply machinery)
    cust_part = ctx.part("customer")

    def nation_lookup(req_keys, mask):
        local_idx = cust_part.local_index(req_keys)
        return jnp.where(mask, cust["c_nationkey"][local_idx], -1)

    c_nat_order, ovf = exchange.request_reply(
        o["o_custkey"], o_ok, cust_part.owner(o["o_custkey"]),
        nation_lookup, capacity=ctx.cap("q5_request", 2048),
        axis=ctx.axis, backend=ctx.backend, reply_dtype=jnp.int32,
        wire=ctx.wire_fmt("q5_request"),
    )
    l_order_local = local_index(ctx, "orders", li["l_orderkey"])
    l_sup_nat = s_nat_all[li["l_suppkey"]]
    sel = (
        o_ok[l_order_local]
        & (S.nation_region(l_sup_nat) == p.q5_region)
        & (c_nat_order[l_order_local] == l_sup_nat)
    )
    rev = aggregation.group_sum_onehot(revenue(li), l_sup_nat, 25, sel)
    return lax.psum(rev, ctx.axis), ovf


# ---------------------------------------------------------------------------
# Q11 — important stock (Alt-2 bitset; threshold from a global allreduce)
# ---------------------------------------------------------------------------


def q11(ctx, t, p=DP, cap: int = 128, sf: float | None = None):
    ps = t["partsupp"]
    sup = t["supplier"]
    sf = ctx.scale_factor if sf is None else sf
    # no locally evaluable filter -> replicate the nation bitset (paper)
    words = semijoin.alt2_bitset(sup["s_nationkey"] == p.q11_nation, axis=ctx.axis)
    sel = semijoin.probe(words, ps["ps_suppkey"], ctx.part("supplier"))
    value = ps["ps_supplycost"] * ps["ps_availqty"]
    per_part = dense_local_sum(ctx, "part", ps["ps_partkey"], value, sel)
    total = lax.psum(jnp.sum(per_part), ctx.axis)     # allreduce (paper)
    thresh = total * (p.q11_fraction / sf)
    local = topk.local_topk(per_part, my_keys(ctx, "part"), cap, per_part > thresh)
    return topk.topk_allreduce(local, ctx.axis)


# ---------------------------------------------------------------------------
# Q13 — customer distribution (owner-routed group-by on a remote key)
# ---------------------------------------------------------------------------


def q13(ctx, t, p=DP, hist_cap: int = 64):
    o = t["orders"]
    cust_part = ctx.part("customer")
    sel = ~o["o_comment_special"]
    # ship qualified order->customer keys to the customers' owners
    recv_keys, recv_vals, recv_mask, ovf = exchange.exchange_by_owner(
        o["o_custkey"], jnp.ones_like(o["o_custkey"], dtype=jnp.float32), sel,
        cust_part.owner(o["o_custkey"]),
        capacity=ctx.cap("q13_route", 4096), axis=ctx.axis, backend=ctx.backend,
        wire=ctx.wire_fmt("q13_route"),
    )
    rows = cust_part.rows_per_node
    local_idx = jnp.where(
        recv_mask, recv_keys - cust_part.my_base(ctx.axis), rows
    ).reshape(-1)
    counts = jnp.zeros(rows, jnp.float32).at[local_idx].add(
        jnp.where(recv_mask, recv_vals, 0.0).reshape(-1), mode="drop"
    )
    # histogram over per-customer order counts (0 orders included — the SQL
    # left outer join)
    c_count = jnp.minimum(counts.astype(jnp.int32), hist_cap - 1)
    hist = aggregation.group_count(c_count, hist_cap)
    return lax.psum(hist, ctx.axis), ovf


# ---------------------------------------------------------------------------
# Q14 — promotion effect (Alt-1 request on part type)
# ---------------------------------------------------------------------------


def q14(ctx, t, p=DP):
    li = t["lineitem"]
    part = t["part"]
    sel = (li["l_shipdate"] >= p.q14_date_min) & (li["l_shipdate"] < p.q14_date_max)

    def promo_pred(local_idx, mask):
        return (part["p_type"][local_idx] < S.PROMO_TYPES) & mask

    promo, ovf = semijoin.alt1_request(
        li["l_partkey"], sel, ctx.part("part"), promo_pred,
        capacity=ctx.cap("q14_request", 2048), axis=ctx.axis, backend=ctx.backend,
        wire=ctx.wire_fmt("q14_request"),
    )
    rev = revenue(li)
    total = lax.psum(jnp.sum(jnp.where(sel, rev, 0.0)), ctx.axis)
    promo_rev = lax.psum(jnp.sum(jnp.where(sel & promo, rev, 0.0)), ctx.axis)
    return jnp.stack([100.0 * promo_rev / total, promo_rev, total]), ovf
