"""Shared plan helpers.

A *plan* is the paper's hand-translated query function: it runs inside
shard_map over the ``nodes`` axis, sees the local partition of every table,
and synchronizes only through the exchange layer.  XLA compiles each plan to
one SPMD executable (the paper's precompiled C++ function).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import PlanContext
from repro.tpch.schema import DEFAULT_PARAMS  # noqa: F401  (re-export)


def local_index(ctx: PlanContext, table: str, global_keys):
    """Global dense key -> local row index on the owner (co-partitioned
    access: caller guarantees the keys are locally owned)."""
    return global_keys - ctx.part(table).my_base(ctx.axis)


def my_keys(ctx: PlanContext, table: str):
    """Global keys of the local partition."""
    return ctx.part(table).global_keys(ctx.axis)


def revenue(li):
    """extendedprice * (1 - discount) — the TPC-H revenue measure."""
    return li["l_extendedprice"] * (1.0 - li["l_discount"])


def dense_local_sum(ctx: PlanContext, table: str, keys_global, values, mask=None):
    """Scatter-add values into a dense per-row vector of the LOCAL partition
    of ``table`` (keys must be locally owned — co-partitioned group-by)."""
    rows = ctx.part(table).rows_per_node
    idx = local_index(ctx, table, keys_global)
    v = values.astype(jnp.float32)
    if mask is not None:
        v = jnp.where(mask, v, 0.0)
    return jnp.zeros(rows, jnp.float32).at[idx].add(v)


def dense_partials(ctx: PlanContext, table: str, keys_global, values, mask=None):
    """Scatter-add into a dense vector over the GLOBAL key space of ``table``
    (partial aggregates for a remote group-by key — §3.2.5 input)."""
    total = ctx.part(table).total_rows
    v = values.astype(jnp.float32)
    if mask is not None:
        v = jnp.where(mask, v, 0.0)
    return jnp.zeros(total, jnp.float32).at[keys_global].add(v)
