"""Precompiled TPC-H query plans (paper §4.3) — one function per query,
plus the variants evaluated in the paper's Fig. 2/4 (lazy, repl, late,
1-factor, approx)."""
from __future__ import annotations

from repro.core.plans.local import q1, q1_kernel, q4, q18
from repro.core.plans.semijoin_plans import q2, q3, q3_lazy, q3_repl, q5, q11, q13, q14
from repro.core.plans.distributed_topk import (
    q15,
    q15_1factor,
    q15_approx,
    q21,
    q21_late,
)

PLANS = {
    "q1": q1,
    "q1_kernel": q1_kernel,
    "q2": q2,
    "q3": q3,
    "q3_lazy": q3_lazy,
    "q3_repl": q3_repl,
    "q4": q4,
    "q5": q5,
    "q11": q11,
    "q13": q13,
    "q14": q14,
    "q15": q15,
    "q15_1factor": q15_1factor,
    "q15_approx": q15_approx,
    "q18": q18,
    "q21": q21,
    "q21_late": q21_late,
}
