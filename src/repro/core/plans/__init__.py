"""Query registry: one ``QueryDef`` per TPC-H query/variant (paper §4.3).

Each entry binds, explicitly:

- ``plan``    the hand-written physical plan function (the escape hatch —
              one precompiled SPMD function per query, paper §3.2), and/or
- ``ir``      the declarative Query IR (``repro.query``) that lowers to the
              same substrate and that the cube router can match, and
- ``oracle``  the ``repro.tpch.reference`` key this query validates
              against — an explicit binding, so multi-suffix variants
              (``q15_1factor``, ``q21_late``) can't silently drift the way
              the old ``name.split("_")[0]`` munging could.

``PLANS`` remains the name -> hand-plan mapping for callers that want the
physical layer directly; ``get`` raises a typed :class:`UnknownPlanError`
instead of a bare ``KeyError``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.plans.local import q1, q1_kernel, q4, q6, q18
from repro.core.plans.semijoin_plans import q2, q3, q3_lazy, q3_repl, q5, q11, q13, q14
from repro.core.plans.distributed_topk import (
    q15,
    q15_1factor,
    q15_approx,
    q21,
    q21_late,
)
from repro.query.ir import Query, UnknownPlanError
from repro.tpch.queries import IR_QUERIES


@dataclasses.dataclass(frozen=True)
class QueryDef:
    """A registered query: physical plan and/or logical IR, plus the
    explicit oracle binding."""

    name: str
    oracle: Optional[str]                 # repro.tpch.reference.ALL key
    plan: Optional[Callable] = None       # hand-written physical plan
    ir: Optional[Query] = None            # declarative IR (lowerable)


def _d(name, oracle, plan=None):
    return QueryDef(name=name, oracle=oracle, plan=plan,
                    ir=IR_QUERIES.get(name))


REGISTRY = {
    q.name: q
    for q in (
        _d("q1", "q1", q1),
        _d("q1_kernel", "q1", q1_kernel),
        _d("q2", "q2", q2),
        _d("q3", "q3", q3),
        _d("q3_lazy", "q3", q3_lazy),
        _d("q3_repl", "q3", q3_repl),
        _d("q4", "q4", q4),
        _d("q5", "q5", q5),
        _d("q6", "q6", q6),
        _d("q11", "q11", q11),
        _d("q13", "q13", q13),
        _d("q14", "q14", q14),
        # IR-only (no hand plan): the Q14 semi-join shape, exercising the
        # cost-model alternative choice and derived request capacities
        _d("q14_promo", None),
        _d("q15", "q15", q15),
        _d("q15_1factor", "q15", q15_1factor),
        _d("q15_approx", "q15", q15_approx),
        _d("q18", "q18", q18),
        _d("q21", "q21", q21),
        _d("q21_late", "q21", q21_late),
    )
}


def get(name: str) -> QueryDef:
    try:
        return REGISTRY[name]
    except KeyError:
        raise UnknownPlanError(
            f"unknown query {name!r}; registered: {sorted(REGISTRY)}"
        ) from None


# physical layer, name -> hand plan (back-compat surface for benchmarks
# and the serving launcher)
PLANS = {n: d.plan for n, d in REGISTRY.items() if d.plan is not None}
