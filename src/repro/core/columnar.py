"""Sharded main-memory column store.

The paper's storage model (§3.1): every table is range-partitioned across the
P nodes of a shared-nothing cluster; only constant-size tables (NATION,
REGION) are replicated.  Here a *node* is a device along the 1-D ``nodes``
mesh axis, a *table* is a dict of equally-long columns, and a *partition* is
the per-device shard of each column (axis 0 sharded over ``nodes``).

String columns are dictionary-encoded at generation time (int32 codes plus a
host-side vocabulary), matching the paper's column-store assumption that
predicates run over dictionary positions, not raw strings.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import compression


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedColumn:
    """A bit-packed RESIDENT column: the execution format, not a wire
    format.  Codes are frame-of-reference (``offset``) or dictionary
    (``values``) positions packed at ``width`` bits into uint32 words.

    The layout is per-node: each node's ``padded_rows`` (a multiple of 32)
    values occupy exactly ``padded_rows * width / 32`` words, so the words
    array shards over the nodes axis with a plain ``P(axis)`` spec and a
    shard_map in_specs prefix broadcasts over the single ``words`` leaf.
    ``shape`` mirrors the raw column's row count in both the global view
    (host) and the local view (inside shard_map), which keeps row-count
    probes like ``next(iter(cols.values())).shape[0]`` working unchanged.
    """

    words: jax.Array                      # uint32, (nodes_present * wpn,)
    rows: int                             # valid rows per node
    padded_rows: int                      # multiple of 32
    width: int                            # bits per code, 1..30
    offset: int = 0                       # frame-of-reference bias
    values: Optional[tuple] = None        # sorted dictionary, or None (FOR)
    dtype: str = "int32"                  # 'int32' | 'float32' | 'bool'
    num_nodes: int = 1

    def tree_flatten(self):
        aux = (self.rows, self.padded_rows, self.width, self.offset,
               self.values, self.dtype, self.num_nodes)
        return (self.words,), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    @property
    def words_per_node(self) -> int:
        return (self.padded_rows * self.width) // 32

    @property
    def nodes_present(self) -> int:
        # global view: num_nodes * wpn words; local view (inside
        # shard_map): wpn words -> 1
        return self.words.shape[0] // max(self.words_per_node, 1)

    @property
    def shape(self) -> tuple:
        return (self.nodes_present * self.rows,)

    @property
    def nbytes(self) -> int:
        return int(self.words.shape[0]) * 4

    @property
    def raw_nbytes(self) -> int:
        """Bytes the same rows would occupy in the raw resident format."""
        itemsize = 1 if self.dtype == "bool" else 4
        return self.nodes_present * self.rows * itemsize

    def _from_codes(self, codes):
        """uint32 codes -> the column's logical dtype."""
        if self.values is not None:
            table = jnp.asarray(np.asarray(self.values,
                                           dtype=np.dtype(self.dtype)))
            return table[codes.astype(jnp.int32)]
        if self.dtype == "bool":
            return codes.astype(bool)
        out = codes.astype(jnp.int32) + jnp.int32(self.offset)
        return out.astype(jnp.dtype(self.dtype))

    def decode(self):
        """Full decode to a dense array (global or local view)."""
        wpn = self.words_per_node
        nodes = self.nodes_present
        w = self.words.reshape(nodes, wpn)
        codes = jax.vmap(
            lambda ww: compression.unpack_bits(ww, self.padded_rows,
                                               self.width))(w)
        return self._from_codes(codes[:, :self.rows].reshape(-1))

    def gather(self, idx):
        """Late materialization: decode ONLY the rows in ``idx`` (local
        view — row indices are node-local)."""
        codes = compression.gather_bits(self.words, idx, self.width)
        return self._from_codes(codes)


def _pad32(n: int) -> int:
    return -(-n // 32) * 32


def plan_packing(chunks: Sequence[np.ndarray],
                 max_width: int = 24) -> Optional[dict]:
    """Decide whether a column (given as per-node chunks) is
    pack-eligible, and with what parameters.  Returns
    ``{'width', 'offset', 'values', 'dtype'}`` or None (stay raw).

    Eligible: bools (width 1); ints whose span fits ``max_width`` bits
    (frame-of-reference); floats that are all-integral with a small span
    (FOR on the integer codes) or low-cardinality (sorted dictionary).
    """
    arr = np.concatenate([np.asarray(c) for c in chunks])
    if arr.size == 0:
        return None
    if arr.dtype == np.bool_:
        return {"width": 1, "offset": 0, "values": None, "dtype": "bool"}
    if np.issubdtype(arr.dtype, np.integer):
        lo, hi = int(arr.min()), int(arr.max())
        w = compression.required_width(hi - lo)
        if w > max_width:
            return None
        return {"width": max(1, w), "offset": lo, "values": None,
                "dtype": "int32"}
    if np.issubdtype(arr.dtype, np.floating):
        if not np.isfinite(arr).all():
            return None
        if (arr == np.floor(arr)).all():
            lo, hi = int(arr.min()), int(arr.max())
            w = compression.required_width(hi - lo)
            if w <= max_width:
                return {"width": max(1, w), "offset": lo, "values": None,
                        "dtype": "float32"}
        vals = np.unique(arr)
        if vals.size <= 64:
            w = compression.required_width(max(vals.size - 1, 0))
            return {"width": max(1, w), "offset": 0,
                    "values": tuple(float(v) for v in vals),
                    "dtype": "float32"}
    return None


def pack_column(chunks: Sequence[np.ndarray], spec: dict) -> PackedColumn:
    """Pack per-node chunks (equal length) into one PackedColumn with the
    globally consistent ``spec`` from :func:`plan_packing`."""
    rows = int(np.asarray(chunks[0]).shape[0])
    padded = _pad32(rows)
    width, offset, values = spec["width"], spec["offset"], spec["values"]
    parts = []
    for c in chunks:
        a = np.asarray(c)
        assert a.shape[0] == rows, "per-node chunks must be equal length"
        if values is not None:
            codes = np.searchsorted(np.asarray(values, a.dtype), a)
        elif a.dtype == np.bool_:
            codes = a.astype(np.uint32)
        else:
            codes = (a.astype(np.int64) - offset).astype(np.uint32)
        if padded > rows:
            codes = np.concatenate(
                [codes, np.zeros(padded - rows, np.uint32)])
        parts.append(np.asarray(
            compression.pack_bits(jnp.asarray(codes, jnp.uint32), width)))
    return PackedColumn(
        words=jnp.asarray(np.concatenate(parts)),
        rows=rows, padded_rows=padded, width=width, offset=offset,
        values=values, dtype=spec["dtype"], num_nodes=len(chunks))


def decode_columns(columns: Mapping) -> dict:
    """Decode any PackedColumn entries to dense arrays (raw columns pass
    through) — the compatibility shim for plans that consume raw arrays."""
    return {n: (c.decode() if isinstance(c, PackedColumn) else c)
            for n, c in columns.items()}


@dataclasses.dataclass
class Table:
    """A columnar table.

    columns: name -> array of shape (rows, ...) — global view.
    dictionaries: name -> tuple of strings for dictionary-encoded columns.
    replicated: if True the table is replicated on every node instead of
        partitioned (paper §3.1: only for tables with <= ~50 rows).
    """

    name: str
    columns: dict
    dictionaries: dict = dataclasses.field(default_factory=dict)
    replicated: bool = False

    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    def column_names(self) -> Sequence[str]:
        return tuple(self.columns.keys())

    def select(self, names: Sequence[str]) -> "Table":
        return Table(
            name=self.name,
            columns={n: self.columns[n] for n in names},
            dictionaries={n: d for n, d in self.dictionaries.items() if n in names},
            replicated=self.replicated,
        )

    def decode(self, name: str, codes) -> list:
        """Host-side dictionary decode for result presentation."""
        vocab = self.dictionaries[name]
        return [vocab[int(c)] for c in np.asarray(codes).ravel()]


def shard_table(table: Table, mesh: jax.sharding.Mesh, axis: str = "nodes") -> Table:
    """Place a table on the mesh: partitioned tables shard axis 0 over
    ``axis``; replicated tables are copied to every node."""
    spec = P() if table.replicated else P(axis)
    cols = {}
    for name, col in table.columns.items():
        sharding = NamedSharding(mesh, spec if not table.replicated else P())
        if isinstance(col, PackedColumn):
            cols[name] = dataclasses.replace(
                col, words=jax.device_put(jnp.asarray(col.words), sharding))
        else:
            cols[name] = jax.device_put(jnp.asarray(col), sharding)
    return Table(table.name, cols, table.dictionaries, table.replicated)


def local_view(columns: Mapping[str, jax.Array]) -> dict:
    """Identity helper used inside shard_map plans for readability: the
    per-device view of a table's columns (shard_map already delivers the
    local partition)."""
    return dict(columns)


def concat_tables(parts: Sequence[Table]) -> Table:
    """Host-side concatenation of per-node chunks (used to build the
    unpartitioned oracle input)."""
    first = parts[0]
    cols = {}
    for n in first.columns:
        vals = [p.columns[n] for p in parts]
        if isinstance(vals[0], PackedColumn):
            cols[n] = dataclasses.replace(
                vals[0],
                words=jnp.concatenate([v.words for v in vals]),
                num_nodes=sum(v.num_nodes for v in vals))
        else:
            cols[n] = np.concatenate([np.asarray(v) for v in vals], axis=0)
    return Table(first.name, cols, first.dictionaries, first.replicated)
