"""Sharded main-memory column store.

The paper's storage model (§3.1): every table is range-partitioned across the
P nodes of a shared-nothing cluster; only constant-size tables (NATION,
REGION) are replicated.  Here a *node* is a device along the 1-D ``nodes``
mesh axis, a *table* is a dict of equally-long columns, and a *partition* is
the per-device shard of each column (axis 0 sharded over ``nodes``).

String columns are dictionary-encoded at generation time (int32 codes plus a
host-side vocabulary), matching the paper's column-store assumption that
predicates run over dictionary positions, not raw strings.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class Table:
    """A columnar table.

    columns: name -> array of shape (rows, ...) — global view.
    dictionaries: name -> tuple of strings for dictionary-encoded columns.
    replicated: if True the table is replicated on every node instead of
        partitioned (paper §3.1: only for tables with <= ~50 rows).
    """

    name: str
    columns: dict
    dictionaries: dict = dataclasses.field(default_factory=dict)
    replicated: bool = False

    @property
    def num_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    def column_names(self) -> Sequence[str]:
        return tuple(self.columns.keys())

    def select(self, names: Sequence[str]) -> "Table":
        return Table(
            name=self.name,
            columns={n: self.columns[n] for n in names},
            dictionaries={n: d for n, d in self.dictionaries.items() if n in names},
            replicated=self.replicated,
        )

    def decode(self, name: str, codes) -> list:
        """Host-side dictionary decode for result presentation."""
        vocab = self.dictionaries[name]
        return [vocab[int(c)] for c in np.asarray(codes).ravel()]


def shard_table(table: Table, mesh: jax.sharding.Mesh, axis: str = "nodes") -> Table:
    """Place a table on the mesh: partitioned tables shard axis 0 over
    ``axis``; replicated tables are copied to every node."""
    spec = P() if table.replicated else P(axis)
    cols = {}
    for name, col in table.columns.items():
        sharding = NamedSharding(mesh, spec if not table.replicated else P())
        cols[name] = jax.device_put(jnp.asarray(col), sharding)
    return Table(table.name, cols, table.dictionaries, table.replicated)


def local_view(columns: Mapping[str, jax.Array]) -> dict:
    """Identity helper used inside shard_map plans for readability: the
    per-device view of a table's columns (shard_map already delivers the
    local partition)."""
    return dict(columns)


def concat_tables(parts: Sequence[Table]) -> Table:
    """Host-side concatenation of per-node chunks (used to build the
    unpartitioned oracle input)."""
    first = parts[0]
    cols = {
        n: np.concatenate([np.asarray(p.columns[n]) for p in parts], axis=0)
        for n in first.columns
    }
    return Table(first.name, cols, first.dictionaries, first.replicated)
