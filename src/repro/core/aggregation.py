"""Grouped aggregation (the paper's local-aggregation substrate, §4.3).

Small-cardinality group-bys (Q1: 6 groups, Q4: 5 groups, Q5: 25 nations) are
computed as *one-hot MXU contractions* — the TPU-native reformulation of the
paper's scalar hash-table inner loop (DESIGN.md §3.2).  Large dense key
spaces (revenue per supplier, orders per customer) use scatter-add into a
dense vector, which is the column-store analogue of the paper's dense
aggregation arrays.

Distributed variants combine local aggregates with a collective reduce —
the paper's "custom reduce operator merges the partial result sets".
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def group_sum_onehot(values, group_ids, num_groups: int, mask=None):
    """sum(values) per group via one-hot matmul: (G, n) @ (n, c) on the MXU.

    values: (n,) or (n, c) — c aggregates share one pass.
    Returns (G,) or (G, c) f32.
    """
    v = values if values.ndim == 2 else values[:, None]
    v = v.astype(jnp.float32)
    if mask is not None:
        v = jnp.where(mask[:, None], v, 0.0)
    onehot = (group_ids[None, :] == jnp.arange(num_groups, dtype=group_ids.dtype)[:, None])
    out = jnp.dot(onehot.astype(jnp.float32), v, preferred_element_type=jnp.float32)
    return out if values.ndim == 2 else out[:, 0]


def group_count(group_ids, num_groups: int, mask=None):
    ones = jnp.ones(group_ids.shape[0], jnp.float32)
    return group_sum_onehot(ones, group_ids, num_groups, mask)


def group_sum_dense(values, keys, num_keys: int, mask=None):
    """Dense scatter-add aggregation for large key spaces: out[k] += v."""
    v = values.astype(jnp.float32)
    if mask is not None:
        v = jnp.where(mask, v, 0.0)
        keys = jnp.where(mask, keys, 0)
    return jnp.zeros(num_keys, jnp.float32).at[keys].add(v)


def group_count_dense(keys, num_keys: int, mask=None):
    ones = jnp.ones(keys.shape[0], jnp.float32)
    return group_sum_dense(ones, keys, num_keys, mask)


def distributed_group_sum(values, group_ids, num_groups: int, mask=None, axis="nodes"):
    """Local one-hot aggregation + allreduce (paper Q1/Q4 pattern)."""
    return lax.psum(group_sum_onehot(values, group_ids, num_groups, mask), axis)


def segment_run_bounds(sorted_keys):
    """For each element of a sorted key array, the [start, end) bounds of its
    run of equal keys — vectorized run-length probe used by Q21's EXISTS
    logic (count of same-order / same-(order,supplier) lineitems)."""
    n = sorted_keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    left = jnp.searchsorted(sorted_keys, sorted_keys, side="left").astype(jnp.int32)
    right = jnp.searchsorted(sorted_keys, sorted_keys, side="right").astype(jnp.int32)
    del idx
    return left, right
