"""Inter-node communication layer (paper §3.2.6, §4.2) and its wire codec
(§3.2.1).

The paper exchanges data with MPI collectives (gather, allgather, scatter,
personalized all-to-all, reduce/allreduce with user-defined operators) and a
hand-rolled 1-factor all-to-all that beat the library implementation by 2x.

On TPU the collective *schedule* is still a tunable: XLA's ``all_to_all`` is
the fused, topology-aware default, and we additionally provide the paper's
1-factor algorithm as ``P-1`` ``ppermute`` rounds (partner of node ``u`` in
round ``i`` is ``(i - u) mod P``) — the ICI analogue of the paper's
non-blocking point-to-point schedule.  Both run inside ``shard_map`` over the
``nodes`` axis, and benchmarks compare them from the lowered HLO.

Wire formats
------------

Exchanged key sets are delta- and bit-packed before they hit the wire
(paper §3.2.1); a :class:`WireFormat` selects between:

``raw``     int32 key buckets + a separate bool-mask collective (+ a third
            collective for replies / values): 6–9 bytes per slot.

``packed``  one uint32 buffer per exchange.  Keys are made destination-
            relative (``key - dest * domain`` — every key routed to owner
            ``d`` of a range-partitioned table lies in ``[d*domain,
            (d+1)*domain)``), sorted, and Elias–Fano coded with a BOUNDED
            high universe: the low ``l = max(0, ceil(log2(domain)) - 4)``
            bits are fixed-width bit-packed (the catalog-derived width),
            the at-most-16 distinct high parts are unary-coded in a
            bitvector — the static-shape form of delta coding, ~``l + 2``
            bits/key for ANY bucket content, decodable with a CONSTANT
            number of zero-rank queries (``repro.kernels.wire_codec``).
            The validity mask is folded into the same payload as appended
            bitset words, eliminating the separate mask collective.

            Packed message layout, per destination row (uint32 words)::

              [ EF upper bitvector | EF lower bits | mask bitset | values ]
                capacity+domain/2^l  capacity*l/32   capacity/32   capacity
                bits (unary highs)   (packed lows)   (validity)    (fused
                                                                  payload,
                                                         exchange_by_owner
                                                                    only)

            Replies travel back as a packed bitset when they are boolean
            (the semi-join case), so a full request/reply round trip ships
            ``~(l + 4)/8`` bytes per slot instead of 6.

Packed buckets must be sorted ascending per destination; ``request_reply``
and ``exchange_by_owner`` pre-sort their inputs by key (the paper sorts key
sets before shipping them for better compression — §5.3) and scatter
replies back to the caller's original order.  The §3.2.2 byte-accurate cost
model in ``repro.core.compression`` shares ``ef_params`` with this codec,
so its Alt-1/Alt-2 choice reflects these exact wire shapes.

All functions here are called INSIDE shard_map; arrays are per-device views.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compression

# ---------------------------------------------------------------------------
# basic collectives (thin wrappers so plans read like the paper's pseudocode)
# ---------------------------------------------------------------------------


def axis_size(axis: str = "nodes") -> int:
    return lax.axis_size(axis)


def my_rank(axis: str = "nodes"):
    return lax.axis_index(axis)


def allreduce_sum(x, axis: str = "nodes"):
    return lax.psum(x, axis)


def allreduce_max(x, axis: str = "nodes"):
    return lax.pmax(x, axis)


def allreduce_min(x, axis: str = "nodes"):
    return lax.pmin(x, axis)


def allgather(x, axis: str = "nodes", tiled: bool = False):
    """MPI_Allgather: every node receives every node's ``x``.
    tiled=False stacks a leading P axis; tiled=True concatenates on axis 0."""
    return lax.all_gather(x, axis, tiled=tiled)


def broadcast_from(x, root: int, axis: str = "nodes"):
    """MPI_Bcast via masked psum (root contributes, others contribute 0)."""
    contrib = jnp.where(my_rank(axis) == root, x, jnp.zeros_like(x))
    return lax.psum(contrib, axis)


# ---------------------------------------------------------------------------
# personalized all-to-all: XLA backend and the paper's 1-factor schedule
# ---------------------------------------------------------------------------


def all_to_all(x, axis: str = "nodes", *, backend: str = "xla"):
    """Personalized all-to-all.

    ``x`` has shape (P, m, ...) on every node: row ``d`` is the message for
    node ``d``.  Returns shape (P, m, ...): row ``s`` is the message received
    from node ``s``.

    backend="xla": single fused lax.all_to_all (default; ICI-topology-aware).
    backend="one_factor": the paper's §3.2.6 algorithm — P rounds of paired
    exchanges via ppermute, partner of u in round i is (i - u) mod P.
    """
    if backend == "xla":
        return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)
    if backend == "one_factor":
        return _all_to_all_one_factor(x, axis)
    raise ValueError(f"unknown all_to_all backend: {backend}")


def _all_to_all_one_factor(x, axis: str):
    """1-factor personalized all-to-all [Sanders & Träff 2002].

    Round i pairs u with v = (i - u) mod P (self-paired when 2u ≡ i mod P,
    which is a local copy).  Each round is one ppermute whose permutation IS
    the 1-factor: u -> (i - u) mod P.  Because the pairing is an involution
    (v(v(u)) = u), sending x[partner] to the partner delivers exactly the
    personalized message, and P rounds cover all partners.
    """
    P = lax.axis_size(axis)
    u = lax.axis_index(axis)
    out = jnp.zeros_like(x)
    for i in range(P):
        partner = (i - u) % P  # traced per-device value, same formula everywhere
        # message this node must send in round i: the row addressed to partner
        msg = jnp.take(x, partner, axis=0)
        perm = [(src, (i - src) % P) for src in range(P)]
        recv = lax.ppermute(msg, axis, perm)
        # recv came from the same partner (involution); store at its slot
        out = lax.dynamic_update_index_in_dim(out, recv, partner, axis=0)
    return out


# ---------------------------------------------------------------------------
# butterfly reduce with a user-defined merge operator (paper §3.2.3)
# ---------------------------------------------------------------------------


def butterfly_allreduce(state, merge: Callable, axis: str = "nodes"):
    """Allreduce with an arbitrary merge operator in log2(P) rounds.

    MPI lets the paper register custom reduce operators (merge two sorted
    top-k lists).  XLA reduces are element-wise monoids, so we build the
    log-depth schedule explicitly: round r exchanges ``state`` with the
    XOR-partner ``u ^ 2^r`` (recursive doubling) and merges.  Every node ends
    with the full reduction (the allreduce flavor — the paper notes the
    gather-based alternative has Θ(kP) bottleneck volume vs Θ(k log P) here).

    Requires P to be a power of two (all evaluation meshes are).
    A TUPLE of axis names folds the reduction over each axis in turn
    (the combined group is the product — used by the decode-optimized
    (model_kv, model_b) vocab sharding).
    """
    if isinstance(axis, (tuple, list)):
        for ax in axis:
            state = butterfly_allreduce(state, merge, ax)
        return state
    P = lax.axis_size(axis)
    assert P & (P - 1) == 0, f"butterfly requires power-of-two nodes, got {P}"
    rounds = P.bit_length() - 1
    for r in range(rounds):
        d = 1 << r
        perm = [(u, u ^ d) for u in range(P)]
        other = jax.tree.map(lambda s: lax.ppermute(s, axis, perm), state)
        state = merge(state, other)
    return state


# ---------------------------------------------------------------------------
# wire codec: m-bit packed key buckets with the validity mask folded in
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Wire encoding of an exchange (see module docstring for the layout).

    ``domain`` is the per-destination key domain — ``rows_per_node`` of the
    range-partitioned target table, so every key routed to destination
    ``d`` lies in ``[d*domain, (d+1)*domain)``.  ``key_bits`` is the
    catalog-derived ``required_width(domain - 1)`` (informational; the
    codec derives its exact split from ``domain`` and the capacity)."""

    kind: str = "raw"   # "raw" | "packed"
    domain: int = 0     # per-destination key domain (target rows_per_node)
    key_bits: int = 0   # required_width(domain - 1)

    @property
    def packed(self) -> bool:
        return self.kind == "packed" and self.domain > 0

    @classmethod
    def raw(cls) -> "WireFormat":
        return cls()

    @classmethod
    def packed_for(cls, total_rows: int, num_nodes: int) -> "WireFormat":
        dom = max(1, int(total_rows) // max(num_nodes, 1))
        return cls(kind="packed", domain=dom,
                   key_bits=compression.required_width(dom - 1))


def _pack_mask_rows(mask):
    """(P, c) bool -> (P, ceil(c/32)) uint32 bitset rows (kernel-backed)."""
    from repro.kernels import ops

    return ops.mask_fold(mask)


def _unpack_mask_rows(words, c: int):
    from repro.kernels import ops

    return ops.mask_unfold(words, n=c)


def encode_key_buckets(buckets, bucket_mask, wf: WireFormat):
    """Encode (P, capacity) key buckets into the packed wire message
    (P, packed_request_words) uint32.  Valid keys of row ``d`` MUST be a
    sorted ascending prefix with values in ``[d*domain, (d+1)*domain)`` —
    ``_bucket_presorted`` on key-sorted input produces exactly that.
    Delegates to the kernel codec (``repro.kernels.ops.ef_encode``);
    ``repro.kernels.ref.ef_encode`` is the bit-identical oracle."""
    from repro.kernels import ops

    return ops.ef_encode(buckets, bucket_mask, domain=wf.domain)


def decode_key_buckets(words, capacity: int, wf: WireFormat, my_base):
    """Inverse of :func:`encode_key_buckets` on the receiving node: returns
    (global keys (P, capacity) int32, mask (P, capacity) bool).  ``my_base``
    is the receiver's first owned key (``rank * domain``)."""
    from repro.kernels import ops

    return ops.ef_decode(words, my_base, capacity=capacity, domain=wf.domain)


def _sort_by_key(keys, mask, *aligned):
    """Pre-sort an exchange's inputs by key value so per-destination buckets
    come out ascending (the packed codec's precondition; §5.3 — the paper
    sorts key sets before shipping for better compression).  Masked keys
    sort LAST (sentinel), so the sorted order is grouped by destination —
    owners are monotone in key under range partitioning — which is what
    :func:`_bucket_presorted` requires.  Returns the permutation (for
    scattering results back) and the reordered arrays."""
    order = jnp.argsort(jnp.where(mask, keys, jnp.int32(2**31 - 1)))
    return (order, keys[order], mask[order]) + tuple(a[order] for a in aligned)


def _bucket_presorted(keys, mask, owner, num_nodes: int, capacity: int):
    """Bucket KEY-SORTED masked keys into per-destination rows with gathers
    only — no (n,)-sized scatters.  After :func:`_sort_by_key` the valid
    keys form contiguous runs per destination (range partitioning makes the
    owner monotone in key; masked keys sit at the end), so each bucket row
    is a strided gather from ``starts[d]``.

    Returns (buckets, bucket_mask, (dest_of_key, slot_of_key), src,
    overflow); ``src`` is the (P, capacity) gather index used for the
    buckets, reusable for aligned payloads (fused value rows)."""
    n = keys.shape[0]
    dest = jnp.where(mask, owner, num_nodes)  # masked keys -> virtual node P
    counts = jnp.zeros(num_nodes + 1, jnp.int32).at[dest].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_group = jnp.arange(n, dtype=jnp.int32) - starts[dest]
    overflow = jnp.any((pos_in_group >= capacity) & (dest < num_nodes))
    slot_of_key = jnp.minimum(pos_in_group, capacity - 1)
    s = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    src = jnp.minimum(starts[:num_nodes][:, None] + s, n - 1)
    bucket_mask = s < jnp.minimum(counts[:num_nodes], capacity)[:, None]
    buckets = jnp.where(bucket_mask, keys[src], 0)
    return buckets, bucket_mask, (dest, slot_of_key), src, overflow


def _codec_prediction(capacity: int, P: int, wf: WireFormat):
    """Predicted (encode_ms, decode_ms) of this exchange's packed codec
    under the machine calibration — trace-time observability only (events
    and histograms), never part of the compiled computation.  0.0 on raw
    wire (no codec runs)."""
    if not wf.packed:
        return 0.0, 0.0
    from repro.core import wirecal

    return wirecal.predict_codec_ms(int(capacity), int(P), wf.domain,
                                    cal=wirecal.cached())


# ---------------------------------------------------------------------------
# request/reply exchange for remote lookups (paper §3.2.2 Alternative 1)
# ---------------------------------------------------------------------------


def bucket_by_destination(keys, mask, owner, num_nodes: int, capacity: int):
    """Pack a masked set of keys into fixed-capacity per-destination buckets.

    Returns (buckets, bucket_mask, slot_of_key, overflow):
      buckets     (P, capacity) int32 — keys routed to each destination
      bucket_mask (P, capacity) bool
      slot_of_key (n, 2) int32 — (dest, slot) for each input key (for
                  scattering replies back); masked keys get (0, capacity-1).
      overflow    bool scalar — True if any bucket overflowed (the plan's
                  capacity estimate was too small; surfaced to the caller).
    """
    n = keys.shape[0]
    dest = jnp.where(mask, owner, num_nodes)  # masked keys -> virtual node P
    # stable counting sort by destination
    order = jnp.argsort(dest, stable=True)
    sorted_dest = dest[order]
    sorted_keys = keys[order]
    # position within destination group
    counts = jnp.zeros(num_nodes + 1, jnp.int32).at[sorted_dest].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_group = jnp.arange(n, dtype=jnp.int32) - starts[sorted_dest]
    overflow = jnp.any((pos_in_group >= capacity) & (sorted_dest < num_nodes))
    slot = jnp.minimum(pos_in_group, capacity - 1)
    valid = (sorted_dest < num_nodes) & (pos_in_group < capacity)
    # invalid entries scatter to the out-of-bounds row num_nodes and are
    # DROPPED (never clobber a live slot)
    scatter_dest = jnp.where(valid, sorted_dest, num_nodes)
    buckets = jnp.full((num_nodes, capacity), 0, dtype=keys.dtype)
    buckets = buckets.at[scatter_dest, slot].set(sorted_keys, mode="drop")
    bucket_mask = jnp.zeros((num_nodes, capacity), bool)
    bucket_mask = bucket_mask.at[scatter_dest, slot].set(True, mode="drop")
    # mapping back: for input position order[j] the reply lives at
    # (sorted_dest[j], slot[j])
    inv = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    dest_of_key = sorted_dest[inv]
    slot_of_key = slot[inv]
    return buckets, bucket_mask, (dest_of_key, slot_of_key), overflow


def request_reply(
    keys,
    mask,
    owner,
    lookup: Callable,
    *,
    capacity: int,
    axis: str = "nodes",
    backend: str = "xla",
    reply_dtype=None,
    wire: Optional[WireFormat] = None,
    observer=None,
    label: str = "",
):
    """The paper's explicit remote request pattern (§3.2.2 Alt-1):

    1. after all local filtering, collect the keys each node still needs,
    2. route them to their owners with a personalized all-to-all,
    3. owners answer with ``lookup(keys, mask) -> values`` (e.g. one filter
       bit per key),
    4. a second all-to-all returns the replies, scattered back to the
       original key order.

    With a packed ``wire`` the request keys are Elias–Fano coded at their
    catalog-derived width with the validity mask folded into the same
    uint32 buffer (ONE request collective instead of two), and boolean
    replies travel back as a packed bitset.  Packed wire requires the
    destinations to be the owners of a range-partitioned key space with
    ``wire.domain`` rows per node.

    Returns (replies aligned with ``keys``, overflow flag).
    """
    P = lax.axis_size(axis)
    wf = wire or WireFormat.raw()
    if observer is not None:
        # fires at TRACE time — once per compiled specialization, with the
        # exchange's static shape (the dynamic byte truth comes from HLO)
        enc_ms, dec_ms = _codec_prediction(capacity, P, wf)
        observer.event(
            "exchange.request_reply", cat="exchange", label=label,
            capacity=int(capacity), wire=wf.kind,
            key_bits=int(wf.key_bits), backend=backend,
            collectives=2 if wf.packed else 3,
            encode_ms=enc_ms, decode_ms=dec_ms,
        )
        observer.metrics.histogram("exchange.encode_ms").record(enc_ms)
        observer.metrics.histogram("exchange.decode_ms").record(dec_ms)
    order = None
    if wf.packed:
        # sorted + gather-bucketed + EF-coded: no (n,)-sized scatter touches
        # the packed hot path (codec and bucketing are gather/reshape only)
        order, keys, mask, owner = _sort_by_key(keys, mask, owner)
        buckets, bucket_mask, (dest_of_key, slot_of_key), _, overflow = (
            _bucket_presorted(keys, mask, owner, P, capacity)
        )
        msg = encode_key_buckets(buckets, bucket_mask, wf)
        my_base = lax.axis_index(axis) * wf.domain
        req, req_mask = decode_key_buckets(
            all_to_all(msg, axis, backend=backend), capacity, wf, my_base
        )
    else:
        buckets, bucket_mask, (dest_of_key, slot_of_key), overflow = (
            bucket_by_destination(keys, mask, owner, P, capacity)
        )
        req = all_to_all(buckets, axis, backend=backend)
        req_mask = all_to_all(bucket_mask, axis, backend=backend)
    # owners evaluate the lookup on their partition
    flat_req = req.reshape(P * capacity)
    flat_mask = req_mask.reshape(P * capacity)
    replies = lookup(flat_req, flat_mask)
    if reply_dtype is not None:
        replies = replies.astype(reply_dtype)
    replies = replies.reshape(P, capacity)
    # ship replies back (boolean replies as a packed bitset on packed wire)
    if wf.packed and replies.dtype == jnp.bool_:
        back_words = all_to_all(_pack_mask_rows(replies), axis, backend=backend)
        back = _unpack_mask_rows(back_words, capacity)
    else:
        back = all_to_all(replies, axis, backend=backend)
    # gather each key's reply from (dest, slot); masked keys point at the
    # (clamped) out-of-bounds row, so zero them explicitly
    out = back[jnp.minimum(dest_of_key, P - 1), slot_of_key]
    out = jnp.where(mask, out, jnp.zeros_like(out))
    if order is not None:
        out = jnp.zeros_like(out).at[order].set(out)  # undo the wire sort
    return out, overflow


# ---------------------------------------------------------------------------
# scatter-to-owner exchange (route values to the node owning their key)
# ---------------------------------------------------------------------------


def exchange_by_owner(
    keys,
    values,
    mask,
    owner,
    *,
    capacity: int,
    axis: str = "nodes",
    backend: str = "xla",
    wire: Optional[WireFormat] = None,
    observer=None,
    label: str = "",
):
    """Route (key, value) pairs to the owner node of each key (used when a
    group-by key lies on a remote join path — paper Q13/Q15/Q21).

    With a packed ``wire`` (and a 4-byte value dtype) the packed key
    buckets, the folded validity mask AND the bitcast value buckets fuse
    into ONE uint32 buffer, so the whole exchange is a single collective
    instead of three.  Received slot order is then per-sender key-sorted
    (callers are order-agnostic: they scatter by the received keys).

    Returns (recv_keys, recv_values, recv_mask, overflow): the pairs this
    node received, shape (P, capacity).
    """
    P = lax.axis_size(axis)
    wf = wire or WireFormat.raw()
    fused = wf.packed and values.dtype.itemsize == 4
    if observer is not None:
        enc_ms, dec_ms = _codec_prediction(capacity, P, wf)
        observer.event(
            "exchange.by_owner", cat="exchange", label=label,
            capacity=int(capacity), wire=wf.kind,
            key_bits=int(wf.key_bits), backend=backend,
            collectives=1 if fused else 3,
            encode_ms=enc_ms, decode_ms=dec_ms,
        )
        observer.metrics.histogram("exchange.encode_ms").record(enc_ms)
        observer.metrics.histogram("exchange.decode_ms").record(dec_ms)
    if fused:
        # no un-sort needed: callers consume the received buckets by key
        _, keys, mask, values, owner = _sort_by_key(keys, mask, values, owner)
        buckets, bucket_mask, _, src, overflow = _bucket_presorted(
            keys, mask, owner, P, capacity
        )
        # value rows ride the same gather index as the key buckets
        vbuckets = jnp.where(bucket_mask, values[src], 0)
        msg = jnp.concatenate(
            [encode_key_buckets(buckets, bucket_mask, wf),
             lax.bitcast_convert_type(vbuckets, jnp.uint32)],
            axis=1,
        )
        recv = all_to_all(msg, axis, backend=backend)
        my_base = lax.axis_index(axis) * wf.domain
        recv_keys, recv_mask = decode_key_buckets(
            recv[:, :-capacity], capacity, wf, my_base
        )
        recv_vals = lax.bitcast_convert_type(recv[:, -capacity:], values.dtype)
        recv_vals = jnp.where(recv_mask, recv_vals, 0)
        return recv_keys, recv_vals, recv_mask, overflow
    buckets, bucket_mask, (dest_of_key, slot_of_key), overflow = (
        bucket_by_destination(keys, mask, owner, P, capacity)
    )
    vbuckets = jnp.zeros((P, capacity), values.dtype)
    # masked keys carry dest == P (out of bounds) and are dropped
    vbuckets = vbuckets.at[dest_of_key, slot_of_key].set(values, mode="drop")
    vbuckets = jnp.where(bucket_mask, vbuckets, 0)
    recv_keys = all_to_all(buckets, axis, backend=backend)
    recv_vals = all_to_all(vbuckets, axis, backend=backend)
    recv_mask = all_to_all(bucket_mask, axis, backend=backend)
    return recv_keys, recv_vals, recv_mask, overflow


def exchange_vectors_by_owner(
    keys,
    vectors,
    mask,
    owner,
    *,
    capacity: int,
    axis: str = "nodes",
    backend: str = "xla",
):
    """exchange_by_owner for VECTOR payloads (d-dim rows) — the MoE expert
    dispatch case: route (expert_id, token_vector) pairs to the expert's
    owner rank with the paper's personalized all-to-all (§3.2.6 backend
    selectable).  Returns (recv_keys (P,cap), recv_vectors (P,cap,d),
    recv_mask (P,cap), (dest,slot) of each input, overflow)."""
    P = lax.axis_size(axis)
    d = vectors.shape[-1]
    buckets, bucket_mask, (dest_of_key, slot_of_key), overflow = (
        bucket_by_destination(keys, mask, owner, P, capacity)
    )
    vbuckets = jnp.zeros((P, capacity, d), vectors.dtype)
    vbuckets = vbuckets.at[dest_of_key, slot_of_key].set(vectors, mode="drop")
    vbuckets = jnp.where(bucket_mask[..., None], vbuckets, 0)
    recv_keys = all_to_all(buckets, axis, backend=backend)
    recv_vecs = all_to_all(vbuckets, axis, backend=backend)
    recv_mask = all_to_all(bucket_mask, axis, backend=backend)
    return recv_keys, recv_vecs, recv_mask, (dest_of_key, slot_of_key), overflow
