"""Inter-node communication layer (paper §3.2.6, §4.2).

The paper exchanges data with MPI collectives (gather, allgather, scatter,
personalized all-to-all, reduce/allreduce with user-defined operators) and a
hand-rolled 1-factor all-to-all that beat the library implementation by 2x.

On TPU the collective *schedule* is still a tunable: XLA's ``all_to_all`` is
the fused, topology-aware default, and we additionally provide the paper's
1-factor algorithm as ``P-1`` ``ppermute`` rounds (partner of node ``u`` in
round ``i`` is ``(i - u) mod P``) — the ICI analogue of the paper's
non-blocking point-to-point schedule.  Both run inside ``shard_map`` over the
``nodes`` axis, and benchmarks compare them from the lowered HLO.

All functions here are called INSIDE shard_map; arrays are per-device views.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# basic collectives (thin wrappers so plans read like the paper's pseudocode)
# ---------------------------------------------------------------------------


def axis_size(axis: str = "nodes") -> int:
    return lax.axis_size(axis)


def my_rank(axis: str = "nodes"):
    return lax.axis_index(axis)


def allreduce_sum(x, axis: str = "nodes"):
    return lax.psum(x, axis)


def allreduce_max(x, axis: str = "nodes"):
    return lax.pmax(x, axis)


def allreduce_min(x, axis: str = "nodes"):
    return lax.pmin(x, axis)


def allgather(x, axis: str = "nodes", tiled: bool = False):
    """MPI_Allgather: every node receives every node's ``x``.
    tiled=False stacks a leading P axis; tiled=True concatenates on axis 0."""
    return lax.all_gather(x, axis, tiled=tiled)


def broadcast_from(x, root: int, axis: str = "nodes"):
    """MPI_Bcast via masked psum (root contributes, others contribute 0)."""
    contrib = jnp.where(my_rank(axis) == root, x, jnp.zeros_like(x))
    return lax.psum(contrib, axis)


# ---------------------------------------------------------------------------
# personalized all-to-all: XLA backend and the paper's 1-factor schedule
# ---------------------------------------------------------------------------


def all_to_all(x, axis: str = "nodes", *, backend: str = "xla"):
    """Personalized all-to-all.

    ``x`` has shape (P, m, ...) on every node: row ``d`` is the message for
    node ``d``.  Returns shape (P, m, ...): row ``s`` is the message received
    from node ``s``.

    backend="xla": single fused lax.all_to_all (default; ICI-topology-aware).
    backend="one_factor": the paper's §3.2.6 algorithm — P rounds of paired
    exchanges via ppermute, partner of u in round i is (i - u) mod P.
    """
    if backend == "xla":
        return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)
    if backend == "one_factor":
        return _all_to_all_one_factor(x, axis)
    raise ValueError(f"unknown all_to_all backend: {backend}")


def _all_to_all_one_factor(x, axis: str):
    """1-factor personalized all-to-all [Sanders & Träff 2002].

    Round i pairs u with v = (i - u) mod P (self-paired when 2u ≡ i mod P,
    which is a local copy).  Each round is one ppermute whose permutation IS
    the 1-factor: u -> (i - u) mod P.  Because the pairing is an involution
    (v(v(u)) = u), sending x[partner] to the partner delivers exactly the
    personalized message, and P rounds cover all partners.
    """
    P = lax.axis_size(axis)
    u = lax.axis_index(axis)
    out = jnp.zeros_like(x)
    for i in range(P):
        partner = (i - u) % P  # traced per-device value, same formula everywhere
        # message this node must send in round i: the row addressed to partner
        msg = jnp.take(x, partner, axis=0)
        perm = [(src, (i - src) % P) for src in range(P)]
        recv = lax.ppermute(msg, axis, perm)
        # recv came from the same partner (involution); store at its slot
        out = lax.dynamic_update_index_in_dim(out, recv, partner, axis=0)
    return out


# ---------------------------------------------------------------------------
# butterfly reduce with a user-defined merge operator (paper §3.2.3)
# ---------------------------------------------------------------------------


def butterfly_allreduce(state, merge: Callable, axis: str = "nodes"):
    """Allreduce with an arbitrary merge operator in log2(P) rounds.

    MPI lets the paper register custom reduce operators (merge two sorted
    top-k lists).  XLA reduces are element-wise monoids, so we build the
    log-depth schedule explicitly: round r exchanges ``state`` with the
    XOR-partner ``u ^ 2^r`` (recursive doubling) and merges.  Every node ends
    with the full reduction (the allreduce flavor — the paper notes the
    gather-based alternative has Θ(kP) bottleneck volume vs Θ(k log P) here).

    Requires P to be a power of two (all evaluation meshes are).
    A TUPLE of axis names folds the reduction over each axis in turn
    (the combined group is the product — used by the decode-optimized
    (model_kv, model_b) vocab sharding).
    """
    if isinstance(axis, (tuple, list)):
        for ax in axis:
            state = butterfly_allreduce(state, merge, ax)
        return state
    P = lax.axis_size(axis)
    assert P & (P - 1) == 0, f"butterfly requires power-of-two nodes, got {P}"
    rounds = P.bit_length() - 1
    for r in range(rounds):
        d = 1 << r
        perm = [(u, u ^ d) for u in range(P)]
        other = jax.tree.map(lambda s: lax.ppermute(s, axis, perm), state)
        state = merge(state, other)
    return state


# ---------------------------------------------------------------------------
# request/reply exchange for remote lookups (paper §3.2.2 Alternative 1)
# ---------------------------------------------------------------------------


def bucket_by_destination(keys, mask, owner, num_nodes: int, capacity: int):
    """Pack a masked set of keys into fixed-capacity per-destination buckets.

    Returns (buckets, bucket_mask, slot_of_key, overflow):
      buckets     (P, capacity) int32 — keys routed to each destination
      bucket_mask (P, capacity) bool
      slot_of_key (n, 2) int32 — (dest, slot) for each input key (for
                  scattering replies back); masked keys get (0, capacity-1).
      overflow    bool scalar — True if any bucket overflowed (the plan's
                  capacity estimate was too small; surfaced to the caller).
    """
    n = keys.shape[0]
    dest = jnp.where(mask, owner, num_nodes)  # masked keys -> virtual node P
    # stable counting sort by destination
    order = jnp.argsort(dest, stable=True)
    sorted_dest = dest[order]
    sorted_keys = keys[order]
    # position within destination group
    counts = jnp.zeros(num_nodes + 1, jnp.int32).at[sorted_dest].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_group = jnp.arange(n, dtype=jnp.int32) - starts[sorted_dest]
    overflow = jnp.any((pos_in_group >= capacity) & (sorted_dest < num_nodes))
    slot = jnp.minimum(pos_in_group, capacity - 1)
    valid = (sorted_dest < num_nodes) & (pos_in_group < capacity)
    # invalid entries scatter to the out-of-bounds row num_nodes and are
    # DROPPED (never clobber a live slot)
    scatter_dest = jnp.where(valid, sorted_dest, num_nodes)
    buckets = jnp.full((num_nodes, capacity), 0, dtype=keys.dtype)
    buckets = buckets.at[scatter_dest, slot].set(sorted_keys, mode="drop")
    bucket_mask = jnp.zeros((num_nodes, capacity), bool)
    bucket_mask = bucket_mask.at[scatter_dest, slot].set(True, mode="drop")
    # mapping back: for input position order[j] the reply lives at
    # (sorted_dest[j], slot[j])
    inv = jnp.zeros(n, jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    dest_of_key = sorted_dest[inv]
    slot_of_key = slot[inv]
    return buckets, bucket_mask, (dest_of_key, slot_of_key), overflow


def request_reply(
    keys,
    mask,
    owner,
    lookup: Callable,
    *,
    capacity: int,
    axis: str = "nodes",
    backend: str = "xla",
    reply_dtype=None,
):
    """The paper's explicit remote request pattern (§3.2.2 Alt-1):

    1. after all local filtering, collect the keys each node still needs,
    2. route them to their owners with a personalized all-to-all,
    3. owners answer with ``lookup(keys, mask) -> values`` (e.g. one filter
       bit per key),
    4. a second all-to-all returns the replies, scattered back to the
       original key order.

    Returns (replies aligned with ``keys``, overflow flag).
    """
    P = lax.axis_size(axis)
    buckets, bucket_mask, (dest_of_key, slot_of_key), overflow = (
        bucket_by_destination(keys, mask, owner, P, capacity)
    )
    # ship requests to owners
    req = all_to_all(buckets, axis, backend=backend)
    req_mask = all_to_all(bucket_mask, axis, backend=backend)
    # owners evaluate the lookup on their partition
    flat_req = req.reshape(P * capacity)
    flat_mask = req_mask.reshape(P * capacity)
    replies = lookup(flat_req, flat_mask)
    if reply_dtype is not None:
        replies = replies.astype(reply_dtype)
    replies = replies.reshape(P, capacity)
    # ship replies back
    back = all_to_all(replies, axis, backend=backend)
    # gather each key's reply from (dest, slot); masked keys point at the
    # (clamped) out-of-bounds row, so zero them explicitly
    out = back[jnp.minimum(dest_of_key, P - 1), slot_of_key]
    out = jnp.where(mask, out, jnp.zeros_like(out))
    return out, overflow


# ---------------------------------------------------------------------------
# scatter-to-owner exchange (route values to the node owning their key)
# ---------------------------------------------------------------------------


def exchange_by_owner(
    keys,
    values,
    mask,
    owner,
    *,
    capacity: int,
    axis: str = "nodes",
    backend: str = "xla",
):
    """Route (key, value) pairs to the owner node of each key (used when a
    group-by key lies on a remote join path — paper Q13/Q15/Q21).

    Returns (recv_keys, recv_values, recv_mask, overflow): the pairs this
    node received, shape (P, capacity).
    """
    P = lax.axis_size(axis)
    buckets, bucket_mask, (dest_of_key, slot_of_key), overflow = (
        bucket_by_destination(keys, mask, owner, P, capacity)
    )
    vbuckets = jnp.zeros((P, capacity), values.dtype)
    # masked keys carry dest == P (out of bounds) and are dropped
    vbuckets = vbuckets.at[dest_of_key, slot_of_key].set(values, mode="drop")
    vbuckets = jnp.where(bucket_mask, vbuckets, 0)
    recv_keys = all_to_all(buckets, axis, backend=backend)
    recv_vals = all_to_all(vbuckets, axis, backend=backend)
    recv_mask = all_to_all(bucket_mask, axis, backend=backend)
    return recv_keys, recv_vals, recv_mask, overflow


def exchange_vectors_by_owner(
    keys,
    vectors,
    mask,
    owner,
    *,
    capacity: int,
    axis: str = "nodes",
    backend: str = "xla",
):
    """exchange_by_owner for VECTOR payloads (d-dim rows) — the MoE expert
    dispatch case: route (expert_id, token_vector) pairs to the expert's
    owner rank with the paper's personalized all-to-all (§3.2.6 backend
    selectable).  Returns (recv_keys (P,cap), recv_vectors (P,cap,d),
    recv_mask (P,cap), (dest,slot) of each input, overflow)."""
    P = lax.axis_size(axis)
    d = vectors.shape[-1]
    buckets, bucket_mask, (dest_of_key, slot_of_key), overflow = (
        bucket_by_destination(keys, mask, owner, P, capacity)
    )
    vbuckets = jnp.zeros((P, capacity, d), vectors.dtype)
    vbuckets = vbuckets.at[dest_of_key, slot_of_key].set(vectors, mode="drop")
    vbuckets = jnp.where(bucket_mask[..., None], vbuckets, 0)
    recv_keys = all_to_all(buckets, axis, backend=backend)
    recv_vecs = all_to_all(vbuckets, axis, backend=backend)
    recv_mask = all_to_all(bucket_mask, axis, backend=backend)
    return recv_keys, recv_vecs, recv_mask, (dest_of_key, slot_of_key), overflow
