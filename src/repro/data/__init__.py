from repro.data.synthetic import SyntheticLM, batch_specs  # noqa: F401
