"""Deterministic sharded data pipeline.

Same contract as tpch/dbgen (and the paper's `dbgen -S rank -C P`): shard i
of step t is a pure function of (seed, t, i) — no central dispatcher, no
shared filesystem, which is both the straggler-mitigation story (any node
can regenerate any shard) and the elastic-restart story (a different mesh
re-derives its shards from the same seed).

Token streams are Zipf-ish synthetic text: a mixture of a per-sequence
topic distribution and a global unigram distribution, giving non-trivial
(learnable) statistics for the convergence examples.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_topics: int = 64

    def host_batch(self, step: int, shard: int = 0, num_shards: int = 1):
        """Numpy batch for shard `shard` of `num_shards` (host-side)."""
        b = self.global_batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard])
        )
        # zipf-ish unigram over vocab, shifted per topic
        topics = rng.integers(0, self.num_topics, b)
        ranks = np.arange(1, self.vocab_size + 1)
        base = 1.0 / ranks
        base /= base.sum()
        tokens = np.empty((b, self.seq_len + 1), np.int32)
        for i in range(b):
            shift = (topics[i] * 97) % self.vocab_size
            p = np.roll(base, shift)
            tokens[i] = rng.choice(self.vocab_size, self.seq_len + 1, p=p)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def device_batch(self, step, *, key=None):
        """Fast on-device batch for the training examples: the same
        (seed, step)-determinism, drawn with jax PRNG (no host loop)."""
        key = key if key is not None else jax.random.key(self.seed)
        k = jax.random.fold_in(key, step)
        shape = (self.global_batch, self.seq_len + 1)
        # truncated-zipf via inverse-cdf on uniform
        u = jax.random.uniform(k, shape, jnp.float32, 1e-6, 1.0)
        zipf = jnp.clip(
            (jnp.exp(-jnp.log(u) * 0.35) - 1.0).astype(jnp.int32),
            0, self.vocab_size - 1,
        )
        return {"tokens": zipf[:, :-1], "labels": zipf[:, 1:]}


def batch_specs(arch_cfg, shape, mesh=None):
    """ShapeDtypeStructs for one global batch of a given (arch, shape) cell —
    what the dry-run feeds to jit().lower() (never allocated)."""
    import jax

    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if arch_cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, arch_cfg.encdec.enc_seq, arch_cfg.d_model), jnp.bfloat16
        )
    if arch_cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (B, arch_cfg.vlm.num_patches, arch_cfg.vlm.patch_dim), jnp.bfloat16
        )
    return specs
