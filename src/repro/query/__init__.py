"""Declarative Query IR: one logical algebra for every execution tier.

  ir      expression + operator nodes, the ``Q`` builder, catalog,
          validation, typed errors
  stats   §3.2.2 selectivity model and derived exchange capacities
  lower   IR -> physical SPMD plan (compiled by ``Cluster.compile``)

A single ``Query`` object routes to a Tier-1 rollup slice (the cube router
matches ``GroupAgg`` roots directly), a registered hand-written plan, or a
freshly lowered SPMD executable — see ``repro.tpch.driver.TPCHDriver.query``.
"""
from repro.query.ir import (  # noqa: F401
    Agg,
    Bin,
    BinOp,
    C,
    Catalog,
    Col,
    ColumnStats,
    Exists,
    Expr,
    Fetch,
    Filter,
    GroupAgg,
    GroupAggByKey,
    GroupKey,
    IRValidationError,
    Lit,
    LoweringError,
    Param,
    Project,
    Q,
    Query,
    QueryError,
    Scan,
    SemiJoin,
    TopK,
    UnaryOp,
    UnboundParamError,
    UncoveredQueryError,
    UnknownPlanError,
    build_catalog,
    conjuncts,
    eval_expr,
    expr_columns,
    expr_params,
    query_params,
    same_expr,
    same_node,
    same_query,
    substitute,
    validate,
)
from repro.query.lower import (  # noqa: F401
    decide_semijoins,
    explain_chain,
    lower,
)
from repro.query.params import bind_params, parameterize  # noqa: F401
# the static plan verifier lives in the repro.query.verify subpackage
# (imported lazily by TPCHDriver.check / explain to keep import cost low)
