"""Declarative query IR: one logical operator algebra for every execution
tier.

The paper's engine precompiles each query from a fixed set of building
blocks — scan, semi-join via index-lookup exchange, grouped aggregation,
top-k with a merging reduction (§3.2).  This module gives those blocks a
declarative form: expression trees over columns, logical operators
(``Scan``/``Filter``/``Project``/``SemiJoin``/``Exists``/``GroupAgg``/
``GroupAggByKey``/``TopK``) and a fluent builder (``Q.scan("lineitem")
.filter(...).group_agg(...)``).  One ``Query`` object then serves every
consumer:

- ``repro.query.lower`` compiles it into a physical plan function (one SPMD
  executable under ``Cluster.compile``), deriving exchange buffer
  capacities from the §3.2.2 selectivity model,
- ``repro.cube.router`` matches a ``GroupAgg`` root against the Tier-1
  rollup cubes directly (deriving the internal ``AggQuery`` form),
- the registry in ``repro.core.plans`` carries the IR next to the
  hand-written physical plan (the escape hatch) and the oracle binding.

Precedence gotcha: ``&``/``|`` bind tighter than comparisons in Python —
always parenthesize comparisons inside conjunctions:
``(C("a") >= lo) & (C("a") < hi)``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# typed errors (the satellite contract: never a bare KeyError/TypeError)
# ---------------------------------------------------------------------------


class QueryError(Exception):
    """Base class for all query-IR errors."""


class UnknownPlanError(QueryError, LookupError):
    """A plan/query name is not in the registry."""


class IRValidationError(QueryError):
    """The IR tree is malformed w.r.t. the catalog (unbound column,
    semi-join on a non-partitioned table, unknown table, ...)."""


class LoweringError(QueryError):
    """The IR is valid but not compilable to the SPMD substrate (e.g.
    min/max aggregates, which only Tier-1 cubes serve)."""


class UncoveredQueryError(QueryError, LookupError):
    """No rollup cube covers the query AND it has no lowerable Tier-2
    form — nothing can answer it."""


class UnboundParamError(QueryError, LookupError):
    """A :class:`Param` placeholder was evaluated without a binding for
    its name (execute a prepared query with the missing parameter)."""


# ---------------------------------------------------------------------------
# expressions
# ---------------------------------------------------------------------------


class Expr:
    """Base expression node.  Operators build trees; ``==`` builds a
    predicate (use :func:`same_expr` for structural comparison)."""

    # arithmetic ----------------------------------------------------------
    def __add__(self, other):
        return BinOp("+", self, _wrap(other))

    def __radd__(self, other):
        return BinOp("+", _wrap(other), self)

    def __sub__(self, other):
        return BinOp("-", self, _wrap(other))

    def __rsub__(self, other):
        return BinOp("-", _wrap(other), self)

    def __mul__(self, other):
        return BinOp("*", self, _wrap(other))

    def __rmul__(self, other):
        return BinOp("*", _wrap(other), self)

    def __truediv__(self, other):
        return BinOp("/", self, _wrap(other))

    def __neg__(self):
        return UnaryOp("neg", self)

    # comparisons ---------------------------------------------------------
    def __eq__(self, other):  # noqa: D105 — structural eq is same_expr()
        return BinOp("==", self, _wrap(other))

    def __ne__(self, other):
        return BinOp("!=", self, _wrap(other))

    def __lt__(self, other):
        return BinOp("<", self, _wrap(other))

    def __le__(self, other):
        return BinOp("<=", self, _wrap(other))

    def __gt__(self, other):
        return BinOp(">", self, _wrap(other))

    def __ge__(self, other):
        return BinOp(">=", self, _wrap(other))

    # boolean -------------------------------------------------------------
    def __and__(self, other):
        return BinOp("and", self, _wrap(other))

    def __or__(self, other):
        return BinOp("or", self, _wrap(other))

    def __invert__(self):
        return UnaryOp("not", self)

    __hash__ = object.__hash__


def _wrap(v) -> "Expr":
    return v if isinstance(v, Expr) else Lit(v)


@dataclasses.dataclass(frozen=True, eq=False)
class Col(Expr):
    """Reference to a column of the current stream (base table column or a
    projected/aggregated derived column)."""

    name: str


@dataclasses.dataclass(frozen=True, eq=False)
class Lit(Expr):
    value: object


@dataclasses.dataclass(frozen=True, eq=False)
class Param(Expr):
    """Runtime query parameter (the paper's §2/§3.1 compile-once model):
    a scalar placeholder bound at execute time, traced as a jit argument
    by the lowering so ONE compiled plan serves every literal binding.

    ``lo``/``hi`` optionally declare the binding range; the selectivity
    model sizes exchange buffer capacities for the WORST binding in the
    declared range (no range -> fully conservative).  The range is a
    sizing hint, not a runtime check."""

    name: str
    dtype: str = "float32"  # numpy dtype name of the bound scalar
    lo: Optional[float] = None
    hi: Optional[float] = None

    def __post_init__(self):
        np.dtype(self.dtype)  # typo-proof: fail at build, not at bind


@dataclasses.dataclass(frozen=True, eq=False)
class BinOp(Expr):
    op: str  # + - * / == != < <= > >= and or
    lhs: Expr
    rhs: Expr


@dataclasses.dataclass(frozen=True, eq=False)
class UnaryOp(Expr):
    op: str  # not neg
    operand: Expr


@dataclasses.dataclass(frozen=True, eq=False)
class Bin(Expr):
    """Digitize a numeric expression against sorted ``edges``: code ``j``
    covers the half-open interval ``(edges[j-1], edges[j]]`` — the same
    convention as binned cube dimensions, so a ``Bin`` group key matches a
    binned ``Dimension`` with identical edges."""

    child: Expr
    edges: tuple

    def __post_init__(self):
        object.__setattr__(self, "edges", tuple(sorted(self.edges)))

    @property
    def cardinality(self) -> int:
        return len(self.edges) + 1


C = Col  # builder shorthand: C("l_shipdate") <= cutoff


_BINOPS = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "and": lambda a, b: a & b,
    "or": lambda a, b: a | b,
}


def eval_expr(e: Expr, cols: Mapping[str, object], params=None):
    """Evaluate an expression against a column dict (jnp inside a plan, np
    on the host — both work: only python operators and searchsorted).
    ``params`` binds :class:`Param` placeholders by name (traced scalars
    inside a prepared plan, python/np scalars on the host)."""
    if isinstance(e, Col):
        return cols[e.name]
    if isinstance(e, Lit):
        return e.value
    if isinstance(e, Param):
        if params is None or e.name not in params:
            raise UnboundParamError(
                f"parameter {e.name!r} has no binding — pass it via "
                f"params= (bound: {sorted(params) if params else 'none'})"
            )
        return params[e.name]
    if isinstance(e, BinOp):
        return _BINOPS[e.op](eval_expr(e.lhs, cols, params),
                             eval_expr(e.rhs, cols, params))
    if isinstance(e, UnaryOp):
        v = eval_expr(e.operand, cols, params)
        return ~v if e.op == "not" else -v
    if isinstance(e, Bin):
        import jax.numpy as jnp

        col = eval_expr(e.child, cols, params)
        edges = jnp.asarray(np.asarray(e.edges), col.dtype)
        return jnp.searchsorted(edges, col, side="left").astype(jnp.int32)
    raise IRValidationError(f"unknown expression node {type(e).__name__}")


def expr_columns(e: Expr) -> frozenset:
    """Set of column names an expression reads."""
    if isinstance(e, Col):
        return frozenset((e.name,))
    if isinstance(e, (Lit, Param)):
        return frozenset()
    if isinstance(e, BinOp):
        return expr_columns(e.lhs) | expr_columns(e.rhs)
    if isinstance(e, UnaryOp):
        return expr_columns(e.operand)
    if isinstance(e, Bin):
        return expr_columns(e.child)
    raise IRValidationError(f"unknown expression node {type(e).__name__}")


def expr_params(e: Optional[Expr]) -> tuple:
    """Params an expression binds, in deterministic pre-order (duplicates
    by name kept once, first occurrence wins)."""
    if e is None or isinstance(e, (Col, Lit)):
        return ()
    if isinstance(e, Param):
        return (e,)
    if isinstance(e, BinOp):
        return _dedup_params(expr_params(e.lhs) + expr_params(e.rhs))
    if isinstance(e, UnaryOp):
        return expr_params(e.operand)
    if isinstance(e, Bin):
        return expr_params(e.child)
    raise IRValidationError(f"unknown expression node {type(e).__name__}")


def _dedup_params(ps: tuple) -> tuple:
    out, seen = [], {}
    for p in ps:
        prev = seen.get(p.name)
        if prev is None:
            seen[p.name] = p
            out.append(p)
        elif not same_expr(prev, p):
            raise IRValidationError(
                f"parameter {p.name!r} declared twice with different "
                f"dtype/range ({prev.dtype}/[{prev.lo},{prev.hi}] vs "
                f"{p.dtype}/[{p.lo},{p.hi}])"
            )
    return tuple(out)


def same_expr(a: Optional[Expr], b: Optional[Expr]) -> bool:
    """Structural equality (``==`` on Expr builds a predicate instead)."""
    if a is None or b is None:
        return a is b
    if type(a) is not type(b):
        return False
    if isinstance(a, Col):
        return a.name == b.name
    if isinstance(a, Lit):
        return a.value == b.value
    if isinstance(a, Param):
        return (a.name == b.name and a.dtype == b.dtype
                and a.lo == b.lo and a.hi == b.hi)
    if isinstance(a, BinOp):
        return a.op == b.op and same_expr(a.lhs, b.lhs) and same_expr(a.rhs, b.rhs)
    if isinstance(a, UnaryOp):
        return a.op == b.op and same_expr(a.operand, b.operand)
    if isinstance(a, Bin):
        return a.edges == b.edges and same_expr(a.child, b.child)
    return False


_FLIP_CMP = {"<": ">", "<=": ">=", ">": "<", ">=": "<=",
             "==": "==", "!=": "!="}


def normalize_comparison(e: Expr) -> Optional[tuple]:
    """``Col op Lit`` / ``Col op Param`` (either side) -> (column, op,
    value), with the operator flipped when the scalar is on the left; None
    for anything else.  For a literal ``value`` is the raw python value;
    for a parameter it is the :class:`Param` node itself (consumers decide
    how to bind it).  The single normalizer shared by the selectivity
    model and the cube router's predicate derivation."""
    if not isinstance(e, BinOp) or e.op not in _FLIP_CMP:
        return None

    def _scalar(x):
        return x.value if isinstance(x, Lit) else x

    if isinstance(e.lhs, Col) and isinstance(e.rhs, (Lit, Param)):
        return e.lhs.name, e.op, _scalar(e.rhs)
    if isinstance(e.lhs, (Lit, Param)) and isinstance(e.rhs, Col):
        return e.rhs.name, _FLIP_CMP[e.op], _scalar(e.lhs)
    return None


def same_node(a, b) -> bool:
    """Structural equality of operator trees (``Expr.__eq__`` builds
    predicates, so dataclass equality is unavailable by design)."""
    if type(a) is not type(b):
        return False
    if isinstance(a, Scan):
        return a.table == b.table
    if isinstance(a, Filter):
        return same_expr(a.pred, b.pred) and same_node(a.child, b.child)
    if isinstance(a, Project):
        return (len(a.cols) == len(b.cols)
                and all(n1 == n2 and same_expr(e1, e2)
                        for (n1, e1), (n2, e2) in zip(a.cols, b.cols))
                and same_node(a.child, b.child))
    if isinstance(a, SemiJoin):
        return (a.table == b.table and a.alt == b.alt
                and same_expr(a.key, b.key) and same_expr(a.pred, b.pred)
                and same_node(a.child, b.child))
    if isinstance(a, Exists):
        return (a.table == b.table and a.key == b.key
                and same_expr(a.pred, b.pred) and same_node(a.child, b.child))
    if isinstance(a, GroupAgg):
        return (a.method == b.method
                and len(a.keys) == len(b.keys) and len(a.aggs) == len(b.aggs)
                and all(k1.name == k2.name and k1.cardinality == k2.cardinality
                        and same_expr(k1.expr, k2.expr)
                        for k1, k2 in zip(a.keys, b.keys))
                and all(g1.name == g2.name and g1.agg == g2.agg
                        and same_expr(g1.expr, g2.expr)
                        for g1, g2 in zip(a.aggs, b.aggs))
                and same_node(a.child, b.child))
    if isinstance(a, GroupAggByKey):
        return (a.into == b.into and same_expr(a.key, b.key)
                and len(a.aggs) == len(b.aggs)
                and all(g1.name == g2.name and g1.agg == g2.agg
                        and same_expr(g1.expr, g2.expr)
                        for g1, g2 in zip(a.aggs, b.aggs))
                and same_node(a.child, b.child))
    if isinstance(a, TopK):
        return (a.k == b.k and same_expr(a.value, b.value)
                and same_expr(a.pred, b.pred) and a.fetch == b.fetch
                and same_node(a.child, b.child))
    return False


def same_query(a: Optional["Query"], b: Optional["Query"]) -> bool:
    """Structural equality of two queries (names ignored)."""
    if a is None or b is None:
        return a is b
    return same_node(a.root, b.root)


def conjuncts(e: Expr) -> list:
    """Flatten a conjunction into its factors."""
    if isinstance(e, BinOp) and e.op == "and":
        return conjuncts(e.lhs) + conjuncts(e.rhs)
    return [e]


def query_params(node) -> tuple:
    """All :class:`Param` placeholders an operator tree (or ``Query``)
    binds, deduplicated by name, in deterministic scan-first order — the
    ordered parameter signature of a prepared plan.  Raises
    :class:`IRValidationError` when one name is declared with conflicting
    dtype/range."""
    if isinstance(node, Query):
        node = node.root
    if isinstance(node, Scan):
        return ()
    ps = query_params(node.child)
    if isinstance(node, Filter):
        ps += expr_params(node.pred)
    elif isinstance(node, Project):
        for _, e in node.cols:
            ps += expr_params(e)
    elif isinstance(node, SemiJoin):
        ps += expr_params(node.key) + expr_params(node.pred)
    elif isinstance(node, Exists):
        ps += expr_params(node.pred)
    elif isinstance(node, GroupAgg):
        for k in node.keys:
            ps += expr_params(k.expr)
        for a in node.aggs:
            ps += expr_params(a.expr)
    elif isinstance(node, GroupAggByKey):
        ps += expr_params(node.key)
        for a in node.aggs:
            ps += expr_params(a.expr)
    elif isinstance(node, TopK):
        ps += expr_params(node.value) + expr_params(node.pred)
    return _dedup_params(ps)


def substitute(e: Expr, env: Mapping[str, Expr]) -> Expr:
    """Inline projected columns so derived expressions read base columns.
    A projection may shadow the column it reads (``x = x * 2``), so while
    expanding a name that name is excluded from further expansion."""
    if isinstance(e, Col):
        if e.name not in env:
            return e
        inner = {k: v for k, v in env.items() if k != e.name}
        return substitute(env[e.name], inner)
    if isinstance(e, BinOp):
        return BinOp(e.op, substitute(e.lhs, env), substitute(e.rhs, env))
    if isinstance(e, UnaryOp):
        return UnaryOp(e.op, substitute(e.operand, env))
    if isinstance(e, Bin):
        return Bin(substitute(e.child, env), e.edges)
    return e


# ---------------------------------------------------------------------------
# logical operators
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class Scan:
    """Leaf: the sharded base table (one partition per node)."""

    table: str


@dataclasses.dataclass(frozen=True, eq=False)
class Filter:
    child: object
    pred: Expr


@dataclasses.dataclass(frozen=True, eq=False)
class Project:
    """Add derived columns (name -> expression over the stream)."""

    child: object
    cols: tuple  # ((name, Expr), ...)


@dataclasses.dataclass(frozen=True, eq=False)
class SemiJoin:
    """Keep stream rows whose foreign ``key`` points at a row of ``table``
    satisfying ``pred`` — the paper's §3.2.2 remote-attribute filter.

    alt: "auto" picks local evaluation for co-partitioned edges, else the
    cheaper of Alt-1 (index-lookup request exchange) / Alt-2 (replicated
    bitset) under the analytic cost model; "request"/"bitset" pin it.
    """

    child: object
    table: str
    key: Expr
    pred: Expr
    alt: str = "auto"  # auto | local | request | bitset


@dataclasses.dataclass(frozen=True, eq=False)
class Exists:
    """EXISTS probe: keep stream rows (over their base table) for which some
    row of the co-partitioned ``table`` with ``key`` == the stream row's
    primary key satisfies ``pred`` (Q4's late-lineitem probe)."""

    child: object
    table: str
    key: str  # foreign-key column of ``table`` referencing the stream's base
    pred: Expr


@dataclasses.dataclass(frozen=True, eq=False)
class GroupKey:
    name: str
    expr: Expr
    cardinality: int


@dataclasses.dataclass(frozen=True, eq=False)
class Agg:
    name: str
    agg: str  # sum | count | min | max (min/max are Tier-1/cube-only)
    expr: Optional[Expr] = None  # None for count

    VALID = ("sum", "count", "min", "max")


@dataclasses.dataclass(frozen=True, eq=False)
class GroupAgg:
    """Grouped aggregation over small composite key spaces; the root form
    the cube router matches.  Result: dense ``(prod(cardinalities),
    len(aggs))`` array, groups in row-major key order."""

    child: object
    keys: tuple  # (GroupKey, ...) — may be empty (global aggregate)
    aggs: tuple  # (Agg, ...)
    method: str = "auto"  # auto | onehot | dense | kernel


@dataclasses.dataclass(frozen=True, eq=False)
class GroupAggByKey:
    """Dense group-by on a co-partitioned foreign key: aggregates stream
    rows into one value per row of the parent ``into`` table (Q18's
    quantity-per-order), yielding a new stream over ``into`` with the
    aggregate names as derived columns."""

    child: object
    key: Expr  # foreign-key column referencing ``into``'s primary key
    into: str
    aggs: tuple  # (Agg, ...) — sum/count only


@dataclasses.dataclass(frozen=True)  # field equality: plain strings only
class Fetch:
    """Late-materialized output attribute (§3.2.7).  ``table=None`` fetches
    ``name`` from the stream's own table (derived columns included);
    otherwise ``name`` is fetched from ``table`` keyed by the previously
    fetched attribute ``key``."""

    name: str
    table: Optional[str] = None
    key: Optional[str] = None


@dataclasses.dataclass(frozen=True, eq=False)
class TopK:
    """Global top-k of the stream by ``value`` (desc, primary key asc
    tiebreak), via per-node selection + the §3.2.3 merging reduction."""

    child: object
    value: Expr
    k: int
    pred: Optional[Expr] = None
    fetch: tuple = ()


# ---------------------------------------------------------------------------
# catalog: what the validator/lowerer knows about the data
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Cheap per-column statistics for the §3.2.2 selectivity model."""

    lo: float
    hi: float
    n_distinct: int  # 0 = unknown (float domains)


@dataclasses.dataclass(frozen=True)
class PackedInfo:
    """Resident encoding of a bit-packed column (``core.columnar.
    PackedColumn``): what the lowering needs to rewrite predicates into
    code space and to predict bytes scanned — width/offset for
    frame-of-reference columns, the sorted ``values`` tuple for
    dictionary columns."""

    width: int
    offset: int = 0
    values: Optional[tuple] = None
    dtype: str = "int32"


@dataclasses.dataclass(frozen=True)
class TableInfo:
    name: str
    columns: tuple
    replicated: bool
    num_rows: int
    stats: Mapping[str, ColumnStats] = dataclasses.field(default_factory=dict)
    # packed-resident columns: name -> PackedInfo (empty = raw residency)
    packed: Mapping[str, PackedInfo] = dataclasses.field(default_factory=dict)


# TPC-H co-partitioned edges (solid edges of the paper's Fig. 1):
# child table -> (parent table, child's foreign-key column)
TPCH_COPARTITIONED = {
    "lineitem": ("orders", "l_orderkey"),
    "partsupp": ("part", "ps_partkey"),
}


@dataclasses.dataclass(frozen=True)
class Catalog:
    tables: Mapping[str, TableInfo]
    copartitioned: Mapping[str, tuple]
    num_nodes: int = 1

    def table(self, name: str) -> TableInfo:
        try:
            return self.tables[name]
        except KeyError:
            raise IRValidationError(
                f"unknown table {name!r}; catalog has {sorted(self.tables)}"
            ) from None


def build_catalog(tables: Mapping[str, object], *, num_nodes: int = 1,
                  copartitioned: Optional[Mapping[str, tuple]] = None,
                  packed: Optional[Mapping[str, Mapping[str, PackedInfo]]] = None,
                  ) -> Catalog:
    """Catalog from host-side ``Table`` objects (the driver's
    ``self.tables``): column names, replication, and min/max/distinct
    stats feeding the selectivity model.  ``packed`` optionally declares
    the resident encoding per table/column (the driver derives it from
    the packed resident tables) — the lowering and the SCAN001 verifier
    rule key off it."""
    infos = {}
    for name, t in tables.items():
        stats = {}
        for cname, col in t.columns.items():
            arr = np.asarray(col)
            if arr.size == 0:
                continue
            lo, hi = float(arr.min()), float(arr.max())
            if arr.dtype == np.bool_:
                nd = 2
            elif np.issubdtype(arr.dtype, np.integer):
                nd = int(min(hi - lo + 1, arr.shape[0]))
            else:
                nd = 0
            stats[cname] = ColumnStats(lo=lo, hi=hi, n_distinct=nd)
        infos[name] = TableInfo(
            name=name,
            columns=tuple(t.columns.keys()),
            replicated=bool(getattr(t, "replicated", False)),
            num_rows=int(t.num_rows),
            stats=stats,
            packed=dict((packed or {}).get(name, {})),
        )
    return Catalog(
        tables=infos,
        copartitioned=dict(TPCH_COPARTITIONED if copartitioned is None
                           else copartitioned),
        num_nodes=num_nodes,
    )


# ---------------------------------------------------------------------------
# validation: IR tree x catalog -> stream schema (or a typed error)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamInfo:
    """Inferred schema of the tuple stream at a node: the base table whose
    partitioning the stream follows, plus all visible column names."""

    base: str
    columns: frozenset


def _check_bound(expr: Expr, stream: StreamInfo, what: str):
    missing = expr_columns(expr) - stream.columns
    if missing:
        raise IRValidationError(
            f"{what} references unbound column(s) {sorted(missing)} — the "
            f"stream over {stream.base!r} has {sorted(stream.columns)}"
        )


def validate(node, catalog: Catalog) -> StreamInfo:
    """Validate an operator tree bottom-up; returns the root's stream
    schema.  Raises :class:`IRValidationError` with a precise message."""
    if isinstance(node, Scan):
        info = catalog.table(node.table)
        return StreamInfo(base=node.table, columns=frozenset(info.columns))

    if isinstance(node, Filter):
        s = validate(node.child, catalog)
        _check_bound(node.pred, s, "filter predicate")
        return s

    if isinstance(node, Project):
        s = validate(node.child, catalog)
        cols = set(s.columns)
        for name, e in node.cols:
            _check_bound(e, dataclasses.replace(s, columns=frozenset(cols)),
                         f"projection {name!r}")
            cols.add(name)
        return StreamInfo(base=s.base, columns=frozenset(cols))

    if isinstance(node, SemiJoin):
        s = validate(node.child, catalog)
        _check_bound(node.key, s, "semijoin key")
        target = catalog.table(node.table)
        if target.replicated:
            raise IRValidationError(
                f"semijoin against replicated table {node.table!r}: "
                f"replicated tables are not partitioned — evaluate the "
                f"predicate locally with project/filter instead"
            )
        t_stream = StreamInfo(base=node.table,
                              columns=frozenset(target.columns))
        _check_bound(node.pred, t_stream, "semijoin predicate")
        if node.alt not in ("auto", "local", "request", "bitset"):
            raise IRValidationError(f"unknown semijoin alt {node.alt!r}")
        return s

    if isinstance(node, Exists):
        s = validate(node.child, catalog)
        inner = catalog.table(node.table)
        if inner.replicated:
            raise IRValidationError(
                f"exists-probe against replicated table {node.table!r}: "
                f"replicated tables are not partitioned"
            )
        edge = catalog.copartitioned.get(node.table)
        if edge is None or edge[0] != s.base or edge[1] != node.key:
            raise IRValidationError(
                f"exists-probe needs {node.table!r} co-partitioned with the "
                f"stream's base table {s.base!r} on {node.key!r}; known "
                f"co-partitioned edges: {dict(catalog.copartitioned)}"
            )
        if node.key not in inner.columns:
            raise IRValidationError(
                f"exists key {node.key!r} is not a column of {node.table!r}"
            )
        i_stream = StreamInfo(base=node.table, columns=frozenset(inner.columns))
        _check_bound(node.pred, i_stream, "exists predicate")
        return s

    if isinstance(node, GroupAgg):
        s = validate(node.child, catalog)
        seen = set()
        for k in node.keys:
            if k.cardinality is None or k.cardinality <= 0:
                raise IRValidationError(
                    f"group key {k.name!r} needs a positive cardinality"
                )
            _check_bound(k.expr, s, f"group key {k.name!r}")
            if k.name in seen:
                raise IRValidationError(f"duplicate output name {k.name!r}")
            seen.add(k.name)
        for a in node.aggs:
            if a.agg not in Agg.VALID:
                raise IRValidationError(
                    f"aggregate {a.name!r}: unknown kind {a.agg!r} "
                    f"(valid: {Agg.VALID})"
                )
            if a.agg != "count":
                if a.expr is None:
                    raise IRValidationError(
                        f"aggregate {a.name!r}: {a.agg} needs an expression"
                    )
                _check_bound(a.expr, s, f"aggregate {a.name!r}")
            if a.name in seen:
                raise IRValidationError(f"duplicate output name {a.name!r}")
            seen.add(a.name)
        if node.method not in ("auto", "onehot", "dense", "kernel"):
            raise IRValidationError(f"unknown group-agg method {node.method!r}")
        return StreamInfo(base=s.base, columns=frozenset(seen))

    if isinstance(node, GroupAggByKey):
        s = validate(node.child, catalog)
        parent = catalog.table(node.into)
        edge = catalog.copartitioned.get(s.base)
        if (edge is None or edge[0] != node.into
                or not isinstance(node.key, Col) or node.key.name != edge[1]):
            raise IRValidationError(
                f"group_by_key into {node.into!r} needs the stream's base "
                f"table {s.base!r} co-partitioned with it on the key column; "
                f"known co-partitioned edges: {dict(catalog.copartitioned)}"
            )
        _check_bound(node.key, s, "group_by_key key")
        cols = set(parent.columns)
        for a in node.aggs:
            if a.agg not in ("sum", "count"):
                raise IRValidationError(
                    f"group_by_key aggregate {a.name!r}: only sum/count are "
                    f"supported (got {a.agg!r})"
                )
            if a.agg != "count":
                _check_bound(a.expr, s, f"aggregate {a.name!r}")
            cols.add(a.name)
        return StreamInfo(base=node.into, columns=frozenset(cols))

    if isinstance(node, TopK):
        s = validate(node.child, catalog)
        _check_bound(node.value, s, "top-k value")
        if node.pred is not None:
            _check_bound(node.pred, s, "top-k predicate")
        if node.k <= 0:
            raise IRValidationError("top-k needs k > 0")
        fetched = set()
        for f in node.fetch:
            if f.table is None:
                if f.name not in s.columns:
                    raise IRValidationError(
                        f"fetch {f.name!r}: not a column of the stream over "
                        f"{s.base!r}"
                    )
            else:
                remote = catalog.table(f.table)
                if f.name not in remote.columns:
                    raise IRValidationError(
                        f"fetch {f.name!r}: not a column of {f.table!r}"
                    )
                if f.key is None or f.key not in fetched:
                    raise IRValidationError(
                        f"remote fetch {f.name!r} from {f.table!r} needs "
                        f"key= one of the previously fetched attributes "
                        f"({sorted(fetched) or 'none yet'})"
                    )
            fetched.add(f.name)
        return s

    raise IRValidationError(f"unknown operator {type(node).__name__}")


# ---------------------------------------------------------------------------
# the fluent builder
# ---------------------------------------------------------------------------


def _as_group_key(k) -> GroupKey:
    if isinstance(k, GroupKey):
        return k
    name, expr = k[0], _wrap(k[1])
    card = k[2] if len(k) > 2 else None
    if card is None and isinstance(expr, Bin):
        card = expr.cardinality
    return GroupKey(name=name, expr=expr, cardinality=card)


def _as_agg(a) -> Agg:
    if isinstance(a, Agg):
        return a
    name, kind = a[0], a[1]
    expr = a[2] if len(a) > 2 else None
    return Agg(name=name, agg=kind,
               expr=_wrap(expr) if expr is not None else None)


@dataclasses.dataclass(frozen=True, eq=False)
class Query:
    """An IR tree plus an optional name (registry queries are named; the
    name keys plan caches and benchmark rows)."""

    root: object
    name: Optional[str] = None

    # -- chaining ----------------------------------------------------------
    def _with(self, root) -> "Query":
        return Query(root=root, name=self.name)

    def filter(self, pred: Expr) -> "Query":
        return self._with(Filter(self.root, _wrap(pred)))

    def project(self, **cols) -> "Query":
        items = tuple((n, _wrap(e)) for n, e in cols.items())
        return self._with(Project(self.root, items))

    def semijoin(self, table: str, key: Expr, pred: Expr,
                 alt: str = "auto") -> "Query":
        return self._with(SemiJoin(self.root, table, _wrap(key), _wrap(pred),
                                   alt))

    def exists(self, table: str, key: str, pred: Expr) -> "Query":
        return self._with(Exists(self.root, table, key, _wrap(pred)))

    def group_agg(self, keys: Sequence = (), aggs: Sequence = (),
                  method: str = "auto") -> "Query":
        return self._with(GroupAgg(
            self.root,
            keys=tuple(_as_group_key(k) for k in keys),
            aggs=tuple(_as_agg(a) for a in aggs),
            method=method,
        ))

    def group_by_key(self, key: Expr, into: str, aggs: Sequence) -> "Query":
        return self._with(GroupAggByKey(
            self.root, _wrap(key), into, tuple(_as_agg(a) for a in aggs)
        ))

    def top_k(self, value: Expr, k: int, pred: Optional[Expr] = None,
              fetch: Sequence = ()) -> "Query":
        return self._with(TopK(
            self.root, _wrap(value), int(k),
            _wrap(pred) if pred is not None else None, tuple(fetch),
        ))

    def named(self, name: str) -> "Query":
        return Query(root=self.root, name=name)

    # -- introspection -----------------------------------------------------
    @property
    def table(self) -> str:
        """Base table of the root stream (the leaf scan's table)."""
        node = self.root
        while not isinstance(node, Scan):
            node = node.child
        return node.table


class Q:
    """Entry point: ``Q.scan("lineitem")``."""

    @staticmethod
    def scan(table: str) -> Query:
        return Query(root=Scan(table))
