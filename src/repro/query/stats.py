"""The §3.2.2 selectivity model: predicate selectivities, semi-join
alternative choice, and exchange buffer capacities derived from them.

The paper sizes its communication buffers from the expected number of
surviving keys after local filtering (n requests over a remote table of m
rows; §3.2.2 gives the bits-communicated model, ``repro.core.compression``
implements it).  Plans here are static-shape SPMD programs, so the same
estimate must become a COMPILE-TIME buffer capacity: we take the expected
per-destination message count under uniform key routing (a binomial with
mean ``e = n_local / P``), add a 6-sigma tail margin plus a constant floor,
and round up to a power of two.  Overflow flags in the exchange layer
surface any under-estimate at run time instead of corrupting results.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Callable, Mapping, Optional

from repro.core.exchange import WireFormat
from repro.query.ir import (
    Bin,
    BinOp,
    Col,
    ColumnStats,
    Expr,
    Lit,
    PackedInfo,
    Param,
    UnaryOp,
    expr_columns,
    normalize_comparison,
)

# Selinger-style default for predicates the model cannot see through
# (column-vs-column comparisons, opaque expressions).
DEFAULT_SELECTIVITY = 1.0 / 3.0


def next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def capacity_for(expected: float, *, floor: int = 64) -> int:
    """Static per-destination buffer capacity for an expected message count:
    mean + 6*sqrt(mean) binomial tail margin + constant slack, rounded up to
    a power of two (fixed shapes; see DESIGN.md on static shapes)."""
    e = max(float(expected), 0.0)
    need = e + 6.0 * math.sqrt(e) + 16.0
    return next_pow2(max(floor, math.ceil(need)))


def _range_fraction(st: ColumnStats, op: str, v: float) -> float:
    """Fraction of a uniform [lo, hi] domain satisfying ``col op v``."""
    lo, hi = st.lo, st.hi
    if hi <= lo:
        return 1.0
    integral = st.n_distinct > 0
    span = (hi - lo + 1.0) if integral else (hi - lo)
    if op == "<":
        frac = (v - lo) / span
    elif op == "<=":
        frac = (v - lo + (1.0 if integral else 0.0)) / span
    elif op == ">":
        frac = (hi - v) / span
    elif op == ">=":
        frac = (hi - v + (1.0 if integral else 0.0)) / span
    else:
        return DEFAULT_SELECTIVITY
    return min(1.0, max(0.0, frac))


def estimate_selectivity(pred: Expr, stats: Mapping[str, ColumnStats],
                         binding=None) -> float:
    """Estimated fraction of rows satisfying ``pred`` under independence +
    uniformity (the paper's model; good enough to size buffers, and the
    run-time overflow flag catches the rest).

    Parameterized comparisons (``col op Param``) are resolved in order of
    preference: the value from ``binding`` when one is supplied (the
    prepare-time defaults of an auto-parameterized literal query), else
    the WORST binding in the parameter's declared ``lo``/``hi`` range
    (range selectivity is monotone in the bound, so the worst case sits at
    an endpoint), else a fully conservative 1.0 — a prepared plan's
    exchange capacities must stay sound for every future binding."""
    if isinstance(pred, BinOp):
        if pred.op == "and":
            return (estimate_selectivity(pred.lhs, stats, binding)
                    * estimate_selectivity(pred.rhs, stats, binding))
        if pred.op == "or":
            a = estimate_selectivity(pred.lhs, stats, binding)
            b = estimate_selectivity(pred.rhs, stats, binding)
            return min(1.0, a + b - a * b)
        norm = normalize_comparison(pred)
        if norm is not None:
            col, op, v = norm
            st = stats.get(col)
            if st is None:
                return 1.0 if isinstance(v, Param) else DEFAULT_SELECTIVITY
            if op == "==":
                # value-independent under the distinct-count model, so a
                # parameterized equality needs no binding
                return 1.0 / st.n_distinct if st.n_distinct else DEFAULT_SELECTIVITY
            if op == "!=":
                return 1.0 - (1.0 / st.n_distinct) if st.n_distinct else DEFAULT_SELECTIVITY
            if isinstance(v, Param):
                if binding is not None and v.name in binding:
                    v = binding[v.name]
                elif v.lo is not None and v.hi is not None:
                    return max(_range_fraction(st, op, float(v.lo)),
                               _range_fraction(st, op, float(v.hi)))
                else:
                    return 1.0
            try:
                return _range_fraction(st, op, float(v))
            except (TypeError, ValueError):
                return DEFAULT_SELECTIVITY
        return DEFAULT_SELECTIVITY
    if isinstance(pred, UnaryOp) and pred.op == "not":
        return 1.0 - estimate_selectivity(pred.operand, stats, binding)
    if isinstance(pred, Col):
        # bare boolean column: no histogram, assume an even split
        return 0.5
    if isinstance(pred, (Lit, Bin, Param)):
        return DEFAULT_SELECTIVITY
    return DEFAULT_SELECTIVITY


def request_capacity(table_rows: int, selectivity: float, num_nodes: int) -> int:
    """Capacity for an Alt-1 request / owner-routed exchange: each node
    ships ``rows/P * sel`` keys, spread uniformly over P destinations."""
    n_local = (table_rows / max(num_nodes, 1)) * min(max(selectivity, 0.0), 1.0)
    return capacity_for(n_local / max(num_nodes, 1))


# ---------------------------------------------------------------------------
# compressed residency: code-space predicate rewrite + per-column scan
# strategy.  A comparison against a constant/parameter rewrites into an
# inclusive code-range test ``lo <= code <= hi`` (optionally negated) over
# the packed words — frame-of-reference columns by integer arithmetic on
# the offset, dictionary columns by binary search over the sorted values.
# Anything else (column-vs-column, arithmetic on the column) forces an
# eager full-column decode; the SCAN001 verifier rule reports those.
# ---------------------------------------------------------------------------

_I32_MIN, _I32_MAX = -(2 ** 31), 2 ** 31 - 1


def _clamp_i32(v: float) -> int:
    return int(min(max(v, _I32_MIN), _I32_MAX))


@dataclasses.dataclass(frozen=True)
class ScanRewrite:
    """A predicate rewritten into code space: ``bounds(params)`` yields
    the inclusive (lo, hi) code range (python ints for literal
    predicates, traced int32 scalars for parameterized ones)."""

    column: str
    negate: bool
    describe: str
    bounds: Callable

    def static_bounds(self) -> Optional[tuple]:
        """(lo, hi) when the predicate is literal (binding-free);
        None for parameterized rewrites."""
        try:
            lo, hi = self.bounds(None)
        except Exception:
            return None
        if isinstance(lo, int) and isinstance(hi, int):
            return lo, hi
        return None


def _for_bounds(op: str, v, offset: int, maxc: int):
    """Inclusive code bounds of ``x op v`` over FOR codes ``x - offset``.
    ``v`` may be a python scalar (static) or a traced jnp scalar."""
    if isinstance(v, (int, float)):
        fl, ce = math.floor(v), math.ceil(v)
        if op == "<=":
            return 0, _clamp_i32(fl - offset)
        if op == "<":
            return 0, _clamp_i32(ce - 1 - offset)
        if op == ">=":
            return _clamp_i32(ce - offset), maxc
        if op == ">":
            return _clamp_i32(fl + 1 - offset), maxc
        # == / != : a non-integral value matches nothing (negation of an
        # empty range is everything, which the negate flag handles)
        if fl == v:
            c = _clamp_i32(fl - offset)
            return c, c
        return 0, -1
    import jax.numpy as jnp

    fl = jnp.floor(v).astype(jnp.int32)
    ce = jnp.ceil(v).astype(jnp.int32)
    off = jnp.int32(offset)
    if op == "<=":
        return jnp.int32(0), fl - off
    if op == "<":
        return jnp.int32(0), ce - jnp.int32(1) - off
    if op == ">=":
        return ce - off, jnp.int32(maxc)
    if op == ">":
        return fl + jnp.int32(1) - off, jnp.int32(maxc)
    exact = fl.astype(v.dtype if hasattr(v, "dtype") else jnp.float32) == v
    c = fl - off
    return (jnp.where(exact, c, 0).astype(jnp.int32),
            jnp.where(exact, c, -1).astype(jnp.int32))


def _dict_bounds(op: str, v, values: tuple):
    """Inclusive code bounds of ``x op v`` over dictionary positions in
    the sorted ``values``."""
    k = len(values)
    if isinstance(v, (int, float)):
        left = bisect.bisect_left(values, v)
        right = bisect.bisect_right(values, v)
        if op == "<=":
            return 0, right - 1
        if op == "<":
            return 0, left - 1
        if op == ">=":
            return left, k - 1
        if op == ">":
            return right, k - 1
        if right > left:  # == / != : present in the dictionary?
            return left, left
        return 0, -1
    import jax.numpy as jnp
    import numpy as np

    va = jnp.asarray(np.asarray(values))
    vv = jnp.asarray(v).astype(va.dtype)
    left = jnp.searchsorted(va, vv, side="left").astype(jnp.int32)
    right = jnp.searchsorted(va, vv, side="right").astype(jnp.int32)
    if op == "<=":
        return jnp.int32(0), right - jnp.int32(1)
    if op == "<":
        return jnp.int32(0), left - jnp.int32(1)
    if op == ">=":
        return left, jnp.int32(k - 1)
    if op == ">":
        return right, jnp.int32(k - 1)
    found = right > left
    return (jnp.where(found, left, 0).astype(jnp.int32),
            jnp.where(found, left, -1).astype(jnp.int32))


def scan_rewrite(conjunct: Expr,
                 packed: Mapping[str, PackedInfo]) -> Optional[ScanRewrite]:
    """Rewrite one filter conjunct into a code-space range test over a
    packed column, or None when the shape does not admit it (not a
    ``col op scalar`` comparison, or the column is not packed-resident)."""
    norm = normalize_comparison(conjunct)
    if norm is None:
        return None
    col, op, v = norm
    info = packed.get(col)
    if info is None:
        return None
    negate = op == "!="
    cmp_op = "==" if negate else op
    maxc = (1 << info.width) - 1

    if isinstance(v, Param):
        param = v

        def bounds(params):
            if params is None or param.name not in params:
                raise KeyError(param.name)
            pv = params[param.name]
            if info.values is not None:
                return _dict_bounds(cmp_op, pv, info.values)
            return _for_bounds(cmp_op, pv, info.offset, maxc)

        vs = f"${param.name}"
    else:
        if not isinstance(v, (int, float, bool)):
            return None
        if info.values is not None:
            lo, hi = _dict_bounds(cmp_op, v, info.values)
        else:
            lo, hi = _for_bounds(cmp_op, v, info.offset, maxc)

        def bounds(params, _lo=lo, _hi=hi):
            return _lo, _hi

        vs = repr(v)
    kind = "dict" if info.values is not None else "for"
    return ScanRewrite(
        column=col, negate=negate,
        describe=f"{col}{op}{vs} -> {kind} code range", bounds=bounds)


@dataclasses.dataclass(frozen=True)
class ScanDecision:
    """Per-(filter conjunct, packed column) scan strategy, decided at
    lower time by the :mod:`repro.core.scancal` roofline and rendered by
    EXPLAIN."""

    table: str
    column: str
    mode: str                      # 'packed' | 'decode'
    width: int
    rows_per_node: int
    scan_bytes: int                # predicted bytes scanned per node
    raw_bytes: int                 # raw-residency bytes for the same scan
    rewrite: Optional[ScanRewrite] = None
    reason: str = ""

    @property
    def rewritable(self) -> bool:
        return self.rewrite is not None


def decide_scan_conjunct(conjunct: Expr, table_name: str,
                         packed: Mapping[str, PackedInfo],
                         rows_per_node: int, *, cal=None) -> list:
    """Scan strategy for one filter conjunct over a packed-resident base
    table: one :class:`ScanDecision` per packed column the conjunct
    touches.  Rewritable predicates go packed iff the roofline says the
    saved bandwidth beats the in-place ALU cost; non-rewritable shapes
    are 'decode' (SCAN001 territory)."""
    from repro.core import scancal

    touched = [c for c in sorted(expr_columns(conjunct)) if c in packed]
    if not touched:
        return []
    rewrite = scan_rewrite(conjunct, packed)
    out = []
    for cname in touched:
        info = packed[cname]
        itemsize = 1 if info.dtype == "bool" else 4
        pb = scancal.packed_scan_bytes(rows_per_node, info.width)
        db = scancal.decode_scan_bytes(rows_per_node, info.width, itemsize)
        raw = rows_per_node * itemsize
        if rewrite is not None and rewrite.column == cname:
            mode = scancal.choose_scan_mode(rows_per_node, info.width,
                                            itemsize, cal=cal)
            out.append(ScanDecision(
                table=table_name, column=cname, mode=mode, width=info.width,
                rows_per_node=rows_per_node,
                scan_bytes=pb if mode == "packed" else db, raw_bytes=raw,
                rewrite=rewrite,
                reason=(rewrite.describe if mode == "packed"
                        else "roofline prefers decode")))
        else:
            out.append(ScanDecision(
                table=table_name, column=cname, mode="decode",
                width=info.width, rows_per_node=rows_per_node,
                scan_bytes=db, raw_bytes=raw, rewrite=None,
                reason="predicate not rewritable into code space"))
    return out


def merge_rewrites(a: ScanRewrite, b: ScanRewrite) -> ScanRewrite:
    """Intersect two non-negated code-space range tests over the SAME
    column into one: ``a AND b`` holds iff the code lies in
    ``[max(lo_a, lo_b), min(hi_a, hi_b)]`` — one kernel scan instead of
    two passes over the packed words."""
    assert a.column == b.column and not a.negate and not b.negate

    def bounds(params, _a=a, _b=b):
        lo1, hi1 = _a.bounds(params)
        lo2, hi2 = _b.bounds(params)
        if all(isinstance(v, (int, float)) for v in (lo1, hi1, lo2, hi2)):
            return max(lo1, lo2), min(hi1, hi2)
        import jax.numpy as jnp

        return (jnp.maximum(jnp.asarray(lo1, jnp.int32),
                            jnp.asarray(lo2, jnp.int32)),
                jnp.minimum(jnp.asarray(hi1, jnp.int32),
                            jnp.asarray(hi2, jnp.int32)))

    return ScanRewrite(column=a.column, negate=False,
                       describe=f"{a.describe} & {b.describe}",
                       bounds=bounds)


def merge_scan_conjuncts(per: list) -> list:
    """Fuse a filter's same-column range tests into single scans.

    Input: ``[(conjunct, [ScanDecision, ...]), ...]`` as produced per
    filter by :func:`decide_scan_conjunct`.  Output has the shape
    ``[(conjuncts_tuple, [ScanDecision, ...]), ...]``: entries whose
    decision is a non-negated packed-mode rewrite over the same column
    collapse into one entry carrying all their conjuncts and a merged
    rewrite (bounds intersected), so e.g. ``lo <= c AND c < hi`` costs
    ONE pass over the packed words.  Everything else — negated tests,
    decode-mode or non-rewritable decisions — passes through unchanged
    with a 1-tuple of its conjunct."""
    out = []
    by_col = {}
    for conj, ds in per:
        d = ds[0] if len(ds) == 1 else None
        mergeable = (d is not None and d.mode == "packed"
                     and d.rewrite is not None and not d.rewrite.negate)
        if not mergeable:
            out.append(((conj,), ds))
            continue
        i = by_col.get(d.column)
        if i is None:
            by_col[d.column] = len(out)
            out.append(((conj,), ds))
        else:
            conjs0, ds0 = out[i]
            d0 = ds0[0]
            merged = merge_rewrites(d0.rewrite, d.rewrite)
            out[i] = (conjs0 + (conj,), [dataclasses.replace(
                d0, rewrite=merged, reason=merged.describe)])
    return out


def wire_format_for(table_rows: int, num_nodes: int,
                    kind: str = "packed", *, capacity: int = 0,
                    cal=None) -> WireFormat:
    """Wire format of an exchange addressing the owners of a table
    range-partitioned over ``num_nodes``: the per-destination key domain is
    ``rows_per_node`` and its catalog-derived ``required_width`` fixes the
    packed key width (``repro.core.compression``).

    ``kind="auto"`` asks the LATENCY model: packed only when the roofline
    (``repro.core.wirecal``) predicts the codec time is bought back by the
    byte reduction — i.e. the exchange is network-bound, not codec-bound.
    Requires the exchange ``capacity``; ``cal`` defaults to the persisted
    (or builtin) machine calibration."""
    if kind == "auto":
        from repro.core import wirecal

        wf = WireFormat.packed_for(table_rows, num_nodes)
        kind = wirecal.choose_wire_kind(
            int(capacity), num_nodes, wf.domain,
            cal=cal if cal is not None else wirecal.load())
        return wf if kind == "packed" else WireFormat.raw()
    if kind != "packed":
        return WireFormat.raw()
    return WireFormat.packed_for(table_rows, num_nodes)
