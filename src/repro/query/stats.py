"""The §3.2.2 selectivity model: predicate selectivities, semi-join
alternative choice, and exchange buffer capacities derived from them.

The paper sizes its communication buffers from the expected number of
surviving keys after local filtering (n requests over a remote table of m
rows; §3.2.2 gives the bits-communicated model, ``repro.core.compression``
implements it).  Plans here are static-shape SPMD programs, so the same
estimate must become a COMPILE-TIME buffer capacity: we take the expected
per-destination message count under uniform key routing (a binomial with
mean ``e = n_local / P``), add a 6-sigma tail margin plus a constant floor,
and round up to a power of two.  Overflow flags in the exchange layer
surface any under-estimate at run time instead of corrupting results.
"""
from __future__ import annotations

import math
from typing import Mapping

from repro.core.exchange import WireFormat
from repro.query.ir import (
    Bin,
    BinOp,
    Col,
    ColumnStats,
    Expr,
    Lit,
    Param,
    UnaryOp,
    normalize_comparison,
)

# Selinger-style default for predicates the model cannot see through
# (column-vs-column comparisons, opaque expressions).
DEFAULT_SELECTIVITY = 1.0 / 3.0


def next_pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def capacity_for(expected: float, *, floor: int = 64) -> int:
    """Static per-destination buffer capacity for an expected message count:
    mean + 6*sqrt(mean) binomial tail margin + constant slack, rounded up to
    a power of two (fixed shapes; see DESIGN.md on static shapes)."""
    e = max(float(expected), 0.0)
    need = e + 6.0 * math.sqrt(e) + 16.0
    return next_pow2(max(floor, math.ceil(need)))


def _range_fraction(st: ColumnStats, op: str, v: float) -> float:
    """Fraction of a uniform [lo, hi] domain satisfying ``col op v``."""
    lo, hi = st.lo, st.hi
    if hi <= lo:
        return 1.0
    integral = st.n_distinct > 0
    span = (hi - lo + 1.0) if integral else (hi - lo)
    if op == "<":
        frac = (v - lo) / span
    elif op == "<=":
        frac = (v - lo + (1.0 if integral else 0.0)) / span
    elif op == ">":
        frac = (hi - v) / span
    elif op == ">=":
        frac = (hi - v + (1.0 if integral else 0.0)) / span
    else:
        return DEFAULT_SELECTIVITY
    return min(1.0, max(0.0, frac))


def estimate_selectivity(pred: Expr, stats: Mapping[str, ColumnStats],
                         binding=None) -> float:
    """Estimated fraction of rows satisfying ``pred`` under independence +
    uniformity (the paper's model; good enough to size buffers, and the
    run-time overflow flag catches the rest).

    Parameterized comparisons (``col op Param``) are resolved in order of
    preference: the value from ``binding`` when one is supplied (the
    prepare-time defaults of an auto-parameterized literal query), else
    the WORST binding in the parameter's declared ``lo``/``hi`` range
    (range selectivity is monotone in the bound, so the worst case sits at
    an endpoint), else a fully conservative 1.0 — a prepared plan's
    exchange capacities must stay sound for every future binding."""
    if isinstance(pred, BinOp):
        if pred.op == "and":
            return (estimate_selectivity(pred.lhs, stats, binding)
                    * estimate_selectivity(pred.rhs, stats, binding))
        if pred.op == "or":
            a = estimate_selectivity(pred.lhs, stats, binding)
            b = estimate_selectivity(pred.rhs, stats, binding)
            return min(1.0, a + b - a * b)
        norm = normalize_comparison(pred)
        if norm is not None:
            col, op, v = norm
            st = stats.get(col)
            if st is None:
                return 1.0 if isinstance(v, Param) else DEFAULT_SELECTIVITY
            if op == "==":
                # value-independent under the distinct-count model, so a
                # parameterized equality needs no binding
                return 1.0 / st.n_distinct if st.n_distinct else DEFAULT_SELECTIVITY
            if op == "!=":
                return 1.0 - (1.0 / st.n_distinct) if st.n_distinct else DEFAULT_SELECTIVITY
            if isinstance(v, Param):
                if binding is not None and v.name in binding:
                    v = binding[v.name]
                elif v.lo is not None and v.hi is not None:
                    return max(_range_fraction(st, op, float(v.lo)),
                               _range_fraction(st, op, float(v.hi)))
                else:
                    return 1.0
            try:
                return _range_fraction(st, op, float(v))
            except (TypeError, ValueError):
                return DEFAULT_SELECTIVITY
        return DEFAULT_SELECTIVITY
    if isinstance(pred, UnaryOp) and pred.op == "not":
        return 1.0 - estimate_selectivity(pred.operand, stats, binding)
    if isinstance(pred, Col):
        # bare boolean column: no histogram, assume an even split
        return 0.5
    if isinstance(pred, (Lit, Bin, Param)):
        return DEFAULT_SELECTIVITY
    return DEFAULT_SELECTIVITY


def request_capacity(table_rows: int, selectivity: float, num_nodes: int) -> int:
    """Capacity for an Alt-1 request / owner-routed exchange: each node
    ships ``rows/P * sel`` keys, spread uniformly over P destinations."""
    n_local = (table_rows / max(num_nodes, 1)) * min(max(selectivity, 0.0), 1.0)
    return capacity_for(n_local / max(num_nodes, 1))


def wire_format_for(table_rows: int, num_nodes: int,
                    kind: str = "packed", *, capacity: int = 0,
                    cal=None) -> WireFormat:
    """Wire format of an exchange addressing the owners of a table
    range-partitioned over ``num_nodes``: the per-destination key domain is
    ``rows_per_node`` and its catalog-derived ``required_width`` fixes the
    packed key width (``repro.core.compression``).

    ``kind="auto"`` asks the LATENCY model: packed only when the roofline
    (``repro.core.wirecal``) predicts the codec time is bought back by the
    byte reduction — i.e. the exchange is network-bound, not codec-bound.
    Requires the exchange ``capacity``; ``cal`` defaults to the persisted
    (or builtin) machine calibration."""
    if kind == "auto":
        from repro.core import wirecal

        wf = WireFormat.packed_for(table_rows, num_nodes)
        kind = wirecal.choose_wire_kind(
            int(capacity), num_nodes, wf.domain,
            cal=cal if cal is not None else wirecal.load())
        return wf if kind == "packed" else WireFormat.raw()
    if kind != "packed":
        return WireFormat.raw()
    return WireFormat.packed_for(table_rows, num_nodes)
