"""Static plan verifier: prove SPMD/capacity/recompilation/numeric
properties of a query plan BEFORE it reaches the cluster.

The paper's precompiled-plan model fixes every correctness property of a
query at plan time — which collectives run on every shard, how big the
exchange buffers are, which literals force a fresh compile.  This package
checks those properties from the IR tree + catalog statistics (plus
optional lowering artifacts) without executing anything, the way a race
detector proves properties of threaded code:

>>> from repro.query.verify import verify
>>> report = verify(q, catalog)          # or: TPCHDriver.check(q)
>>> report.ok, report.clean
(True, True)
>>> print(report.text())
VERIFY q14_promo: clean

Rules have stable IDs (``docs/RULES.md``) and severities:

- ``SPMD001-004`` — collective-consistency (divergent sequences,
  data-dependent guards/loops, HLO count cross-check)
- ``CAP001`` — capacity soundness under worst-case declared bindings
- ``PRM001`` — bindings outside declared ``Param`` ranges
- ``RCP001-003`` — recompilation hazards ``query/params.py`` cannot
  canonicalize
- ``NUM001-004`` — numeric hazards (zero-crossing divisions, batched-GEMM
  fallback, packed-wire key-domain overflow, non-integral keys)
"""
from repro.query.verify.collectives import (  # noqa: F401
    CollectiveOp,
    collective_script,
    expected_all_to_alls,
)
from repro.query.verify.core import (  # noqa: F401
    Diagnostic,
    PlanArtifacts,
    Rule,
    RULES,
    VerifyReport,
)
from repro.query.verify.hlo import (  # noqa: F401
    ControlFlowCollective,
    collectives_in_control_flow,
)
from repro.query.verify.rules import (  # noqa: F401
    ANALYZERS,
    VerifyContext,
    interval,
    verify,
    worst_case_binding,
)
