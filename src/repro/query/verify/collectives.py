"""Static collective model: the program-ordered collective sequence a
lowered plan issues on EVERY shard, derived from the IR chain and the
semi-join decisions — without tracing or compiling anything.

The per-operator mapping mirrors ``query/lower.py``:

- ``SemiJoin`` alt=request  -> ``all-to-all`` x2 packed / x3 raw
  (``core.exchange.request_reply``)
- ``SemiJoin`` alt=bitset   -> ``all-gather`` x1 (``semijoin.alt2_bitset``)
- ``SemiJoin`` alt=local, ``Exists``, ``GroupAggByKey`` -> no collective
  (co-partitioned, purely node-local)
- ``GroupAgg`` root         -> ``all-reduce`` x1 (the final ``psum``)
- ``TopK`` root             -> ``collective-permute`` x ``3*log2(P)``
  (the §3.2.3 butterfly merging reduction permutes values/keys/valid each
  of its log2(P) rounds) + one ``all-reduce`` per late-materialized
  output attribute (§3.2.7 fetch)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.query.lower import _chain, decide_semijoins
from repro.query.ir import Catalog, GroupAgg, Query, SemiJoin, TopK


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective step of a plan's static script.

    ``guard``/``in_loop`` describe data-dependent control flow around the
    collective; scripts derived from the IR never set them (the lowering
    has no data-dependent collectives by construction) — they exist so
    fixtures and external lowerings can describe hazardous plans to the
    SPMD analyzers.
    """

    kind: str    # all-to-all | all-gather | all-reduce | collective-permute
    count: int
    source: str  # plan construct that issues it ("q4_sj0", "group_agg", ...)
    guard: Optional[str] = None  # data-dependent predicate gating it
    in_loop: bool = False        # inside a data-dependent loop body

    def describe(self) -> str:
        return f"{self.kind} x{self.count} ({self.source})"

    def signature(self) -> tuple:
        """What must match across shards for the SPMD program to be
        deadlock-free (the source label is allowed to differ)."""
        return (self.kind, self.count)


def collective_script(query, catalog: Catalog, *, wire: str = "packed",
                      binding=None) -> tuple:
    """Program-ordered :class:`CollectiveOp` sequence of the lowered plan.

    Derived from the same ``decide_semijoins`` pass the lowering runs, so
    the script reflects the actual alternative choices (request vs bitset
    vs local) under ``wire`` and ``binding``.
    """
    root = query.root if isinstance(query, Query) else query
    name = query.name if isinstance(query, Query) else None
    decisions = decide_semijoins(
        root, catalog, query_name=name, wire=wire, binding=binding
    )
    num_nodes = max(catalog.num_nodes, 1)
    ops = []
    for node in _chain(root):
        if not isinstance(node, SemiJoin):
            continue
        plan = decisions[id(node)]
        if plan.alt == "request":
            ops.append(CollectiveOp(
                "all-to-all", 2 if plan.wire.packed else 3, plan.key))
        elif plan.alt == "bitset":
            ops.append(CollectiveOp("all-gather", 1, plan.key))
    if isinstance(root, GroupAgg):
        ops.append(CollectiveOp("all-reduce", 1, "group_agg"))
    elif isinstance(root, TopK):
        rounds = int(math.log2(num_nodes)) if num_nodes > 1 else 0
        if rounds:
            # butterfly rounds each ppermute the (values, keys, valid) tuple
            ops.append(CollectiveOp(
                "collective-permute", 3 * rounds, "topk_allreduce"))
        fetches = len(root.fetch)
        if fetches:
            ops.append(CollectiveOp(
                "all-reduce", fetches, "late_materialization"))
    return tuple(ops)


def expected_all_to_alls(script) -> int:
    """All-to-all instruction count the lowered HLO should contain."""
    return sum(op.count for op in script if op.kind == "all-to-all")
