"""The analyzers: collective-consistency, capacity soundness,
recompilation hazards, numeric hazards.

Each analyzer is ``fn(VerifyContext) -> list[Diagnostic]`` and is purely
static: it reads the IR tree, the catalog statistics, the semi-join
decisions the lowering would make, and (optionally) supplied lowering
artifacts — it never traces, compiles, or executes a plan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional

import numpy as np

from repro.core import wirecal
from repro.query import stats as qstats
from repro.query.lower import (
    ONEHOT_MAX_GROUPS,
    _chain,
    _has_division,
    decide_scans,
    decide_semijoins,
)
from repro.query.ir import (
    Bin,
    BinOp,
    Catalog,
    Col,
    Exists,
    Filter,
    GroupAgg,
    GroupAggByKey,
    Lit,
    Param,
    Project,
    Query,
    Scan,
    SemiJoin,
    TopK,
    UnaryOp,
    _FLIP_CMP,
    conjuncts,
    normalize_comparison,
    query_params,
    validate,
)
from repro.query.params import _param_dtype

from .collectives import collective_script, expected_all_to_alls
from .core import (
    PlanArtifacts,
    VerifyReport,
    make_diagnostic,
    sort_diagnostics,
)
from .hlo import collectives_in_control_flow

_INF = float("inf")
_CMP_OPS = frozenset(_FLIP_CMP)


@dataclasses.dataclass
class VerifyContext:
    """Everything the analyzers see about one query."""

    query: Query
    catalog: Catalog
    wire: str = "packed"
    binding: Mapping = dataclasses.field(default_factory=dict)
    # the binding the PLAN was sized with at prepare time (auto-param
    # defaults); capacity soundness compares against it
    stats_binding: Mapping = dataclasses.field(default_factory=dict)
    # PlanContext capacity overrides keyed "<query>_sj<i>"
    capacities: Mapping = dataclasses.field(default_factory=dict)
    artifacts: Optional[PlanArtifacts] = None
    # machine roofline calibration (repro.core.wirecal.WireCalibration)
    # for the wire-choice audit; None disables WIRE001 so the verdict
    # never depends on whatever calibration file the host happens to have
    calibration: Optional[object] = None

    @property
    def name(self) -> str:
        return self.query.name or "query"


# ---------------------------------------------------------------------------
# shared walks
# ---------------------------------------------------------------------------


def _expr_sites(root, catalog: Catalog):
    """(site label, expression, stats the expression evaluates against),
    chain order.  Semi-join/exists PREDICATES evaluate against the target
    table; everything else against the stream's base table."""
    sites = []
    base = None
    for node in _chain(root):
        if isinstance(node, Scan):
            base = node.table
            continue
        stats = catalog.table(base).stats if base else {}
        if isinstance(node, Filter):
            sites.append(("filter", node.pred, stats))
        elif isinstance(node, Project):
            for n, e in node.cols:
                sites.append((f"project.{n}", e, stats))
        elif isinstance(node, SemiJoin):
            tstats = catalog.table(node.table).stats
            sites.append((f"semijoin[{node.table}].key", node.key, stats))
            sites.append((f"semijoin[{node.table}].pred", node.pred, tstats))
        elif isinstance(node, Exists):
            tstats = catalog.table(node.table).stats
            sites.append((f"exists[{node.table}].pred", node.pred, tstats))
        elif isinstance(node, GroupAggByKey):
            sites.append(("group_by_key.key", node.key, stats))
            for a in node.aggs:
                if a.expr is not None:
                    sites.append((f"group_by_key.{a.name}", a.expr, stats))
            base = node.into
        elif isinstance(node, GroupAgg):
            for k in node.keys:
                sites.append((f"group_agg.key.{k.name}", k.expr, stats))
            for a in node.aggs:
                if a.expr is not None:
                    sites.append((f"group_agg.{a.name}", a.expr, stats))
        elif isinstance(node, TopK):
            sites.append(("topk.value", node.value, stats))
            if node.pred is not None:
                sites.append(("topk.pred", node.pred, stats))
    return sites


def _sane(lo: float, hi: float) -> tuple:
    if math.isnan(lo):
        lo = -_INF
    if math.isnan(hi):
        hi = _INF
    return (lo, hi)


def interval(e, stats, binding=None) -> tuple:
    """Conservative static ``[lo, hi]`` of an expression's value, from
    catalog column stats, Param ranges/bindings, and literals.  Unknown ->
    ``(-inf, inf)``."""
    if isinstance(e, Lit):
        v = e.value
        if isinstance(v, bool):
            return (0.0, 1.0)
        if isinstance(v, (int, float)):
            return (float(v), float(v))
        return (-_INF, _INF)
    if isinstance(e, Col):
        st = stats.get(e.name)
        return (st.lo, st.hi) if st is not None else (-_INF, _INF)
    if isinstance(e, Param):
        if binding and e.name in binding:
            try:
                v = float(binding[e.name])
                return (v, v)
            except (TypeError, ValueError):
                return (-_INF, _INF)
        if e.lo is not None and e.hi is not None:
            return (float(e.lo), float(e.hi))
        return (-_INF, _INF)
    if isinstance(e, UnaryOp):
        lo, hi = interval(e.operand, stats, binding)
        return (-hi, -lo) if e.op == "neg" else (0.0, 1.0)
    if isinstance(e, Bin):
        return (0.0, float(len(e.edges)))
    if isinstance(e, BinOp):
        if e.op in _CMP_OPS or e.op in ("and", "or"):
            return (0.0, 1.0)
        a = interval(e.lhs, stats, binding)
        b = interval(e.rhs, stats, binding)
        if e.op == "+":
            return _sane(a[0] + b[0], a[1] + b[1])
        if e.op == "-":
            return _sane(a[0] - b[1], a[1] - b[0])
        if e.op == "*":
            prods = [x * y for x in a for y in b]
            if any(math.isnan(p) for p in prods):
                return (-_INF, _INF)
            return (min(prods), max(prods))
        if e.op == "/":
            if b[0] <= 0.0 <= b[1]:
                return (-_INF, _INF)
            quots = [x / y for x in a for y in b]
            if any(math.isnan(v) for v in quots):
                return (-_INF, _INF)
            return (min(quots), max(quots))
    return (-_INF, _INF)


def _iter_divisions(e):
    if isinstance(e, BinOp):
        if e.op == "/":
            yield e
        yield from _iter_divisions(e.lhs)
        yield from _iter_divisions(e.rhs)
    elif isinstance(e, UnaryOp):
        yield from _iter_divisions(e.operand)
    elif isinstance(e, Bin):
        yield from _iter_divisions(e.child)


def _iter_comparisons(e):
    """All comparison BinOps inside a predicate tree."""
    if isinstance(e, BinOp):
        if e.op in _CMP_OPS:
            yield e
        else:
            yield from _iter_comparisons(e.lhs)
            yield from _iter_comparisons(e.rhs)
    elif isinstance(e, UnaryOp):
        yield from _iter_comparisons(e.operand)


def worst_case_binding(root, catalog: Catalog, binding=None) -> dict:
    """A concrete binding that maximizes estimated selectivity: bound
    params keep their value; unbound ranged params are pinned to the
    declared endpoint with the larger range fraction (the same endpoint
    ``stats.estimate_selectivity`` assumes when sizing capacities)."""
    witness = dict(binding or {})
    base = None
    for node in _chain(root):
        if isinstance(node, Scan):
            base = node.table
            continue
        if isinstance(node, GroupAggByKey):
            base = node.into
            continue
        if isinstance(node, Filter):
            stats = catalog.table(base).stats
            preds = conjuncts(node.pred)
        elif isinstance(node, (SemiJoin, Exists)):
            stats = catalog.table(node.table).stats
            preds = conjuncts(node.pred)
        else:
            continue
        for pred in preds:
            norm = normalize_comparison(pred)
            if norm is None:
                continue
            col, op, v = norm
            if not isinstance(v, Param) or v.name in witness:
                continue
            if v.lo is None or v.hi is None:
                continue
            st = stats.get(col)
            if st is None or op in ("==", "!="):
                pick = v.lo
            else:
                at_lo = qstats._range_fraction(st, op, float(v.lo))
                at_hi = qstats._range_fraction(st, op, float(v.hi))
                pick = v.lo if at_lo >= at_hi else v.hi
            witness[v.name] = np.dtype(v.dtype).type(pick).item()
    return witness


# ---------------------------------------------------------------------------
# analyzer 1: collective consistency (SPMD001-004)
# ---------------------------------------------------------------------------


def check_collectives(ctx: VerifyContext):
    out = []
    script = collective_script(ctx.query, ctx.catalog, wire=ctx.wire,
                               binding=dict(ctx.stats_binding) or None)
    scripts = {"<derived>": script}
    art = ctx.artifacts

    if art is not None and art.shard_scripts:
        shard = {k: tuple(v) for k, v in art.shard_scripts.items()}
        ranks = sorted(shard)
        ref_rank, ref = ranks[0], shard[ranks[0]]
        for rank in ranks[1:]:
            s = shard[rank]
            for i in range(max(len(ref), len(s))):
                a = ref[i].describe() if i < len(ref) else "<end of program>"
                b = s[i].describe() if i < len(s) else "<end of program>"
                same = (i < len(ref) and i < len(s)
                        and ref[i].signature() == s[i].signature())
                if not same:
                    out.append(make_diagnostic(
                        "SPMD001",
                        f"shards {ref_rank} and {rank} issue different "
                        f"collective sequences — first divergence at "
                        f"collective #{i}: {a} vs {b}; the program "
                        f"deadlocks at the earlier of the two",
                        query=ctx.name, site=f"collective#{i}",
                        shards=(ref_rank, rank), index=i))
                    break
            else:
                continue
            break
        scripts.update({f"shard{r}": s for r, s in shard.items()})

    reported = set()
    for s in scripts.values():
        for op in s:
            if op.guard is not None and ("guard", op.source) not in reported:
                reported.add(("guard", op.source))
                out.append(make_diagnostic(
                    "SPMD002",
                    f"collective {op.describe()} is gated by the "
                    f"data-dependent predicate {op.guard!r}; a shard whose "
                    f"data skips the branch hangs every peer inside it",
                    query=ctx.name, site=op.source, guard=op.guard))
            elif op.in_loop and ("loop", op.source) not in reported:
                reported.add(("loop", op.source))
                out.append(make_diagnostic(
                    "SPMD003",
                    f"collective {op.describe()} executes inside a "
                    f"data-dependent loop; safe only if every shard runs "
                    f"the identical trip count",
                    query=ctx.name, site=op.source))

    if art is not None and art.hlo:
        for f in collectives_in_control_flow(art.hlo):
            kinds = ", ".join(f"{k} x{c}" for k, c in f.kinds)
            if f.region == "conditional":
                out.append(make_diagnostic(
                    "SPMD002",
                    f"HLO conditional branch {f.computation!r} executes "
                    f"collectives ({kinds}); shards taking different "
                    f"branches deadlock",
                    query=ctx.name, site=f.computation, kinds=f.kinds))
            else:
                out.append(make_diagnostic(
                    "SPMD003",
                    f"HLO while computation {f.computation!r} executes "
                    f"collectives ({kinds}) — safe only if every shard "
                    f"runs the same trip count",
                    query=ctx.name, site=f.computation, kinds=f.kinds))

    if art is not None and art.instructions is not None:
        expected = expected_all_to_alls(script)
        actual = sum(1 for i in art.instructions if i.kind == "all-to-all")
        if actual != expected:
            out.append(make_diagnostic(
                "SPMD004",
                f"lowered HLO issues {actual} all-to-all(s) but the "
                f"static model expects {expected} (2 per packed request "
                f"semi-join, 3 per raw)",
                query=ctx.name, site="all-to-all",
                expected=expected, actual=actual))
    return out


# ---------------------------------------------------------------------------
# analyzer 2: capacity soundness (CAP001)
# ---------------------------------------------------------------------------


def check_capacity(ctx: VerifyContext):
    out = []
    root = ctx.query.root
    prepared = decide_semijoins(
        root, ctx.catalog, query_name=ctx.query.name, wire=ctx.wire,
        binding=dict(ctx.stats_binding) or None)
    requests = {nid: p for nid, p in prepared.items() if p.alt == "request"}
    if not requests:
        return out
    witness = worst_case_binding(root, ctx.catalog, ctx.binding)
    required = decide_semijoins(
        root, ctx.catalog, query_name=ctx.query.name, wire=ctx.wire,
        binding=witness or None)
    for nid, plan in requests.items():
        effective = int(ctx.capacities.get(plan.key, plan.capacity))
        need = int(required[nid].derived_capacity)
        if need > effective:
            shown = {k: witness[k] for k in sorted(witness)}
            out.append(make_diagnostic(
                "CAP001",
                f"request semi-join {plan.key} against {plan.table!r} has "
                f"buffer capacity {effective} but binding {shown} needs "
                f"{need}; executing it would overflow the exchange",
                query=ctx.name, site=plan.key, table=plan.table,
                capacity=effective, required=need, binding=shown))
    return out


# ---------------------------------------------------------------------------
# analyzer 3: recompilation hazards (RCP001-003)
# ---------------------------------------------------------------------------


def check_recompilation(ctx: VerifyContext):
    out = []
    root = ctx.query.root
    if isinstance(root, GroupAgg) and root.method == "kernel":
        n_lits = sum(
            1
            for node in _chain(root)
            if isinstance(node, (Filter, SemiJoin))
            or (isinstance(node, TopK) and node.pred is not None)
            for cmp_ in _iter_comparisons(node.pred)
            for side in (cmp_.lhs, cmp_.rhs)
            if isinstance(side, Lit))
        if n_lits:
            out.append(make_diagnostic(
                "RCP002",
                f"method='kernel' grouped aggregation skips "
                f"auto-parameterization; {n_lits} predicate literal(s) "
                f"are baked into the fused kernel and any new value "
                f"compiles a fresh executable",
                query=ctx.name, site="group_agg", literals=n_lits))
        return out

    for node in _chain(root):
        if isinstance(node, Filter):
            site, pred, canonicalized = "filter", node.pred, True
        elif isinstance(node, SemiJoin):
            site, pred, canonicalized = f"semijoin[{node.table}]", node.pred, True
        elif isinstance(node, TopK) and node.pred is not None:
            site, pred, canonicalized = "topk", node.pred, True
        elif isinstance(node, Exists):
            site, pred, canonicalized = f"exists[{node.table}]", node.pred, False
        else:
            continue
        for cmp_ in _iter_comparisons(pred):
            lhs_lit = isinstance(cmp_.lhs, Lit)
            rhs_lit = isinstance(cmp_.rhs, Lit)
            if lhs_lit and rhs_lit:
                out.append(make_diagnostic(
                    "RCP003",
                    f"{site} compares two literals "
                    f"({cmp_.lhs.value!r} {cmp_.op} {cmp_.rhs.value!r}); "
                    f"the constant is baked into the plan shape, so "
                    f"distinct constants compile distinct plans",
                    query=ctx.name, site=site))
                continue
            for lit in ((cmp_.lhs,) if lhs_lit else ()) + (
                    (cmp_.rhs,) if rhs_lit else ()):
                if not canonicalized:
                    out.append(make_diagnostic(
                        "RCP001",
                        f"{site} predicate literal {lit.value!r} is not "
                        f"auto-parameterized (parameterize does not "
                        f"rewrite this operator); every distinct value "
                        f"compiles a fresh plan",
                        query=ctx.name, site=site, value=lit.value))
                elif _param_dtype(lit.value) is None:
                    out.append(make_diagnostic(
                        "RCP001",
                        f"{site} compares against literal {lit.value!r} "
                        f"of unparameterizable type "
                        f"{type(lit.value).__name__}; every distinct "
                        f"value compiles a fresh plan and pollutes the "
                        f"shape cache",
                        query=ctx.name, site=site, value=lit.value))
    return out


# ---------------------------------------------------------------------------
# analyzer 4: numeric hazards (NUM001-004)
# ---------------------------------------------------------------------------


def check_numeric(ctx: VerifyContext):
    out = []
    root = ctx.query.root
    catalog = ctx.catalog
    binding = dict(ctx.binding) or None

    for site, expr, stats in _expr_sites(root, catalog):
        for div in _iter_divisions(expr):
            lo, hi = interval(div.rhs, stats, binding)
            if lo <= 0.0 <= hi:
                out.append(make_diagnostic(
                    "NUM001",
                    f"denominator of the division at {site} has static "
                    f"range [{lo}, {hi}], which contains 0 — NaN/Inf can "
                    f"enter masked lanes and poison downstream sums",
                    query=ctx.name, site=site, lo=lo, hi=hi))

    if isinstance(root, GroupAgg):
        groups = 1
        for k in root.keys:
            groups *= k.cardinality
        exprs = [k.expr for k in root.keys]
        exprs += [a.expr for a in root.aggs if a.expr is not None]
        for node in _chain(root)[:-1]:
            if isinstance(node, Project):
                exprs += [e for _, e in node.cols]
        if (1 < groups <= ONEHOT_MAX_GROUPS
                and any(_has_division(e) for e in exprs)):
            out.append(make_diagnostic(
                "NUM002",
                "division feeds the grouped aggregation's keys/measures; "
                "the vmap-batched mask@GEMM lowering is disabled (NaN "
                "guard) and execute_batch falls back to per-lane "
                "pipelines",
                query=ctx.name, site="group_agg", groups=groups))

    prepared = decide_semijoins(
        root, catalog, query_name=ctx.query.name, wire=ctx.wire,
        binding=dict(ctx.stats_binding) or None)
    base = None
    for node in _chain(root):
        if isinstance(node, Scan):
            base = node.table
            continue
        if isinstance(node, GroupAggByKey):
            base = node.into
            continue
        if not isinstance(node, SemiJoin):
            continue
        plan = prepared[id(node)]
        stats = catalog.table(base).stats
        if plan.alt != "local" and isinstance(node.key, Col):
            st = stats.get(node.key.name)
            if st is not None and st.n_distinct == 0:
                out.append(make_diagnostic(
                    "NUM004",
                    f"semi-join {plan.key} key column "
                    f"{node.key.name!r} has float stats (n_distinct=0); "
                    f"Elias-Fano key packing and owner routing assume an "
                    f"integral key domain",
                    query=ctx.name, site=plan.key, column=node.key.name))
        if plan.alt == "request" and plan.wire.packed:
            span = plan.wire.domain * max(catalog.num_nodes, 1)
            lo, hi = interval(node.key, stats, binding)
            if lo < 0.0 or hi > span - 1:
                out.append(make_diagnostic(
                    "NUM003",
                    f"semi-join {plan.key} key range [{lo}, {hi}] exceeds "
                    f"the packed wire key space [0, {span - 1}] (domain "
                    f"{plan.wire.domain} x {catalog.num_nodes} nodes); "
                    f"encode_key_buckets clips out-of-domain offsets, "
                    f"silently corrupting the lookup",
                    query=ctx.name, site=plan.key, lo=lo, hi=hi,
                    domain=plan.wire.domain))
    return out


# ---------------------------------------------------------------------------
# analyzer 5: binding vs declared Param ranges (PRM001)
# ---------------------------------------------------------------------------


def check_param_ranges(ctx: VerifyContext):
    out = []
    for p in query_params(ctx.query.root):
        if (p.lo is None and p.hi is None) or p.name not in ctx.binding:
            continue
        v = ctx.binding[p.name]
        try:
            fv = float(v)
        except (TypeError, ValueError):
            continue  # castability is the driver's eager binding check
        lo = -_INF if p.lo is None else float(p.lo)
        hi = _INF if p.hi is None else float(p.hi)
        if math.isnan(fv) or fv < lo or fv > hi:
            out.append(make_diagnostic(
                "PRM001",
                f"binding {p.name}={v!r} lies outside the declared range "
                f"[{p.lo}, {p.hi}]; exchange capacities were sized for "
                f"in-range bindings only",
                query=ctx.name, site=p.name, value=v, lo=p.lo, hi=p.hi))
    return out


# ---------------------------------------------------------------------------
# analyzer 6: wire-choice audit under a machine calibration (WIRE001)
# ---------------------------------------------------------------------------


def check_wire_choice(ctx: VerifyContext):
    """Audit forced-packed request exchanges against the roofline latency
    model.  Only runs when the caller supplies an explicit calibration —
    the prediction depends on measured codec/link throughputs, and a
    verifier must not change verdicts because of a stray calibration file
    on the host."""
    out = []
    cal = ctx.calibration
    if cal is None or ctx.wire != "packed":
        return out
    prepared = decide_semijoins(
        ctx.query.root, ctx.catalog, query_name=ctx.query.name,
        wire=ctx.wire, binding=dict(ctx.stats_binding) or None)
    P = max(ctx.catalog.num_nodes, 1)
    for plan in prepared.values():
        if plan.alt != "request" or not plan.wire.packed:
            continue
        cap = int(ctx.capacities.get(plan.key, plan.capacity))
        pc, pw = wirecal.predict_alt1_ms(cap, P, plan.wire.domain,
                                         packed=True, cal=cal)
        rc, rw = wirecal.predict_alt1_ms(cap, P, plan.wire.domain,
                                         packed=False, cal=cal)
        if pc + pw > rc + rw:
            out.append(make_diagnostic(
                "WIRE001",
                f"request semi-join {plan.key} is forced onto the packed "
                f"wire, but the calibration predicts it at "
                f"{pc + pw:.3g} ms (codec {pc:.3g} + wire {pw:.3g}) vs "
                f"{rc + rw:.3g} ms raw — the codec costs more than the "
                f"link saves; use wire='raw' or recalibrate",
                query=ctx.name, site=plan.key, table=plan.table,
                packed_ms=pc + pw, raw_ms=rc + rw,
                codec_ms=pc, wire_ms=pw))
    return out


# ---------------------------------------------------------------------------
# analyzer 7: compressed-residency scan audit (SCAN001)
# ---------------------------------------------------------------------------


def check_scan(ctx: VerifyContext):
    """SCAN001: a filter over a packed base-table column whose shape the
    code-space rewrite (``repro.query.stats.scan_rewrite``) cannot serve —
    column-vs-column, arithmetic on the column, non-comparison — forces a
    full decode of the compressed column before the predicate runs.  Only
    Filter conjuncts over the scan stream are in scope: semi-join/exists
    TARGET predicates evaluate on the probe path, not the scan kernel, so
    they decode by design and are not reported."""
    out = []
    for per in decide_scans(ctx.query.root, ctx.catalog).values():
        for conj, ds in per:
            for d in ds:
                if d.rewritable:
                    continue
                out.append(make_diagnostic(
                    "SCAN001",
                    f"filter conjunct over packed column {d.column!r} of "
                    f"{d.table!r} (width {d.width}) is not rewritable into "
                    f"a code-space range test; the scan decodes the full "
                    f"column ({d.scan_bytes} B/node instead of a packed "
                    f"scan) — restructure the predicate as "
                    f"<col> <op> <scalar> to keep it on packed words",
                    query=ctx.name, site=f"scan[{d.table}.{d.column}]",
                    table=d.table, column=d.column, width=d.width))
    return out


ANALYZERS = (
    check_collectives,
    check_capacity,
    check_recompilation,
    check_numeric,
    check_param_ranges,
    check_wire_choice,
    check_scan,
)


def verify(query, catalog: Catalog, *, wire: str = "packed", binding=None,
           stats_binding=None, capacities=None,
           artifacts: Optional[PlanArtifacts] = None,
           calibration=None) -> VerifyReport:
    """Statically verify one query against ``catalog``: run every
    registered analyzer and return a :class:`VerifyReport`.

    ``binding`` is the execute-time binding under scrutiny (may be partial
    or empty — unbound ranged params are analyzed at their worst declared
    endpoint); ``stats_binding`` is the prepare-time binding the plan's
    capacities were derived from (the auto-parameterization defaults);
    ``capacities`` are the driver's PlanContext overrides; ``artifacts``
    optionally supplies lowering outputs (per-shard collective scripts,
    HLO text, parsed collective instructions) for the SPMD analyzers;
    ``calibration`` (a :class:`repro.core.wirecal.WireCalibration`)
    enables the WIRE001 wire-choice audit against that machine's
    roofline model.
    """
    if not isinstance(query, Query):
        query = Query(root=query)
    validate(query.root, catalog)
    ctx = VerifyContext(
        query=query,
        catalog=catalog,
        wire=wire,
        binding=dict(binding or {}),
        stats_binding=dict(stats_binding or {}),
        capacities=dict(capacities or {}),
        artifacts=artifacts,
        calibration=calibration,
    )
    diags = []
    for analyzer in ANALYZERS:
        diags.extend(analyzer(ctx))
    return VerifyReport(query=query.name or "",
                        diagnostics=sort_diagnostics(diags))
