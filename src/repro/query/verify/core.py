"""Verifier core: the rule registry, diagnostics, and report types.

A *rule* is a stable, documented property of a compiled SPMD plan
(``docs/RULES.md`` catalogs them).  Analyzers in :mod:`.rules` emit
:class:`Diagnostic` instances referencing rules by ID; the public
:func:`repro.query.verify.verify` entry point collects them into a
:class:`VerifyReport`.  Nothing in this module executes a plan.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

ERROR = "error"
WARN = "warn"
INFO = "info"
SEVERITIES = (ERROR, WARN, INFO)
_SEV_ORDER = {s: i for i, s in enumerate(SEVERITIES)}


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered static-analysis rule with a stable ID."""

    id: str
    severity: str
    title: str
    summary: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r} for {self.id}")


RULES: dict = {}


def register_rule(id: str, severity: str, title: str, summary: str) -> Rule:
    if id in RULES:
        raise ValueError(f"duplicate rule id {id!r}")
    rule = Rule(id=id, severity=severity, title=title, summary=summary)
    RULES[id] = rule
    return rule


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: a rule violation (or advisory) at a plan site."""

    rule_id: str
    severity: str
    message: str
    query: str = ""
    site: str = ""   # plan construct ("lineitem_sj0", "group_agg", ...)
    data: Mapping = dataclasses.field(default_factory=dict)

    def format(self) -> str:
        where = f" {self.site}:" if self.site else ""
        return f"[{self.rule_id} {self.severity}]{where} {self.message}"


def make_diagnostic(rule_id: str, message: str, *, query: str = "",
                    site: str = "", **data) -> Diagnostic:
    """Diagnostic whose severity comes from the registered rule."""
    rule = RULES[rule_id]
    return Diagnostic(rule_id=rule_id, severity=rule.severity,
                      message=message, query=query, site=site, data=data)


@dataclasses.dataclass(frozen=True)
class PlanArtifacts:
    """Optional lowering/compilation artifacts the analyzers can consume
    beyond the IR + catalog:

    - ``shard_scripts``: per-shard program-ordered collective scripts
      (rank -> tuple of :class:`~.collectives.CollectiveOp`).  Scripts
      derived from one IR tree are identical by construction, so this is
      how divergent/fixture plans reach the SPMD analyzers.
    - ``instructions``: program-ordered HLO
      :class:`repro.launch.roofline.CollectiveInstr` tuple, for
      cross-checking the static collective model against a real lowering.
    - ``hlo``: HLO text, scanned for collectives under data-dependent
      control flow (``while`` bodies, ``conditional`` branches).
    """

    shard_scripts: Optional[Mapping] = None
    instructions: Optional[tuple] = None
    hlo: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class VerifyReport:
    """All diagnostics for one query, ordered most-severe first."""

    query: str
    diagnostics: tuple

    @property
    def errors(self) -> tuple:
        return tuple(d for d in self.diagnostics if d.severity == ERROR)

    @property
    def warnings(self) -> tuple:
        return tuple(d for d in self.diagnostics if d.severity == WARN)

    @property
    def infos(self) -> tuple:
        return tuple(d for d in self.diagnostics if d.severity == INFO)

    @property
    def ok(self) -> bool:
        """No errors (warnings and advisories allowed)."""
        return not self.errors

    @property
    def clean(self) -> bool:
        """No errors and no warnings (info advisories allowed)."""
        return not self.errors and not self.warnings

    def rule_ids(self) -> frozenset:
        return frozenset(d.rule_id for d in self.diagnostics)

    def text(self) -> str:
        head = f"VERIFY {self.query or '<anonymous>'}: " + (
            "clean" if self.clean else
            f"{len(self.errors)} error(s), {len(self.warnings)} warning(s), "
            f"{len(self.infos)} advisory(ies)")
        lines = [head]
        lines.extend("  " + d.format() for d in self.diagnostics)
        return "\n".join(lines)


def sort_diagnostics(diags: Sequence[Diagnostic]) -> tuple:
    return tuple(sorted(
        diags, key=lambda d: (_SEV_ORDER[d.severity], d.rule_id, d.site)
    ))


# ---------------------------------------------------------------------------
# the rule catalog (docs/RULES.md mirrors this, one section per ID)
# ---------------------------------------------------------------------------

register_rule(
    "SPMD001", ERROR, "Divergent collective sequence",
    "Shards issue different collective sequences; the SPMD program "
    "deadlocks at the first mismatched collective.")
register_rule(
    "SPMD002", ERROR, "Data-dependent collective guard",
    "A collective is gated by data-dependent control flow; shards that "
    "branch differently hang their peers.")
register_rule(
    "SPMD003", WARN, "Collective inside data-dependent loop",
    "A collective executes inside a loop whose trip count can depend on "
    "data; all shards must iterate in lockstep for it to be safe.")
register_rule(
    "SPMD004", WARN, "Collective count mismatch vs static model",
    "The lowered HLO's all-to-all count disagrees with the plan's static "
    "collective model (2 per packed request semi-join, 3 per raw).")
register_rule(
    "CAP001", ERROR, "Exchange capacity unsound for declared bindings",
    "A worst-case parameter binding drives a request exchange past its "
    "derived buffer capacity; execution would raise the overflow flag.")
register_rule(
    "PRM001", ERROR, "Binding outside declared Param range",
    "A bound parameter value lies outside the Param's declared lo/hi "
    "range; capacities were only proven for in-range bindings.")
register_rule(
    "RCP001", WARN, "Unparameterizable comparison literal",
    "A predicate compares against a literal params.parameterize cannot "
    "canonicalize (non-numeric dtype); every distinct value compiles a "
    "fresh executable and pollutes the plan cache.")
register_rule(
    "RCP002", INFO, "Kernel plan skips auto-parameterization",
    "method='kernel' grouped aggregation bakes predicate literals into "
    "the Pallas kernel; re-running with different literals recompiles.")
register_rule(
    "RCP003", WARN, "Constant comparison baked into plan shape",
    "A literal-vs-literal comparison is constant-foldable but still part "
    "of the cached plan shape; distinct constants compile distinct plans.")
register_rule(
    "NUM001", WARN, "Division by possibly-zero denominator",
    "A division's denominator interval (from catalog stats and Param "
    "ranges) contains zero; NaN/Inf can enter masked lanes.")
register_rule(
    "NUM002", INFO, "Division disables batched GEMM lowering",
    "Division feeding a grouped aggregation disables the vmap-batched "
    "mask@GEMM lowering (the PR-4 NaN guard); batched lanes fall back to "
    "per-lane pipelines.")
register_rule(
    "NUM003", ERROR, "Semi-join key can exceed packed wire domain",
    "A request semi-join key's static range exceeds the packed wire "
    "format's P*domain key space; encode_key_buckets clips out-of-domain "
    "offsets, silently corrupting lookups.")
register_rule(
    "NUM004", WARN, "Non-integral semi-join key",
    "A semi-join key column has float (n_distinct=0) catalog stats; "
    "Elias-Fano key packing and owner routing assume integral keys.")
register_rule(
    "SCAN001", WARN, "Packed column scanned outside code space",
    "A filter references a compressed-resident (packed) column with a "
    "predicate that cannot be rewritten into a code-space range test "
    "(column-vs-column, arithmetic on the column, non-comparison shape); "
    "the column is fully decoded before the predicate runs, forfeiting "
    "the predicate-on-packed bandwidth savings.")
register_rule(
    "WIRE001", INFO, "Forced packed wire predicted slower than raw",
    "The wire= override forces the packed codec on a request exchange, "
    "but the supplied machine calibration's roofline model predicts the "
    "codec time exceeds the raw link-time savings; raw would be faster.")
