"""HLO control-flow scan: find collectives executing under data-dependent
control flow (``while`` bodies, ``conditional`` branches) in HLO text.

Works on both pre-optimization (``lowered.as_text(dialect="hlo")``) and
post-optimization (``compiled.as_text()``) HLO — the textual syntax is the
same: named computations with brace-delimited bodies, ``while``
instructions naming ``condition=``/``body=`` computations, and
``conditional`` instructions naming branch computations.  Collectives are
attributed transitively: a collective inside a fusion/call reached from a
while body counts as inside the loop.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter

from repro.launch.roofline import COLLECTIVES

_COMMENT_RE = re.compile(r"/\*.*?\*/", re.S)
_COMP_HEAD_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*(?:\([^)]*\))?"
    r"\s*(?:->\s*[^{]*)?\{\s*$")
_OPCODE_RE = re.compile(r"=\s*\S+\s+([\w-]+)\(")
_REF_RE = re.compile(
    r"(?:condition|body|to_apply|true_computation|false_computation)"
    r"=%?([\w.\-]+)")
_REF_SET_RE = re.compile(
    r"(?:branch_computations|called_computations|calls)=\{([^}]*)\}")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)", re.S)
_COND_RE = re.compile(r"\bconditional\(")


def _collective_kind(opcode: str):
    for kind in COLLECTIVES:
        if opcode == kind or opcode.startswith(kind + "-"):
            return kind
    return None


@dataclasses.dataclass(frozen=True)
class ControlFlowCollective:
    """Collectives found (transitively) inside one control-flow region."""

    region: str       # "while" | "conditional"
    computation: str  # the body/branch computation containing them
    kinds: tuple      # ((collective kind, count), ...) sorted by kind


def _parse_computations(text: str):
    """computation name -> (direct collective Counter, referenced comps,
    raw body text)."""
    comps = {}
    current = None
    for line in text.splitlines():
        head = _COMP_HEAD_RE.match(line)
        if head is not None and "=" not in line.split("{")[0]:
            current = head.group("name")
            comps[current] = (Counter(), set(), [])
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        direct, refs, body = comps[current]
        body.append(line)
        m = _OPCODE_RE.search(line)
        if m is not None:
            kind = _collective_kind(m.group(1))
            if kind is not None:
                direct[kind] += 1
        for ref in _REF_RE.findall(line):
            refs.add(ref)
        for group in _REF_SET_RE.findall(line):
            for ref in re.findall(r"%?([\w.\-]+)", group):
                refs.add(ref)
    return comps


def _transitive_collectives(name, comps, memo, stack=()):
    if name in memo:
        return memo[name]
    if name not in comps or name in stack:
        return Counter()
    direct, refs, _ = comps[name]
    total = Counter(direct)
    for ref in refs:
        total.update(_transitive_collectives(ref, comps, memo,
                                             stack + (name,)))
    memo[name] = total
    return total


def collectives_in_control_flow(hlo_text: str) -> tuple:
    """All ``while`` bodies/conditions and ``conditional`` branches that
    (transitively) execute a collective, as
    :class:`ControlFlowCollective` findings."""
    text = _COMMENT_RE.sub("", hlo_text)
    comps = _parse_computations(text)
    memo = {}
    findings = []
    seen = set()

    def _report(region, comp_name):
        if (region, comp_name) in seen:
            return
        seen.add((region, comp_name))
        kinds = _transitive_collectives(comp_name, comps, memo)
        if kinds:
            findings.append(ControlFlowCollective(
                region=region, computation=comp_name,
                kinds=tuple(sorted(kinds.items()))))

    for name, (_, _, body) in comps.items():
        body_text = "\n".join(body)
        for cond_name, body_name in _WHILE_RE.findall(body_text):
            _report("while", body_name)
            _report("while", cond_name)
        for line in body:
            if _COND_RE.search(line):
                for ref in _REF_RE.findall(line):
                    _report("conditional", ref)
                for group in _REF_SET_RE.findall(line):
                    for ref in re.findall(r"%?([\w.\-]+)", group):
                        _report("conditional", ref)
    return tuple(findings)
