"""Lowering pass: logical IR -> physical SPMD plan.

``lower(query, catalog)`` compiles an IR tree into a plan function with the
engine's standard signature ``plan(ctx, tables)`` — it runs inside
``shard_map`` over the ``nodes`` axis and synchronizes only through the
exchange layer, so ``Cluster.compile`` turns it into ONE SPMD executable
exactly like the hand-written plans (the paper's precompiled query
function).

Physical mapping:

- ``Filter``/``Project``   -> vectorized column ops on the local partition
- ``SemiJoin``             -> local probe for co-partitioned edges, else
  Alt-1 (index-lookup request exchange) or Alt-2 (replicated bitset),
  chosen by the §3.2.2 cost model; exchange buffer capacities come from the
  selectivity model (``repro.query.stats``), not hand knobs
- ``Exists``               -> co-partitioned scatter probe
- ``GroupAggByKey``        -> dense scatter-add over the parent partition
- ``GroupAgg``             -> one-hot MXU contraction / dense scatter-add /
  the fused Pallas ``grouped_agg`` kernel, merged with one ``psum``
- ``TopK``                 -> per-node top-k + §3.2.3 merging reduction,
  late-materializing fetch attributes (§3.2.7)

Lowered plans return a dict: ``{"value"}`` for ``GroupAgg`` roots,
``{"values", "keys", "valid", <fetched attrs>}`` for ``TopK`` roots.  When
(and only when) the plan contains a request exchange, an ``"overflow"``
flag is included: True iff a derived buffer capacity was exceeded at run
time.  The result is then incomplete; recover by re-compiling with an
explicit capacity override in ``PlanContext.capacities`` under the key
``"<query-name>_sj<i>"`` (the i-th request semijoin of the chain) — for
``TPCHDriver``, pass it via the ``capacities=`` constructor argument.

Min/max aggregates are Tier-1-only (rollup cubes serve them); lowering
them raises :class:`LoweringError`.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
from jax import lax

from repro.core import aggregation, late_materialization, semijoin, topk
from repro.core import compression, scancal, wirecal
from repro.core.columnar import PackedColumn
from repro.core.compression import choose_semijoin_wire
from repro.core.exchange import WireFormat
from repro.query import stats as qstats
from repro.query.ir import (
    Bin,
    BinOp,
    Catalog,
    Col,
    Exists,
    Filter,
    GroupAgg,
    GroupAggByKey,
    Lit,
    LoweringError,
    Project,
    Query,
    Scan,
    SemiJoin,
    TopK,
    UnaryOp,
    conjuncts,
    eval_expr,
    expr_columns,
    expr_params,
    query_params,
    validate,
)

ONEHOT_MAX_GROUPS = 8192
KERNEL_MAX_GROUPS = 512


# ---------------------------------------------------------------------------
# static planning: walk the chain once on the host, fix every runtime knob
# ---------------------------------------------------------------------------


def _chain(root) -> list:
    """Operator chain scan-first (every operator here is single-child)."""
    out = []
    node = root
    while not isinstance(node, Scan):
        out.append(node)
        node = node.child
    out.append(node)
    return out[::-1]


@dataclasses.dataclass(frozen=True)
class _SemiJoinPlan:
    alt: str        # local | request | bitset
    capacity: int   # derived request-exchange bucket capacity (0 if unused)
    key: str = ""   # PlanContext.capacities override key ("<name>_sj<i>")
    wire: WireFormat = WireFormat.raw()  # packed format of the exchange
    table: str = ""    # semi-join target table (observability/EXPLAIN)
    gamma: float = 0.0  # predicted target-predicate selectivity
    # model capacity regardless of the chosen alternative — what the
    # request exchange WOULD need under this binding (the static verifier
    # compares it against the compiled capacity for other bindings)
    derived_capacity: int = 0
    # roofline predictions (repro.core.wirecal) for the chosen alternative
    # at its static shapes: codec time vs link volume + collective latency
    codec_ms: float = 0.0
    wire_ms: float = 0.0


def _decide_semijoins(root, catalog: Catalog, query_name=None,
                      wire: str = "packed", binding=None, cal=None,
                      predict_cal=None) -> dict:
    """Choose each SemiJoin's physical alternative and buffer capacity from
    the §3.2.2 model, using selectivities accumulated along the chain.  The
    alternative choice is BYTE-ACCURATE by default: it compares the static
    wire bytes of the compiled Alt-1 exchange — at its derived capacity and
    actual packed widths under ``wire`` — against the Alt-2 bitset
    allgather.  With a ``cal`` (:class:`repro.core.wirecal.WireCalibration`)
    the comparison is LATENCY-accurate (codec + link + per-collective
    roofline), and ``wire="auto"`` lets the same model pick packed vs raw
    per semi-join.  Every decision carries its predicted ``codec_ms`` /
    ``wire_ms`` for EXPLAIN, computed with ``predict_cal`` (else ``cal``,
    else builtin) — a prediction-only calibration NEVER changes the
    decisions, so EXPLAIN can render machine-calibrated estimates for the
    exact plan the byte model compiled.  ``binding`` resolves parameterized
    predicates for the estimates; an unbound param is sized for the worst
    binding in its declared range (see ``repro.query.stats``)."""
    pcal = (predict_cal if predict_cal is not None
            else cal if cal is not None else wirecal.BUILTIN)
    decisions = {}
    base = None
    sel = 1.0
    for node in _chain(root):
        if isinstance(node, Scan):
            base = node.table
            sel = 1.0
            continue
        tinfo = catalog.table(base)
        if isinstance(node, Filter):
            sel *= qstats.estimate_selectivity(node.pred, tinfo.stats, binding)
        elif isinstance(node, Exists):
            sel *= qstats.DEFAULT_SELECTIVITY
        elif isinstance(node, GroupAggByKey):
            base = node.into
            sel = 1.0
        elif isinstance(node, SemiJoin):
            target = catalog.table(node.table)
            gamma = qstats.estimate_selectivity(node.pred, target.stats,
                                                binding)
            edge = catalog.copartitioned.get(base)
            local_ok = (
                edge is not None and edge[0] == node.table
                and isinstance(node.key, Col) and node.key.name == edge[1]
            )
            alt = node.alt
            if alt == "local" and not local_ok:
                raise LoweringError(
                    f"semijoin alt='local' requires {node.table!r} "
                    f"co-partitioned with {base!r} on the key column"
                )
            if local_ok:
                # co-partitioned keys all route to their LOCAL owner when
                # forced through the request exchange — no uniform spread
                # over P destinations, the self-bucket takes everything
                cap = qstats.capacity_for(
                    tinfo.num_rows / max(catalog.num_nodes, 1) * sel
                )
            else:
                cap = qstats.request_capacity(
                    tinfo.num_rows, sel, catalog.num_nodes
                )
            wf = qstats.wire_format_for(
                target.num_rows, catalog.num_nodes, kind=wire,
                capacity=cap, cal=cal,
            )
            if alt == "auto":
                if local_ok:
                    alt = "local"
                else:
                    choice = choose_semijoin_wire(
                        cap, target.num_rows, max(catalog.num_nodes, 1),
                        domain=wf.domain, packed=wf.packed, cal=cal,
                    )
                    alt = "request" if choice == 1 else "bitset"
            P = max(catalog.num_nodes, 1)
            if alt == "request":
                codec_ms, wire_ms = wirecal.predict_alt1_ms(
                    cap, P, wf.domain, packed=wf.packed, cal=pcal)
            elif alt == "bitset":
                codec_ms, wire_ms = wirecal.predict_alt2_ms(
                    target.num_rows, P, cal=pcal)
            else:
                codec_ms, wire_ms = 0.0, 0.0
            decisions[id(node)] = _SemiJoinPlan(
                alt=alt, capacity=cap if alt == "request" else 0,
                key=f"{query_name or 'query'}_sj{len(decisions)}",
                wire=wf, table=node.table, gamma=gamma,
                derived_capacity=cap,
                codec_ms=codec_ms, wire_ms=wire_ms,
            )
            sel *= gamma
    return decisions


def _decide_scans(root, catalog: Catalog, cal=None) -> dict:
    """Per-Filter predicate-on-packed decisions over compressed-resident
    base tables: each filter conjunct that is a ``col op scalar``
    comparison against a packed column rewrites into a code-space range
    test the scan kernel evaluates on the packed words directly
    (``repro.query.stats.scan_rewrite``); the :mod:`repro.core.scancal`
    roofline arbitrates packed vs decode per column.  Same-column range
    tests fuse into one scan (``qstats.merge_scan_conjuncts``).  Returns
    ``{id(filter): [(conjuncts_tuple, [ScanDecision, ...]), ...]}`` for
    filters touching at least one packed column."""
    if cal is None:
        cal = scancal.load(strict=False)
    decisions = {}
    base = None
    for node in _chain(root):
        if isinstance(node, Scan):
            base = node.table
            continue
        if isinstance(node, GroupAggByKey):
            base = node.into
            continue
        if not isinstance(node, Filter):
            continue
        tinfo = catalog.table(base)
        if not tinfo.packed:
            continue
        rows = tinfo.num_rows // max(catalog.num_nodes, 1)
        per = [(conj, qstats.decide_scan_conjunct(conj, base, tinfo.packed,
                                                  rows, cal=cal))
               for conj in conjuncts(node.pred)]
        if any(ds for _, ds in per):
            decisions[id(node)] = qstats.merge_scan_conjuncts(per)
    return decisions


# stable public entry points for the static verifier (repro.query.verify):
# the same decision passes the lowering runs, usable without lowering
decide_semijoins = _decide_semijoins
SemiJoinPlan = _SemiJoinPlan
decide_scans = _decide_scans


def explain_chain(query: Query, catalog: Catalog, *, wire: str = "packed",
                  binding=None, cal=None, predict_cal=None) -> list:
    """Scan-first per-operator annotations for EXPLAIN: each operator as a
    dict carrying the cost model's view of it — predicted selectivity for
    filters/probes, the chosen alternative / derived capacity / wire
    format for semi-joins (exactly what :func:`lower` would decide, via
    the same ``_decide_semijoins`` call), group/agg shape for roots.
    Purely static: nothing is compiled or executed."""
    root = query.root
    validate(root, catalog)
    decisions = _decide_semijoins(root, catalog, query_name=query.name,
                                  wire=wire, binding=binding, cal=cal,
                                  predict_cal=predict_cal)
    scan_plans = _decide_scans(root, catalog)
    rows = []
    base, sel = None, 1.0
    for node in _chain(root):
        if isinstance(node, Scan):
            base, sel = node.table, 1.0
            tinfo = catalog.table(node.table)
            rows.append({"op": "Scan", "table": node.table,
                         "rows": tinfo.num_rows,
                         "packed_cols": sorted(tinfo.packed)})
            continue
        tinfo = catalog.table(base)
        if isinstance(node, Filter):
            s = qstats.estimate_selectivity(node.pred, tinfo.stats, binding)
            sel *= s
            rows.append({"op": "Filter", "pred": node.pred, "sel": s,
                         "cum_sel": sel,
                         "scans": [d for _, ds in scan_plans.get(id(node), [])
                                   for d in ds]})
        elif isinstance(node, Project):
            rows.append({"op": "Project",
                         "cols": [n for n, _ in node.cols]})
        elif isinstance(node, SemiJoin):
            d = decisions[id(node)]
            sel *= d.gamma
            rows.append({
                "op": "SemiJoin", "table": node.table, "key": node.key,
                "pred": node.pred, "alt": d.alt, "capacity": d.capacity,
                "capacity_key": d.key, "wire": d.wire, "gamma": d.gamma,
                "codec_ms": d.codec_ms, "wire_ms": d.wire_ms,
                "cum_sel": sel,
            })
        elif isinstance(node, Exists):
            sel *= qstats.DEFAULT_SELECTIVITY
            rows.append({"op": "Exists", "table": node.table,
                         "sel": qstats.DEFAULT_SELECTIVITY, "cum_sel": sel})
        elif isinstance(node, GroupAggByKey):
            base, sel = node.into, 1.0
            rows.append({"op": "GroupAggByKey", "into": node.into,
                         "aggs": [a.name for a in node.aggs]})
        elif isinstance(node, GroupAgg):
            groups = math.prod(k.cardinality for k in node.keys) \
                if node.keys else 1
            method = node.method
            if method == "auto":
                method = "onehot" if groups <= ONEHOT_MAX_GROUPS else "dense"
            rows.append({"op": "GroupAgg", "groups": groups,
                         "method": method,
                         "keys": [k.name for k in node.keys],
                         "aggs": [a.name for a in node.aggs]})
        elif isinstance(node, TopK):
            rows.append({"op": "TopK", "k": node.k})
    return rows


def _has_division(e) -> bool:
    """Whether an expression can turn finite inputs non-finite (division).
    Used to gate the batched mask-GEMM: it folds the lane mask in AFTER
    aggregation inputs are built, and 0 * inf = NaN would poison a group
    sum that the pre-masked scalar path computes correctly."""
    if isinstance(e, BinOp):
        return e.op == "/" or _has_division(e.lhs) or _has_division(e.rhs)
    if isinstance(e, UnaryOp):
        return _has_division(e.operand)
    if isinstance(e, Bin):
        return _has_division(e.child)
    return False


def _maskgemm_eligible(root: GroupAgg, num_groups: int) -> bool:
    """The batched ``mask @ (onehot (x) measures)`` GEMM requires the
    expanded tensor to be parameter-independent (else vmap batches it B
    times), bounded (onehot-sized group spaces only), and NaN-safe (no
    division anywhere feeding group codes or measures — the lane mask is
    folded in multiplicatively, after evaluation)."""
    if not 1 < num_groups <= ONEHOT_MAX_GROUPS:
        return False
    exprs = [k.expr for k in root.keys]
    exprs += [a.expr for a in root.aggs if a.expr is not None]
    # projections below the root may feed group keys / measures
    for node in _chain(root)[:-1]:
        if isinstance(node, Project):
            exprs += [e for _, e in node.cols]
    return not any(expr_params(e) or _has_division(e) for e in exprs)


def _kernel_filter(root: GroupAgg) -> tuple:
    """The fused Pallas kernel consumes its filter directly: the chain must
    be Scan -> Filter(Col <= Lit int) -> GroupAgg.  Returns (col, cutoff)."""
    ops_below = _chain(root)[:-1]  # strip GroupAgg
    if len(ops_below) == 2 and isinstance(ops_below[1], Filter):
        p = ops_below[1].pred
        if (isinstance(p, BinOp) and p.op == "<="
                and isinstance(p.lhs, Col) and isinstance(p.rhs, Lit)
                and isinstance(p.rhs.value, int)):
            return p.lhs.name, int(p.rhs.value)
    raise LoweringError(
        "method='kernel' lowers to the fused filter+aggregate Pallas kernel "
        "and requires exactly Scan -> Filter(col <= int) -> GroupAgg"
    )


# ---------------------------------------------------------------------------
# trace-time stream evaluation
# ---------------------------------------------------------------------------


class _LazyCols(dict):
    """Column view over a (possibly packed-resident) local partition.
    Packed columns decode on first touch and the decoded view is cached,
    so a column whose only consumer is the predicate-on-packed kernel is
    NEVER expanded to raw — late materialization at filter granularity.
    ``raw()`` exposes the undecoded resident form for gather/kernel
    consumers."""

    def __getitem__(self, name):
        v = super().__getitem__(name)
        if isinstance(v, PackedColumn):
            v = v.decode()
            super().__setitem__(name, v)
        return v

    def raw(self, name):
        return super().__getitem__(name)


def _col_at(col, idx):
    """Rows ``idx`` of a local column — code-space gather + decode for
    packed residents (touches O(len(idx)) words, not the column)."""
    return col.gather(idx) if isinstance(col, PackedColumn) else col[idx]


@dataclasses.dataclass
class _Stream:
    base: str          # table whose partitioning the stream follows
    cols: dict         # visible columns (local partition views)
    mask: object       # bool array or None
    overflow: object   # python False until an exchange contributes a flag

    def and_mask(self, bits):
        self.mask = bits if self.mask is None else (self.mask & bits)


def _local_index(ctx, table, keys):
    return keys - ctx.part(table).my_base(ctx.axis)


def _measure_stack(aggs, cols, mask, pv=None):
    n = next(iter(cols.values())).shape[0]
    outs = []
    for a in aggs:
        if a.agg == "count":
            v = jnp.ones(n, jnp.float32)
        else:
            v = eval_expr(a.expr, cols, pv).astype(jnp.float32)
        outs.append(v)
    stacked = jnp.stack(outs, axis=1)
    if mask is not None:
        stacked = jnp.where(mask[:, None], stacked, 0.0)
    return stacked


def lower(query: Query, catalog: Catalog, *, wire: str = "packed",
          binding=None, batched: bool = False, obs=None):
    """Compile ``query`` into ``plan(ctx, tables)`` (see module docstring
    for the output contract).  ``wire`` selects the exchange encoding the
    §3.2.2 byte-accurate cost model assumes ("packed" bit-packs request
    keys to catalog-derived widths with the mask folded in; "raw" ships
    int32 buckets + a separate mask collective); the compiled plan applies
    the packed format only when the execution context agrees
    (``PlanContext.wire == "packed"``).

    A query containing :class:`~repro.query.ir.Param` placeholders lowers
    to ``plan(ctx, tables, params)`` — the params become TRACED jit
    arguments (dict name -> scalar), so one compiled executable serves
    every binding; the ordered parameter signature is exposed as
    ``plan.params`` and ``Cluster.compile`` threads the extra argument
    through ``shard_map``.  ``binding`` only feeds the STATIC capacity /
    alternative decisions (never the traced values): pass the prepare-time
    defaults of an auto-parameterized literal query to size its buffers
    exactly as the literal plan would; without it, parameterized
    predicates are sized for the worst binding in their declared range.

    ``batched=True`` tunes the physical choices for a plan that will be
    ``vmap``-ed over a stacked parameter axis (``Cluster.compile(...,
    batch=True)``): a ``method="auto"`` GroupAgg factors its masked
    contraction as ``mask @ (onehot (x) measures)`` — group codes and
    measures are parameter-independent, so vmap keeps the ``n x (G*M)``
    expanded tensor UNBATCHED and B lanes cost ONE ``(B,n) x (n,G*M)``
    GEMM over the lane masks instead of B independently masked pipelines
    (or B scatter passes — XLA has no fast batched segment-sum).
    Explicit methods are honored either way, and shapes the GEMM cannot
    serve soundly (params or division feeding the keys/measures,
    beyond-onehot group spaces) fall back to the plain per-lane
    lowering.

    Raises :class:`IRValidationError` for malformed IR and
    :class:`LoweringError` for valid-but-uncompilable queries (min/max
    aggregates, kernel-ineligible shapes)."""
    root = query.root
    validate(root, catalog)
    params = query_params(root)
    if not isinstance(root, (GroupAgg, TopK)):
        raise LoweringError(
            f"query root must be group_agg or top_k to produce a result set "
            f"(got {type(root).__name__}) — add an aggregation or selection"
        )
    if isinstance(root, GroupAgg):
        bad = [a.name for a in root.aggs if a.agg in ("min", "max")]
        if bad:
            raise LoweringError(
                f"min/max aggregates {bad} are served by Tier-1 rollup cubes "
                f"only; the SPMD lowering supports sum/count — route this "
                f"query through a covering cube or drop the measure"
            )
        num_groups = math.prod(k.cardinality for k in root.keys) if root.keys else 1
        if root.method == "kernel":
            if num_groups > KERNEL_MAX_GROUPS:
                raise LoweringError(
                    f"{num_groups} groups exceeds the grouped_agg kernel "
                    f"limit {KERNEL_MAX_GROUPS}"
                )
            kernel_col, kernel_cutoff = _kernel_filter(root)

    sj_plans = _decide_semijoins(root, catalog, query_name=query.name,
                                 wire=wire, binding=binding)
    scan_plans = _decide_scans(root, catalog)
    if obs is not None:
        obs.event(
            "lower", cat="plan",
            query=query.name or "<lowered-ir>", batched=batched, wire=wire,
            n_params=len(params),
            semijoins=" ".join(f"{d.key}:{d.alt}" for d in sj_plans.values())
            or "none",
        )

    def _eval(node, ctx, t, pv) -> _Stream:
        if isinstance(node, Scan):
            return _Stream(base=node.table, cols=_LazyCols(t[node.table]),
                           mask=None, overflow=False)

        s = _eval(node.child, ctx, t, pv)

        if isinstance(node, Filter):
            per = scan_plans.get(id(node))
            if per is None:
                s.and_mask(eval_expr(node.pred, s.cols, pv))
                return s
            from repro.kernels import ops

            acc = None          # AND of per-column bitsets, in word space
            acc_shape = None    # (rows, padded_rows) — same table, so same
            for conjs, ds in per:
                dec = next((d for d in ds if d.mode == "packed"
                            and d.rewrite is not None), None)
                col = (s.cols.raw(dec.rewrite.column)
                       if dec is not None else None)
                if isinstance(col, PackedColumn):
                    # predicate-on-packed: code-space range test over the
                    # resident words, no decode of the column at all
                    lo, hi = dec.rewrite.bounds(pv)
                    words = ops.scan_filter(
                        col.words, lo, hi, rows=col.rows,
                        padded_rows=col.padded_rows, width=col.width,
                        negate=dec.rewrite.negate)
                    acc = words if acc is None else acc & words
                    acc_shape = (col.rows, col.padded_rows)
                else:
                    for conj in conjs:
                        s.and_mask(eval_expr(conj, s.cols, pv))
            if acc is not None:
                rows, padded = acc_shape
                s.and_mask(compression.unpack_bitset(acc, padded)[:rows])
            return s

        if isinstance(node, Project):
            for name, e in node.cols:
                s.cols[name] = eval_expr(e, s.cols, pv)
            return s

        if isinstance(node, SemiJoin):
            plan = sj_plans[id(node)]
            target_cols = _LazyCols(t[node.table])
            part = ctx.part(node.table)
            key = eval_expr(node.key, s.cols, pv)
            if plan.alt == "local":
                bits_owner = eval_expr(node.pred, target_cols, pv)
                s.and_mask(bits_owner[_local_index(ctx, node.table, key)])
            elif plan.alt == "bitset":
                local_bits = eval_expr(node.pred, target_cols, pv)
                words = semijoin.alt2_bitset(local_bits, axis=ctx.axis)
                s.and_mask(semijoin.probe(words, key, part))
            else:  # request (Alt-1 index-lookup exchange)
                needed = expr_columns(node.pred)

                def pred_fn(local_idx, m, _cols=target_cols, _p=node.pred,
                            _need=needed, _pv=pv):
                    # requested rows only: packed targets gather+decode
                    # capacity-many codes instead of expanding the column
                    view = {c: _col_at(_cols.raw(c), local_idx)
                            for c in _need}
                    return eval_expr(_p, view, _pv) & m

                mask = (s.mask if s.mask is not None
                        else jnp.ones(key.shape[0], bool))
                bits, ovf = semijoin.alt1_request(
                    key, mask, part, pred_fn,
                    # the derived capacity, unless the execution context
                    # carries an explicit override under this plan's key
                    capacity=ctx.cap(plan.key, plan.capacity),
                    axis=ctx.axis, backend=ctx.backend,
                    # the plan's per-semijoin wire decision ("auto" may mix
                    # packed and raw) unless the context forces raw
                    wire=(plan.wire if ctx.wire != "raw"
                          else WireFormat.raw()),
                    observer=getattr(ctx, "obs", None), label=plan.key,
                )
                s.and_mask(bits)
                s.overflow = s.overflow | ovf
            return s

        if isinstance(node, Exists):
            inner = _LazyCols(t[node.table])
            bits = eval_expr(node.pred, inner, pv)
            rows = ctx.part(s.base).rows_per_node
            fk_local = _local_index(ctx, s.base, inner[node.key])
            has = jnp.zeros(rows, bool).at[fk_local].max(bits)
            s.and_mask(has)
            return s

        if isinstance(node, GroupAggByKey):
            key = eval_expr(node.key, s.cols, pv)
            parent_part = ctx.part(node.into)
            rows = parent_part.rows_per_node
            idx = _local_index(ctx, node.into, key)
            derived = {}
            for a in node.aggs:
                if a.agg == "count":
                    v = jnp.ones(key.shape[0], jnp.float32)
                else:
                    v = eval_expr(a.expr, s.cols, pv).astype(jnp.float32)
                if s.mask is not None:
                    v = jnp.where(s.mask, v, 0.0)
                derived[a.name] = jnp.zeros(rows, jnp.float32).at[idx].add(v)
            cols = _LazyCols(t[node.into])
            cols.update(derived)
            return _Stream(base=node.into, cols=cols, mask=None,
                           overflow=s.overflow)

        raise LoweringError(f"cannot lower operator {type(node).__name__}")

    def _run(ctx, t, pv):
        if isinstance(root, GroupAgg):
            if root.method == "kernel":
                from repro.kernels import ops

                s = _eval(root.child, ctx, t, pv)
                gid = _group_ids(root, s, pv, clip=True)  # kernel indexes by gid
                stacked = _measure_stack(root.aggs, s.cols, mask=None, pv=pv)
                local = ops.filtered_group_sum(
                    stacked, gid, s.cols[kernel_col],
                    cutoff=kernel_cutoff, num_groups=num_groups,
                )
            else:
                s = _eval(root.child, ctx, t, pv)
                method = root.method
                if method == "auto":
                    method = "onehot" if num_groups <= ONEHOT_MAX_GROUPS else "dense"
                    if batched and _maskgemm_eligible(root, num_groups):
                        method = "maskgemm"
                if num_groups == 1:
                    # global aggregate: per-measure masked tree-sums (the
                    # hand-plan shape), no one-hot detour
                    n = next(iter(s.cols.values())).shape[0]
                    outs = []
                    for a in root.aggs:
                        v = (jnp.ones(n, jnp.float32) if a.agg == "count"
                             else eval_expr(a.expr, s.cols, pv).astype(jnp.float32))
                        if s.mask is not None:
                            v = jnp.where(s.mask, v, 0.0)
                        outs.append(jnp.sum(v))
                    local = jnp.stack(outs)[None, :]
                elif method == "maskgemm":
                    # batched-lowering form: group codes and measures are
                    # parameter-independent, only the filter mask varies
                    # per lane — contract the lane mask against the
                    # pre-expanded (n, G*M) one-hot (x) measure tensor so
                    # vmap batches a single GEMM, not the whole pipeline.
                    # Out-of-range codes match no one-hot column and drop
                    # out, like the onehot path.
                    gid = _group_ids(root, s, pv, clip=False)
                    stacked = _measure_stack(root.aggs, s.cols, None, pv)
                    n, m = stacked.shape
                    onehot = (gid[:, None]
                              == jnp.arange(num_groups, dtype=jnp.int32)
                              ).astype(jnp.float32)
                    expanded = (onehot[:, :, None] * stacked[:, None, :]
                                ).reshape(n, num_groups * m)
                    maskf = (jnp.ones(n, jnp.float32) if s.mask is None
                             else s.mask.astype(jnp.float32))
                    local = (maskf @ expanded).reshape(num_groups, m)
                elif method == "onehot":
                    # out-of-range codes match no one-hot row and drop out,
                    # so no clamp pass is needed (keeps the HLO identical
                    # to the hand-written plans)
                    gid = _group_ids(root, s, pv, clip=False)
                    stacked = _measure_stack(root.aggs, s.cols, s.mask, pv)
                    local = aggregation.group_sum_onehot(stacked, gid, num_groups)
                else:
                    gid = _group_ids(root, s, pv, clip=True)  # scatter safety
                    stacked = _measure_stack(root.aggs, s.cols, s.mask, pv)
                    local = jnp.stack(
                        [aggregation.group_sum_dense(stacked[:, c], gid, num_groups)
                         for c in range(stacked.shape[1])],
                        axis=1,
                    )
            out = {"value": lax.psum(local, ctx.axis)}
            if s.overflow is not False:
                out["overflow"] = s.overflow
            return out

        # TopK root
        s = _eval(root.child, ctx, t, pv)
        if root.pred is not None:
            s.and_mask(eval_expr(root.pred, s.cols, pv))
        values = eval_expr(root.value, s.cols, pv)
        keys = ctx.part(s.base).global_keys(ctx.axis)
        local = topk.local_topk(values, keys, root.k, s.mask)
        winners = topk.topk_allreduce(local, ctx.axis)
        out = {"values": winners.values, "keys": winners.keys,
               "valid": winners.valid}
        own = [f for f in root.fetch if f.table is None]
        if own:
            # hand materialize the RESIDENT form: packed fetch attributes
            # stay packed and only the k winners are gathered + decoded
            attrs = late_materialization.materialize(
                winners.keys, winners.valid, ctx.part(s.base),
                {f.name: s.cols.raw(f.name) for f in own}, axis=ctx.axis,
            )
            out.update(attrs)
        for f in root.fetch:
            if f.table is None:
                continue
            attrs = late_materialization.materialize(
                out[f.key], winners.valid, ctx.part(f.table),
                {f.name: t[f.table][f.name]}, axis=ctx.axis,
            )
            out.update(attrs)
        if s.overflow is not False:
            out["overflow"] = s.overflow
        return out

    def _group_ids(node: GroupAgg, s: _Stream, pv, *, clip: bool):
        n = next(iter(s.cols.values())).shape[0]
        if not node.keys:
            return jnp.zeros(n, jnp.int32)
        gid = None
        for k in node.keys:
            code = eval_expr(k.expr, s.cols, pv).astype(jnp.int32)
            if clip:
                code = jnp.clip(code, 0, k.cardinality - 1)
            gid = code if gid is None else gid * k.cardinality + code
        return gid

    if params:
        def plan(ctx, t, pvals):
            return _run(ctx, t, pvals)
    else:
        def plan(ctx, t):
            return _run(ctx, t, None)
    plan.params = params
    # the static semi-join decisions, in chain order (observability /
    # EXPLAIN attribute per-exchange collective bytes against these)
    plan.semijoins = tuple(sj_plans.values())
    # per-column scan strategies (chain order) — the driver's
    # storage.bytes_scanned accounting and EXPLAIN read these
    plan.scans = tuple(d for per in scan_plans.values()
                       for _, ds in per for d in ds)
    # lowered plans consume packed-resident columns directly (lazy decode,
    # predicate-on-packed, gather-based late materialization) — the engine
    # must NOT expand them at entry
    plan.handles_packed = True
    return plan
