"""Lowering pass: logical IR -> physical SPMD plan.

``lower(query, catalog)`` compiles an IR tree into a plan function with the
engine's standard signature ``plan(ctx, tables)`` — it runs inside
``shard_map`` over the ``nodes`` axis and synchronizes only through the
exchange layer, so ``Cluster.compile`` turns it into ONE SPMD executable
exactly like the hand-written plans (the paper's precompiled query
function).

Physical mapping:

- ``Filter``/``Project``   -> vectorized column ops on the local partition
- ``SemiJoin``             -> local probe for co-partitioned edges, else
  Alt-1 (index-lookup request exchange) or Alt-2 (replicated bitset),
  chosen by the §3.2.2 cost model; exchange buffer capacities come from the
  selectivity model (``repro.query.stats``), not hand knobs
- ``Exists``               -> co-partitioned scatter probe
- ``GroupAggByKey``        -> dense scatter-add over the parent partition
- ``GroupAgg``             -> one-hot MXU contraction / dense scatter-add /
  the fused Pallas ``grouped_agg`` kernel, merged with one ``psum``
- ``TopK``                 -> per-node top-k + §3.2.3 merging reduction,
  late-materializing fetch attributes (§3.2.7)

Lowered plans return a dict: ``{"value"}`` for ``GroupAgg`` roots,
``{"values", "keys", "valid", <fetched attrs>}`` for ``TopK`` roots.  When
(and only when) the plan contains a request exchange, an ``"overflow"``
flag is included: True iff a derived buffer capacity was exceeded at run
time.  The result is then incomplete; recover by re-compiling with an
explicit capacity override in ``PlanContext.capacities`` under the key
``"<query-name>_sj<i>"`` (the i-th request semijoin of the chain) — for
``TPCHDriver``, pass it via the ``capacities=`` constructor argument.

Min/max aggregates are Tier-1-only (rollup cubes serve them); lowering
them raises :class:`LoweringError`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro.core import aggregation, late_materialization, semijoin, topk
from repro.core.compression import choose_semijoin_wire
from repro.core.exchange import WireFormat
from repro.query import stats as qstats
from repro.query.ir import (
    Agg,
    Bin,
    BinOp,
    Catalog,
    Col,
    Exists,
    Filter,
    GroupAgg,
    GroupAggByKey,
    Lit,
    LoweringError,
    Project,
    Query,
    Scan,
    SemiJoin,
    TopK,
    eval_expr,
    expr_columns,
    validate,
)

ONEHOT_MAX_GROUPS = 8192
KERNEL_MAX_GROUPS = 512


# ---------------------------------------------------------------------------
# static planning: walk the chain once on the host, fix every runtime knob
# ---------------------------------------------------------------------------


def _chain(root) -> list:
    """Operator chain scan-first (every operator here is single-child)."""
    out = []
    node = root
    while not isinstance(node, Scan):
        out.append(node)
        node = node.child
    out.append(node)
    return out[::-1]


@dataclasses.dataclass(frozen=True)
class _SemiJoinPlan:
    alt: str        # local | request | bitset
    capacity: int   # derived request-exchange bucket capacity (0 if unused)
    key: str = ""   # PlanContext.capacities override key ("<name>_sj<i>")
    wire: WireFormat = WireFormat.raw()  # packed format of the exchange


def _decide_semijoins(root, catalog: Catalog, query_name=None,
                      wire: str = "packed") -> dict:
    """Choose each SemiJoin's physical alternative and buffer capacity from
    the §3.2.2 model, using selectivities accumulated along the chain.  The
    alternative choice is BYTE-ACCURATE: it compares the static wire bytes
    of the compiled Alt-1 exchange — at its derived capacity and actual
    packed widths under ``wire`` — against the Alt-2 bitset allgather."""
    decisions = {}
    base = None
    sel = 1.0
    for node in _chain(root):
        if isinstance(node, Scan):
            base = node.table
            sel = 1.0
            continue
        tinfo = catalog.table(base)
        if isinstance(node, Filter):
            sel *= qstats.estimate_selectivity(node.pred, tinfo.stats)
        elif isinstance(node, Exists):
            sel *= qstats.DEFAULT_SELECTIVITY
        elif isinstance(node, GroupAggByKey):
            base = node.into
            sel = 1.0
        elif isinstance(node, SemiJoin):
            target = catalog.table(node.table)
            gamma = qstats.estimate_selectivity(node.pred, target.stats)
            edge = catalog.copartitioned.get(base)
            local_ok = (
                edge is not None and edge[0] == node.table
                and isinstance(node.key, Col) and node.key.name == edge[1]
            )
            alt = node.alt
            if alt == "local" and not local_ok:
                raise LoweringError(
                    f"semijoin alt='local' requires {node.table!r} "
                    f"co-partitioned with {base!r} on the key column"
                )
            if local_ok:
                # co-partitioned keys all route to their LOCAL owner when
                # forced through the request exchange — no uniform spread
                # over P destinations, the self-bucket takes everything
                cap = qstats.capacity_for(
                    tinfo.num_rows / max(catalog.num_nodes, 1) * sel
                )
            else:
                cap = qstats.request_capacity(
                    tinfo.num_rows, sel, catalog.num_nodes
                )
            wf = qstats.wire_format_for(
                target.num_rows, catalog.num_nodes, kind=wire
            )
            if alt == "auto":
                if local_ok:
                    alt = "local"
                else:
                    choice = choose_semijoin_wire(
                        cap, target.num_rows, max(catalog.num_nodes, 1),
                        domain=wf.domain, packed=wf.packed,
                    )
                    alt = "request" if choice == 1 else "bitset"
            decisions[id(node)] = _SemiJoinPlan(
                alt=alt, capacity=cap if alt == "request" else 0,
                key=f"{query_name or 'query'}_sj{len(decisions)}",
                wire=wf,
            )
            sel *= gamma
    return decisions


def _kernel_filter(root: GroupAgg) -> tuple:
    """The fused Pallas kernel consumes its filter directly: the chain must
    be Scan -> Filter(Col <= Lit int) -> GroupAgg.  Returns (col, cutoff)."""
    ops_below = _chain(root)[:-1]  # strip GroupAgg
    if len(ops_below) == 2 and isinstance(ops_below[1], Filter):
        p = ops_below[1].pred
        if (isinstance(p, BinOp) and p.op == "<="
                and isinstance(p.lhs, Col) and isinstance(p.rhs, Lit)
                and isinstance(p.rhs.value, int)):
            return p.lhs.name, int(p.rhs.value)
    raise LoweringError(
        "method='kernel' lowers to the fused filter+aggregate Pallas kernel "
        "and requires exactly Scan -> Filter(col <= int) -> GroupAgg"
    )


# ---------------------------------------------------------------------------
# trace-time stream evaluation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Stream:
    base: str          # table whose partitioning the stream follows
    cols: dict         # visible columns (local partition views)
    mask: object       # bool array or None
    overflow: object   # python False until an exchange contributes a flag

    def and_mask(self, bits):
        self.mask = bits if self.mask is None else (self.mask & bits)


def _local_index(ctx, table, keys):
    return keys - ctx.part(table).my_base(ctx.axis)


def _measure_stack(aggs, cols, mask):
    n = next(iter(cols.values())).shape[0]
    outs = []
    for a in aggs:
        if a.agg == "count":
            v = jnp.ones(n, jnp.float32)
        else:
            v = eval_expr(a.expr, cols).astype(jnp.float32)
        outs.append(v)
    stacked = jnp.stack(outs, axis=1)
    if mask is not None:
        stacked = jnp.where(mask[:, None], stacked, 0.0)
    return stacked


def lower(query: Query, catalog: Catalog, *, wire: str = "packed"):
    """Compile ``query`` into ``plan(ctx, tables)`` (see module docstring
    for the output contract).  ``wire`` selects the exchange encoding the
    §3.2.2 byte-accurate cost model assumes ("packed" bit-packs request
    keys to catalog-derived widths with the mask folded in; "raw" ships
    int32 buckets + a separate mask collective); the compiled plan applies
    the packed format only when the execution context agrees
    (``PlanContext.wire == "packed"``).  Raises :class:`IRValidationError`
    for malformed IR and :class:`LoweringError` for
    valid-but-uncompilable queries (min/max aggregates, kernel-ineligible
    shapes)."""
    root = query.root
    validate(root, catalog)
    if not isinstance(root, (GroupAgg, TopK)):
        raise LoweringError(
            f"query root must be group_agg or top_k to produce a result set "
            f"(got {type(root).__name__}) — add an aggregation or selection"
        )
    if isinstance(root, GroupAgg):
        bad = [a.name for a in root.aggs if a.agg in ("min", "max")]
        if bad:
            raise LoweringError(
                f"min/max aggregates {bad} are served by Tier-1 rollup cubes "
                f"only; the SPMD lowering supports sum/count — route this "
                f"query through a covering cube or drop the measure"
            )
        num_groups = math.prod(k.cardinality for k in root.keys) if root.keys else 1
        if root.method == "kernel":
            if num_groups > KERNEL_MAX_GROUPS:
                raise LoweringError(
                    f"{num_groups} groups exceeds the grouped_agg kernel "
                    f"limit {KERNEL_MAX_GROUPS}"
                )
            kernel_col, kernel_cutoff = _kernel_filter(root)

    sj_plans = _decide_semijoins(root, catalog, query_name=query.name,
                                 wire=wire)

    def _eval(node, ctx, t) -> _Stream:
        if isinstance(node, Scan):
            return _Stream(base=node.table, cols=dict(t[node.table]),
                           mask=None, overflow=False)

        s = _eval(node.child, ctx, t)

        if isinstance(node, Filter):
            s.and_mask(eval_expr(node.pred, s.cols))
            return s

        if isinstance(node, Project):
            for name, e in node.cols:
                s.cols[name] = eval_expr(e, s.cols)
            return s

        if isinstance(node, SemiJoin):
            plan = sj_plans[id(node)]
            target_cols = t[node.table]
            part = ctx.part(node.table)
            key = eval_expr(node.key, s.cols)
            if plan.alt == "local":
                bits_owner = eval_expr(node.pred, target_cols)
                s.and_mask(bits_owner[_local_index(ctx, node.table, key)])
            elif plan.alt == "bitset":
                local_bits = eval_expr(node.pred, target_cols)
                words = semijoin.alt2_bitset(local_bits, axis=ctx.axis)
                s.and_mask(semijoin.probe(words, key, part))
            else:  # request (Alt-1 index-lookup exchange)
                needed = expr_columns(node.pred)

                def pred_fn(local_idx, m, _cols=target_cols, _p=node.pred,
                            _need=needed):
                    view = {c: _cols[c][local_idx] for c in _need}
                    return eval_expr(_p, view) & m

                mask = (s.mask if s.mask is not None
                        else jnp.ones(key.shape[0], bool))
                bits, ovf = semijoin.alt1_request(
                    key, mask, part, pred_fn,
                    # the derived capacity, unless the execution context
                    # carries an explicit override under this plan's key
                    capacity=ctx.cap(plan.key, plan.capacity),
                    axis=ctx.axis, backend=ctx.backend,
                    wire=(plan.wire if ctx.wire == "packed"
                          else WireFormat.raw()),
                )
                s.and_mask(bits)
                s.overflow = s.overflow | ovf
            return s

        if isinstance(node, Exists):
            inner = t[node.table]
            bits = eval_expr(node.pred, inner)
            rows = ctx.part(s.base).rows_per_node
            fk_local = _local_index(ctx, s.base, inner[node.key])
            has = jnp.zeros(rows, bool).at[fk_local].max(bits)
            s.and_mask(has)
            return s

        if isinstance(node, GroupAggByKey):
            key = eval_expr(node.key, s.cols)
            parent_part = ctx.part(node.into)
            rows = parent_part.rows_per_node
            idx = _local_index(ctx, node.into, key)
            derived = {}
            for a in node.aggs:
                if a.agg == "count":
                    v = jnp.ones(key.shape[0], jnp.float32)
                else:
                    v = eval_expr(a.expr, s.cols).astype(jnp.float32)
                if s.mask is not None:
                    v = jnp.where(s.mask, v, 0.0)
                derived[a.name] = jnp.zeros(rows, jnp.float32).at[idx].add(v)
            return _Stream(
                base=node.into,
                cols={**dict(t[node.into]), **derived},
                mask=None,
                overflow=s.overflow,
            )

        raise LoweringError(f"cannot lower operator {type(node).__name__}")

    def plan(ctx, t):
        if isinstance(root, GroupAgg):
            if root.method == "kernel":
                from repro.kernels import ops

                s = _eval(root.child, ctx, t)
                gid = _group_ids(root, s, clip=True)  # kernel indexes by gid
                stacked = _measure_stack(root.aggs, s.cols, mask=None)
                local = ops.filtered_group_sum(
                    stacked, gid, s.cols[kernel_col],
                    cutoff=kernel_cutoff, num_groups=num_groups,
                )
            else:
                s = _eval(root.child, ctx, t)
                method = root.method
                if method == "auto":
                    method = "onehot" if num_groups <= ONEHOT_MAX_GROUPS else "dense"
                if num_groups == 1:
                    # global aggregate: per-measure masked tree-sums (the
                    # hand-plan shape), no one-hot detour
                    n = next(iter(s.cols.values())).shape[0]
                    outs = []
                    for a in root.aggs:
                        v = (jnp.ones(n, jnp.float32) if a.agg == "count"
                             else eval_expr(a.expr, s.cols).astype(jnp.float32))
                        if s.mask is not None:
                            v = jnp.where(s.mask, v, 0.0)
                        outs.append(jnp.sum(v))
                    local = jnp.stack(outs)[None, :]
                elif method == "onehot":
                    # out-of-range codes match no one-hot row and drop out,
                    # so no clamp pass is needed (keeps the HLO identical
                    # to the hand-written plans)
                    gid = _group_ids(root, s, clip=False)
                    stacked = _measure_stack(root.aggs, s.cols, s.mask)
                    local = aggregation.group_sum_onehot(stacked, gid, num_groups)
                else:
                    gid = _group_ids(root, s, clip=True)  # scatter safety
                    stacked = _measure_stack(root.aggs, s.cols, s.mask)
                    local = jnp.stack(
                        [aggregation.group_sum_dense(stacked[:, c], gid, num_groups)
                         for c in range(stacked.shape[1])],
                        axis=1,
                    )
            out = {"value": lax.psum(local, ctx.axis)}
            if s.overflow is not False:
                out["overflow"] = s.overflow
            return out

        # TopK root
        s = _eval(root.child, ctx, t)
        if root.pred is not None:
            s.and_mask(eval_expr(root.pred, s.cols))
        values = eval_expr(root.value, s.cols)
        keys = ctx.part(s.base).global_keys(ctx.axis)
        local = topk.local_topk(values, keys, root.k, s.mask)
        winners = topk.topk_allreduce(local, ctx.axis)
        out = {"values": winners.values, "keys": winners.keys,
               "valid": winners.valid}
        own = [f for f in root.fetch if f.table is None]
        if own:
            attrs = late_materialization.materialize(
                winners.keys, winners.valid, ctx.part(s.base),
                {f.name: s.cols[f.name] for f in own}, axis=ctx.axis,
            )
            out.update(attrs)
        for f in root.fetch:
            if f.table is None:
                continue
            attrs = late_materialization.materialize(
                out[f.key], winners.valid, ctx.part(f.table),
                {f.name: t[f.table][f.name]}, axis=ctx.axis,
            )
            out.update(attrs)
        if s.overflow is not False:
            out["overflow"] = s.overflow
        return out

    def _group_ids(node: GroupAgg, s: _Stream, *, clip: bool):
        n = next(iter(s.cols.values())).shape[0]
        if not node.keys:
            return jnp.zeros(n, jnp.int32)
        gid = None
        for k in node.keys:
            code = eval_expr(k.expr, s.cols).astype(jnp.int32)
            if clip:
                code = jnp.clip(code, 0, k.cardinality - 1)
            gid = code if gid is None else gid * k.cardinality + code
        return gid

    return plan
