"""Runtime-parameter canonicalization: separate a query's SHAPE from its
literal VALUES.

The paper's engine compiles each query once and re-executes it with runtime
parameters (§2, §3.1).  :func:`parameterize` is the seam that makes the
compiled-plan cache work that way: it rewrites every literal that appears as
a comparison operand inside a predicate (``Filter``/``SemiJoin``/``TopK``)
into an auto-named :class:`~repro.query.ir.Param`, returning the
parameterized shape plus the extracted binding.  Two IR trees differing only
in predicate literals canonicalize to the SAME shape (identical auto-names —
the rewrite order is deterministic), so they share one lowered SPMD
executable and differ only in the scalars passed at execute time.

Literals that are structural — ``Bin`` edges, group-key cardinalities,
``TopK.k``, arithmetic constants inside measure expressions (``1.0 -
l_discount``) — are left in place: they shape the compiled program.

:func:`bind_params` is the inverse: substitute a binding back into a
parameterized tree, yielding the literal query (used by the cube router's
execute-time matching, oracle evaluation, and tests comparing a prepared
plan against a freshly compiled literal one).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import numpy as np

from repro.query.ir import (
    Bin,
    BinOp,
    Exists,
    Filter,
    GroupAgg,
    GroupAggByKey,
    GroupKey,
    Lit,
    Param,
    Project,
    Query,
    Scan,
    SemiJoin,
    TopK,
    UnaryOp,
    _FLIP_CMP,
    query_params,
)

_AUTO_PREFIX = "_p"


def _param_dtype(value) -> Optional[str]:
    """Numpy dtype name for a parameterizable scalar, or None when the
    value must stay a baked-in literal (strings, tuples, ...)."""
    if isinstance(value, bool) or isinstance(value, np.bool_):
        return "bool"
    if isinstance(value, (np.integer, np.floating)):
        return value.dtype.name
    if isinstance(value, int):
        return "int32"
    if isinstance(value, float):
        return "float32"
    return None


def parameterize(q: Query, obs=None) -> tuple:
    """``(shape, binding)``: ``q`` with every predicate comparison literal
    replaced by an auto-named ``Param`` (deterministic ``_p0, _p1, ...`` in
    scan-first order), plus the extracted name -> value binding.  Explicit
    user params are untouched; a ``method='kernel'`` GroupAgg root skips
    the rewrite entirely (the fused Pallas kernel consumes its cutoff as a
    compile-time constant).  ``obs`` (an :class:`repro.obs.Observer`)
    records the extraction as a trace event."""
    root = q.root
    if isinstance(root, GroupAgg) and root.method == "kernel":
        if obs is not None:
            obs.event("parameterize", cat="plan", query=q.name or "<anon>",
                      extracted=0, skipped="kernel")
        return q, {}
    taken = {p.name for p in query_params(root)}
    binding: dict = {}

    def _fresh(value) -> Optional[Param]:
        dtype = _param_dtype(value)
        if dtype is None:
            return None
        i = len(binding)
        name = f"{_AUTO_PREFIX}{i}"
        while name in taken:
            i += 1
            name = f"{_AUTO_PREFIX}{i}"
        taken.add(name)
        binding[name] = value.item() if hasattr(value, "item") else value
        return Param(name, dtype)

    def rw_pred(e):
        if isinstance(e, UnaryOp) and e.op == "not":
            return UnaryOp("not", rw_pred(e.operand))
        if not isinstance(e, BinOp):
            return e
        if e.op in ("and", "or"):
            return BinOp(e.op, rw_pred(e.lhs), rw_pred(e.rhs))
        if e.op in _FLIP_CMP:
            lhs, rhs = e.lhs, e.rhs
            # exactly one literal side becomes a parameter; Lit-vs-Lit is a
            # structural constant and literals inside arithmetic operands
            # stay (they shape the compiled expression)
            if isinstance(rhs, Lit) and not isinstance(lhs, Lit):
                p = _fresh(rhs.value)
                if p is not None:
                    return BinOp(e.op, lhs, p)
            elif isinstance(lhs, Lit) and not isinstance(rhs, Lit):
                p = _fresh(lhs.value)
                if p is not None:
                    return BinOp(e.op, p, rhs)
        return e

    def walk(node):
        if isinstance(node, Scan):
            return node
        child = walk(node.child)
        if isinstance(node, Filter):
            return Filter(child, rw_pred(node.pred))
        if isinstance(node, SemiJoin):
            return dataclasses.replace(node, child=child,
                                       pred=rw_pred(node.pred))
        if isinstance(node, TopK):
            pred = rw_pred(node.pred) if node.pred is not None else None
            return dataclasses.replace(node, child=child, pred=pred)
        return dataclasses.replace(node, child=child)

    shape = Query(root=walk(root), name=q.name)
    if obs is not None:
        obs.event("parameterize", cat="plan", query=q.name or "<anon>",
                  extracted=len(binding),
                  params=" ".join(sorted(binding)) or "none")
    return shape, binding


def bind_params(q: Query, binding: Mapping[str, object]) -> Query:
    """Substitute ``binding`` back into a parameterized query, replacing
    each bound ``Param`` with a ``Lit`` of its value (unbound params are
    left in place — check :func:`~repro.query.ir.query_params` on the
    result when a fully literal tree is required)."""

    def rwe(e):
        if e is None:
            return None
        if isinstance(e, Param) and e.name in binding:
            v = binding[e.name]
            return Lit(v.item() if hasattr(v, "item") else v)
        if isinstance(e, BinOp):
            return BinOp(e.op, rwe(e.lhs), rwe(e.rhs))
        if isinstance(e, UnaryOp):
            return UnaryOp(e.op, rwe(e.operand))
        if isinstance(e, Bin):
            return Bin(rwe(e.child), e.edges)
        return e

    def walk(node):
        if isinstance(node, Scan):
            return node
        child = walk(node.child)
        if isinstance(node, Filter):
            return Filter(child, rwe(node.pred))
        if isinstance(node, Project):
            return Project(child, tuple((n, rwe(e)) for n, e in node.cols))
        if isinstance(node, SemiJoin):
            return dataclasses.replace(node, child=child, key=rwe(node.key),
                                       pred=rwe(node.pred))
        if isinstance(node, Exists):
            return dataclasses.replace(node, child=child, pred=rwe(node.pred))
        if isinstance(node, GroupAgg):
            keys = tuple(GroupKey(k.name, rwe(k.expr), k.cardinality)
                         for k in node.keys)
            aggs = tuple(dataclasses.replace(a, expr=rwe(a.expr))
                         for a in node.aggs)
            return dataclasses.replace(node, child=child, keys=keys, aggs=aggs)
        if isinstance(node, GroupAggByKey):
            aggs = tuple(dataclasses.replace(a, expr=rwe(a.expr))
                         for a in node.aggs)
            return dataclasses.replace(node, child=child, key=rwe(node.key),
                                       aggs=aggs)
        if isinstance(node, TopK):
            return dataclasses.replace(node, child=child,
                                       value=rwe(node.value),
                                       pred=rwe(node.pred))
        return dataclasses.replace(node, child=child)

    return Query(root=walk(q.root), name=q.name)
