"""Predicate -> packed bitset Pallas kernel (TPU) — §3.2.2 Alternative 2.

Building the semi-join bitset is a full scan of the filter column; shipping
it is an allgather of the PACKED words.  The kernel fuses predicate
evaluation (equality against a dictionary code) with 32-way lane packing:
a (BN/32, 32) view of the block is contracted against the bit-weight vector
(1<<lane) — one VPU multiply-add per row, no gathers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8192  # rows per step; must be a multiple of 32


def _kernel(col_ref, out_ref, *, value):
    col = col_ref[...]                       # (1, BN) i32
    bn = col.shape[1]
    bits = (col == value).astype(jnp.uint32).reshape(bn // 32, 32)
    weights = (jnp.uint32(1) << jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1))
    out_ref[...] = jnp.sum(bits * weights, axis=1, dtype=jnp.uint32)[None, :]


def predicate_bitset(
    column,
    value: int,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Packed bitset of (column == value).

    column: (N,) i32 dictionary codes, N padded to a multiple of 32 by the
    caller-visible wrapper.  Returns (ceil(N/32),) uint32.
    """
    assert block % 32 == 0
    n = column.shape[0]
    pad = (-n) % block
    if pad:
        column = jnp.pad(column, (0, pad), constant_values=value - 1)
    n_pad = n + pad
    grid = (n_pad // block,)
    kernel = functools.partial(_kernel, value=value)
    words = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block // 32), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad // 32), jnp.uint32),
        interpret=interpret,
    )(column[None, :])
    return words[0, : (n + 31) // 32]
