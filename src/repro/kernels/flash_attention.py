"""Flash attention Pallas kernel (TPU) — §Perf beyond-paper optimization.

The roofline analysis (EXPERIMENTS.md §Roofline) shows every train/prefill
cell memory-bound, dominated by the (cq x ck) f32 score tiles the XLA
chunked-attention baseline materializes to HBM.  This kernel keeps the
online-softmax state (m, l, acc) in VMEM scratch across the key-block grid
dimension, so HBM traffic is exactly q + k + v + out — the flash-attention
property.

Layout: GQA-grouped.  Inputs are reshaped to
    q: (B*KV, G, S, D)   k, v: (B*KV, S, D)
and the grid is (B*KV, nq, nk) — the LAST dim is sequential on TPU, so the
scratch accumulators carry across key blocks of one (batch-kv-head, q-block)
pair.  The score tile is (G*bq, bk): G query heads of one kv head share the
kv block (G*bq rows keep the MXU fed even for MQA).

Causal masking skips whole key blocks above the diagonal with pl.when
(predicated-off on TPU, near-zero cost); windows/prefixes mask in-tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = float(np.finfo(np.float32).min)


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, prefix, bq, bk, nk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level causal skip: key block strictly above the diagonal
    # contributes nothing (unless a bidirectional prefix reaches into it)
    run = True
    if causal:
        run = (k_start <= q_start + bq - 1) | (k_start < prefix)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (G, bq, D)
        G, _, D = q.shape
        k = k_ref[0].astype(jnp.float32)              # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        qf = q.reshape(G * bq, D) * scale
        s = jnp.dot(qf, k.T, preferred_element_type=jnp.float32)  # (G*bq, bk)
        q_pos = q_start + lax.broadcasted_iota(jnp.int32, (G * bq, bk), 0) % bq
        # NOTE: row index within the (G*bq) block is h*bq + q_off; q position
        # depends only on q_off -> mod bq
        k_pos = k_start + lax.broadcasted_iota(jnp.int32, (G * bq, bk), 1)
        if causal:
            vis = k_pos <= q_pos
            if window is not None:
                vis &= k_pos > q_pos - window
            if prefix:
                vis |= k_pos < prefix
            s = jnp.where(vis, s, NEG_INF)
        m_prev = m_ref[...]                            # (G*bq,) as (G*bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, corr)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        G = o_ref.shape[1]
        acc = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = acc.reshape(G, bq, -1).astype(o_ref.dtype)
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        lse_ref[0] = lse.reshape(G, bq)


def block_pairs(S, Sk, bq, bk, causal, prefix) -> int:
    """Exact number of (q,k) pairs the kernel's MXU touches (block-run
    granularity — masked lanes inside a running block still do work)."""
    nq, nk = S // bq, Sk // bk
    if not causal:
        return S * Sk
    n_run = 0
    for qi in range(nq):
        for ki in range(nk):
            if ki * bk <= qi * bq + bq - 1 or ki * bk < prefix:
                n_run += 1
    return n_run * bq * bk


def fwd_cost(BKV, G, S, Sk, D, bq, bk, causal, prefix, dtype_bytes=4):
    pairs = BKV * G * block_pairs(S, Sk, bq, bk, causal, prefix)
    io = (BKV * G * S * D * 2 + BKV * Sk * D * 2) * dtype_bytes \
        + BKV * G * S * 4
    return pl.CostEstimate(flops=4 * pairs * D, bytes_accessed=io,
                           transcendentals=pairs)


def group(q, k, v):
    """(B, S, H, D) layout -> GQA-grouped (B*KV, G, S, D) / (B*KV, Sk, D)."""
    B, S, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = (q.transpose(0, 2, 1, 3).reshape(B, KV, G, S, D)
          .reshape(B * KV, G, S, D))
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, D)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, D)
    return qg, kg, vg


def ungroup(out, B, KV):
    BKV, G, S, D = out.shape
    return (out.reshape(B, KV, G, S, D).reshape(B, KV * G, S, D)
            .transpose(0, 2, 1, 3))


def flash_attention_fwd_grouped(qg, kg, vg, *, causal=True, window=None,
                                prefix=0, bq: int = DEFAULT_BQ,
                                bk: int = DEFAULT_BK, interpret: bool = False):
    """Grouped-layout forward: returns (out (BKV,G,S,D), lse (BKV,G,S))."""
    BKV, G, S, D = qg.shape
    Sk = kg.shape[1]
    bq = min(bq, S)
    bk = min(bk, Sk)
    assert S % bq == 0 and Sk % bk == 0, (S, Sk, bq, bk)
    nq, nk = S // bq, Sk // bk
    scale = 1.0 / np.sqrt(D)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, prefix=prefix,
        bq=bq, bk=bk, nk=nk)
    out, lse = pl.pallas_call(
        kernel,
        grid=(BKV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, G, bq, D), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, G, bq, D), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, G, bq), lambda b, i, j: (b, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BKV, G, S, D), qg.dtype),
            jax.ShapeDtypeStruct((BKV, G, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G * bq, 1), jnp.float32),   # m
            pltpu.VMEM((G * bq, 1), jnp.float32),   # l
            pltpu.VMEM((G * bq, D), jnp.float32),   # acc
        ],
        cost_estimate=fwd_cost(BKV, G, S, Sk, D, bq, bk, causal, prefix,
                               jnp.dtype(qg.dtype).itemsize),
        name=f"flash_fwd_causal{int(causal)}",
        interpret=interpret,
    )(qg, kg, vg)
    return out, lse


def flash_attention(q, k, v, *, causal=True, window=None, prefix=0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False):
    """q: (B, S, H, D); k, v: (B, Sk, KV, D) with H % KV == 0.
    Returns (B, S, H, D).  S, Sk must divide by the block sizes.
    NON-differentiable entry (serving); training uses ops.flash_attention."""
    B, KV = q.shape[0], k.shape[2]
    qg, kg, vg = group(q, k, v)
    out, _ = flash_attention_fwd_grouped(
        qg, kg, vg, causal=causal, window=window, prefix=prefix,
        bq=bq, bk=bk, interpret=interpret)
    return ungroup(out, B, KV)
