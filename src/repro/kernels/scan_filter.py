"""Predicate-on-packed scan kernel: range tests over bit-packed words.

The resident format packs codes at ``width`` bits into uint32 words
(``core.columnar.PackedColumn``).  Because 32 consecutive values occupy
EXACTLY ``width`` words starting at a word boundary, a ``(R, width)``
reshape of the word stream (R = padded_rows/32) makes every extraction
offset STATIC: value ``j`` of a group lives at word ``(j*width)>>5``, bit
``(j*width)&31``, possibly straddling into the next word — a static
per-``j`` shift/or, no gathers.  The kernel evaluates the
dictionary/FOR-rewritten code-space predicate ``lo <= code <= hi``
(optionally negated) per word group and accumulates the 32 outcomes into
one validity-bitset word per group — the column is never expanded to
one-value-per-lane, so bytes touched stay at the packed footprint.

Same formulation twice: pure-XLA (the CPU path the benchmarks measure)
and a Pallas lane kernel for TPU (interpret-mode on CPU in parity tests).
The oracle lives in ``kernels/ref.py``; dispatch in ``kernels/ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256  # bitset words (row groups of 32) per Pallas grid step


def _check(padded_rows: int, width: int) -> int:
    assert padded_rows % 32 == 0, "padded_rows must be a multiple of 32"
    assert 1 <= width <= 30, "code width must fit a non-negative int32"
    return padded_rows // 32


def _group_scan(W, lo, hi, base, *, rows: int, width: int, negate: bool):
    """Shared SWAR body: W (R, width) uint32 word groups, base (R, 1) int32
    first-row index of each group -> (R, 1) uint32 bitset words."""
    mask = jnp.uint32((1 << width) - 1)
    out = jnp.zeros(base.shape, jnp.uint32)
    for j in range(32):
        bit = j * width
        wi, off = bit >> 5, bit & 31
        va = W[:, wi:wi + 1] >> jnp.uint32(off)
        if off + width > 32:  # static straddle test
            va = va | (W[:, wi + 1:wi + 2] << jnp.uint32(32 - off))
        code = (va & mask).astype(jnp.int32)
        ok = (code >= lo) & (code <= hi)
        if negate:
            ok = jnp.logical_not(ok)
        ok = jnp.logical_and(ok, (base + j) < rows)
        out = out | (ok.astype(jnp.uint32) << jnp.uint32(j))
    return out


def scan_filter_xla(words, lo, hi, *, rows: int, padded_rows: int,
                    width: int, negate: bool = False):
    """Pure-XLA formulation; returns (padded_rows/32,) uint32 bitset."""
    R = _check(padded_rows, width)
    W = words.reshape(R, width)
    base = (jnp.arange(R, dtype=jnp.int32) * 32)[:, None]
    return _group_scan(W, jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
                       base, rows=rows, width=width, negate=negate)[:, 0]


def _kernel(bounds_ref, w_ref, out_ref, *, rows, width, negate, br):
    b = bounds_ref[...]                           # (1, 2) int32
    lo, hi = b[0, 0], b[0, 1]
    W = w_ref[...]                                # (br, width) uint32
    r0 = pl.program_id(0) * br
    base = (jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0) + r0) * 32
    out_ref[...] = _group_scan(W, lo, hi, base, rows=rows, width=width,
                               negate=negate)


def scan_filter_pallas(words, lo, hi, *, rows: int, padded_rows: int,
                       width: int, negate: bool = False,
                       block: int = DEFAULT_BLOCK, interpret: bool = False):
    """Pallas lane-kernel formulation (grid over row groups)."""
    R = _check(padded_rows, width)
    W = words.reshape(R, width)
    br = min(block, R)
    pad = (-R) % br
    if pad:  # zero groups decode to code 0 but base >= rows masks them off
        W = jnp.pad(W, ((0, pad), (0, 0)))
    Rp = R + pad
    bounds = jnp.stack([jnp.asarray(lo, jnp.int32),
                        jnp.asarray(hi, jnp.int32)]).reshape(1, 2)
    kernel = functools.partial(_kernel, rows=rows, width=width,
                               negate=negate, br=br)
    out = pl.pallas_call(
        kernel,
        grid=(Rp // br,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0)),
                  pl.BlockSpec((br, W.shape[1]), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), jnp.uint32),
        interpret=interpret,
    )(bounds, W)
    return out[:R, 0]
