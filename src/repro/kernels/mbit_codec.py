"""m-bit partial-sum codec Pallas kernel (TPU) — the §3.2.5 encoder.

Encodes quantized partial sums (uint32) into m-bit codes at a group-shared
offset and packs them into uint32 words in one VMEM pass:

  per group of ``group`` keys: shift = max(0, bits(max(group)) - m)
  code = value >> shift;  words = lane-pack of (32/m) codes each.

m must divide 32 (4/8/16 in practice) so codes never straddle a word — the
branchless lane-packing that replaces FastPFor's SIMD shuffles (DESIGN.md
§3.3).  The paper's intra-node codec throughput (14 GB/s encode) is the
analogous budget for this kernel's single VPU pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_GROUPS_PER_BLOCK = 8


def _significant_bits(x):
    bits = jnp.zeros_like(x)
    for shift in (16, 8, 4, 2, 1):
        above = x >= (jnp.uint32(1) << shift)
        bits = jnp.where(above, bits + shift, bits)
        x = jnp.where(above, x >> shift, x)
    return bits + (x > 0).astype(jnp.uint32)


def _kernel(q_ref, words_ref, shifts_ref, *, m, group):
    q = q_ref[...]                              # (GB, group) uint32
    gmax = jnp.max(q, axis=1)                   # (GB,)
    nbits = _significant_bits(gmax)
    shift = jnp.maximum(nbits.astype(jnp.int32) - m, 0).astype(jnp.uint32)
    codes = q >> shift[:, None]                 # (GB, group) < 2^m
    per_word = 32 // m
    gb = q.shape[0]
    lanes = codes.reshape(gb, group // per_word, per_word)
    lane_shift = (
        jnp.uint32(m) * lax.broadcasted_iota(jnp.uint32, (1, 1, per_word), 2)
    )
    words_ref[...] = jnp.sum(lanes << lane_shift, axis=2, dtype=jnp.uint32)
    shifts_ref[...] = shift


def encode(
    q,
    m: int,
    group: int,
    *,
    groups_per_block: int = DEFAULT_GROUPS_PER_BLOCK,
    interpret: bool = False,
):
    """Encode quantized partials.

    q: (K,) uint32 with K % group == 0, values < 2^31.
    Returns (words (K*m/32,) uint32, shifts (K/group,) uint32).
    """
    assert 32 % m == 0, "m must divide 32 (no straddling lanes)"
    assert group % (32 // m) == 0
    K = q.shape[0]
    assert K % group == 0
    ngroups = K // group
    gb = min(groups_per_block, ngroups)
    while ngroups % gb:
        gb -= 1
    grid = (ngroups // gb,)
    kernel = functools.partial(_kernel, m=m, group=group)
    words, shifts = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((gb, group), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((gb, group * m // 32), lambda i: (i, 0)),
            pl.BlockSpec((gb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ngroups, group * m // 32), jnp.uint32),
            jax.ShapeDtypeStruct((ngroups,), jnp.uint32),
        ],
        interpret=interpret,
    )(q.reshape(ngroups, group))
    return words.reshape(K * m // 32), shifts


def decode_bounds(words, shifts, m: int, group: int):
    """Pure-jnp decode (runs on the receiving node inside the §3.2.5 plan):
    codes -> (lower, upper) uint32 bounds."""
    per_word = 32 // m
    K = words.shape[0] * per_word
    lane_shift = jnp.uint32(m) * jnp.arange(per_word, dtype=jnp.uint32)
    codes = (
        (words[:, None] >> lane_shift[None, :]) & jnp.uint32((1 << m) - 1)
    ).reshape(K)
    s = jnp.repeat(shifts, group, total_repeat_length=K)
    lower = codes << s
    upper = lower + ((jnp.uint32(1) << s) - jnp.uint32(1))
    return lower, upper
