"""Wire-codec kernels (§3.2.1): blockwise Elias–Fano bucket encode/decode
and the fused mask-fold/bitset-append stage.

The exchange layer's packed wire format splits every destination-relative
key into ``l`` fixed-width low bits and a unary-coded high part whose
universe is bounded to ``compression.EF_UNIVERSE`` values; this module is
the FAST implementation of that codec, pinned bit-for-bit to the pure-jnp
oracles in :mod:`repro.kernels.ref` by the parity tests.

Two tiers, selected by ``use_pallas``:

- The gather-light XLA formulation (default off-TPU).  The oracle's
  per-bit rank pass and big scatters dominate the compiled exchange on
  CPU, so every hot stage here is reformulated around tiny-state work:
  the encoder finds the ``EF_UNIVERSE - 1`` upper-bitvector zero markers
  with a binary search over the bucket (15 columns of state, not
  ``capacity``), builds the bitvector as ``ones-band & ~zero-markers``,
  and lane-packs low bits and mask with reshapes; the decoder locates
  each zero with a per-word popcount prefix + in-word SWAR select, then
  reconstructs all high parts from the 15 marker positions with 15
  one-element-per-row scatters and a single prefix sum.  No stage gathers
  or scatters a ``capacity``-sized index set.

- Pallas kernels for the bandwidth-bound lane stages (mask fold/unfold,
  EF lower-bits pack/unpack when ``32 % l == 0``), one destination row
  per grid step.  ``interpret=True`` runs them anywhere for parity
  testing; the compiled path is for real accelerator backends —
  interpret mode executes Python per grid step and would lose the
  exchange latency gate, so CPU dispatch (``kernels.ops``) uses the XLA
  formulation above as its fast path.

Straddling low-bit widths (``32 % l != 0``) always take the XLA
formulation — the word-straddle gather is the wrong shape for a lane
kernel and those widths do not occur for power-of-two domains.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import compression
from repro.core.compression import EF_UNIVERSE

def _popcount(x):
    """SWAR popcount of a uint32 array."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


# ---------------------------------------------------------------------------
# Pallas lane kernels: one destination row per grid step
# ---------------------------------------------------------------------------


def _mask_fold_kernel(mask_ref, out_ref):
    bits = mask_ref[...].astype(jnp.uint32).reshape(-1, 32)
    w = jnp.uint32(1) << jax.lax.broadcasted_iota(jnp.uint32, (1, 32), 1)
    out_ref[...] = jnp.sum(bits * w, axis=1, dtype=jnp.uint32).reshape(1, -1)


def _mask_unfold_kernel(words_ref, out_ref):
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (words_ref[...][:, :, None] >> lane) & jnp.uint32(1)
    out_ref[...] = bits.astype(jnp.bool_).reshape(1, -1)


def _lower_pack_kernel(vals_ref, out_ref, *, l):
    k = 32 // l
    x = vals_ref[...].reshape(-1, k)
    sh = jax.lax.broadcasted_iota(jnp.uint32, (1, k), 1) * jnp.uint32(l)
    out_ref[...] = jnp.sum(x << sh, axis=1, dtype=jnp.uint32).reshape(1, -1)


def _lower_unpack_kernel(words_ref, out_ref, *, l):
    k = 32 // l
    sh = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, k), 2) * jnp.uint32(l)
    x = (words_ref[...][:, :, None] >> sh) & jnp.uint32((1 << l) - 1)
    out_ref[...] = x.reshape(1, -1)


def _row_call(kernel, rows, in_cols, out_cols, out_dtype, interpret):
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, in_cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, out_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, out_cols), out_dtype),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# mask fold/unfold (the validity bitset appended to every packed row)
# ---------------------------------------------------------------------------


def mask_fold(mask, *, use_pallas: bool = False, interpret: bool = False):
    """(P, c) bool -> (P, ceil(c/32)) uint32 bitset rows."""
    rows, c = mask.shape
    pad = (-c) % 32
    if pad:
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    cw = mask.shape[1] // 32
    if use_pallas:
        return _row_call(_mask_fold_kernel, rows, cw * 32, cw,
                         jnp.uint32, interpret)(mask)
    x = mask.reshape(rows, cw, 32).astype(jnp.uint32)
    w = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    return jnp.sum(x * w, axis=2, dtype=jnp.uint32)


def mask_unfold(words, n: int, *, use_pallas: bool = False,
                interpret: bool = False):
    """Inverse of :func:`mask_fold`: (P, w) uint32 -> (P, n) bool."""
    rows, cw = words.shape
    if use_pallas:
        bits = _row_call(_mask_unfold_kernel, rows, cw, cw * 32,
                         jnp.bool_, interpret)(words)
        return bits[:, :n]
    lane = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((words[:, :, None] >> lane) & jnp.uint32(1)).astype(bool)
    return bits.reshape(rows, cw * 32)[:, :n]


# ---------------------------------------------------------------------------
# EF lower-bits lane pack/unpack
# ---------------------------------------------------------------------------


def _lower_pack(lov, l: int, lw: int, use_pallas, interpret):
    """(P, cap) uint32 values < 2^l -> (P, lw) packed words."""
    rows, cap = lov.shape
    if 32 % l == 0:
        k = 32 // l
        pad = lw * k - cap
        if pad:
            lov = jnp.pad(lov, ((0, 0), (0, pad)))
        if use_pallas:
            return _row_call(functools.partial(_lower_pack_kernel, l=l),
                             rows, lw * k, lw, jnp.uint32, interpret)(lov)
        x = lov.reshape(rows, lw, k)
        sh = (jnp.arange(k, dtype=jnp.uint32) * jnp.uint32(l))[None, None, :]
        return jnp.sum(x << sh, axis=2, dtype=jnp.uint32)
    # straddling width: each word collects the <= ceil(32/l)+1 values that
    # overlap it, via a short unrolled loop of one-column gathers
    K = 32 // l + 1
    wk = jnp.arange(lw, dtype=jnp.int32)[None, :]
    word = jnp.zeros((rows, lw), jnp.uint32)
    j0 = (wk * 32) // l
    for k in range(K + 1):
        jv = j0 + k
        valid = ((jv * l < (wk + 1) * 32) & ((jv + 1) * l > wk * 32)
                 & (jv < cap))
        v = jnp.take_along_axis(lov, jnp.minimum(jv, cap - 1), axis=1)
        sh = jv * l - wk * 32
        contrib = jnp.where(
            sh >= 0,
            v << jnp.minimum(sh, 31).astype(jnp.uint32),
            v >> jnp.minimum(-sh, 31).astype(jnp.uint32),
        )
        word = word | jnp.where(valid, contrib, 0)
    return word


def _lower_unpack(lower, l: int, cap: int, use_pallas, interpret):
    """(P, lw) packed words -> (P, cap) uint32 values < 2^l."""
    rows, lw = lower.shape
    if 32 % l == 0:
        k = 32 // l
        if use_pallas:
            vals = _row_call(functools.partial(_lower_unpack_kernel, l=l),
                             rows, lw, lw * k, jnp.uint32, interpret)(lower)
            return vals[:, :cap]
        sh = (jnp.arange(k, dtype=jnp.uint32) * jnp.uint32(l))[None, None, :]
        vals = (lower[:, :, None] >> sh) & jnp.uint32((1 << l) - 1)
        return vals.reshape(rows, lw * k)[:, :cap]
    j = jnp.arange(cap, dtype=jnp.int32)[None, :]
    bit = j * l
    wk = bit >> 5
    sh = (bit & 31).astype(jnp.uint32)
    w0 = jnp.take_along_axis(lower, jnp.minimum(wk, lw - 1), axis=1)
    w1 = jnp.take_along_axis(lower, jnp.minimum(wk + 1, lw - 1), axis=1)
    return ((w0 >> sh) | jnp.where(sh > 0, w1 << (jnp.uint32(32) - sh), 0)) \
        & jnp.uint32((1 << l) - 1)


# ---------------------------------------------------------------------------
# blockwise EF bucket encode
# ---------------------------------------------------------------------------


def ef_encode(buckets, bucket_mask, domain: int, *, use_pallas: bool = False,
              interpret: bool = False):
    """Encode (P, capacity) sorted key buckets into packed wire rows
    (P, ``compression.packed_request_words(capacity, domain)``) uint32.
    Bit-identical to :func:`repro.kernels.ref.ef_encode`."""
    rows, cap = buckets.shape
    l, uw, lw = compression.ef_params(cap, domain)
    base = (jnp.arange(rows, dtype=jnp.int32) * domain)[:, None]
    offs = jnp.clip(jnp.where(bucket_mask, buckets - base, 0),
                    0, domain - 1).astype(jnp.uint32)
    hi = (offs >> l).astype(jnp.int32)
    n = jnp.sum(bucket_mask, axis=1, dtype=jnp.int32)[:, None]
    # v-th zero marker position: (#keys with high part < v) + v - 1, found
    # by binary-searching the sorted high parts — 15 columns of state
    him = jnp.where(bucket_mask, hi, jnp.int32(1 << 30))
    vq = jnp.arange(1, EF_UNIVERSE, dtype=jnp.int32)[None, :]
    lo_b = jnp.zeros((rows, EF_UNIVERSE - 1), jnp.int32)
    hi_b = jnp.full((rows, EF_UNIVERSE - 1), cap, jnp.int32)
    for _ in range(int(cap).bit_length()):
        mid = (lo_b + hi_b) >> 1
        am = jnp.take_along_axis(him, jnp.minimum(mid, cap - 1), axis=1)
        go = am < vq
        lo_b = jnp.where(go, mid + 1, lo_b)
        hi_b = jnp.where(go, hi_b, mid)
    z = lo_b + vq - 1
    hlast = jnp.take_along_axis(hi, jnp.maximum(n - 1, 0), axis=1)
    hlast = jnp.where(n > 0, hlast, 0)
    end = n + hlast                      # bits used by the unary coding
    w = jnp.arange(uw, dtype=jnp.int32)[None, :]
    rem = jnp.clip(end - w * 32, 0, 32)
    band = jnp.where(rem >= 32, jnp.uint32(0xFFFFFFFF),
                     (jnp.uint32(1) << rem.astype(jnp.uint32)) - 1)
    zb = jnp.zeros((rows, uw), jnp.uint32)
    for v in range(EF_UNIVERSE - 1):
        zv = z[:, v][:, None]
        inw = (zv >> 5) == w
        zb = zb | jnp.where(
            inw & (zv < end),
            jnp.uint32(1) << (zv & 31).astype(jnp.uint32), 0)
    parts = [band & ~zb]
    if l:
        lov = jnp.where(bucket_mask, offs & jnp.uint32((1 << l) - 1),
                        jnp.uint32(0))
        parts.append(_lower_pack(lov, l, lw, use_pallas, interpret))
    parts.append(mask_fold(bucket_mask, use_pallas=use_pallas,
                           interpret=interpret))
    return jnp.concatenate(parts, axis=1)


# ---------------------------------------------------------------------------
# blockwise EF bucket decode
# ---------------------------------------------------------------------------


def ef_decode(words, capacity: int, domain: int, my_base, *,
              use_pallas: bool = False, interpret: bool = False):
    """Inverse of :func:`ef_encode` on the receiving node: returns
    (global keys (P, capacity) int32, mask (P, capacity) bool).
    Bit-identical to :func:`repro.kernels.ref.ef_decode`."""
    rows = words.shape[0]
    l, uw, lw = compression.ef_params(capacity, domain)
    upper = words[:, :uw]
    mk = mask_unfold(
        words[:, uw + lw:uw + lw + compression.bitset_words(capacity)],
        capacity, use_pallas=use_pallas, interpret=interpret)
    # word-granular zero-rank prefix, then binary search for the word
    # holding each of the 15 zero markers
    pc0 = (32 - _popcount(upper)).astype(jnp.int32)
    W0 = jnp.cumsum(pc0, axis=1, dtype=jnp.int32)
    vq = jnp.arange(1, EF_UNIVERSE, dtype=jnp.int32)[None, :]
    lo_b = jnp.zeros((rows, EF_UNIVERSE - 1), jnp.int32)
    hi_b = jnp.full((rows, EF_UNIVERSE - 1), uw, jnp.int32)
    for _ in range(int(uw).bit_length()):
        mid = (lo_b + hi_b) >> 1
        am = jnp.take_along_axis(W0, jnp.minimum(mid, uw - 1), axis=1)
        go = am < vq
        lo_b = jnp.where(go, mid + 1, lo_b)
        hi_b = jnp.where(go, hi_b, mid)
    wz = jnp.minimum(lo_b, uw - 1)
    W0pad = jnp.concatenate([jnp.zeros((rows, 1), jnp.int32), W0], axis=1)
    r = vq - 1 - jnp.take_along_axis(W0pad, wz, axis=1)
    # in-word select of the r-th zero: SWAR halving on the inverted word
    word = ~jnp.take_along_axis(upper, wz, axis=1)
    pos = jnp.zeros(word.shape, jnp.int32)
    for half in (16, 8, 4, 2, 1):
        low = word & jnp.uint32((1 << half) - 1)
        c = _popcount(low).astype(jnp.int32)
        go = r >= c
        r = jnp.where(go, r - c, r)
        pos = pos + jnp.where(go, half, 0)
        word = jnp.where(go, word >> half, low)
    Hi = wz * 32 + pos - vq + 1          # (rows, 15), non-decreasing
    # hi[j] = #{v : Hi[v] <= j}: run-length deltas via 15 one-element
    # row scatters, then one prefix sum — never a capacity-sized scatter
    ridx = jnp.arange(rows, dtype=jnp.int32)
    d = jnp.zeros((rows, capacity + 1), jnp.int32)
    for v in range(EF_UNIVERSE - 1):
        d = d.at[ridx, jnp.clip(Hi[:, v], 0, capacity)].add(1)
    hi = jnp.cumsum(d[:, :capacity], axis=1, dtype=jnp.int32)
    if l:
        lo = _lower_unpack(words[:, uw:uw + lw], l, capacity,
                           use_pallas, interpret).astype(jnp.int32)
    else:
        lo = jnp.zeros((rows, capacity), jnp.int32)
    keys = jnp.where(mk, my_base + ((hi << l) | lo), 0).astype(jnp.int32)
    return keys, mk
