"""Per-block local top-k Pallas kernel (TPU).

Step 1 of the paper's §3.2.3 scheme (and of the distributed top-k sampler in
``repro.serve``): each node reduces its partition to k candidates.  On TPU a
small fixed k is selected with k masked-argmax sweeps over a VMEM-resident
block — k*BN VPU work, no sort, no scatter (hardware-friendly for k <= ~128).

Per grid step the kernel emits that block's (k values, k keys); the tiny
(num_blocks, k) tails are merged by the ops.py wrapper.  Ties break toward
the smaller key: within a block argmax returns the first (= lowest-key)
occurrence, and the wrapper's final merge uses (value desc, key asc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096
NEG_INF = float("-inf")


def _kernel(vals_ref, keys_ref, out_v_ref, out_k_ref, *, k):
    vals = vals_ref[...]            # (1, BN) f32
    keys = keys_ref[...]            # (1, BN) i32
    for j in range(k):              # k static and small: unrolled sweeps
        m = jnp.max(vals)
        am = jnp.argmax(vals)       # first occurrence -> smallest key
        out_v_ref[0, j] = m
        out_k_ref[0, j] = keys.reshape(-1)[am]
        vals = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1) == am,
            NEG_INF,
            vals,
        )


def block_topk(
    values,
    keys,
    k: int,
    mask=None,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Per-block top-k: returns ((num_blocks, k) values, (num_blocks, k) keys).

    values: (N,) f32;  keys: (N,) i32;  mask: optional (N,) bool — masked
    rows never win (value forced to -inf).
    """
    n = values.shape[0]
    v = values.astype(jnp.float32)
    if mask is not None:
        v = jnp.where(mask, v, NEG_INF)
    pad = (-n) % block
    if pad:
        v = jnp.pad(v, (0, pad), constant_values=NEG_INF)
        keys = jnp.pad(keys, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    n_pad = n + pad
    grid = (n_pad // block,)
    kernel = functools.partial(_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad // block, k), jnp.float32),
            jax.ShapeDtypeStruct((n_pad // block, k), jnp.int32),
        ],
        interpret=interpret,
    )(v[None, :], keys[None, :])
