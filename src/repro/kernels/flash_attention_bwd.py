"""Flash attention backward Pallas kernels (TPU).

Standard two-kernel flash backward with the log-sum-exp trick:
  residuals: q, k, v, out, lse (= m + log l), delta (= rowsum(dout * out)).
  dq kernel : grid (B*KV, nq, nk) — accumulates dq for one q block across
              key blocks in VMEM scratch.
  dkv kernel: grid (B*KV, nk, nq) — accumulates dk, dv for one key block
              across q blocks.
Both recompute p = exp(q k^T * scale - lse) per tile — no score tensor ever
reaches HBM, matching the forward kernel's traffic model.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(np.finfo(np.float32).min)


def _bwd_cost(BKV, G, S, Sk, D, bq, bk, causal, prefix, n_dots, itemsize):
    from repro.kernels.flash_attention import block_pairs

    pairs = BKV * G * block_pairs(S, Sk, bq, bk, causal, prefix)
    io = (BKV * G * S * D * 3 + BKV * Sk * D * 2 * 2) * itemsize \
        + BKV * G * S * 8
    return pl.CostEstimate(flops=2 * n_dots * pairs * D, bytes_accessed=io,
                           transcendentals=pairs)


def _mask(s, q_start, k_start, bq, bk, G, causal, window, prefix):
    q_pos = q_start + lax.broadcasted_iota(jnp.int32, (G * bq, bk), 0) % bq
    k_pos = k_start + lax.broadcasted_iota(jnp.int32, (G * bq, bk), 1)
    if causal:
        vis = k_pos <= q_pos
        if window is not None:
            vis &= k_pos > q_pos - window
        if prefix:
            vis |= k_pos < prefix
        return jnp.where(vis, s, NEG_INF)
    return s


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, window, prefix, bq, bk, nk):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start, k_start = qi * bq, ki * bk
    run = True
    if causal:
        run = (k_start <= q_start + bq - 1) | (k_start < prefix)

    @pl.when(run)
    def _body():
        G = q_ref.shape[1]
        D = q_ref.shape[3]
        q = q_ref[0].astype(jnp.float32).reshape(G * bq, D)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32).reshape(G * bq, D)
        lse = lse_ref[0, 0]                    # (G*bq, 1)
        delta = delta_ref[0, 0]                # (G*bq, 1)
        s = jnp.dot(q * scale, k.T, preferred_element_type=jnp.float32)
        s = _mask(s, q_start, k_start, bq, bk, G, causal, window, prefix)
        p = jnp.exp(s - lse)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        acc_ref[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale

    @pl.when(ki == nk - 1)
    def _fin():
        G = dq_ref.shape[1]
        dq_ref[0] = acc_ref[...].reshape(G, bq, -1).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, causal, window, prefix, bq, bk, nq):
    ki, qi = pl.program_id(1), pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start, k_start = qi * bq, ki * bk
    run = True
    if causal:
        run = (k_start <= q_start + bq - 1) | (k_start < prefix)

    @pl.when(run)
    def _body():
        G = q_ref.shape[1]
        D = q_ref.shape[3]
        q = q_ref[0].astype(jnp.float32).reshape(G * bq, D)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32).reshape(G * bq, D)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jnp.dot(q * scale, k.T, preferred_element_type=jnp.float32)
        s = _mask(s, q_start, k_start, bq, bk, G, causal, window, prefix)
        p = jnp.exp(s - lse)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale

    @pl.when(qi == nq - 1)
    def _fin():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, do, *, causal, window, prefix,
                        bq, bk, interpret=False):
    """All grouped tensors: q/do/out (BKV, G, S, D); k/v (BKV, Sk, D);
    lse (BKV, G*S... see ops.py for the packing).  Returns (dq, dk, dv)."""
    BKV, G, S, D = q.shape
    Sk = k.shape[1]
    nq, nk = S // bq, Sk // bk
    scale = 1.0 / np.sqrt(D)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                               # (BKV, G, S)

    # lse/delta packed to (BKV, nq, G*bq, 1) so a (G*bq, 1) tile aligns with
    # the kernels' row blocks
    def pack(x):
        return (x.reshape(BKV, G, nq, bq).transpose(0, 2, 1, 3)
                .reshape(BKV, nq, G * bq, 1))

    lse_p, delta_p = pack(lse), pack(delta)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, prefix=prefix, bq=bq, bk=bk, nk=nk),
        grid=(BKV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, G, bq, D), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, G, bq, D), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, 1, G * bq, 1), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, G * bq, 1), lambda b, i, j: (b, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, bq, D), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, G, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((G * bq, D), jnp.float32)],
        cost_estimate=_bwd_cost(BKV, G, S, Sk, D, bq, bk, causal, prefix,
                                n_dots=3, itemsize=jnp.dtype(q.dtype).itemsize),
        name=f"flash_dq_causal{int(causal)}",
        interpret=interpret,
    )(q, k, v, do, lse_p, delta_p)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, prefix=prefix, bq=bq, bk=bk, nq=nq),
        grid=(BKV, nk, nq),
        in_specs=[
            pl.BlockSpec((1, G, bq, D), lambda b, j, i: (b, 0, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, G, bq, D), lambda b, j, i: (b, 0, i, 0)),
            pl.BlockSpec((1, 1, G * bq, 1), lambda b, j, i: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, G * bq, 1), lambda b, j, i: (b, i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BKV, Sk, D), k.dtype),
            jax.ShapeDtypeStruct((BKV, Sk, D), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        cost_estimate=_bwd_cost(BKV, G, S, Sk, D, bq, bk, causal, prefix,
                                n_dots=4, itemsize=jnp.dtype(q.dtype).itemsize),
        name=f"flash_dkv_causal{int(causal)}",
        interpret=interpret,
    )(q, k, v, do, lse_p, delta_p)
    return dq, dk, dv
