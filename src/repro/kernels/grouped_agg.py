"""Fused filter + grouped aggregation Pallas kernel (TPU).

The paper's dominant inner loop (Q1: predicate + 6-group, 6-measure
aggregate over lineitem) is a scalar hash-table update per row on CPUs.  The
TPU-native formulation: evaluate the predicate on the VPU and contract a
one-hot group matrix against the measure block on the MXU —
``out[g, c] += sum_n onehot[g, n] * measures[n, c]``.

Tiling: the measure block (BN, C) and the one-hot (G, BN) both live in VMEM;
G and C are tiny (<= 64), BN is the streaming dimension.  The (G, C)
accumulator is the kernel output, revisited every grid step (sequential TPU
grid), initialized at step 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048  # rows per grid step; (BN, C) f32 tile ~ 2048*8*4 = 64 KiB


def _kernel(measures_ref, groups_ref, pred_ref, out_ref, *, cutoff, num_groups):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    measures = measures_ref[...]          # (BN, C) f32
    groups = groups_ref[...]              # (1, BN) i32
    pred = pred_ref[...]                  # (1, BN) i32
    bn = measures.shape[0]
    sel = pred <= cutoff                  # fused predicate (VPU)
    gids = lax.broadcasted_iota(jnp.int32, (num_groups, bn), 0)
    onehot = jnp.where((groups == gids) & sel, 1.0, 0.0).astype(jnp.float32)
    out_ref[...] += jnp.dot(onehot, measures, preferred_element_type=jnp.float32)


def filtered_group_sum(
    measures,
    groups,
    pred,
    cutoff,
    num_groups: int,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """sum(measures[n]) per group over rows with pred[n] <= cutoff.

    measures: (N, C) f32;  groups: (N,) i32 in [0, num_groups);
    pred: (N,) i32 (e.g. l_shipdate);  cutoff: static int.
    Returns (num_groups, C) f32.
    """
    n, c = measures.shape
    pad = (-n) % block
    if pad:
        measures = jnp.pad(measures, ((0, pad), (0, 0)))
        groups = jnp.pad(groups, (0, pad))
        # padded rows fail the predicate
        pred = jnp.pad(pred, (0, pad), constant_values=cutoff + 1)
    n_pad = n + pad
    grid = (n_pad // block,)
    kernel = functools.partial(_kernel, cutoff=cutoff, num_groups=num_groups)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, c), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((num_groups, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, c), jnp.float32),
        interpret=interpret,
    )(measures, groups[None, :], pred[None, :])
