"""Single-token decode attention Pallas kernel (TPU), with optional int8
KV cache dequantized in-kernel — §Perf optimization for the decode cells.

Decode is memory-bound by the cache read (§Roofline): the win is (a) never
materializing the (B, H, Smax) score row to HBM and (b) reading the cache at
1 byte/elem (int8 + per-position scales) instead of 2 — the dequant runs on
the VPU between the cache load and the MXU dot, so HBM sees only int8.

Layout: grouped like the flash kernel — q (B*KV, G, D) one token per
sequence; caches (B*KV, Smax, D) [+ scales (B*KV, Smax)].  Grid
(B*KV, nS): online softmax across cache blocks in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(np.finfo(np.float32).min)
DEFAULT_BS = 1024


def _kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, bs, ns, quant):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    k_start = si * bs

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale      # (G, D)
        k = k_ref[0].astype(jnp.float32)              # (bs, D)
        v = v_ref[0].astype(jnp.float32)
        if quant:
            k = k * ks_ref[0][:, None]
            v = v * vs_ref[0][:, None]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, bs)
        pos = k_start + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0, corr)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _fin():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, length, *, k_scale=None,
                     v_scale=None, bs: int = DEFAULT_BS,
                     interpret: bool = False):
    """q: (BKV, G, D); caches (BKV, Smax, D) bf16 or int8 (+ (BKV, Smax)
    f32 scales); length: scalar int32.  Returns (BKV, G, D)."""
    BKV, G, D = q.shape
    Smax = k_cache.shape[1]
    bs = min(bs, Smax)
    while Smax % bs:
        bs -= 1
    ns = Smax // bs
    quant = k_scale is not None
    scale = 1.0 / np.sqrt(D)
    if not quant:
        k_scale = jnp.ones((BKV, Smax), jnp.float32)
        v_scale = jnp.ones((BKV, Smax), jnp.float32)
    itemsize = jnp.dtype(k_cache.dtype).itemsize
    cost = pl.CostEstimate(
        flops=4 * BKV * G * Smax * D,
        bytes_accessed=(BKV * G * D * 4 * 2
                        + BKV * Smax * D * 2 * itemsize
                        + (BKV * Smax * 4 * 2 if quant else 0)),
        transcendentals=BKV * G * Smax,
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bs=bs, ns=ns, quant=quant),
        grid=(BKV, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bs, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bs, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bs), lambda b, j: (b, j)),
            pl.BlockSpec((1, bs), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        cost_estimate=cost,
        name=f"decode_attn_quant{int(quant)}",
        interpret=interpret,
    )(jnp.reshape(length, (1, 1)).astype(jnp.int32), q, k_cache, v_cache,
      k_scale, v_scale)
    return out
