"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU container,
unit tests) they execute in interpret mode against the same BlockSpec
schedule.  ``use_kernels(False)`` (or REPRO_NO_KERNELS=1) falls back to the
pure-jnp oracles in ref.py — plans call through these wrappers only.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import (
    bitset_pack,
    grouped_agg,
    mbit_codec,
    ref,
    topk_select,
    wire_codec,
)
from repro.kernels import scan_filter as scan_filter_kernel

_FORCE_REF = os.environ.get("REPRO_NO_KERNELS", "0") == "1"
_USE_KERNELS = not _FORCE_REF


def use_kernels(enable: bool) -> None:
    global _USE_KERNELS
    _USE_KERNELS = enable and not _FORCE_REF


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("cutoff", "num_groups", "block"))
def filtered_group_sum(measures, groups, pred, *, cutoff, num_groups, block=2048):
    if not _USE_KERNELS:
        return ref.filtered_group_sum(measures, groups, pred, cutoff, num_groups)
    return grouped_agg.filtered_group_sum(
        measures, groups, pred, cutoff, num_groups, block=block,
        interpret=_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("k", "block"))
def block_topk(values, keys, *, k, mask=None, block=4096):
    if not _USE_KERNELS:
        return ref.block_topk(values, keys, k, mask, block)
    return topk_select.block_topk(
        values, keys, k, mask, block=block, interpret=_interpret()
    )


@functools.partial(jax.jit, static_argnames=("value", "block"))
def predicate_bitset(column, *, value, block=8192):
    if not _USE_KERNELS:
        return ref.predicate_bitset(column, value)
    return bitset_pack.predicate_bitset(
        column, value, block=block, interpret=_interpret()
    )


@functools.partial(jax.jit, static_argnames=("m", "group"))
def mbit_encode(q, *, m, group):
    if not _USE_KERNELS:
        return ref.mbit_encode(q, m, group)
    return mbit_codec.encode(q, m, group, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("m", "group"))
def mbit_decode_bounds(words, shifts, *, m, group):
    return mbit_codec.decode_bounds(words, shifts, m, group)


# ---------------------------------------------------------------------------
# wire codec (§3.2.1): EF bucket encode/decode + mask fold/unfold
#
# The Pallas lane kernels compile only on real accelerator backends
# (interpret mode is Python per grid step — orders of magnitude too slow
# for the exchange latency budget).  On CPU the kernel path IS the
# gather-light XLA formulation in wire_codec.py, which is what the
# latency gate measures; parity tests exercise the Pallas kernels in
# interpret mode directly against ref.py.
# ---------------------------------------------------------------------------


def _codec_impl() -> str:
    """'ref' | 'xla' | 'pallas' — resolved at CALL time so the benchmark's
    use_kernels() toggle selects a distinct jit cache entry (the impl is a
    static argument of the jitted workers below, never a baked-in global)."""
    if not _USE_KERNELS:
        return "ref"
    return "pallas" if not _interpret() else "xla"


@functools.partial(jax.jit, static_argnames=("domain", "impl"))
def _ef_encode(buckets, bucket_mask, *, domain, impl):
    if impl == "ref":
        return ref.ef_encode(buckets, bucket_mask, domain)
    return wire_codec.ef_encode(
        buckets, bucket_mask, domain, use_pallas=impl == "pallas"
    )


@functools.partial(jax.jit, static_argnames=("capacity", "domain", "impl"))
def _ef_decode(words, my_base, *, capacity, domain, impl):
    if impl == "ref":
        return ref.ef_decode(words, capacity, domain, my_base)
    return wire_codec.ef_decode(
        words, capacity, domain, my_base, use_pallas=impl == "pallas"
    )


@functools.partial(jax.jit, static_argnames=("impl",))
def _mask_fold(mask, *, impl):
    if impl == "ref":
        return ref.mask_fold(mask)
    return wire_codec.mask_fold(mask, use_pallas=impl == "pallas")


@functools.partial(jax.jit, static_argnames=("n", "impl"))
def _mask_unfold(words, *, n, impl):
    if impl == "ref":
        return ref.mask_unfold(words, n)
    return wire_codec.mask_unfold(words, n, use_pallas=impl == "pallas")


def ef_encode(buckets, bucket_mask, *, domain):
    return _ef_encode(buckets, bucket_mask, domain=domain, impl=_codec_impl())


def ef_decode(words, my_base, *, capacity, domain):
    return _ef_decode(words, my_base, capacity=capacity, domain=domain,
                      impl=_codec_impl())


def mask_fold(mask):
    return _mask_fold(mask, impl=_codec_impl())


def mask_unfold(words, *, n):
    return _mask_unfold(words, n=n, impl=_codec_impl())


# ---------------------------------------------------------------------------
# predicate-on-packed scan (compressed residency): code-space range test
# over bit-packed resident words, emitting a validity bitset.  Same
# dispatch discipline as the wire codec — the SWAR formulation is pure XLA
# on CPU, a Pallas lane kernel on TPU, and ref.py decodes-then-compares.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("rows", "padded_rows", "width",
                                             "negate", "impl"))
def _scan_filter(words, lo, hi, *, rows, padded_rows, width, negate, impl):
    if impl == "ref":
        return ref.scan_filter(words, lo, hi, rows, padded_rows, width, negate)
    if impl == "pallas":
        return scan_filter_kernel.scan_filter_pallas(
            words, lo, hi, rows=rows, padded_rows=padded_rows, width=width,
            negate=negate, interpret=_interpret())
    return scan_filter_kernel.scan_filter_xla(
        words, lo, hi, rows=rows, padded_rows=padded_rows, width=width,
        negate=negate)


def scan_filter(words, lo, hi, *, rows, padded_rows, width, negate=False):
    """Validity bitset of ``lo <= code <= hi`` (optionally negated) over a
    packed word stream; rows past ``rows`` are invalid."""
    return _scan_filter(words, lo, hi, rows=rows, padded_rows=padded_rows,
                        width=width, negate=negate, impl=_codec_impl())


# ---------------------------------------------------------------------------
# flash attention (custom_vjp: Pallas fwd + Pallas bwd) — §Perf optimization
# ---------------------------------------------------------------------------


def _fit_block(S: int, target: int) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return c


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_grouped(qg, kg, vg, causal, window, prefix, bq, bk):
    from repro.kernels import flash_attention as FA

    out, _ = FA.flash_attention_fwd_grouped(
        qg, kg, vg, causal=causal, window=window, prefix=prefix,
        bq=bq, bk=bk, interpret=_interpret())
    return out


def _flash_fwd(qg, kg, vg, causal, window, prefix, bq, bk):
    from repro.kernels import flash_attention as FA

    out, lse = FA.flash_attention_fwd_grouped(
        qg, kg, vg, causal=causal, window=window, prefix=prefix,
        bq=bq, bk=bk, interpret=_interpret())
    return out, (qg, kg, vg, out, lse)


def _flash_bwd(causal, window, prefix, bq, bk, res, do):
    from repro.kernels import flash_attention_bwd as FB

    qg, kg, vg, out, lse = res
    dq, dk, dv = FB.flash_attention_bwd(
        qg, kg, vg, out, lse, do, causal=causal, window=window,
        prefix=prefix, bq=bq, bk=bk, interpret=_interpret())
    return dq, dk, dv


_flash_grouped.defvjp(_flash_fwd, _flash_bwd)


def _maybe_shard_map(fn, arg_specs, out_spec):
    """Wrap a grouped-kernel call in shard_map when an ambient mesh is set —
    GSPMD otherwise REPLICATES pallas_call operands (models/runtime.py)."""
    from jax.sharding import PartitionSpec as P

    from repro.models import runtime

    ctx = runtime.current()
    if ctx is None:
        return fn
    mesh, _ = ctx
    return jax.shard_map(fn, mesh=mesh, in_specs=arg_specs,
                         out_specs=out_spec, check_vma=False)


def flash_attention(q, k, v, *, causal=True, window=None, prefix=0,
                    bq=512, bk=512):
    """Differentiable flash attention, (B, S, H, D) layout (GQA via the KV
    dim of k/v).  Block sizes auto-shrink to divide the sequence lengths.
    Runs per-shard (shard_map over the fused batch*kv dim) when an ambient
    mesh is active."""
    from jax.sharding import PartitionSpec as P

    from repro.kernels import flash_attention as FA
    from repro.models import runtime

    B, KV = q.shape[0], k.shape[2]
    bq = _fit_block(q.shape[1], bq)
    bk = _fit_block(k.shape[1], bk)
    qg, kg, vg = FA.group(q, k, v)
    ctx = runtime.current()
    if ctx is not None:
        bkv = runtime.fused_bkv_spec()
        spec4 = P(bkv, None, None, None)
        spec3 = P(bkv, None, None)
        call = _maybe_shard_map(
            lambda a, b_, c: _flash_grouped(a, b_, c, causal, window, prefix,
                                            bq, bk),
            (spec4, spec3, spec3), spec4)
        out = call(qg, kg, vg)
    else:
        out = _flash_grouped(qg, kg, vg, causal, window, prefix, bq, bk)
    return FA.ungroup(out, B, KV)
