"""Pure-jnp oracles for every Pallas kernel (interpret-mode validation)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import compression


def filtered_group_sum(measures, groups, pred, cutoff, num_groups):
    sel = pred <= cutoff
    onehot = (
        groups[None, :] == jnp.arange(num_groups, dtype=groups.dtype)[:, None]
    ) & sel[None, :]
    return jnp.dot(
        onehot.astype(jnp.float32),
        measures.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def block_topk(values, keys, k, mask=None, block: int = 4096):
    v = values.astype(jnp.float32)
    if mask is not None:
        v = jnp.where(mask, v, -jnp.inf)
    n = v.shape[0]
    pad = (-n) % block
    v = jnp.pad(v, (0, pad), constant_values=-jnp.inf)
    keys = jnp.pad(keys, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    vb = v.reshape(-1, block)
    kb = keys.reshape(-1, block)
    out_v, out_k = [], []
    for j in range(k):
        m = jnp.max(vb, axis=1)
        am = jnp.argmax(vb, axis=1)
        out_v.append(m)
        out_k.append(jnp.take_along_axis(kb, am[:, None], axis=1)[:, 0])
        vb = vb.at[jnp.arange(vb.shape[0]), am].set(-jnp.inf)
    return jnp.stack(out_v, axis=1), jnp.stack(out_k, axis=1)


def predicate_bitset(column, value):
    bits = column == value
    pad = (-bits.shape[0]) % 32
    bits = jnp.concatenate([bits, jnp.zeros(pad, bool)])
    return compression.pack_bitset(bits)


def scan_filter(words, lo, hi, rows, padded_rows, width, negate=False):
    """Decode-then-compare oracle for the predicate-on-packed kernel:
    unpack the full column, apply the code-space range test, pack the
    validity bitset (rows past ``rows`` are never valid)."""
    codes = compression.unpack_bits(words, padded_rows, width).astype(jnp.int32)
    ok = (codes >= jnp.asarray(lo, jnp.int32)) & (codes <= jnp.asarray(hi, jnp.int32))
    if negate:
        ok = jnp.logical_not(ok)
    ok = jnp.logical_and(ok, jnp.arange(padded_rows) < rows)
    return compression.pack_bitset(ok)


def mbit_encode(q, m, group):
    K = q.shape[0]
    g = q.reshape(K // group, group)
    gmax = jnp.max(g, axis=1)
    # significant bits via log2-free ladder (same as the kernel)
    x = gmax
    bits = jnp.zeros_like(x)
    for shift in (16, 8, 4, 2, 1):
        above = x >= (jnp.uint32(1) << shift)
        bits = jnp.where(above, bits + shift, bits)
        x = jnp.where(above, x >> shift, x)
    nbits = bits + (x > 0).astype(jnp.uint32)
    shiftv = jnp.maximum(nbits.astype(jnp.int32) - m, 0).astype(jnp.uint32)
    codes = (g >> shiftv[:, None]).reshape(K)
    words = compression.pack_bits(codes, m)
    return words, shiftv


def mbit_decode_bounds(words, shifts, m, group):
    K = shifts.shape[0] * group
    codes = compression.unpack_bits(words, K, m)
    s = jnp.repeat(shifts, group, total_repeat_length=K)
    lower = codes << s
    upper = lower + ((jnp.uint32(1) << s) - jnp.uint32(1))
    return lower, upper


def flash_attention(q, k, v, causal=True, window=None, prefix=0):
    """Pure-jnp oracle for the flash kernel: full-materialization GQA
    attention.  q: (B,S,H,D); k,v: (B,Sk,KV,D)."""
    import numpy as np
    import jax

    B, S, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B, KV, G, S, D)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    s = jnp.einsum("bkgsd,bktd->bkgst", qf, kf) / np.sqrt(D)
    if causal:
        q_pos = jnp.arange(S)[:, None]
        k_pos = jnp.arange(Sk)[None, :]
        vis = k_pos <= q_pos
        if window is not None:
            vis &= k_pos > q_pos - window
        if prefix:
            vis |= k_pos < prefix
        s = jnp.where(vis[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, vf)
    return (o.reshape(B, H, S, D).transpose(0, 2, 1, 3)).astype(q.dtype)


# ---------------------------------------------------------------------------
# wire codec (§3.2.1): EF key buckets + folded validity mask
# ---------------------------------------------------------------------------


def mask_fold(mask):
    """(P, c) bool -> (P, ceil(c/32)) uint32 bitset rows (little-endian bit
    order within each word, row-major words)."""
    import jax

    c = mask.shape[1]
    pad = (-c) % 32
    if pad:
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    return jax.vmap(compression.pack_bitset)(mask)


def mask_unfold(words, n):
    import jax

    return jax.vmap(lambda w: compression.unpack_bitset(w, n))(words)


def ef_encode(buckets, bucket_mask, domain):
    """Scatter-based EF bucket encoder: row ``d`` of ``buckets`` holds a
    sorted ascending prefix of keys in ``[d*domain, (d+1)*domain)`` under
    ``bucket_mask``; returns the packed wire rows
    (P, ``compression.packed_request_words(capacity, domain)``) uint32.
    One upper-bitvector one per key at position ``(off >> l) + j`` (unary
    high parts), fixed-width packed low bits, appended mask bitset."""
    import jax

    P, cap = buckets.shape
    l, uw, _ = compression.ef_params(cap, domain)
    offs = buckets.astype(jnp.int32) - jnp.arange(P, dtype=jnp.int32)[:, None] * domain
    offs = jnp.clip(jnp.where(bucket_mask, offs, 0), 0, domain - 1).astype(jnp.uint32)
    j = jnp.arange(cap, dtype=jnp.uint32)[None, :]
    pos = (offs >> l) + j                 # strictly increasing per row
    rows = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[:, None], (P, cap))
    word = jnp.where(bucket_mask, (pos >> 5).astype(jnp.int32), uw)
    upper = jnp.zeros((P, uw), jnp.uint32).at[rows, word].add(
        jnp.uint32(1) << (pos & jnp.uint32(31)), mode="drop"
    )
    parts = [upper]
    if l:
        lo = jnp.where(bucket_mask, offs & jnp.uint32((1 << l) - 1), jnp.uint32(0))
        parts.append(jax.vmap(lambda v: compression.pack_bits(v, l))(lo))
    parts.append(mask_fold(bucket_mask))
    return jnp.concatenate(parts, axis=1)


def ef_decode(words, capacity, domain, my_base):
    """Rank/select EF bucket decoder (inverse of :func:`ef_encode` on the
    receiving node): bit-expands the upper bitvector, ranks the set bits
    with one cumsum, and scatters each one's position back to its slot.
    Returns (global keys (P, capacity) int32, mask (P, capacity) bool)."""
    import jax

    P = words.shape[0]
    l, uw, lw = compression.ef_params(capacity, domain)
    upper = words[:, :uw]
    lane = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((upper[:, :, None] >> lane) & jnp.uint32(1)).reshape(P, uw * 32)
    on = bits.astype(bool)
    rank = jnp.cumsum(bits, axis=1).astype(jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[:, None], bits.shape)
    tgt = jnp.where(on, rank - 1, capacity)     # <= capacity bits set per row
    posv = jnp.broadcast_to(
        jnp.arange(uw * 32, dtype=jnp.int32)[None, :], bits.shape
    )
    sel = jnp.zeros((P, capacity), jnp.int32).at[rows, tgt].add(posv, mode="drop")
    j = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    hi = sel - j
    if l:
        lo = jax.vmap(lambda w: compression.unpack_bits(w, capacity, l))(
            words[:, uw:uw + lw]
        ).astype(jnp.int32)
    else:
        lo = jnp.zeros((P, capacity), jnp.int32)
    mask = mask_unfold(
        words[:, uw + lw:uw + lw + compression.bitset_words(capacity)], capacity
    )
    keys = jnp.where(mask, my_base + ((hi << l) | lo), 0).astype(jnp.int32)
    return keys, mask
