from repro.optim.adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    compress_gradients,
    decompress_gradients,
    CompressionState,
)
